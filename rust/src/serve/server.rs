//! The threaded TCP server: admission, coalescing dispatch, pooled
//! execution, and graceful shutdown.
//!
//! Thread anatomy (all scoped — `run` returns only after every thread
//! has exited):
//!
//! * **acceptor** — accepts connections until shutdown; the shutdown
//!   path wakes a blocked `accept()` with a loop-back connection.
//! * **reader (one per connection)** — parses newline-delimited JSON
//!   requests. *Admission* happens here: roots and targets are
//!   validated against the plan before a query may enter the coalescer
//!   (one out-of-range root answered at admission can never fail a
//!   whole coalesced batch with `RootOutOfRange`), `stats` is answered
//!   inline, and a full queue answers `overloaded` immediately.
//! * **dispatcher** — owns the clock side of the
//!   [`Coalescer`](super::coalescer::Coalescer) contract: sleeps until
//!   the earliest due time, expires past-deadline requests with
//!   `timeout` responses, and hands due batches to the workers.
//! * **workers** — draw a [`PooledSession`](crate::coordinator::PooledSession)
//!   from the panic-hardened [`SessionPool`], run the coalesced
//!   `run_batch`, and write every member's response. Batch execution is
//!   wrapped in `catch_unwind`: a panicking query answers `error` for
//!   its batch and discards the session (the pool's unwind-discard
//!   path), while every other connection keeps being served.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::bfs::serial::INF;
use crate::coordinator::{BatchWidth, SessionPool, TraversalPlan};
use crate::fault::plan::FaultInjector;
use crate::graph::csr::VertexId;
use crate::util::json::Json;

use super::coalescer::{Coalescer, Pending};
use super::metrics::{Health, ServeMetrics};
use super::protocol::{self, Request};

/// Serving knobs; see the field docs for the latency/throughput levers.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:4600` (`:0` for an ephemeral port).
    pub addr: String,
    /// Worker threads executing coalesced batches.
    pub workers: usize,
    /// How long a lone request waits for co-travellers before it
    /// dispatches anyway (the p50-vs-throughput lever; 0 disables
    /// coalescing).
    pub coalesce_window_us: u64,
    /// Maximum coalesced batch width (1..=512 — one `BatchWidth` lane
    /// set; checked at [`Server::bind`] via [`BatchWidth::for_lanes`]).
    pub max_batch: usize,
    /// Admission-queue bound; requests past it get `overloaded`.
    pub queue_depth: usize,
    /// Default per-request deadline when the request carries no
    /// `timeout_us` field; `None` = wait indefinitely.
    pub default_timeout_us: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            coalesce_window_us: 200,
            max_batch: 64,
            queue_depth: 1024,
            default_timeout_us: None,
        }
    }
}

/// One admitted query waiting in the coalescer.
///
/// `root`/`targets` are in *execution* id space (relabeled when the plan
/// came from a degree-sorted store); `root_echo`/`targets_echo` keep the
/// client's original ids for the response. `closed` is the per-connection
/// liveness flag: the reader raises it when the socket dies, and the
/// dispatcher drops still-queued queries from a dead client into the
/// `cancelled` metric instead of burning a batch lane on them.
struct QueuedQuery {
    id: u64,
    root: VertexId,
    root_echo: u64,
    targets: Vec<VertexId>,
    targets_echo: Vec<u64>,
    conn: Arc<Mutex<TcpStream>>,
    closed: Arc<AtomicBool>,
}

/// A batch the dispatcher handed to the workers, stamped with its
/// dispatch time (for the `wait_us` figure in responses).
struct DispatchedBatch {
    members: Vec<Pending<QueuedQuery>>,
    dispatched_us: u64,
}

/// Write one response line, ignoring a vanished client.
fn send_line(conn: &Mutex<TcpStream>, response: &Json) {
    let mut stream = conn.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let _ = stream.write_all(response.render().as_bytes());
    let _ = stream.write_all(b"\n");
}

/// A bound, not-yet-running query server over one shared plan.
pub struct Server {
    listener: TcpListener,
    plan: Arc<TraversalPlan>,
    cfg: ServeConfig,
    metrics: Arc<ServeMetrics>,
    injector: Option<Arc<FaultInjector>>,
}

impl Server {
    /// Bind the listener and validate the config. A `max_batch` outside
    /// `1..=512` is a config-time error echoing the requested width —
    /// the serve-side face of the `for_lanes` width-clamp bugfix.
    pub fn bind(plan: Arc<TraversalPlan>, cfg: ServeConfig) -> std::io::Result<Self> {
        if BatchWidth::for_lanes(cfg.max_batch).is_none() {
            return Err(std::io::Error::new(
                ErrorKind::InvalidInput,
                format!("--max-batch must be in 1..=512 (got {})", cfg.max_batch),
            ));
        }
        if cfg.workers == 0 {
            return Err(std::io::Error::new(
                ErrorKind::InvalidInput,
                "--workers must be at least 1",
            ));
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        Ok(Self { listener, plan, cfg, metrics: Arc::new(ServeMetrics::new()), injector: None })
    }

    /// Arm every worker session with a deterministic fault injector
    /// (fault-injection smoke tests and `serve --fault-plan`). Injected
    /// exchange faults surface as batch errors and exercise the
    /// transparent-retry / health-degradation path.
    pub fn arm_faults(&mut self, injector: Arc<FaultInjector>) {
        self.injector = Some(injector);
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Live metrics handle (shared with the `stats` op).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Serve until a client sends `{"op":"shutdown"}`: queued queries
    /// drain (every admitted request is answered), then all threads
    /// join. Returns the final metrics report.
    pub fn run(self) -> std::io::Result<Json> {
        let start = Instant::now();
        let now_us = move || start.elapsed().as_micros() as u64;
        let shutdown = AtomicBool::new(false);
        let queue = (
            Mutex::new(Coalescer::<QueuedQuery>::new(
                self.cfg.coalesce_window_us,
                self.cfg.max_batch,
                self.cfg.queue_depth,
            )),
            Condvar::new(),
        );
        let pool = SessionPool::new(Arc::clone(&self.plan));
        let (tx, rx) = mpsc::channel::<DispatchedBatch>();
        let rx = Mutex::new(rx);
        let local = self.local_addr()?;

        std::thread::scope(|scope| -> std::io::Result<()> {
            // Workers: coalesced batches through pooled sessions.
            for _ in 0..self.cfg.workers {
                let rx = &rx;
                let pool = &pool;
                let metrics = &self.metrics;
                let injector = &self.injector;
                scope.spawn(move || loop {
                    let batch = {
                        let guard =
                            rx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    run_one_batch(pool, metrics, injector.as_ref(), batch, now_us);
                });
            }

            // Dispatcher: the coalescer's clock.
            {
                let queue = &queue;
                let shutdown = &shutdown;
                let metrics = &self.metrics;
                let tx = tx.clone();
                scope.spawn(move || {
                    let (lock, cvar) = queue;
                    let mut q =
                        lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                    loop {
                        let now = now_us();
                        for expired in q.expire(now) {
                            metrics.record_timed_out();
                            send_line(
                                &expired.item.conn,
                                &protocol::timeout(expired.item.id),
                            );
                        }
                        let draining = shutdown.load(Ordering::SeqCst);
                        if q.due(now) || (draining && !q.is_empty()) {
                            let mut members = q.take_batch();
                            // A client that hung up while its query was
                            // queued gets no lane and no response — just
                            // the `cancelled` metric.
                            members.retain(|p| {
                                if p.item.closed.load(Ordering::SeqCst) {
                                    metrics.record_cancelled();
                                    false
                                } else {
                                    true
                                }
                            });
                            if members.is_empty() {
                                continue;
                            }
                            let batch = DispatchedBatch { members, dispatched_us: now };
                            let _ = tx.send(batch);
                            continue;
                        }
                        if draining && q.is_empty() {
                            break;
                        }
                        // Sleep until the earliest due time (capped so a
                        // shutdown or a sharper deadline is noticed).
                        let wait = q
                            .due_at()
                            .map(|t| t.saturating_sub(now))
                            .unwrap_or(50_000)
                            .clamp(1, 50_000);
                        let (guard, _) = cvar
                            .wait_timeout(q, Duration::from_micros(wait))
                            .unwrap_or_else(|poisoned| poisoned.into_inner());
                        q = guard;
                    }
                    drop(tx); // last sender (with the one below) gone → workers exit
                });
            }
            drop(tx);

            // Acceptor + readers, on the scope's own thread.
            for stream in self.listener.incoming() {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let queue = &queue;
                let shutdown = &shutdown;
                let metrics = &self.metrics;
                let plan = &self.plan;
                let cfg = &self.cfg;
                scope.spawn(move || {
                    serve_connection(stream, plan, queue, shutdown, metrics, cfg, now_us, local);
                });
            }
            // Unblock the dispatcher in case it is mid-sleep.
            queue.1.notify_all();
            Ok(())
        })?;

        Ok(self.metrics.report(now_us()))
    }
}

/// Per-connection reader: parse, validate, admit (or answer inline).
fn serve_connection(
    stream: TcpStream,
    plan: &TraversalPlan,
    queue: &(Mutex<Coalescer<QueuedQuery>>, Condvar),
    shutdown: &AtomicBool,
    metrics: &ServeMetrics,
    cfg: &ServeConfig,
    now_us: impl Fn() -> u64,
    local: SocketAddr,
) {
    // Short read timeouts keep the reader responsive to shutdown even
    // on an idle connection.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let conn = Arc::new(Mutex::new(match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    }));
    // Raised when the socket dies (EOF or a hard read error) so the
    // dispatcher can cancel this connection's still-queued queries. A
    // clean shutdown return leaves it low: those clients are alive and
    // expect their drained answers.
    let closed = Arc::new(AtomicBool::new(false));
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                // EOF: the client hung up.
                closed.store(true, Ordering::SeqCst);
                return;
            }
            Ok(_) => {}
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => {
                closed.store(true, Ordering::SeqCst);
                return;
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        let request = match protocol::parse_request(line.trim()) {
            Ok(r) => r,
            Err(e) => {
                metrics.record_bad_request();
                send_line(&conn, &protocol::bad_request(0, &e));
                continue;
            }
        };
        match request {
            Request::Stats => {
                send_line(&conn, &protocol::stats_ok(metrics.report(now_us())));
            }
            Request::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                metrics.set_health(Health::Draining);
                send_line(&conn, &protocol::shutdown_ok());
                queue.1.notify_all();
                // Wake a blocked accept() so the acceptor loop observes
                // the flag and stops.
                let _ = TcpStream::connect(local);
                return;
            }
            Request::Query { id, root, targets, timeout_us } => {
                let n = plan.num_vertices() as u64;
                if root >= n {
                    metrics.record_bad_request();
                    let e = format!("root {root} out of range (graph has {n} vertices)");
                    send_line(&conn, &protocol::bad_request(id, &e));
                    continue;
                }
                if let Some(&t) = targets.iter().find(|&&t| t >= n) {
                    metrics.record_bad_request();
                    let e = format!("target {t} out of range (graph has {n} vertices)");
                    send_line(&conn, &protocol::bad_request(id, &e));
                    continue;
                }
                if shutdown.load(Ordering::SeqCst) {
                    metrics.record_rejected();
                    send_line(&conn, &protocol::overloaded(id));
                    continue;
                }
                let now = now_us();
                let deadline = timeout_us
                    .or(cfg.default_timeout_us)
                    .map(|t| now.saturating_add(t));
                // Clients speak original ids; a degree-sorted store plan
                // executes in relabeled space. Map at admission, echo the
                // originals back in the response.
                let to_exec = |v: u64| -> VertexId {
                    match plan.relabeling() {
                        Some(r) => r.new_id[v as usize],
                        None => v as VertexId,
                    }
                };
                let query = QueuedQuery {
                    id,
                    root: to_exec(root),
                    root_echo: root,
                    targets: targets.iter().map(|&t| to_exec(t)).collect(),
                    targets_echo: targets.clone(),
                    conn: Arc::clone(&conn),
                    closed: Arc::clone(&closed),
                };
                let admitted = {
                    let mut q =
                        queue.0.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                    q.try_push(now, deadline, query)
                };
                match admitted {
                    Ok(()) => queue.1.notify_all(),
                    Err(rejected) => {
                        metrics.record_rejected();
                        send_line(&rejected.conn, &protocol::overloaded(rejected.id));
                    }
                }
            }
        }
    }
}

/// Execute one coalesced batch through a pooled session and answer
/// every member. Panics inside the engine answer `error` and discard
/// the session via the pool's unwind-discard path.
///
/// Graceful degradation: a batch whose first attempt fails (engine
/// error *or* panic) gets **one** transparent retry on a fresh pooled
/// session — the failed session was already discarded, so transient
/// faults (an injected exchange fault, a torn session) are invisible to
/// clients beyond latency. The retry is recorded and moves the server's
/// health to [`Health::Degraded`]; only a second consecutive failure
/// answers `error`.
fn run_one_batch(
    pool: &SessionPool,
    metrics: &ServeMetrics,
    injector: Option<&Arc<FaultInjector>>,
    batch: DispatchedBatch,
    now_us: impl Fn() -> u64,
) {
    let roots: Vec<VertexId> = batch.members.iter().map(|p| p.item.root).collect();
    let width = roots.len();
    let attempt = || {
        catch_unwind(AssertUnwindSafe(|| {
            // The PooledSession lives entirely inside the unwind boundary:
            // a panic drops it while `thread::panicking()` is observable on
            // the unwind path of this closure's stack, discarding the
            // possibly-torn session instead of returning it to the pool.
            let mut session = pool.acquire();
            session.arm_faults(injector.map(Arc::clone));
            session.run_batch(&roots)
        }))
    };
    let mut result = attempt();
    if !matches!(result, Ok(Ok(_))) {
        metrics.record_retried();
        result = attempt();
    }
    match result {
        Ok(Ok(b)) => {
            metrics.record_batch(width);
            let finished_us = now_us();
            for (lane, p) in batch.members.iter().enumerate() {
                let dist = b.dist(lane);
                let reached = dist.iter().filter(|&&d| d != INF).count() as u64;
                let depth =
                    dist.iter().filter(|&&d| d != INF).max().copied().unwrap_or(0) as u64;
                let dists: Vec<Option<u32>> = p
                    .item
                    .targets
                    .iter()
                    .map(|&t| {
                        let d = dist[t as usize];
                        (d != INF).then_some(d)
                    })
                    .collect();
                let latency = finished_us.saturating_sub(p.arrived_us);
                let wait = batch.dispatched_us.saturating_sub(p.arrived_us);
                metrics.record_completed(latency);
                send_line(
                    &p.item.conn,
                    &protocol::ok_query(
                        p.item.id,
                        p.item.root_echo,
                        width,
                        wait,
                        reached,
                        depth,
                        &p.item.targets_echo,
                        &dists,
                    ),
                );
            }
        }
        Ok(Err(e)) => {
            // Roots are validated at admission, so absent injected
            // faults this is unreachable; with a fault plan armed it is
            // the retry-budget-exhausted path. Answer every member with
            // the typed error rather than going silent (or wrong).
            for p in &batch.members {
                metrics.record_error();
                send_line(&p.item.conn, &protocol::internal_error(p.item.id, &e.to_string()));
            }
        }
        Err(_panic) => {
            for p in &batch.members {
                metrics.record_error();
                send_line(
                    &p.item.conn,
                    &protocol::internal_error(p.item.id, "query panicked server-side"),
                );
            }
        }
    }
}
