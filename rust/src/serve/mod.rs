//! Online query serving: a long-running TCP service over one shared
//! [`TraversalPlan`](crate::coordinator::TraversalPlan), with
//! **cross-request batch coalescing**.
//!
//! The engine's MS-BFS lane batching amortizes one butterfly exchange
//! per level across up to 512 roots — but only if someone supplies 512
//! roots at once. A single interactive user supplies one. This module
//! turns the amortization into a *multi-tenant* win: single-root
//! queries from many clients that arrive within a configurable window
//! are packed into one wide
//! [`run_batch`](crate::coordinator::QuerySession::run_batch), so 512
//! users' queries cost one exchange per level instead of 512. Results
//! are bit-identical to running each query alone (lanes are
//! independent; the integration tests pin this), so coalescing is
//! purely a scheduling decision.
//!
//! The moving parts:
//!
//! * [`coalescer`] — the bounded admission queue and the dispatch rule
//!   (batch-full OR window-expiry, whichever first; per-request
//!   deadlines). Pure and clock-agnostic, so the identical logic runs
//!   in the threaded server, the deterministic `serve_throughput`
//!   protocol simulation, and the Python mirror.
//! * [`protocol`] — the newline-delimited JSON wire format and the
//!   typed response statuses (`ok`, `overloaded`, `timeout`,
//!   `bad_request`, `error`).
//! * [`metrics`] — latency percentiles (nearest-rank, integer µs),
//!   qps, and the coalesced-width distribution.
//! * [`server`] — the threaded TCP server: acceptor, per-connection
//!   readers (admission + validation), a dispatcher that owns the
//!   clock, and workers drawing
//!   [`PooledSession`](crate::coordinator::PooledSession)s from the
//!   panic-hardened [`SessionPool`](crate::coordinator::SessionPool).
//!
//! Tuning in one sentence each: `--coalesce-window-us` trades p50
//! latency (every request may wait the window) for throughput (wider
//! batches, fewer exchanges); `--max-batch` caps the lane width (and
//! thus per-batch memory); `--queue-depth` bounds admission so
//! overload degrades into fast typed `overloaded` rejections instead
//! of unbounded queueing collapse.

pub mod coalescer;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use coalescer::{Coalescer, Pending};
pub use metrics::{nearest_rank_us, Health, LatencyHistogram, ServeMetrics, LATENCY_WINDOW_CAP};
pub use protocol::Request;
pub use server::{ServeConfig, Server};
