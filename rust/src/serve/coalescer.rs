//! Cross-request batch coalescing: the admission queue + dispatch rule.
//!
//! The coalescer is the heart of the serve mode: single-root queries
//! arriving within a window are packed into one wide `run_batch`, so
//! one butterfly exchange per level serves the whole batch (the MS-BFS
//! amortization applied across *tenants* instead of across one caller's
//! root list). It is deliberately a pure data structure over an abstract
//! clock — every decision is a function of caller-supplied microsecond
//! timestamps — so the exact same logic drives the threaded server, the
//! deterministic `serve_throughput` simulation in `harness/protocol.rs`,
//! and the Python mirror in `python/bench_protocol_port.py`.
//!
//! Dispatch rule (the fairness contract):
//!
//! * a batch becomes due when it is **full** (`max_batch` pending — due
//!   at the arrival time of the request that filled it), or when the
//!   **window expires** for the oldest pending request
//!   (`arrived_us + window_us`), whichever comes first;
//! * `take_batch` always drains the *oldest* requests first (FIFO), so
//!   a straggler that never sees a full batch still dispatches — alone,
//!   as a width-1 batch — once its window runs out;
//! * admission is bounded: past `depth` queued requests, `try_push`
//!   hands the request back for a typed `Overloaded` response instead
//!   of growing an unbounded queue.

use std::collections::VecDeque;

/// One queued request: the caller's payload plus the timestamps the
/// dispatch rule needs.
#[derive(Clone, Debug)]
pub struct Pending<T> {
    /// Arrival time (microseconds on the caller's clock).
    pub arrived_us: u64,
    /// Absolute deadline; a request still queued at its deadline is
    /// expired via [`Coalescer::expire`] rather than dispatched.
    pub deadline_us: Option<u64>,
    /// The caller's request payload.
    pub item: T,
}

/// Bounded FIFO admission queue with window/batch-full dispatch.
///
/// Time is abstract: all methods take `now_us` (or store timestamps the
/// caller supplied), so the structure is fully deterministic under a
/// simulated clock. See the module docs for the dispatch contract.
#[derive(Debug)]
pub struct Coalescer<T> {
    window_us: u64,
    max_batch: usize,
    depth: usize,
    pending: VecDeque<Pending<T>>,
}

impl<T> Coalescer<T> {
    /// A coalescer that packs up to `max_batch` requests per dispatch,
    /// waits at most `window_us` for co-travellers, and admits at most
    /// `depth` queued requests.
    pub fn new(window_us: u64, max_batch: usize, depth: usize) -> Self {
        assert!(max_batch >= 1, "max_batch must be at least 1");
        assert!(depth >= 1, "queue depth must be at least 1");
        Self { window_us, max_batch, depth, pending: VecDeque::new() }
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Maximum batch width this coalescer will dispatch.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Admit a request, or hand it back if the queue is at capacity
    /// (the caller should answer `Overloaded`).
    pub fn try_push(
        &mut self,
        now_us: u64,
        deadline_us: Option<u64>,
        item: T,
    ) -> Result<(), T> {
        if self.pending.len() >= self.depth {
            return Err(item);
        }
        self.pending.push_back(Pending { arrived_us: now_us, deadline_us, item });
        Ok(())
    }

    /// The instant the oldest batch becomes due, or `None` when the
    /// queue is empty. Batch-full beats window expiry: with `max_batch`
    /// requests queued the batch was due the moment the last one
    /// arrived, which is never later than the oldest window expiry.
    pub fn due_at(&self) -> Option<u64> {
        if self.pending.len() >= self.max_batch {
            return Some(self.pending[self.max_batch - 1].arrived_us);
        }
        self.pending.front().map(|p| p.arrived_us.saturating_add(self.window_us))
    }

    /// True when a batch should dispatch at `now_us`.
    pub fn due(&self, now_us: u64) -> bool {
        self.due_at().is_some_and(|t| t <= now_us)
    }

    /// Drain the oldest `min(len, max_batch)` requests, in arrival
    /// order. Callers decide *when* via [`due`](Self::due); taking early
    /// (e.g. on shutdown drain) is allowed.
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.pending.len().min(self.max_batch);
        self.pending.drain(..n).collect()
    }

    /// Remove and return every queued request whose deadline has passed
    /// (`now_us >= deadline_us`), preserving arrival order of both the
    /// expired set and the survivors.
    pub fn expire(&mut self, now_us: u64) -> Vec<Pending<T>> {
        let mut expired = Vec::new();
        let mut kept = VecDeque::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            match p.deadline_us {
                Some(d) if now_us >= d => expired.push(p),
                _ => kept.push_back(p),
            }
        }
        self.pending = kept;
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lone_request_dispatches_on_window_expiry_as_width_1() {
        let mut c: Coalescer<u32> = Coalescer::new(200, 64, 8);
        assert_eq!(c.due_at(), None);
        c.try_push(1_000, None, 7).unwrap();
        assert_eq!(c.due_at(), Some(1_200));
        assert!(!c.due(1_199));
        assert!(c.due(1_200));
        let batch = c.take_batch();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].item, 7);
        assert_eq!(batch[0].arrived_us, 1_000);
        assert!(c.is_empty());
    }

    #[test]
    fn batch_full_beats_window_expiry() {
        let mut c: Coalescer<u32> = Coalescer::new(1_000, 4, 16);
        for (i, t) in [10u64, 20, 30, 40].into_iter().enumerate() {
            c.try_push(t, None, i as u32).unwrap();
        }
        // Full at the arrival of the 4th request — long before the
        // oldest window would expire at t=1_010.
        assert_eq!(c.due_at(), Some(40));
        assert!(c.due(40));
        let batch = c.take_batch();
        assert_eq!(batch.iter().map(|p| p.item).collect::<Vec<_>>(), [0, 1, 2, 3]);
    }

    #[test]
    fn take_batch_is_fifo_and_leaves_the_remainder() {
        let mut c: Coalescer<u32> = Coalescer::new(100, 2, 16);
        for (i, t) in [1u64, 2, 3, 4, 5].into_iter().enumerate() {
            c.try_push(t, None, i as u32).unwrap();
        }
        assert_eq!(c.take_batch().iter().map(|p| p.item).collect::<Vec<_>>(), [0, 1]);
        assert_eq!(c.take_batch().iter().map(|p| p.item).collect::<Vec<_>>(), [2, 3]);
        // The straggler's window now drives the next dispatch.
        assert_eq!(c.due_at(), Some(105));
        assert_eq!(c.take_batch().iter().map(|p| p.item).collect::<Vec<_>>(), [4]);
        assert_eq!(c.due_at(), None);
    }

    #[test]
    fn admission_is_bounded_and_hands_the_request_back() {
        let mut c: Coalescer<&str> = Coalescer::new(100, 64, 2);
        c.try_push(0, None, "a").unwrap();
        c.try_push(1, None, "b").unwrap();
        assert_eq!(c.try_push(2, None, "c"), Err("c"));
        assert_eq!(c.len(), 2);
        // Draining frees capacity again.
        let _ = c.take_batch();
        c.try_push(3, None, "c").unwrap();
    }

    #[test]
    fn expire_removes_only_past_deadline_requests_in_order() {
        let mut c: Coalescer<u32> = Coalescer::new(1_000, 64, 16);
        c.try_push(0, Some(50), 0).unwrap();
        c.try_push(1, None, 1).unwrap();
        c.try_push(2, Some(40), 2).unwrap();
        c.try_push(3, Some(500), 3).unwrap();
        let expired = c.expire(50);
        assert_eq!(expired.iter().map(|p| p.item).collect::<Vec<_>>(), [0, 2]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.take_batch().iter().map(|p| p.item).collect::<Vec<_>>(), [1, 3]);
    }

    #[test]
    fn window_zero_max_batch_one_degenerates_to_no_coalescing() {
        // The baseline mode of the serve_throughput protocol section.
        let mut c: Coalescer<u32> = Coalescer::new(0, 1, 64);
        c.try_push(100, None, 1).unwrap();
        c.try_push(100, None, 2).unwrap();
        assert_eq!(c.due_at(), Some(100));
        assert_eq!(c.take_batch().len(), 1);
        assert_eq!(c.take_batch().len(), 1);
    }
}
