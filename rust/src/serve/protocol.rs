//! The serve wire protocol: newline-delimited JSON over TCP.
//!
//! One request per line, one response per line, both compact JSON
//! rendered by [`util::json`](crate::util::json) — the default build
//! stays dependency-free. Requests:
//!
//! * `{"op":"query","root":R}` — BFS from root `R`. Optional fields:
//!   `"id"` (u64 correlation tag, echoed back — responses on a pipelined
//!   connection may complete out of order), `"targets"` (array of vertex
//!   ids whose distances to return), `"timeout_us"` (per-request
//!   deadline; a request still queued past it gets `status:"timeout"`).
//! * `{"op":"stats"}` — server metrics snapshot, answered immediately.
//! * `{"op":"shutdown"}` — graceful shutdown: queued queries drain,
//!   then the listener closes.
//!
//! Every response carries `"status"`: `ok`, `overloaded` (admission
//! queue at capacity — real backpressure), `timeout`, `bad_request`
//! (malformed line, unknown op, out-of-range root — rejected at
//! admission so one bad root can never fail a coalesced batch), or
//! `error` (the query panicked server-side; the pooled session is
//! discarded, other requests are unaffected).

use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// BFS from `root`, optionally reporting distances to `targets`.
    Query {
        /// Client correlation tag, echoed in the response (default 0).
        id: u64,
        /// Source vertex.
        root: u64,
        /// Vertices whose distances the response should include.
        targets: Vec<u64>,
        /// Per-request deadline relative to arrival, in microseconds.
        timeout_us: Option<u64>,
    },
    /// Metrics snapshot.
    Stats,
    /// Graceful shutdown.
    Shutdown,
}

/// Parse one request line. Errors are human-readable strings the server
/// wraps into a `bad_request` response.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line).map_err(|e| format!("malformed JSON: {e}"))?;
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or_else(|| "missing string field \"op\"".to_string())?;
    match op {
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "query" => {
            let root = v
                .get("root")
                .and_then(|r| r.as_u64())
                .ok_or_else(|| "query requires an unsigned \"root\"".to_string())?;
            let id = v.get("id").and_then(|i| i.as_u64()).unwrap_or(0);
            let timeout_us = v.get("timeout_us").and_then(|t| t.as_u64());
            let targets = match v.get("targets") {
                None => Vec::new(),
                Some(t) => t
                    .as_arr()
                    .ok_or_else(|| "\"targets\" must be an array".to_string())?
                    .iter()
                    .map(|x| {
                        x.as_u64().ok_or_else(|| {
                            "\"targets\" entries must be unsigned integers".to_string()
                        })
                    })
                    .collect::<Result<Vec<u64>, String>>()?,
            };
            Ok(Request::Query { id, root, targets, timeout_us })
        }
        other => Err(format!("unknown op {other:?}")),
    }
}

/// Successful query response. `dists[i]` is the distance to
/// `targets[i]`, `None` for unreachable (rendered as JSON `null`).
/// `batch_width` and `wait_us` expose the coalescing decision: how many
/// co-travellers this query shared its exchange with, and how long it
/// sat in the admission queue.
pub fn ok_query(
    id: u64,
    root: u64,
    batch_width: usize,
    wait_us: u64,
    reached: u64,
    depth: u64,
    targets: &[u64],
    dists: &[Option<u32>],
) -> Json {
    debug_assert_eq!(targets.len(), dists.len());
    let mut pairs = vec![
        ("status", Json::s("ok")),
        ("id", Json::u(id)),
        ("root", Json::u(root)),
        ("batch_width", Json::u(batch_width as u64)),
        ("wait_us", Json::u(wait_us)),
        ("reached", Json::u(reached)),
        ("depth", Json::u(depth)),
    ];
    if !targets.is_empty() {
        pairs.push(("targets", Json::Arr(targets.iter().map(|&t| Json::u(t)).collect())));
        pairs.push((
            "dist",
            Json::Arr(
                dists
                    .iter()
                    .map(|d| d.map_or(Json::Null, |x| Json::u(x as u64)))
                    .collect(),
            ),
        ));
    }
    Json::obj(pairs)
}

/// The admission queue was at capacity.
pub fn overloaded(id: u64) -> Json {
    Json::obj(vec![("status", Json::s("overloaded")), ("id", Json::u(id))])
}

/// The request's deadline passed while it was still queued.
pub fn timeout(id: u64) -> Json {
    Json::obj(vec![("status", Json::s("timeout")), ("id", Json::u(id))])
}

/// The request could not be admitted (malformed, unknown op, or
/// out-of-range root/target — validated *before* coalescing).
pub fn bad_request(id: u64, error: &str) -> Json {
    Json::obj(vec![
        ("status", Json::s("bad_request")),
        ("id", Json::u(id)),
        ("error", Json::s(error)),
    ])
}

/// The query failed server-side (e.g. a panic inside the batch); the
/// session was discarded, the pool stays healthy.
pub fn internal_error(id: u64, error: &str) -> Json {
    Json::obj(vec![
        ("status", Json::s("error")),
        ("id", Json::u(id)),
        ("error", Json::s(error)),
    ])
}

/// Metrics snapshot response.
pub fn stats_ok(stats: Json) -> Json {
    Json::obj(vec![("status", Json::s("ok")), ("stats", stats)])
}

/// Acknowledgement that a graceful shutdown has begun.
pub fn shutdown_ok() -> Json {
    Json::obj(vec![("status", Json::s("ok")), ("shutting_down", Json::Bool(true))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_and_full_query() {
        assert_eq!(
            parse_request("{\"op\":\"query\",\"root\":5}").unwrap(),
            Request::Query { id: 0, root: 5, targets: vec![], timeout_us: None }
        );
        assert_eq!(
            parse_request(
                "{\"op\":\"query\",\"id\":9,\"root\":5,\"targets\":[1,2],\"timeout_us\":250}"
            )
            .unwrap(),
            Request::Query { id: 9, root: 5, targets: vec![1, 2], timeout_us: Some(250) }
        );
    }

    #[test]
    fn parses_control_ops() {
        assert_eq!(parse_request("{\"op\":\"stats\"}").unwrap(), Request::Stats);
        assert_eq!(parse_request("{\"op\":\"shutdown\"}").unwrap(), Request::Shutdown);
    }

    #[test]
    fn rejects_malformed_requests_with_a_reason() {
        assert!(parse_request("not json").unwrap_err().contains("malformed"));
        assert!(parse_request("{\"root\":1}").unwrap_err().contains("op"));
        assert!(parse_request("{\"op\":\"frobnicate\"}").unwrap_err().contains("unknown op"));
        assert!(parse_request("{\"op\":\"query\"}").unwrap_err().contains("root"));
        assert!(parse_request("{\"op\":\"query\",\"root\":1,\"targets\":3}")
            .unwrap_err()
            .contains("array"));
    }

    #[test]
    fn ok_response_reports_coalescing_and_null_for_unreachable() {
        let r = ok_query(3, 7, 12, 180, 900, 6, &[1, 2], &[Some(4), None]);
        let text = r.render();
        assert!(text.starts_with('{') && !text.contains('\n'));
        assert_eq!(r.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(r.get("batch_width").unwrap().as_u64(), Some(12));
        assert_eq!(r.get("wait_us").unwrap().as_u64(), Some(180));
        let dist = r.get("dist").unwrap().as_arr().unwrap();
        assert_eq!(dist[0].as_u64(), Some(4));
        assert_eq!(dist[1], Json::Null);
        // Round-trips through the parser.
        assert_eq!(Json::parse(&text).unwrap(), r);
    }

    #[test]
    fn error_statuses_echo_the_id() {
        for (resp, status) in [
            (overloaded(42), "overloaded"),
            (timeout(42), "timeout"),
            (bad_request(42, "boom"), "bad_request"),
            (internal_error(42, "boom"), "error"),
        ] {
            assert_eq!(resp.get("status").unwrap().as_str(), Some(status));
            assert_eq!(resp.get("id").unwrap().as_u64(), Some(42));
        }
    }
}
