//! Serving metrics: latency percentiles, throughput, and the
//! coalesced-batch-width distribution.
//!
//! The same statistics appear in three places and must agree: the live
//! server's `stats` op, the `benches/serve_throughput.rs` load-generator
//! report, and the deterministic `serve_throughput` simulation committed
//! to `BENCH_engine.json`. The shared definitions live here —
//! percentiles are **nearest-rank on integer microseconds**
//! ([`nearest_rank_us`]), so a simulated run produces bit-stable values
//! the CI check can recompute exactly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::util::json::Json;

/// Server health ladder, surfaced in every `stats` response.
///
/// Transitions are monotonic (a server never silently "heals"): the
/// server starts [`Healthy`](Health::Healthy), moves to
/// [`Degraded`](Health::Degraded) the first time a batch needed the
/// transparent retry path (an engine fault or panic tore a pooled
/// session), and to [`Draining`](Health::Draining) once shutdown begins —
/// queued queries still drain, but new ones are rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Health {
    /// No engine faults observed; full capacity.
    Healthy,
    /// At least one batch needed a retry on a fresh session; the server
    /// keeps answering, and `stats` reports `degraded: true`.
    Degraded,
    /// Shutdown in progress: admitted queries drain, new ones bounce.
    Draining,
}

impl Health {
    /// Wire name used in the `stats` response.
    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Draining => "draining",
        }
    }

    fn from_u8(v: u8) -> Self {
        match v {
            1 => Health::Degraded,
            2 => Health::Draining,
            _ => Health::Healthy,
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted slice of integer
/// microsecond latencies: the smallest value with at least `p`% of the
/// samples at or below it. Returns 0 for an empty slice.
pub fn nearest_rank_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Samples the latency window keeps before old values rotate out. Below
/// the cap statistics are exact; past it, percentiles describe the most
/// recent [`LATENCY_WINDOW_CAP`] requests — which is what a live `stats`
/// probe wants anyway — while counts and the mean stay exact lifetime
/// values. The point of the cap: a server up for weeks no longer grows an
/// unbounded vector, and a `stats` report no longer clones + sorts the
/// entire service history.
pub const LATENCY_WINDOW_CAP: usize = 4096;

/// Bounded latency record: a ring of the last [`LATENCY_WINDOW_CAP`]
/// queue-to-response times (microseconds) plus exact lifetime count/sum.
/// Deterministic: same record sequence, same window, same percentiles.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    /// Ring buffer, insertion order until the cap, then rotating.
    samples: Vec<u64>,
    /// Next ring slot to overwrite once the cap is reached.
    cursor: usize,
    /// Lifetime sample count (exact, never truncated).
    total: u64,
    /// Lifetime latency sum in microseconds (exact).
    sum_us: u64,
}

impl LatencyHistogram {
    /// Record one completed request's latency.
    pub fn record(&mut self, latency_us: u64) {
        if self.samples.len() < LATENCY_WINDOW_CAP {
            self.samples.push(latency_us);
        } else {
            self.samples[self.cursor] = latency_us;
            self.cursor = (self.cursor + 1) % LATENCY_WINDOW_CAP;
        }
        self.total += 1;
        self.sum_us = self.sum_us.saturating_add(latency_us);
    }

    /// Lifetime number of recorded samples (exact past the window cap).
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Samples currently held in the window (`min(count, cap)`).
    pub fn window_len(&self) -> usize {
        self.samples.len()
    }

    /// Nearest-rank percentiles over the window, computed with **one**
    /// sort for any number of requested ranks — a `stats` report asks for
    /// p50 and p99 together instead of sorting the history twice.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        ps.iter().map(|&p| nearest_rank_us(&sorted, p)).collect()
    }

    /// Nearest-rank percentile (integer microseconds) over the window.
    pub fn percentile(&self, p: f64) -> u64 {
        self.percentiles(&[p])[0]
    }

    /// Lifetime mean latency in microseconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.total as f64
    }
}

#[derive(Debug, Default)]
struct MetricsInner {
    latency: LatencyHistogram,
    /// Dispatched batches keyed by width — the coalescing evidence.
    width_counts: BTreeMap<usize, u64>,
    completed: u64,
    rejected: u64,
    timed_out: u64,
    bad_requests: u64,
    errors: u64,
    cancelled: u64,
    /// Batches that failed once and were transparently retried on a
    /// fresh pooled session.
    retried: u64,
}

/// Thread-safe serving counters, shared by workers and the `stats` op.
///
/// Lock poisoning is recovered the same way as in
/// [`SessionPool`](crate::coordinator::SessionPool): the guarded state
/// is plain counters, always valid, so a panic elsewhere must not take
/// the stats endpoint down with it.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<MetricsInner>,
    /// [`Health`] as its ladder index; advanced monotonically with
    /// `fetch_max` so concurrent workers can only move it forward.
    health: AtomicU8,
}

impl ServeMetrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, MetricsInner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Record one dispatched batch of `width` coalesced requests.
    pub fn record_batch(&self, width: usize) {
        *self.lock().width_counts.entry(width).or_insert(0) += 1;
    }

    /// Record one successfully answered request and its latency
    /// (admission to response, microseconds).
    pub fn record_completed(&self, latency_us: u64) {
        let mut m = self.lock();
        m.completed += 1;
        m.latency.record(latency_us);
    }

    /// Record a request rejected with `Overloaded`.
    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    /// Record a request that timed out in the queue.
    pub fn record_timed_out(&self) {
        self.lock().timed_out += 1;
    }

    /// Record a malformed or inadmissible request.
    pub fn record_bad_request(&self) {
        self.lock().bad_requests += 1;
    }

    /// Record a server-side execution failure.
    pub fn record_error(&self) {
        self.lock().errors += 1;
    }

    /// Record a queued request dropped at dispatch because its client
    /// connection had already closed — dead work the batch never carried.
    pub fn record_cancelled(&self) {
        self.lock().cancelled += 1;
    }

    /// Record a batch that failed its first attempt and was retried on a
    /// fresh pooled session. Also advances health to
    /// [`Health::Degraded`].
    pub fn record_retried(&self) {
        self.lock().retried += 1;
        self.set_health(Health::Degraded);
    }

    /// Advance the health ladder. Transitions are monotonic: attempts to
    /// move backwards (e.g. `Healthy` after `Draining`) are ignored.
    pub fn set_health(&self, health: Health) {
        self.health.fetch_max(health as u8, Ordering::SeqCst);
    }

    /// Current health state.
    pub fn health(&self) -> Health {
        Health::from_u8(self.health.load(Ordering::SeqCst))
    }

    /// Number of transparently retried batches so far.
    pub fn retried(&self) -> u64 {
        self.lock().retried
    }

    /// Number of cancelled (client-gone-at-dispatch) requests so far.
    pub fn cancelled(&self) -> u64 {
        self.lock().cancelled
    }

    /// Number of completed requests so far.
    pub fn completed(&self) -> u64 {
        self.lock().completed
    }

    /// Snapshot every statistic as JSON. `elapsed_us` is the
    /// observation-window length used for the qps figure.
    pub fn report(&self, elapsed_us: u64) -> Json {
        let m = self.lock();
        let batches: u64 = m.width_counts.values().sum();
        let coalesced_requests: u64 =
            m.width_counts.iter().map(|(w, c)| *w as u64 * c).sum();
        let mean_width =
            if batches == 0 { 0.0 } else { coalesced_requests as f64 / batches as f64 };
        let qps = if elapsed_us == 0 {
            0.0
        } else {
            m.completed as f64 / (elapsed_us as f64 / 1e6)
        };
        let width_counts = Json::Obj(
            m.width_counts
                .iter()
                .map(|(w, c)| (w.to_string(), Json::u(*c)))
                .collect(),
        );
        // One sort serves every requested rank.
        let pcts = m.latency.percentiles(&[50.0, 99.0]);
        Json::obj(vec![
            ("completed", Json::u(m.completed)),
            ("rejected", Json::u(m.rejected)),
            ("timed_out", Json::u(m.timed_out)),
            ("bad_requests", Json::u(m.bad_requests)),
            ("errors", Json::u(m.errors)),
            ("cancelled", Json::u(m.cancelled)),
            ("retried", Json::u(m.retried)),
            ("health", Json::s(self.health().name())),
            ("degraded", Json::Bool(self.health() == Health::Degraded)),
            ("p50_us", Json::u(pcts[0])),
            ("p99_us", Json::u(pcts[1])),
            ("mean_latency_us", Json::n(m.latency.mean())),
            ("qps", Json::n(qps)),
            ("batches", Json::u(batches)),
            ("mean_batch_width", Json::n(mean_width)),
            ("width_counts", width_counts),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_matches_hand_computed_values() {
        let sorted = [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(nearest_rank_us(&sorted, 50.0), 50);
        assert_eq!(nearest_rank_us(&sorted, 99.0), 100);
        assert_eq!(nearest_rank_us(&sorted, 10.0), 10);
        assert_eq!(nearest_rank_us(&sorted, 100.0), 100);
        assert_eq!(nearest_rank_us(&[], 50.0), 0);
        assert_eq!(nearest_rank_us(&[7], 50.0), 7);
        assert_eq!(nearest_rank_us(&[7], 99.0), 7);
    }

    #[test]
    fn report_aggregates_counters_widths_and_percentiles() {
        let m = ServeMetrics::new();
        for lat in [100u64, 200, 300, 400] {
            m.record_completed(lat);
        }
        m.record_batch(1);
        m.record_batch(3);
        m.record_rejected();
        m.record_timed_out();
        let r = m.report(2_000_000); // 2 seconds
        assert_eq!(r.get("completed").unwrap().as_u64(), Some(4));
        assert_eq!(r.get("rejected").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("timed_out").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("p50_us").unwrap().as_u64(), Some(200));
        assert_eq!(r.get("p99_us").unwrap().as_u64(), Some(400));
        assert_eq!(r.get("qps").unwrap().as_f64(), Some(2.0));
        assert_eq!(r.get("batches").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("mean_batch_width").unwrap().as_f64(), Some(2.0));
        let wc = r.get("width_counts").unwrap();
        assert_eq!(wc.get("1").unwrap().as_u64(), Some(1));
        assert_eq!(wc.get("3").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn percentiles_stable_across_the_window_cap() {
        let mut h = LatencyHistogram::default();
        // Below the cap: exact over everything recorded.
        for _ in 0..LATENCY_WINDOW_CAP {
            h.record(100);
        }
        assert_eq!(h.count(), LATENCY_WINDOW_CAP as u64);
        assert_eq!(h.window_len(), LATENCY_WINDOW_CAP);
        assert_eq!(h.percentiles(&[50.0, 99.0]), vec![100, 100]);
        // A full cap of newer, slower samples rotates the old era out
        // entirely: the window now describes recent behavior only, while
        // the lifetime count stays exact.
        for _ in 0..LATENCY_WINDOW_CAP {
            h.record(200);
        }
        assert_eq!(h.count(), 2 * LATENCY_WINDOW_CAP as u64);
        assert_eq!(h.window_len(), LATENCY_WINDOW_CAP);
        assert_eq!(h.percentiles(&[50.0, 99.0]), vec![200, 200]);
        // Half a cap of 300s: the window is half 200s, half 300s — p50
        // pins to the old value, p99 to the new, deterministically.
        for _ in 0..LATENCY_WINDOW_CAP / 2 {
            h.record(300);
        }
        assert_eq!(h.percentiles(&[50.0, 99.0]), vec![200, 300]);
        assert_eq!(h.count(), 2 * LATENCY_WINDOW_CAP as u64 + LATENCY_WINDOW_CAP as u64 / 2);
        // Lifetime mean is exact across all eras, not just the window.
        let cap = LATENCY_WINDOW_CAP as f64;
        let expect = (100.0 * cap + 200.0 * cap + 300.0 * (cap / 2.0)) / (2.5 * cap);
        assert!((h.mean() - expect).abs() < 1e-9, "{}", h.mean());
    }

    #[test]
    fn report_counts_cancelled_requests() {
        let m = ServeMetrics::new();
        m.record_cancelled();
        m.record_cancelled();
        let r = m.report(1_000_000);
        assert_eq!(r.get("cancelled").unwrap().as_u64(), Some(2));
        assert_eq!(m.cancelled(), 2);
    }

    #[test]
    fn health_ladder_is_monotonic_and_surfaced() {
        let m = ServeMetrics::new();
        assert_eq!(m.health(), Health::Healthy);
        let r = m.report(1_000_000);
        assert_eq!(r.get("health").unwrap().as_str(), Some("healthy"));
        assert_eq!(r.get("degraded"), Some(&Json::Bool(false)));
        assert_eq!(r.get("retried").unwrap().as_u64(), Some(0));

        m.record_retried();
        assert_eq!(m.health(), Health::Degraded);
        assert_eq!(m.retried(), 1);
        let r = m.report(1_000_000);
        assert_eq!(r.get("health").unwrap().as_str(), Some("degraded"));
        assert_eq!(r.get("degraded"), Some(&Json::Bool(true)));
        assert_eq!(r.get("retried").unwrap().as_u64(), Some(1));

        // No healing: Healthy after Degraded is a no-op.
        m.set_health(Health::Healthy);
        assert_eq!(m.health(), Health::Degraded);
        // Draining wins over everything and is terminal.
        m.set_health(Health::Draining);
        m.set_health(Health::Degraded);
        assert_eq!(m.health(), Health::Draining);
        let r = m.report(1_000_000);
        assert_eq!(r.get("health").unwrap().as_str(), Some("draining"));
        assert_eq!(r.get("degraded"), Some(&Json::Bool(false)));
    }

    #[test]
    fn metrics_survive_a_poisoning_panic() {
        let m = std::sync::Arc::new(ServeMetrics::new());
        let m2 = m.clone();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _guard = m2.inner.lock().unwrap();
            panic!("injected while holding the metrics lock");
        }));
        m.record_completed(10);
        assert_eq!(m.completed(), 1);
    }
}
