//! Benchmarking harness: criterion-lite timing, the paper's root-sampling
//! protocol, and table rendering.

pub mod bench;
pub mod experiments;
pub mod protocol;
pub mod roots;
pub mod table;

pub use bench::{bench, black_box, BenchConfig, Measurement};
pub use roots::{run_protocol, sample_roots, RootProtocol};
pub use table::Table;
