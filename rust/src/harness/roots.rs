//! The paper's root-sampling benchmark protocol (§4 Inputs): "For each
//! graph, we select 100 different random roots … We exclude the 25 fastest
//! and 25 slowest times and report the average time for the remaining
//! roots." The same roots are reused across GPU counts, which
//! [`sample_roots`]'s seed determinism guarantees.

use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;
use crate::util::stats::trimmed_mean;

/// Root-protocol configuration. Paper values: `num_roots=100, trim=25`.
#[derive(Clone, Copy, Debug)]
pub struct RootProtocol {
    /// Roots sampled.
    pub num_roots: usize,
    /// Samples trimmed from each end.
    pub trim: usize,
    /// Seed (same seed ⇒ same roots across node counts, per the paper).
    pub seed: u64,
}

impl RootProtocol {
    /// The paper's exact protocol.
    pub fn paper() -> Self {
        Self { num_roots: 100, trim: 25, seed: 0x0DE9_6EE4 }
    }

    /// A scaled-down profile for quick benchmarking (same shape: trim 25 %
    /// from each end).
    pub fn quick() -> Self {
        Self { num_roots: 6, trim: 1, seed: 0x0DE9_6EE4 }
    }

    /// From `BBFS_BENCH_PROFILE` (quick default).
    pub fn from_env() -> Self {
        match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => Self::paper(),
            _ => Self::quick(),
        }
    }
}

/// Sample roots uniformly over vertices, preferring vertices with nonzero
/// degree (a zero-degree root gives a trivial traversal; the trimming step
/// exists exactly to discard such outliers, but starting from plausible
/// roots matches the paper's SuiteSparse setup where roots land in the
/// big component 90–95 % of the time).
pub fn sample_roots(g: &Csr, proto: &RootProtocol) -> Vec<VertexId> {
    let n = g.num_vertices();
    assert!(n > 0, "empty graph");
    let mut rng = Xoshiro256StarStar::seed_from_u64(proto.seed);
    let mut roots = Vec::with_capacity(proto.num_roots);
    for _ in 0..proto.num_roots {
        // Up to 8 retries to find a non-isolated vertex; fall back to
        // whatever we drew (trimming will discard it).
        let mut v = rng.next_usize(n) as VertexId;
        for _ in 0..8 {
            if g.degree(v) > 0 {
                break;
            }
            v = rng.next_usize(n) as VertexId;
        }
        roots.push(v);
    }
    roots
}

/// Run `f(root)` for every sampled root and return the paper-protocol
/// trimmed mean of the times `f` reports, plus the raw samples.
pub fn run_protocol<F>(g: &Csr, proto: &RootProtocol, mut f: F) -> (f64, Vec<f64>)
where
    F: FnMut(VertexId) -> f64,
{
    let roots = sample_roots(g, proto);
    let times: Vec<f64> = roots.into_iter().map(&mut f).collect();
    (trimmed_mean(&times, proto.trim), times)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn roots_deterministic_across_calls() {
        let (g, _) = uniform_random(500, 4, 1);
        let p = RootProtocol::paper();
        assert_eq!(sample_roots(&g, &p), sample_roots(&g, &p));
    }

    #[test]
    fn paper_protocol_counts() {
        let p = RootProtocol::paper();
        assert_eq!(p.num_roots, 100);
        assert_eq!(p.trim, 25);
        let (g, _) = uniform_random(300, 4, 2);
        assert_eq!(sample_roots(&g, &p).len(), 100);
    }

    #[test]
    fn protocol_trims_outliers() {
        let (g, _) = uniform_random(200, 4, 3);
        let proto = RootProtocol { num_roots: 10, trim: 2, seed: 9 };
        let mut call = 0;
        let (mean, times) = run_protocol(&g, &proto, |_r| {
            call += 1;
            if call == 1 {
                1000.0 // absurd outlier, must be trimmed
            } else {
                1.0
            }
        });
        assert_eq!(times.len(), 10);
        assert!((mean - 1.0).abs() < 1e-12, "mean {mean}");
    }

    #[test]
    fn roots_prefer_connected_vertices() {
        use crate::graph::builder::GraphBuilder;
        // 100 connected vertices + 900 isolated: with up to 8 retries per
        // draw, far more than the raw 10 % of roots should be connected.
        let mut b = GraphBuilder::new(1000);
        for v in 1..100u32 {
            b.add_edge(0, v);
        }
        let (g, _) = b.build_undirected();
        let p = RootProtocol { num_roots: 50, trim: 5, seed: 4 };
        let roots = sample_roots(&g, &p);
        let connected = roots.iter().filter(|&&r| g.degree(r) > 0).count();
        // Expected ≈ (1 − 0.9⁹) ≈ 61 % connected; assert well above the
        // no-retry 10 % baseline.
        assert!(connected * 4 > roots.len(), "{connected}/{}", roots.len());
    }
}
