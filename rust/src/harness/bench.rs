//! Criterion-lite benchmarking harness (no `criterion` in the offline
//! set): warmup + timed iterations + summary statistics, with a text
//! report in criterion's familiar shape.

use crate::util::stats::Summary;
use std::time::Instant;

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: usize,
    /// Measured iterations.
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 2, iters: 10 }
    }
}

impl BenchConfig {
    /// Environment profile: `BBFS_BENCH_PROFILE=quick|full` (quick default
    /// keeps `cargo bench` total under a few minutes on one core).
    pub fn from_env() -> Self {
        match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => Self { warmup_iters: 3, iters: 20 },
            _ => Self { warmup_iters: 1, iters: 5 },
        }
    }
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark id (`group/name`).
    pub id: String,
    /// Summary over measured iterations (seconds).
    pub seconds: Summary,
}

impl Measurement {
    /// criterion-style one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  (± {})",
            self.id,
            fmt_time(self.seconds.min),
            fmt_time(self.seconds.median),
            fmt_time(self.seconds.max),
            fmt_time(self.seconds.stddev),
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run one benchmark: `f` is called once per iteration; its return value
/// is black-boxed to keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(cfg: &BenchConfig, id: &str, mut f: F) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut times = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let m = Measurement { id: id.to_string(), seconds: Summary::of(&times) };
    println!("{}", m.report());
    m
}

/// Optimizer barrier (stable-Rust version of `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 3 };
        let m = bench(&cfg, "test/spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(m.seconds.n, 3);
        assert!(m.seconds.min > 0.0);
        assert!(m.seconds.min <= m.seconds.median);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
        assert!(fmt_time(2.5e-9).ends_with(" ns"));
    }
}
