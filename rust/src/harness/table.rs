//! Aligned text tables in the paper's layout (for bench output and the
//! EXPERIMENTS.md records).

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns and a separator under the header.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for c in 0..cols {
            width[c] = self.header[c].chars().count();
            for r in &self.rows {
                width[c] = width[c].max(r[c].chars().count());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = width[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(
            &width
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format a float with 2 decimals (table cells).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format seconds as milliseconds with 2 decimals.
pub fn ms(x: f64) -> String {
    format!("{:.2}", x * 1e3)
}

/// Format a count with thousands separators.
pub fn count(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["graph", "time", "gteps"]);
        t.row(vec!["kron-like".into(), "0.01".into(), "324.87".into()]);
        t.row(vec!["x".into(), "1000.00".into(), "2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w), "{s}");
        assert!(s.contains("kron-like"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(1), "1");
        assert_eq!(count(1234), "1,234");
        assert_eq!(count(1234567), "1,234,567");
    }

    #[test]
    fn float_formats() {
        assert_eq!(f2(3.14159), "3.14");
        assert_eq!(f3(3.14159), "3.142");
        assert_eq!(ms(0.00123), "1.23");
    }
}
