//! Shared experiment drivers: the code behind every bench/example that
//! regenerates a paper table or figure (DESIGN.md §5 experiment index).

use crate::bfs::dirop::{diropt_bfs, DirOptParams};
use crate::bfs::topdown::topdown_bfs;
use crate::coordinator::{EngineConfig, PatternKind, TraversalPlan};
use crate::graph::csr::Csr;
use crate::graph::gen::GraphSpec;
use crate::harness::roots::{run_protocol, RootProtocol};
use crate::net::model::DeviceModel;
use crate::util::stats::gteps;

/// One Table-1 row: CPU (DO/TD) vs simulated DGX-2 ButterFly BFS.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Analog graph name.
    pub name: &'static str,
    /// Paper graph this substitutes.
    pub paper_graph: &'static str,
    /// |V|.
    pub vertices: u64,
    /// |E| (arcs).
    pub edges: u64,
    /// Measured pseudo-diameter of the analog.
    pub diameter: u32,
    /// CPU direction-optimizing simulated time (s).
    pub cpu_do_time: f64,
    /// CPU top-down simulated time (s).
    pub cpu_td_time: f64,
    /// Simulated DGX-2 (16 nodes, fanout 4) time (s).
    pub dgx2_time: f64,
    /// DGX-2 GTEPS (|E|/t convention).
    pub dgx2_gteps: f64,
}

impl Table1Row {
    /// DO speedup over TD on the CPU (the paper's "CPU-DO/CPU-TD" column).
    pub fn cpu_do_over_td(&self) -> f64 {
        self.cpu_td_time / self.cpu_do_time
    }

    /// DGX-2 speedup over CPU-DO.
    pub fn dgx2_over_cpu_do(&self) -> f64 {
        self.cpu_do_time / self.dgx2_time
    }

    /// DGX-2 speedup over CPU-TD.
    pub fn dgx2_over_cpu_td(&self) -> f64 {
        self.cpu_td_time / self.dgx2_time
    }
}

/// CPU-baseline simulated time for a traversal: examined edges priced by
/// the CPU device model (plus per-level overhead), the same simulated
/// clock the DGX-2 runs use — apples-to-apples shape comparison.
pub fn cpu_sim_time(levels: &[crate::bfs::topdown::LevelStats], dev: &DeviceModel) -> f64 {
    levels.iter().map(|l| dev.level_time(l.edges_examined)).sum()
}

/// Direction-aware variant for the direction-optimizing baseline:
/// bottom-up levels pay the BU edge-cost factor.
pub fn cpu_sim_time_directed(
    levels: &[crate::bfs::topdown::LevelStats],
    directions: &[crate::bfs::dirop::Direction],
    dev: &DeviceModel,
) -> f64 {
    levels
        .iter()
        .zip(directions)
        .map(|(l, d)| {
            dev.level_time_dir(
                l.edges_examined,
                *d == crate::bfs::dirop::Direction::BottomUp,
            )
        })
        .sum()
}

/// Run one Table-1 row on the given graph (root protocol applied to every
/// engine).
pub fn table1_row(spec: &GraphSpec, g: &Csr, proto: &RootProtocol) -> Table1Row {
    let cpu = DeviceModel::xeon_8168_dual();
    // CPU direction-optimizing (GapBS-DO analog).
    let (cpu_do_time, _) = run_protocol(g, proto, |r| {
        let res = diropt_bfs(g, r, DirOptParams::default());
        cpu_sim_time_directed(&res.levels, &res.directions, &cpu)
    });
    // CPU top-down (GapBS-TD analog).
    let (cpu_td_time, _) = run_protocol(g, proto, |r| {
        let res = topdown_bfs(g, r, true);
        cpu_sim_time(&res.levels, &cpu)
    });
    // Simulated DGX-2: 16 nodes, butterfly fanout 4. One plan, one
    // session, reused across the whole root protocol.
    let plan = TraversalPlan::build(g, EngineConfig::dgx2(16, 4)).expect("valid plan");
    let mut session = plan.session();
    let (dgx2_time, _) = run_protocol(g, proto, |r| {
        session.run_metrics_only(r).expect("protocol root in range").sim_seconds()
    });
    Table1Row {
        name: spec.name,
        paper_graph: spec.paper_graph,
        vertices: g.num_vertices() as u64,
        edges: g.num_edges(),
        diameter: crate::graph::props::pseudo_diameter(g, 0),
        cpu_do_time,
        cpu_td_time,
        dgx2_time,
        dgx2_gteps: gteps(g.num_edges(), dgx2_time),
    }
}

/// One Fig-3 data point: simulated time at a node count and fanout.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    /// Node count.
    pub nodes: usize,
    /// Butterfly fanout.
    pub fanout: u32,
    /// Trimmed-mean simulated time (s).
    pub sim_time: f64,
}

/// Fig-3 strong-scaling sweep for one graph: node counts × fanouts.
pub fn scaling_sweep(
    g: &Csr,
    node_counts: &[usize],
    fanouts: &[u32],
    proto: &RootProtocol,
) -> Vec<ScalingPoint> {
    let mut out = Vec::new();
    for &nodes in node_counts {
        for &fanout in fanouts {
            let plan =
                TraversalPlan::build(g, EngineConfig::dgx2(nodes, fanout)).expect("valid plan");
            let mut session = plan.session();
            let (sim_time, _) = run_protocol(g, proto, |r| {
                session.run_metrics_only(r).expect("protocol root in range").sim_seconds()
            });
            out.push(ScalingPoint { nodes, fanout, sim_time });
        }
    }
    out
}

/// Comparison of communication patterns on one graph at one node count
/// (the §S4 Gunrock/Groute-shaped experiment when run with the
/// dynamic-alloc net model).
pub fn pattern_comparison(
    g: &Csr,
    nodes: usize,
    patterns: &[(PatternKind, crate::net::model::NetModel)],
    proto: &RootProtocol,
) -> Vec<(String, f64)> {
    patterns
        .iter()
        .map(|(p, net)| {
            let cfg = EngineConfig {
                pattern: *p,
                net: *net,
                ..EngineConfig::dgx2(nodes, 1)
            };
            let plan = TraversalPlan::build(g, cfg).expect("valid plan");
            let mut session = plan.session();
            let (t, _) = run_protocol(g, proto, |r| {
                session.run_metrics_only(r).expect("protocol root in range").sim_seconds()
            });
            (format!("{}@{}", p.name(), net.name), t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::table1_suite;

    #[test]
    fn table1_row_runs_on_tiny_graph() {
        let spec = &table1_suite()[6]; // kron-like
        let g = spec.generate_scaled(-7); // tiny
        let proto = RootProtocol { num_roots: 4, trim: 1, seed: 1 };
        let row = table1_row(spec, &g, &proto);
        assert!(row.cpu_do_time > 0.0);
        assert!(row.cpu_td_time > 0.0);
        assert!(row.dgx2_time > 0.0);
        assert!(row.dgx2_gteps > 0.0);
        // Small-world kron: DO should beat TD on the CPU.
        assert!(row.cpu_do_over_td() >= 1.0, "{}", row.cpu_do_over_td());
    }

    #[test]
    fn scaling_sweep_shapes() {
        let spec = &table1_suite()[7]; // urand-like
        let g = spec.generate_scaled(-7);
        let proto = RootProtocol { num_roots: 4, trim: 1, seed: 2 };
        let pts = scaling_sweep(&g, &[2, 4], &[1, 4], &proto);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.sim_time > 0.0));
    }
}
