//! The committed perf-trajectory artifact: `BENCH_engine.json`.
//!
//! A fixed, fully deterministic bench protocol — the RMAT (`kron-like`)
//! suite graph at a fixed scale delta, a fixed 64-root batch, the
//! butterfly fanout-4 engine at p ∈ {16, 64} — run once per direction
//! policy (`topdown` / `bottomup` / `diropt`). The report records the
//! numbers the direction-optimization work is accountable for: edges
//! inspected (total and per level, per direction tag), bytes per level,
//! GTEPS on the simulated clock, and the per-direction level counts.
//!
//! Since v2 the report also carries a **batch-width ablation**
//! (`width_ablation`): width ∈ {64, 256} × mode ∈ {1d, 2d}, each wide
//! batch against the same roots executed as 64-root single-word chunks —
//! the perf trajectory of the const-generic wide lane masks (the
//! acceptance pass requires the 256-wide batch to use strictly fewer
//! sync rounds *and* fewer total exchange bytes than its 4 × 64 chunks).
//!
//! Since v3 the report carries a **serve-throughput** section
//! (`serve_throughput`): a fully deterministic discrete-event simulation
//! of the `serve` mode's cross-request coalescing, run through the *real*
//! [`Coalescer`](crate::serve::Coalescer) dispatch logic and real engine
//! service times quantized to integer microseconds — one open-loop
//! arrival schedule served twice, without coalescing (window 0, batch 1)
//! and with it. The committed numbers are the evidence that coalescing
//! turns an overloaded single-session service (bounded queue full,
//! rejections, multi-millisecond p50) into one that keeps up (strictly
//! higher qps, lower p50, mean batch width > 1) at the committed load
//! point. The section may additionally carry a `measured` subtree written
//! by `benches/serve_throughput.rs --update` (wallclock numbers from a
//! live socket run); `measured` is excluded from the freshness compare —
//! wallclock is not reproducible — but its invariants are still checked.
//!
//! Since v4 the report carries a **storage** section (`storage`): the
//! `.bbfs` v2 container encoded from the web-like suite graph, committed
//! as byte counts (v1 vs v2 vs degree-sorted v2), the container
//! fingerprint, and the loader's decode counters for three load paths —
//! eager full decode, cold plan build (degree-only pass + materialize),
//! and warm start from a plan cache (zero decode work up front). The
//! integers cross-validate the Rust codec against its line-for-line
//! Python port: both produce the identical container, so both report the
//! identical sizes, fingerprint, and counter deltas.
//!
//! Since v5 the report carries a **hierarchical** section
//! (`hierarchical`): the fixed 64-root batch at p = 64 executed in all
//! three partition modes — flat 1D butterfly, 8×8 2D fold/expand, and
//! the 8×8 grid-of-islands composition — every mode priced under the
//! *same* heterogeneous `dgx2-cluster` topology (NVLink-class links
//! inside an island, one shared ~10× slower uplink per island). The
//! committed numbers are the evidence for the hierarchical claim: the
//! grid-of-islands schedule pushes an order of magnitude fewer bytes
//! through the slow inter-island class and finishes the batch faster
//! than both flat layouts ([`check_engine_bench`]'s acceptance pass
//! requires strictly smaller simulated time than 1D *and* 2D, plus the
//! inter-byte reduction). A `static_schedule` subtree pins the
//! per-class message split of the bare schedules, independent of any
//! graph.
//!
//! Since v7 the report carries a **kernel ablation** section
//! (`kernel_ablation`): kernel variant {scalar, chunked} × lane width
//! {64, 256, 512} × partition mode {1d, 2d, hier}, run bottom-up against
//! roots drawn from one connected component (so the chunked kernel's
//! settled-skip has real work to elide), with the deterministic
//! per-kernel work counters (mask words touched / provably skipped,
//! dispatches, and per-dispatch max work) committed as the evidence for
//! the SIMD-shaped mask kernels: all variants bit-identical distances,
//! chunked strictly fewer words than scalar (total and on the sparse
//! tail level), and LRB degree-binning strictly shrinking the largest
//! single dispatch versus the unbinned probe (`no_lrb`).
//!
//! The artifact lives at the repository root and is kept fresh by CI:
//! `butterfly-bfs bench-protocol --check` recomputes the protocol and
//! fails when the committed file drifts (integer counters compare
//! exactly; simulated-clock floats within relative tolerance, so the
//! check is robust to float formatting). Regenerate with
//! `butterfly-bfs bench-protocol` after any change that moves the
//! numbers, and commit the diff — that *is* the perf trajectory.

use crate::bfs::msbfs::sample_batch_roots;
use crate::comm::{class_volume, Butterfly, ClassVolume, CommPattern, GridOfIslands, Schedule};
use crate::coordinator::config::{BatchWidth, DirectionMode};
use crate::coordinator::metrics::BatchMetrics;
use crate::coordinator::{EngineConfig, KernelVariant, TraversalPlan};
use crate::fault::{FaultInjector, FaultPlan};
use crate::net::model::TopologyModel;
use crate::graph::csr::{Csr, VertexId};
use crate::graph::gen::table1_suite;
use crate::graph::store::{
    encode_store, v1_snapshot_bytes, GraphStore, StoreCounters, StoreWriteOptions,
};
use crate::serve::coalescer::Coalescer;
use crate::serve::metrics::nearest_rank_us;
use crate::util::json::Json;
use crate::util::stats::gteps;
use std::path::Path;
use std::sync::Arc;

/// Protocol identifier (bump when the schema or configs change).
/// v2 added the batch-width ablation section (`width_ablation`): wide
/// lane masks vs chunked 64-root execution, in 1D and 2D.
/// v3 added the serve-throughput simulation (`serve_throughput`).
/// v4 added the on-disk storage section (`storage`): `.bbfs` v2
/// compression sizes, container fingerprint, and warm-start decode
/// counters.
/// v5 added the hierarchical section (`hierarchical`): 1d vs 2d vs
/// grid-of-islands at p = 64 under the heterogeneous `dgx2-cluster`
/// topology, with per-link-class message/byte splits.
/// v6 added the fault-recovery section (`fault_recovery`): a committed
/// seeded fault schedule injected at the exchange seam, the
/// retry/backoff/retransmit overhead it prices into the simulated
/// clock, and the bit-identical-distances invariant under recovery.
/// v7 added the kernel-ablation section (`kernel_ablation`): scalar vs
/// chunked mask kernels × width {64, 256, 512} × mode {1d, 2d, hier},
/// bottom-up, with deterministic work counters and the LRB dispatch
/// comparison.
pub const PROTOCOL_NAME: &str = "engine-bench-v7";
/// Suite graph the protocol runs on (the paper's GAP_kron analog).
pub const PROTOCOL_GRAPH: &str = "kron-like";
/// Scale adjustment: `kron-like` is scale 21; −10 ⇒ 2^11 vertices — big
/// enough for dense mid-levels, small enough for CI.
pub const PROTOCOL_SCALE_DELTA: i32 = -10;
/// Batch width (full lane occupancy).
pub const PROTOCOL_BATCH_WIDTH: usize = 64;
/// Root-sampling seed (the CLI `batch` default).
pub const PROTOCOL_ROOT_SEED: u64 = 7;
/// Simulated node counts (the paper's DGX-2 scale and 4 racks of it).
pub const PROTOCOL_NODE_COUNTS: [usize; 2] = [16, 64];
/// Butterfly fanout (the paper's headline configuration).
pub const PROTOCOL_FANOUT: u32 = 4;
/// Batch widths of the width-ablation section (wide lane masks).
pub const PROTOCOL_WIDE_WIDTHS: [usize; 2] = [64, 256];
/// Node count of the width-ablation configs (1D; the 2D grid covers the
/// same count).
pub const PROTOCOL_WIDE_NODES: usize = 16;
/// 2D processor grid of the width-ablation configs.
pub const PROTOCOL_WIDE_GRID: (u32, u32) = (4, 4);
/// Chunk size of the chunked-execution baseline (the single-word lane
/// width).
pub const PROTOCOL_CHUNK: usize = 64;
/// Serve sim: number of open-loop requests.
pub const PROTOCOL_SERVE_REQUESTS: usize = 256;
/// Serve sim: fixed inter-arrival gap (µs) — ~33 k offered qps, chosen
/// to overload a single uncoalesced session (whose per-query service
/// time on this graph is ≈ 4× the gap) while a coalesced one keeps up.
pub const PROTOCOL_SERVE_GAP_US: u64 = 30;
/// Serve sim: admission-queue bound (requests past it are rejected).
pub const PROTOCOL_SERVE_QUEUE_DEPTH: usize = 64;
/// Serve sim: coalescing window of the coalesced mode (µs).
pub const PROTOCOL_SERVE_WINDOW_US: u64 = 240;
/// Serve sim: maximum coalesced batch width.
pub const PROTOCOL_SERVE_MAX_BATCH: usize = 64;
/// Serve sim: root-sampling seed of the request stream.
pub const PROTOCOL_SERVE_SEED: u64 = 11;
/// Storage section: suite graph the container is encoded from (the
/// paper's GAP_web analog — the graph class v2's gap encoding targets).
pub const PROTOCOL_STORAGE_GRAPH: &str = "web-like";
/// Storage section: scale adjustment (`web-like` is scale 20; −8 ⇒ 2^12
/// vertices — several container blocks, small enough for CI).
pub const PROTOCOL_STORAGE_SCALE_DELTA: i32 = -8;
/// Storage section: node count of the cold/warm plan builds (1D).
pub const PROTOCOL_STORAGE_NODES: usize = 16;
/// Hierarchical section: node count (4 racks of DGX-2 scale — the point
/// where flat butterfly rounds start crossing islands heavily).
pub const PROTOCOL_HIER_NODES: usize = 64;
/// Hierarchical section: island grid (islands × nodes-per-island).
pub const PROTOCOL_HIER_GRID: (u32, u32) = (8, 8);
/// Fault section: seed of the committed [`FaultPlan::generate`] schedule
/// (chosen so the schedule exercises all three recoverable kinds against
/// live transfers — the acceptance pass requires `retries >= 1`).
pub const PROTOCOL_FAULT_SEED: u64 = 43;
/// Fault section: number of generated faults.
pub const PROTOCOL_FAULT_COUNT: usize = 6;
/// Fault section: level span the generator addresses faults over.
pub const PROTOCOL_FAULT_LEVELS: u32 = 4;
/// Fault section: round span the generator addresses faults over.
pub const PROTOCOL_FAULT_ROUNDS: usize = 2;
/// Fault section: node count (the paper's DGX-2 scale).
pub const PROTOCOL_FAULT_NODES: usize = 16;
/// Kernel-ablation lane widths (lane word counts 1, 4, and 8 — every
/// mask-kernel shape the const-generic widths monomorphize).
pub const PROTOCOL_KERNEL_WIDTHS: [usize; 3] = [64, 256, 512];
/// Kernel-ablation hier island grid (4 islands × 4 nodes = 16, matching
/// the 1d node count and the 4×4 2d grid).
pub const PROTOCOL_KERNEL_HIER_GRID: (u32, u32) = (4, 4);

fn direction_modes() -> [(&'static str, DirectionMode); 3] {
    [
        ("topdown", DirectionMode::TopDown),
        ("bottomup", DirectionMode::BottomUp),
        ("diropt", DirectionMode::diropt()),
    ]
}

/// One direction's metrics as the protocol records them.
fn direction_json(m: &BatchMetrics) -> Json {
    let per_level: Vec<Json> = m
        .levels
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("level", Json::u(l.level as u64)),
                ("frontier", Json::u(l.frontier)),
                ("edges", Json::u(l.edges_examined)),
                ("bytes", Json::u(l.bytes)),
                ("direction", Json::s(l.direction_name())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("levels", Json::u(m.depth() as u64)),
        ("bottom_up_levels", Json::u(m.bottom_up_levels())),
        ("edges_inspected", Json::u(m.edges_examined())),
        ("bottom_up_edges", Json::u(m.bottom_up_edges())),
        ("bytes", Json::u(m.bytes())),
        (
            "bytes_per_level",
            Json::n(m.bytes() as f64 / m.depth().max(1) as f64),
        ),
        ("messages", Json::u(m.messages())),
        ("sync_rounds", Json::u(m.sync_rounds)),
        ("reached_pairs", Json::u(m.reached_pairs)),
        ("sim_seconds", Json::n(m.sim_seconds())),
        ("sim_gteps", Json::n(gteps(m.graph_edges, m.sim_seconds()))),
        ("per_level", Json::Arr(per_level)),
    ])
}

/// The width-ablation base config for one mode (direction stays
/// top-down: the ablation isolates the lane-width effect on sync rounds
/// and wire bytes; the direction ablation above covers diropt).
fn width_config(mode_2d: bool) -> EngineConfig {
    if mode_2d {
        EngineConfig::dgx2_2d(PROTOCOL_WIDE_GRID.0, PROTOCOL_WIDE_GRID.1)
    } else {
        EngineConfig::dgx2(PROTOCOL_WIDE_NODES, PROTOCOL_FANOUT)
    }
}

/// The width-ablation section: for each mode × width, one wide batch
/// (the lane mask sized to the width) against the same roots executed in
/// 64-root single-word chunks — the committed evidence that widening the
/// lanes amortizes exchange startup across more roots (strictly fewer
/// sync rounds *and* fewer total bytes at width 256, checked by
/// [`check_engine_bench`]'s acceptance pass).
fn width_ablation_json(g: &Csr) -> Json {
    let mut entries = Vec::new();
    for mode_2d in [false, true] {
        for &width in &PROTOCOL_WIDE_WIDTHS {
            let roots = sample_batch_roots(g, width, PROTOCOL_ROOT_SEED);
            let mut cfg = width_config(mode_2d);
            cfg.batch_width =
                BatchWidth::for_lanes(width).expect("protocol widths are within the lane limit");
            let mut session =
                TraversalPlan::build(g, cfg).expect("valid protocol plan").session();
            let m = session
                .run_batch_metrics_only(&roots)
                .expect("protocol roots in range");
            // Chunked baseline: same roots, 64-root single-word chunks
            // through one pooled session (the pre-widening execution).
            let mut chunked =
                TraversalPlan::build(g, width_config(mode_2d))
                    .expect("valid protocol plan")
                    .session();
            let (mut c_rounds, mut c_msgs, mut c_bytes) = (0u64, 0u64, 0u64);
            let (mut c_sim, mut c_reached, mut chunks) = (0f64, 0u64, 0u64);
            for chunk in roots.chunks(PROTOCOL_CHUNK) {
                let cm = chunked
                    .run_batch_metrics_only(chunk)
                    .expect("protocol roots in range");
                c_rounds += cm.sync_rounds;
                c_msgs += cm.messages();
                c_bytes += cm.bytes();
                c_sim += cm.sim_seconds();
                c_reached += cm.reached_pairs;
                chunks += 1;
            }
            let mut fields = vec![
                ("mode", Json::s(if mode_2d { "2d" } else { "1d" })),
                ("width", Json::u(width as u64)),
                ("nodes", Json::u(PROTOCOL_WIDE_NODES as u64)),
            ];
            if mode_2d {
                fields.push((
                    "grid",
                    Json::s(format!(
                        "{}x{}",
                        PROTOCOL_WIDE_GRID.0, PROTOCOL_WIDE_GRID.1
                    )),
                ));
            }
            fields.extend([
                ("direction", Json::s("topdown")),
                ("lane_words", Json::u(m.lane_words as u64)),
                ("entry_bytes", Json::u(m.entry_bytes())),
                ("levels", Json::u(m.depth() as u64)),
                ("sync_rounds", Json::u(m.sync_rounds)),
                ("messages", Json::u(m.messages())),
                ("bytes", Json::u(m.bytes())),
                ("edges_inspected", Json::u(m.edges_examined())),
                ("reached_pairs", Json::u(m.reached_pairs)),
                ("sim_seconds", Json::n(m.sim_seconds())),
                (
                    "chunked",
                    Json::obj(vec![
                        ("chunks", Json::u(chunks)),
                        ("sync_rounds", Json::u(c_rounds)),
                        ("messages", Json::u(c_msgs)),
                        ("bytes", Json::u(c_bytes)),
                        ("reached_pairs", Json::u(c_reached)),
                        ("sim_seconds", Json::n(c_sim)),
                    ]),
                ),
            ]);
            entries.push(Json::obj(fields));
        }
    }
    Json::Arr(entries)
}

/// One serve-sim mode: drive the fixed open-loop arrival schedule
/// through the real [`Coalescer`] against a single simulated worker.
///
/// Discrete-event rules (mirrored line-for-line in
/// `python/bench_protocol_port.py::serve_sim_mode`):
///
/// * request `i` arrives at `i * PROTOCOL_SERVE_GAP_US`, rooted at the
///   `i`-th sampled protocol root;
/// * an arrival that finds the admission queue full is rejected
///   (counted, never served);
/// * a batch starts at `max(due_at, worker_free)` — the coalescer's own
///   batch-full-or-window-expiry rule, gated on the single worker —
///   with arrivals at or before that instant admitted first;
/// * service time is the *real engine's* simulated clock for exactly
///   that root multiset, quantized up to integer microseconds
///   (`ceil(sim_seconds × 1e6)`), so every latency in the section is an
///   integer and the CI freshness check compares them exactly;
/// * per-request latency is `finish − arrival`.
fn serve_sim_mode(g: &Csr, window_us: u64, max_batch: usize) -> Json {
    let cfg = EngineConfig {
        direction: DirectionMode::TopDown,
        batch_width: BatchWidth::for_lanes(PROTOCOL_SERVE_MAX_BATCH)
            .expect("protocol widths are within the lane limit"),
        ..EngineConfig::dgx2(PROTOCOL_WIDE_NODES, PROTOCOL_FANOUT)
    };
    let plan = TraversalPlan::build(g, cfg).expect("valid protocol plan");
    let mut session = plan.session();
    let mut service_us = |roots: &[VertexId]| -> u64 {
        let m = session.run_batch_metrics_only(roots).expect("protocol roots in range");
        (m.sim_seconds() * 1e6).ceil() as u64
    };
    let roots = sample_batch_roots(g, PROTOCOL_SERVE_REQUESTS, PROTOCOL_SERVE_SEED);
    let mut c: Coalescer<VertexId> =
        Coalescer::new(window_us, max_batch, PROTOCOL_SERVE_QUEUE_DEPTH);
    let mut latencies: Vec<u64> = Vec::new();
    let mut widths: Vec<u64> = Vec::new();
    let (mut rejected, mut worker_free, mut last_finish) = (0u64, 0u64, 0u64);
    let mut next = 0usize;
    loop {
        let t_arr = (next < roots.len()).then(|| next as u64 * PROTOCOL_SERVE_GAP_US);
        let t_disp = c.due_at().map(|d| d.max(worker_free));
        let arrival_first = match (t_arr, t_disp) {
            (None, None) => break,
            (Some(_), None) => true,
            (Some(ta), Some(t)) => ta <= t,
            (None, Some(_)) => false,
        };
        if arrival_first {
            let ta = t_arr.expect("arrival branch has an arrival");
            if c.try_push(ta, None, roots[next]).is_err() {
                rejected += 1;
            }
            next += 1;
        } else {
            let start = t_disp.expect("dispatch branch has a due batch");
            let batch = c.take_batch();
            let batch_roots: Vec<VertexId> = batch.iter().map(|p| p.item).collect();
            let finish = start + service_us(&batch_roots);
            worker_free = finish;
            last_finish = finish;
            widths.push(batch.len() as u64);
            for p in &batch {
                latencies.push(finish - p.arrived_us);
            }
        }
    }
    let completed = latencies.len() as u64;
    let mut sorted = latencies.clone();
    sorted.sort_unstable();
    let mean_latency = if completed == 0 {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / completed as f64
    };
    let qps = if last_finish == 0 {
        0.0
    } else {
        completed as f64 * 1e6 / last_finish as f64
    };
    let batches = widths.len() as u64;
    let mean_width = if batches == 0 {
        0.0
    } else {
        widths.iter().sum::<u64>() as f64 / batches as f64
    };
    Json::obj(vec![
        ("window_us", Json::u(window_us)),
        ("max_batch", Json::u(max_batch as u64)),
        ("offered", Json::u(roots.len() as u64)),
        ("completed", Json::u(completed)),
        ("rejected", Json::u(rejected)),
        ("timed_out", Json::u(0)),
        ("p50_us", Json::u(nearest_rank_us(&sorted, 50.0))),
        ("p99_us", Json::u(nearest_rank_us(&sorted, 99.0))),
        ("mean_latency_us", Json::n(mean_latency)),
        ("qps", Json::n(qps)),
        ("batches", Json::u(batches)),
        ("mean_width", Json::n(mean_width)),
        ("max_width", Json::u(widths.iter().copied().max().unwrap_or(0))),
        ("span_us", Json::u(last_finish)),
    ])
}

/// The serve-throughput section: the committed load point served with
/// and without coalescing. The `measured` subtree (live wallclock
/// numbers from `benches/serve_throughput.rs --update`) is attached by
/// [`write_engine_bench`] when present in the existing artifact and is
/// never part of the freshness compare.
fn serve_throughput_json(g: &Csr) -> Json {
    Json::obj(vec![
        (
            "sim",
            Json::obj(vec![
                ("requests", Json::u(PROTOCOL_SERVE_REQUESTS as u64)),
                ("arrival_gap_us", Json::u(PROTOCOL_SERVE_GAP_US)),
                ("queue_depth", Json::u(PROTOCOL_SERVE_QUEUE_DEPTH as u64)),
                ("root_seed", Json::u(PROTOCOL_SERVE_SEED)),
                ("nodes", Json::u(PROTOCOL_WIDE_NODES as u64)),
                ("fanout", Json::u(PROTOCOL_FANOUT as u64)),
                ("mode", Json::s("1d")),
                ("direction", Json::s("topdown")),
                ("baseline", serve_sim_mode(g, 0, 1)),
                (
                    "coalesced",
                    serve_sim_mode(g, PROTOCOL_SERVE_WINDOW_US, PROTOCOL_SERVE_MAX_BATCH),
                ),
            ]),
        ),
    ])
}

/// A decode-counter snapshot as the storage section records it.
fn store_counters_json(c: &StoreCounters) -> Json {
    Json::obj(vec![
        ("degree_entries", Json::u(c.degree_entries_decoded)),
        ("edges", Json::u(c.edges_decoded)),
        ("blocks", Json::u(c.blocks_decoded)),
    ])
}

/// The storage section: `.bbfs` v2 sizes, fingerprint, and the decode
/// counters of the three load paths — eager full decode, cold plan build
/// (degree-only pass, then materialize), and warm start from a plan
/// cache (zero adjacency decoding up front; the acceptance pass pins
/// that gap). Every integer here is reproduced by the Python port of
/// the codec, so the committed numbers cross-validate the two
/// implementations byte-for-byte.
fn storage_json() -> Json {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == PROTOCOL_STORAGE_GRAPH)
        .expect("suite contains the storage graph");
    let g = spec.generate_scaled(PROTOCOL_STORAGE_SCALE_DELTA);
    let v1 = v1_snapshot_bytes(&g);
    let plain =
        encode_store(&g, StoreWriteOptions::default()).expect("suite graph encodes");
    let relabeled = encode_store(
        &g,
        StoreWriteOptions { relabel: true, ..StoreWriteOptions::default() },
    )
    .expect("suite graph encodes relabeled");
    let v2 = plain.bytes.len() as u64;
    let v2_relabeled = relabeled.bytes.len() as u64;
    let cfg = EngineConfig::dgx2(PROTOCOL_STORAGE_NODES, PROTOCOL_FANOUT);
    let root = sample_batch_roots(&g, 1, PROTOCOL_ROOT_SEED)[0];
    let reference = TraversalPlan::build(&g, cfg.clone())
        .expect("valid protocol plan")
        .session()
        .run(root)
        .expect("protocol root in range")
        .dist()
        .to_vec();

    // Eager path: full decode back to CSR on a dedicated handle.
    let eager_store =
        GraphStore::open_bytes(plain.bytes.clone()).expect("own encoding opens");
    let decoded = eager_store.to_csr().expect("own encoding decodes");
    let eager = eager_store.counters();

    // Cold path: plan build (degree-only pass) + materialize, then save
    // the partition cuts as a plan cache.
    let cold_store = Arc::new(
        GraphStore::open_bytes(plain.bytes.clone()).expect("own encoding opens"),
    );
    let fingerprint = cold_store.fingerprint_hex();
    let cold_plan = TraversalPlan::build_from_store(Arc::clone(&cold_store), cfg.clone())
        .expect("valid store plan");
    let cold_at_load = cold_store.counters();
    cold_plan.materialize().expect("own encoding materializes");
    let cold_after = cold_store.counters();
    let cache = cold_plan.cache_json().expect("store-built plan has a cache");
    let cold_dist = cold_plan
        .session()
        .run(root)
        .expect("protocol root in range")
        .dist()
        .to_vec();

    // 2D cold build: the streaming degree/in-degree pass decodes every
    // block exactly once instead of round-tripping the store through a
    // full CSR — the counters at load are exactly {n, m, num_blocks}.
    let twod_store = Arc::new(
        GraphStore::open_bytes(plain.bytes.clone()).expect("own encoding opens"),
    );
    TraversalPlan::build_from_store(Arc::clone(&twod_store), EngineConfig::dgx2_2d(4, 4))
        .expect("valid store plan");
    let twod_at_load = twod_store.counters();

    // Warm path: restart from the cache on a fresh handle — the counter
    // snapshot before materialize is the warm-start evidence.
    let warm_store =
        Arc::new(GraphStore::open_bytes(plain.bytes).expect("own encoding opens"));
    let warm_plan = TraversalPlan::from_cache_json(Arc::clone(&warm_store), cfg.clone(), &cache)
        .expect("own cache validates");
    let warm_at_load = warm_store.counters();
    warm_plan.materialize().expect("own encoding materializes");
    let warm_after = warm_store.counters();
    let warm_dist = warm_plan
        .session()
        .run(root)
        .expect("protocol root in range")
        .dist()
        .to_vec();

    // Relabeled store: answers must unmap to the in-memory plan's.
    let relabeled_store =
        Arc::new(GraphStore::open_bytes(relabeled.bytes).expect("own encoding opens"));
    let relabeled_plan = TraversalPlan::build_from_store(Arc::clone(&relabeled_store), cfg)
        .expect("valid store plan");
    relabeled_plan.materialize().expect("own encoding materializes");
    let perm = relabeled_plan
        .relabeling()
        .expect("relabeled store plan carries the permutation")
        .clone();
    let relabeled_dist = perm.unmap_dist(
        relabeled_plan
            .session()
            .run(perm.new_id[root as usize])
            .expect("protocol root in range")
            .dist(),
    );

    let warm_equals_cold = warm_dist == cold_dist;
    let matches_in_memory =
        decoded == g && cold_dist == reference && relabeled_dist == reference;
    Json::obj(vec![
        (
            "graph",
            Json::obj(vec![
                ("name", Json::s(PROTOCOL_STORAGE_GRAPH)),
                ("scale_delta", Json::n(PROTOCOL_STORAGE_SCALE_DELTA as f64)),
                ("vertices", Json::u(g.num_vertices() as u64)),
                ("edges", Json::u(g.num_edges())),
            ]),
        ),
        ("nodes", Json::u(PROTOCOL_STORAGE_NODES as u64)),
        ("fanout", Json::u(PROTOCOL_FANOUT as u64)),
        ("mode", Json::s("1d")),
        ("block_size", Json::u(crate::graph::store::BLOCK_SIZE_DEFAULT as u64)),
        ("v1_bytes", Json::u(v1)),
        ("v2_bytes", Json::u(v2)),
        ("v2_relabeled_bytes", Json::u(v2_relabeled)),
        ("compression_ratio", Json::n(v1 as f64 / v2 as f64)),
        ("relabeled_ratio", Json::n(v1 as f64 / v2_relabeled as f64)),
        ("fingerprint", Json::s(fingerprint)),
        (
            "load_counters",
            Json::obj(vec![
                ("eager", store_counters_json(&eager)),
                (
                    "cold_build",
                    Json::obj(vec![
                        ("at_load", store_counters_json(&cold_at_load)),
                        ("after_materialize", store_counters_json(&cold_after)),
                    ]),
                ),
                (
                    "warm_start",
                    Json::obj(vec![
                        ("at_load", store_counters_json(&warm_at_load)),
                        ("after_materialize", store_counters_json(&warm_after)),
                    ]),
                ),
                (
                    "two_d_cold",
                    Json::obj(vec![("at_load", store_counters_json(&twod_at_load))]),
                ),
            ]),
        ),
        ("warm_equals_cold", Json::Bool(warm_equals_cold)),
        ("matches_in_memory", Json::Bool(matches_in_memory)),
    ])
}

/// The engine config for one mode of the hierarchical section. All
/// three modes run at p = 64 and are priced under the identical
/// heterogeneous cluster ([`TopologyModel::dgx2_cluster`]), so the only
/// variable is the communication layout itself.
fn hier_mode_config(mode: &str) -> EngineConfig {
    let (islands, per_island) = PROTOCOL_HIER_GRID;
    let mut cfg = match mode {
        "1d" => EngineConfig::dgx2(PROTOCOL_HIER_NODES, PROTOCOL_FANOUT),
        "2d" => EngineConfig::dgx2_2d(islands, per_island),
        "hier" => EngineConfig::dgx2_cluster_hier(islands, per_island, PROTOCOL_FANOUT),
        m => unreachable!("unknown hierarchical protocol mode {m}"),
    };
    cfg.batch_width = BatchWidth::for_lanes(PROTOCOL_BATCH_WIDTH)
        .expect("protocol widths are within the lane limit");
    cfg.topology = Some(TopologyModel::dgx2_cluster(per_island));
    cfg
}

/// One mode of the hierarchical section: the fixed 64-root batch,
/// recorded with the per-link-class traffic split.
fn hier_mode_json(g: &Csr, roots: &[VertexId], mode: &str) -> Json {
    let mut session =
        TraversalPlan::build(g, hier_mode_config(mode)).expect("valid protocol plan").session();
    let m = session.run_batch_metrics_only(roots).expect("protocol roots in range");
    Json::obj(vec![
        ("levels", Json::u(m.depth() as u64)),
        ("sync_rounds", Json::u(m.sync_rounds)),
        ("messages", Json::u(m.messages())),
        ("bytes", Json::u(m.bytes())),
        ("intra_messages", Json::u(m.intra_messages())),
        ("intra_bytes", Json::u(m.intra_bytes())),
        ("inter_messages", Json::u(m.inter_messages())),
        ("inter_bytes", Json::u(m.inter_bytes())),
        ("reached_pairs", Json::u(m.reached_pairs)),
        ("sim_seconds", Json::n(m.sim_seconds())),
    ])
}

/// The per-class message split of a bare schedule — the graph-free half
/// of the hierarchical evidence.
fn static_schedule_json(s: &Schedule, cv: &ClassVolume) -> Json {
    Json::obj(vec![
        ("rounds", Json::u(s.depth() as u64)),
        ("messages", Json::u(s.total_messages())),
        ("intra_messages", Json::u(cv.intra_messages)),
        ("inter_messages", Json::u(cv.inter_messages)),
    ])
}

/// The hierarchical section: flat 1D, 2D fold/expand, and the
/// grid-of-islands composition, all at p = 64 under the same
/// `dgx2-cluster` pricing. [`check_engine_bench`]'s acceptance pass
/// requires the hierarchical mode to finish the batch strictly faster
/// than both flat layouts while moving strictly fewer inter-island
/// bytes than flat 1D — the committed trajectory of the tentpole claim.
fn hierarchical_json(g: &Csr) -> Json {
    let (islands, per_island) = PROTOCOL_HIER_GRID;
    let roots = sample_batch_roots(g, PROTOCOL_BATCH_WIDTH, PROTOCOL_ROOT_SEED);
    let modes: Vec<(&str, Json)> =
        ["1d", "2d", "hier"].iter().map(|m| (*m, hier_mode_json(g, &roots, m))).collect();
    let sim = |j: &Json| {
        j.get("sim_seconds").and_then(Json::as_f64).expect("mode entries carry sim_seconds")
    };
    let (s1, s2, sh) = (sim(&modes[0].1), sim(&modes[1].1), sim(&modes[2].1));
    let topo = TopologyModel::dgx2_cluster(per_island);
    let n = PROTOCOL_HIER_NODES as u32;
    let flat = Butterfly::new(PROTOCOL_FANOUT).schedule(n);
    let hier = GridOfIslands::new(islands, per_island, PROTOCOL_FANOUT).schedule(n);
    let flat_cv = class_volume(&flat, &topo);
    let hier_cv = class_volume(&hier, &topo);
    Json::obj(vec![
        ("nodes", Json::u(PROTOCOL_HIER_NODES as u64)),
        ("islands", Json::s(format!("{islands}x{per_island}"))),
        ("fanout", Json::u(PROTOCOL_FANOUT as u64)),
        ("width", Json::u(PROTOCOL_BATCH_WIDTH as u64)),
        ("seed", Json::u(PROTOCOL_ROOT_SEED)),
        ("net", Json::s(topo.name)),
        ("speed_ratio", Json::n(topo.speed_ratio())),
        ("direction", Json::s("topdown")),
        ("modes", Json::obj(modes)),
        ("speedup_vs_1d", Json::n(s1 / sh)),
        ("speedup_vs_2d", Json::n(s2 / sh)),
        (
            "static_schedule",
            Json::obj(vec![
                ("flat_1d", static_schedule_json(&flat, &flat_cv)),
                ("hier", static_schedule_json(&hier, &hier_cv)),
            ]),
        ),
    ])
}

/// The engine config for one kernel-ablation run: the named mode at 16
/// nodes, forced bottom-up (the direction whose hot loops the mask
/// kernels restructure), with the kernel variant and LRB toggle under
/// test.
fn kernel_mode_config(
    mode: &str,
    width: usize,
    kernel: KernelVariant,
    use_lrb: bool,
) -> EngineConfig {
    let mut cfg = match mode {
        "1d" => EngineConfig::dgx2(PROTOCOL_WIDE_NODES, PROTOCOL_FANOUT),
        "2d" => EngineConfig::dgx2_2d(PROTOCOL_WIDE_GRID.0, PROTOCOL_WIDE_GRID.1),
        "hier" => EngineConfig::dgx2_cluster_hier(
            PROTOCOL_KERNEL_HIER_GRID.0,
            PROTOCOL_KERNEL_HIER_GRID.1,
            PROTOCOL_FANOUT,
        ),
        m => unreachable!("unknown kernel protocol mode {m}"),
    };
    cfg.direction = DirectionMode::BottomUp;
    cfg.kernel = kernel;
    cfg.use_lrb = use_lrb;
    cfg.batch_width =
        BatchWidth::for_lanes(width).expect("protocol widths are within the lane limit");
    cfg
}

/// One variant's work counters as the kernel-ablation section records
/// them. `tail_words` is the last level's word traffic — the sparse-tail
/// slice where the chunked kernel's settled-skip pays hardest.
fn kernel_work_json(m: &BatchMetrics) -> Json {
    Json::obj(vec![
        ("words_touched", Json::u(m.words_touched())),
        ("words_skipped", Json::u(m.words_skipped())),
        ("dispatches", Json::u(m.dispatches())),
        ("dispatch_max_work", Json::u(m.dispatch_max_work())),
        ("tail_words", Json::u(m.levels.last().map(|l| l.words_touched).unwrap_or(0))),
    ])
}

/// The kernel-ablation section. Roots come from a single connected
/// component (the reachable set of the protocol seed root, cycled in
/// ascending vertex order) so every lane saturates: by the tail levels
/// most owned vertices are fully settled and the chunked kernel's
/// skip-summary words have real work to elide — a mixed-component batch
/// would leave lanes permanently unsettleable and hide the effect.
fn kernel_ablation_json(g: &Csr) -> Json {
    use crate::bfs::serial::{serial_bfs, INF};
    let seed_root = sample_batch_roots(g, 1, PROTOCOL_ROOT_SEED)[0];
    let comp: Vec<VertexId> = serial_bfs(g, seed_root)
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d != INF)
        .map(|(v, _)| v as VertexId)
        .collect();
    let mut entries = Vec::new();
    for mode in ["1d", "2d", "hier"] {
        for &width in &PROTOCOL_KERNEL_WIDTHS {
            let roots: Vec<VertexId> =
                (0..width).map(|i| comp[i % comp.len()]).collect();
            let mut run = |kernel: KernelVariant, use_lrb: bool| {
                TraversalPlan::build(g, kernel_mode_config(mode, width, kernel, use_lrb))
                    .expect("valid protocol plan")
                    .session()
                    .run_batch(&roots)
                    .expect("protocol roots in range")
            };
            let scalar = run(KernelVariant::Scalar, true);
            let chunked = run(KernelVariant::Chunked, true);
            let no_lrb = run(KernelVariant::Chunked, false);
            let equal = (0..width).all(|lane| {
                scalar.dist(lane) == chunked.dist(lane)
                    && chunked.dist(lane) == no_lrb.dist(lane)
            });
            let (sm, cm, nm) = (scalar.metrics(), chunked.metrics(), no_lrb.metrics());
            let mut fields = vec![
                ("mode", Json::s(mode)),
                ("width", Json::u(width as u64)),
                ("nodes", Json::u(PROTOCOL_WIDE_NODES as u64)),
            ];
            if mode == "2d" {
                fields.push((
                    "grid",
                    Json::s(format!("{}x{}", PROTOCOL_WIDE_GRID.0, PROTOCOL_WIDE_GRID.1)),
                ));
            }
            if mode == "hier" {
                fields.push((
                    "islands",
                    Json::s(format!(
                        "{}x{}",
                        PROTOCOL_KERNEL_HIER_GRID.0, PROTOCOL_KERNEL_HIER_GRID.1
                    )),
                ));
            }
            fields.extend([
                ("direction", Json::s("bottomup")),
                ("lane_words", Json::u(cm.lane_words as u64)),
                ("levels", Json::u(cm.depth() as u64)),
                ("reached_pairs", Json::u(cm.reached_pairs)),
                ("edges_inspected", Json::u(cm.edges_examined())),
                ("distances_equal", Json::Bool(equal)),
                ("scalar", kernel_work_json(sm)),
                ("chunked", kernel_work_json(cm)),
                ("no_lrb", kernel_work_json(nm)),
            ]);
            entries.push(Json::obj(fields));
        }
    }
    Json::Arr(entries)
}

/// The fault-recovery section: the committed seeded
/// [`FaultPlan::generate`] schedule injected into the 16-node 1D
/// direction-optimized 64-root batch, next to the identical fault-free
/// run. [`check_engine_bench`]'s acceptance pass requires at least one
/// retry to fire, exact retry byte accounting, a strictly positive
/// priced recovery time, and — the headline invariant — bit-identical
/// distances to the fault-free run.
fn fault_recovery_json(g: &Csr) -> Json {
    let roots = sample_batch_roots(g, PROTOCOL_BATCH_WIDTH, PROTOCOL_ROOT_SEED);
    let cfg = EngineConfig {
        direction: DirectionMode::diropt(),
        ..EngineConfig::dgx2(PROTOCOL_FAULT_NODES, PROTOCOL_FANOUT)
    };
    let plan = TraversalPlan::build(g, cfg).expect("valid protocol plan");
    let free = plan.session().run_batch(&roots).expect("protocol roots in range");
    let fplan = FaultPlan::generate(
        PROTOCOL_FAULT_SEED,
        PROTOCOL_FAULT_COUNT,
        PROTOCOL_FAULT_LEVELS,
        PROTOCOL_FAULT_ROUNDS,
        PROTOCOL_FAULT_NODES as u32,
    );
    let injector = Arc::new(FaultInjector::new(fplan.clone()));
    let mut session = plan.session();
    session.arm_faults(Some(Arc::clone(&injector)));
    let faulted = session.run_batch(&roots).expect("committed schedule is tolerated");
    let equal = (0..roots.len()).all(|lane| free.dist(lane) == faulted.dist(lane));
    let (fm, rm) = (free.metrics(), faulted.metrics());
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("nodes", Json::u(PROTOCOL_FAULT_NODES as u64)),
                ("fanout", Json::u(PROTOCOL_FANOUT as u64)),
                ("mode", Json::s("1d")),
                ("direction", Json::s("diropt")),
                ("width", Json::u(PROTOCOL_BATCH_WIDTH as u64)),
                ("seed", Json::u(PROTOCOL_ROOT_SEED)),
            ]),
        ),
        ("plan", fplan.to_json()),
        (
            "fault_free",
            Json::obj(vec![
                ("levels", Json::u(fm.depth() as u64)),
                ("bytes", Json::u(fm.bytes())),
                ("sim_seconds", Json::n(fm.sim_seconds())),
            ]),
        ),
        (
            "faulted",
            Json::obj(vec![
                ("injected", Json::u(fplan.faults.len() as u64)),
                ("matched", Json::u(injector.specs_matched() as u64)),
                ("retries", Json::u(rm.retries())),
                ("retry_bytes", Json::u(rm.retry_bytes())),
                ("recovery_time", Json::n(rm.recovery_time())),
                ("sim_seconds", Json::n(rm.sim_seconds_with_recovery())),
            ]),
        ),
        ("equal_distances", Json::Bool(equal)),
        ("overhead_ratio", Json::n(rm.sim_seconds_with_recovery() / fm.sim_seconds())),
    ])
}

/// Run the full protocol and build the report. Deterministic: fixed
/// graph seed, fixed roots, simulated clocks only (no wallclock fields).
pub fn engine_bench_report() -> Json {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == PROTOCOL_GRAPH)
        .expect("suite contains the protocol graph");
    let g = spec.generate_scaled(PROTOCOL_SCALE_DELTA);
    let roots = sample_batch_roots(&g, PROTOCOL_BATCH_WIDTH, PROTOCOL_ROOT_SEED);
    let mut configs = Vec::new();
    for &p in &PROTOCOL_NODE_COUNTS {
        let mut dirs: Vec<(&str, Json)> = Vec::new();
        for (name, direction) in direction_modes() {
            let cfg = EngineConfig {
                direction,
                ..EngineConfig::dgx2(p, PROTOCOL_FANOUT)
            };
            let mut session =
                TraversalPlan::build(&g, cfg).expect("valid protocol plan").session();
            let m = session
                .run_batch_metrics_only(&roots)
                .expect("protocol roots in range");
            dirs.push((name, direction_json(&m)));
        }
        configs.push(Json::obj(vec![
            ("nodes", Json::u(p as u64)),
            ("fanout", Json::u(PROTOCOL_FANOUT as u64)),
            ("mode", Json::s("1d")),
            ("directions", Json::obj(dirs)),
        ]));
    }
    Json::obj(vec![
        ("protocol", Json::s(PROTOCOL_NAME)),
        (
            "graph",
            Json::obj(vec![
                ("name", Json::s(PROTOCOL_GRAPH)),
                ("scale_delta", Json::n(PROTOCOL_SCALE_DELTA as f64)),
                ("vertices", Json::u(g.num_vertices() as u64)),
                ("edges", Json::u(g.num_edges())),
            ]),
        ),
        (
            "batch",
            Json::obj(vec![
                ("width", Json::u(PROTOCOL_BATCH_WIDTH as u64)),
                ("seed", Json::u(PROTOCOL_ROOT_SEED)),
            ]),
        ),
        ("configs", Json::Arr(configs)),
        ("width_ablation", width_ablation_json(&g)),
        ("serve_throughput", serve_throughput_json(&g)),
        ("storage", storage_json()),
        ("hierarchical", hierarchical_json(&g)),
        ("fault_recovery", fault_recovery_json(&g)),
        ("kernel_ablation", kernel_ablation_json(&g)),
    ])
}

/// The wallclock subtrees a committed artifact may carry. Wallclock
/// numbers are not reproducible, so they never participate in the
/// freshness compare — they are detached before comparing and
/// re-attached on regeneration.
#[derive(Default)]
struct MeasuredSubtrees {
    /// `serve_throughput.measured` (live-socket load-generator numbers).
    serve: Option<Json>,
    /// Top-level `kernel_ablation_measured` (wallclock kernel timings
    /// from `benches/batch_width.rs --update`).
    kernel: Option<Json>,
}

/// Detach every measured subtree from a report, returning them.
fn take_measured(report: &mut Json) -> MeasuredSubtrees {
    let mut out = MeasuredSubtrees::default();
    if let Json::Obj(top) = report {
        out.kernel = top.remove("kernel_ablation_measured");
        if let Some(Json::Obj(serve)) = top.get_mut("serve_throughput") {
            out.serve = serve.remove("measured");
        }
    }
    out
}

/// Re-attach measured subtrees to a report.
fn put_measured(report: &mut Json, measured: MeasuredSubtrees) {
    if let Json::Obj(top) = report {
        if let Some(kernel) = measured.kernel {
            top.insert("kernel_ablation_measured".to_string(), kernel);
        }
        if let Some(serve) = measured.serve {
            if let Some(Json::Obj(s)) = top.get_mut("serve_throughput") {
                s.insert("measured".to_string(), serve);
            }
        }
    }
}

/// Write (or overwrite) the artifact at `path`, preserving an existing
/// `serve_throughput.measured` subtree (the load-generator's recorded
/// wallclock numbers survive a protocol regeneration). Crash-consistent:
/// the artifact is replaced atomically via
/// [`atomic_write`](crate::util::fsio::atomic_write), so an interrupted
/// regeneration never leaves a torn report behind.
pub fn write_engine_bench(path: &Path) -> std::io::Result<()> {
    let mut report = engine_bench_report();
    if let Ok(old_text) = std::fs::read_to_string(path) {
        if let Ok(mut old) = Json::parse(&old_text) {
            put_measured(&mut report, take_measured(&mut old));
        }
    }
    let mut text = report.render();
    text.push('\n');
    crate::util::fsio::atomic_write(path, text.as_bytes())
}

/// Record the load generator's wallclock report into the committed
/// artifact's `serve_throughput.measured` subtree (used by
/// `benches/serve_throughput.rs --update`). Everything else in the
/// artifact is left byte-untouched apart from re-rendering.
pub fn update_measured_serve(path: &Path, measured: Json) -> Result<(), String> {
    update_measured(path, MeasuredSubtrees { serve: Some(measured), kernel: None })
}

/// Record wallclock kernel timings (from `benches/batch_width.rs
/// --update`) into the committed artifact's top-level
/// `kernel_ablation_measured` subtree. Like the serve subtree, it is
/// excluded from the freshness compare but still sanity-checked.
pub fn update_measured_kernel(path: &Path, measured: Json) -> Result<(), String> {
    update_measured(path, MeasuredSubtrees { serve: None, kernel: Some(measured) })
}

fn update_measured(path: &Path, measured: MeasuredSubtrees) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        format!("cannot read {}: {e} (run bench-protocol first)", path.display())
    })?;
    let mut report = Json::parse(&text)
        .map_err(|e| format!("{} is not valid JSON: {e}", path.display()))?;
    put_measured(&mut report, measured);
    let mut out = report.render();
    out.push('\n');
    std::fs::write(path, out).map_err(|e| format!("cannot write {}: {e}", path.display()))
}

/// Recompute the protocol and verify the committed artifact matches:
/// integer counters exactly, floats within relative tolerance 1e-6 —
/// then verify the direction-optimization acceptance invariants on the
/// fresh report itself. Any drift or invariant break is an `Err` with
/// the offending JSON path. A `serve_throughput.measured` subtree is
/// excluded from the compare (wallclock) but still invariant-checked.
pub fn check_engine_bench(path: &Path) -> Result<(), String> {
    let committed = std::fs::read_to_string(path).map_err(|e| {
        format!("cannot read {}: {e} (run bench-protocol to create it)", path.display())
    })?;
    let mut committed = Json::parse(&committed)
        .map_err(|e| format!("{} is not valid JSON: {e}", path.display()))?;
    let measured = take_measured(&mut committed);
    let fresh = engine_bench_report();
    compare("$", &committed, &fresh)
        .map_err(|e| format!("{} is stale: {e} (regenerate with bench-protocol)", path.display()))?;
    acceptance(&fresh)?;
    if let Some(m) = measured.serve {
        acceptance_measured(&m)?;
    }
    if let Some(m) = measured.kernel {
        acceptance_measured_kernel(&m)?;
    }
    Ok(())
}

/// Structural + numeric comparison (committed vs recomputed).
fn compare(path: &str, a: &Json, b: &Json) -> Result<(), String> {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => {
            let int_x = x.fract() == 0.0 && x.abs() < 9.0e15;
            let int_y = y.fract() == 0.0 && y.abs() < 9.0e15;
            if int_x && int_y {
                if x != y {
                    return Err(format!("{path}: {x} != {y}"));
                }
            } else {
                let scale = x.abs().max(y.abs());
                if (x - y).abs() > 1e-6 * scale && (x - y).abs() > 1e-12 {
                    return Err(format!("{path}: {x} !~ {y}"));
                }
            }
            Ok(())
        }
        (Json::Str(x), Json::Str(y)) => {
            if x == y {
                Ok(())
            } else {
                Err(format!("{path}: {x:?} != {y:?}"))
            }
        }
        (Json::Bool(x), Json::Bool(y)) => {
            if x == y {
                Ok(())
            } else {
                Err(format!("{path}: {x} != {y}"))
            }
        }
        (Json::Null, Json::Null) => Ok(()),
        (Json::Arr(xs), Json::Arr(ys)) => {
            if xs.len() != ys.len() {
                return Err(format!("{path}: array lengths {} vs {}", xs.len(), ys.len()));
            }
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                compare(&format!("{path}[{i}]"), x, y)?;
            }
            Ok(())
        }
        (Json::Obj(xm), Json::Obj(ym)) => {
            if xm.keys().ne(ym.keys()) {
                return Err(format!(
                    "{path}: key sets differ ({:?} vs {:?})",
                    xm.keys().collect::<Vec<_>>(),
                    ym.keys().collect::<Vec<_>>()
                ));
            }
            for (k, x) in xm {
                compare(&format!("{path}.{k}"), x, &ym[k])?;
            }
            Ok(())
        }
        _ => Err(format!("{path}: value kinds differ")),
    }
}

/// The acceptance invariants the committed trajectory must show: on the
/// dense-frontier RMAT configs, direction optimization switches bottom-up
/// and inspects measurably fewer edges than pure top-down — in total and
/// at the densest level.
fn acceptance(report: &Json) -> Result<(), String> {
    fn dir_of<'a>(c: &'a Json, nodes: u64, name: &str) -> Result<&'a Json, String> {
        c.get("directions")
            .and_then(|d| d.get(name))
            .ok_or_else(|| format!("p={nodes}: missing direction {name}"))
    }
    fn u64_field(d: &Json, key: &str) -> Result<u64, String> {
        d.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing {key}"))
    }
    fn per_level_of(d: &Json) -> Result<&[Json], String> {
        d.get("per_level")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing per_level".to_string())
    }
    let configs = report
        .get("configs")
        .and_then(Json::as_arr)
        .ok_or("missing configs")?;
    for c in configs {
        let nodes = u64_field(c, "nodes")?;
        let td = u64_field(dir_of(c, nodes, "topdown")?, "edges_inspected")?;
        let dopt = u64_field(dir_of(c, nodes, "diropt")?, "edges_inspected")?;
        if dopt >= td {
            return Err(format!(
                "p={nodes}: diropt inspected {dopt} edges, not fewer than top-down's {td}"
            ));
        }
        let bu_levels = u64_field(dir_of(c, nodes, "diropt")?, "bottom_up_levels")?;
        if bu_levels == 0 {
            return Err(format!("p={nodes}: diropt never switched bottom-up"));
        }
        // Densest level: bottom-up must beat top-down exactly where the
        // optimization claims to pay.
        let td_levels = per_level_of(dir_of(c, nodes, "topdown")?)?;
        let dense = td_levels
            .iter()
            .max_by_key(|l| l.get("frontier").and_then(Json::as_u64).unwrap_or(0))
            .ok_or("empty per_level")?;
        let dense_idx = u64_field(dense, "level")? as usize;
        let dopt_levels = per_level_of(dir_of(c, nodes, "diropt")?)?;
        let td_dense = u64_field(&td_levels[dense_idx], "edges")?;
        let dopt_dense = dopt_levels
            .get(dense_idx)
            .and_then(|l| l.get("edges"))
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("p={nodes}: diropt has no level {dense_idx}"))?;
        if dopt_dense >= td_dense {
            return Err(format!(
                "p={nodes} level {dense_idx}: diropt inspected {dopt_dense}, \
                 not fewer than top-down's {td_dense}"
            ));
        }
    }
    // Width-ablation invariants: at 256 lanes the wide batch must
    // strictly beat its own roots run as 4 × 64-root chunks on both sync
    // rounds and total exchange bytes, in both modes — and reach exactly
    // the same (root, vertex) pairs (a free correctness cross-check).
    let ablation = report
        .get("width_ablation")
        .and_then(Json::as_arr)
        .ok_or("missing width_ablation")?;
    for entry in ablation {
        let mode = entry
            .get("mode")
            .and_then(Json::as_str)
            .ok_or("width_ablation entry missing mode")?
            .to_string();
        let width = u64_field(entry, "width")?;
        let chunked = entry
            .get("chunked")
            .ok_or_else(|| format!("{mode} width {width}: missing chunked"))?;
        if u64_field(entry, "reached_pairs")? != u64_field(chunked, "reached_pairs")? {
            return Err(format!(
                "{mode} width {width}: wide and chunked reached different pair counts"
            ));
        }
        if width as usize <= PROTOCOL_CHUNK {
            continue; // a single chunk is the batch itself
        }
        let (wide_r, chunk_r) =
            (u64_field(entry, "sync_rounds")?, u64_field(chunked, "sync_rounds")?);
        if wide_r >= chunk_r {
            return Err(format!(
                "{mode} width {width}: {wide_r} sync rounds, not fewer than \
                 chunked's {chunk_r}"
            ));
        }
        let (wide_b, chunk_b) = (u64_field(entry, "bytes")?, u64_field(chunked, "bytes")?);
        if wide_b >= chunk_b {
            return Err(format!(
                "{mode} width {width}: {wide_b} exchange bytes, not fewer than \
                 chunked's {chunk_b}"
            ));
        }
    }
    // Serve-throughput invariants: at the committed load point the
    // coalesced service must strictly out-serve the uncoalesced one.
    let sim = report
        .get("serve_throughput")
        .and_then(|s| s.get("sim"))
        .ok_or("missing serve_throughput.sim")?;
    let base = sim.get("baseline").ok_or("missing serve_throughput.sim.baseline")?;
    let coal = sim.get("coalesced").ok_or("missing serve_throughput.sim.coalesced")?;
    fn f64_field(d: &Json, key: &str) -> Result<f64, String> {
        d.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing {key}"))
    }
    for (name, mode) in [("baseline", base), ("coalesced", coal)] {
        let offered = u64_field(mode, "offered")?;
        let completed = u64_field(mode, "completed")?;
        let rejected = u64_field(mode, "rejected")?;
        let timed_out = u64_field(mode, "timed_out")?;
        if completed + rejected + timed_out != offered {
            return Err(format!(
                "serve sim {name}: {completed} completed + {rejected} rejected + \
                 {timed_out} timed out != {offered} offered (requests went missing)"
            ));
        }
        if u64_field(mode, "p50_us")? > u64_field(mode, "p99_us")? {
            return Err(format!("serve sim {name}: p50 exceeds p99"));
        }
    }
    let (base_qps, coal_qps) = (f64_field(base, "qps")?, f64_field(coal, "qps")?);
    if coal_qps <= base_qps {
        return Err(format!(
            "serve sim: coalesced qps {coal_qps:.0} not strictly above baseline's \
             {base_qps:.0} — coalescing stopped paying at the committed load point"
        ));
    }
    if f64_field(base, "mean_width")? != 1.0 {
        return Err("serve sim baseline: mean batch width must be exactly 1".to_string());
    }
    if f64_field(coal, "mean_width")? <= 1.0 {
        return Err("serve sim coalesced: mean batch width must exceed 1".to_string());
    }
    if u64_field(base, "rejected")? == 0 {
        return Err(
            "serve sim baseline: expected rejections (the load point must overload \
             the uncoalesced service)"
                .to_string(),
        );
    }
    if u64_field(coal, "rejected")? != 0 {
        return Err("serve sim coalesced: must keep up with the load (no rejections)"
            .to_string());
    }
    if u64_field(coal, "p50_us")? >= u64_field(base, "p50_us")? {
        return Err("serve sim: coalesced p50 must beat the overloaded baseline's"
            .to_string());
    }
    // Storage invariants: the compression claim and the warm-start claim,
    // each pinned as a counter fact rather than prose.
    let storage = report.get("storage").ok_or("missing storage")?;
    let ratio = f64_field(storage, "compression_ratio")?;
    if ratio < 2.0 {
        return Err(format!(
            "storage: v2 compression ratio {ratio:.2} below the promised 2x"
        ));
    }
    let edges = u64_field(storage.get("graph").ok_or("storage: missing graph")?, "edges")?;
    let counters = storage.get("load_counters").ok_or("storage: missing load_counters")?;
    fn at<'a>(counters: &'a Json, path: &[&str]) -> Result<&'a Json, String> {
        let mut cur = counters;
        for key in path {
            cur = cur
                .get(key)
                .ok_or_else(|| format!("storage: missing load_counters.{}", path.join(".")))?;
        }
        Ok(cur)
    }
    if u64_field(at(counters, &["eager"])?, "edges")? != edges {
        return Err("storage: eager decode must touch every edge".to_string());
    }
    let cold_at_load = at(counters, &["cold_build", "at_load"])?;
    if u64_field(cold_at_load, "degree_entries")? == 0 {
        return Err("storage: cold build must run the degree-only pass".to_string());
    }
    if u64_field(cold_at_load, "edges")? != 0 {
        return Err(
            "storage: cold build decoded adjacency before materialize".to_string()
        );
    }
    let warm_at_load = at(counters, &["warm_start", "at_load"])?;
    if u64_field(warm_at_load, "degree_entries")? != 0
        || u64_field(warm_at_load, "edges")? != 0
    {
        return Err(
            "storage: warm start must decode nothing up front (that is the point)"
                .to_string(),
        );
    }
    if u64_field(at(counters, &["warm_start", "after_materialize"])?, "edges")? == 0 {
        return Err("storage: warm materialize never decoded adjacency".to_string());
    }
    let twod_at_load = at(counters, &["two_d_cold", "at_load"])?;
    if u64_field(twod_at_load, "edges")? != edges
        || u64_field(twod_at_load, "blocks")? != u64_field(at(counters, &["eager"])?, "blocks")?
    {
        return Err(
            "storage: 2d cold build must stream each block exactly once".to_string()
        );
    }
    for key in ["warm_equals_cold", "matches_in_memory"] {
        if storage.get(key).and_then(Json::as_bool) != Some(true) {
            return Err(format!("storage: {key} must be true"));
        }
    }
    // Hierarchical invariants: under the shared dgx2-cluster pricing the
    // grid-of-islands layout must strictly beat both flat layouts on the
    // simulated clock, move strictly fewer inter-island bytes than flat
    // 1D, and — the free correctness cross-check — reach exactly the
    // same (root, vertex) pairs as both.
    let hier = report.get("hierarchical").ok_or("missing hierarchical")?;
    let modes = hier.get("modes").ok_or("hierarchical: missing modes")?;
    let mode_of = |name: &str| -> Result<&Json, String> {
        modes.get(name).ok_or_else(|| format!("hierarchical: missing mode {name}"))
    };
    let (m1, m2, mh) = (mode_of("1d")?, mode_of("2d")?, mode_of("hier")?);
    let pairs = u64_field(mh, "reached_pairs")?;
    if u64_field(m1, "reached_pairs")? != pairs || u64_field(m2, "reached_pairs")? != pairs {
        return Err("hierarchical: modes reached different pair counts".to_string());
    }
    let (s1, s2, sh) = (
        f64_field(m1, "sim_seconds")?,
        f64_field(m2, "sim_seconds")?,
        f64_field(mh, "sim_seconds")?,
    );
    if sh >= s1 {
        return Err(format!(
            "hierarchical: sim {sh:.6}s not strictly below flat 1d's {s1:.6}s"
        ));
    }
    if sh >= s2 {
        return Err(format!(
            "hierarchical: sim {sh:.6}s not strictly below 2d's {s2:.6}s"
        ));
    }
    let (ib1, ibh) = (u64_field(m1, "inter_bytes")?, u64_field(mh, "inter_bytes")?);
    if ibh >= ib1 {
        return Err(format!(
            "hierarchical: {ibh} inter-island bytes, not fewer than flat 1d's {ib1}"
        ));
    }
    if u64_field(mh, "inter_messages")? == 0 || u64_field(mh, "intra_messages")? == 0 {
        return Err("hierarchical: hier mode must use both link classes".to_string());
    }
    // Fault-recovery invariants: the committed schedule must actually
    // exercise the detect → retry path (a schedule that never fires
    // proves nothing), the retry overhead must be priced into the
    // simulated clock, and recovery must not change a single distance.
    let fault = report.get("fault_recovery").ok_or("missing fault_recovery")?;
    if fault.get("equal_distances").and_then(Json::as_bool) != Some(true) {
        return Err(
            "fault_recovery: distances under injection must be bit-identical to the \
             fault-free run"
                .to_string(),
        );
    }
    let faulted = fault.get("faulted").ok_or("fault_recovery: missing faulted")?;
    if u64_field(faulted, "matched")? == 0 {
        return Err(
            "fault_recovery: no committed fault matched a live transfer (dead schedule)"
                .to_string(),
        );
    }
    if u64_field(faulted, "retries")? == 0 || u64_field(faulted, "retry_bytes")? == 0 {
        return Err(
            "fault_recovery: committed schedule never forced a retransmission".to_string()
        );
    }
    if f64_field(faulted, "recovery_time")? <= 0.0 {
        return Err("fault_recovery: recovery time must be strictly positive".to_string());
    }
    let ratio = f64_field(fault, "overhead_ratio")?;
    if ratio <= 1.0 {
        return Err(format!(
            "fault_recovery: overhead ratio {ratio:.6} not above 1 — recovery priced \
             as free"
        ));
    }
    // Kernel-ablation invariants: every variant must agree bit-for-bit on
    // distances; the chunked kernel must provably read fewer mask words
    // than the scalar one (in total and on the sparse tail level, where
    // the settled-skip pays hardest); and LRB degree-binning must
    // strictly shrink the largest single dispatch on this hub-heavy RMAT.
    let kernel = report
        .get("kernel_ablation")
        .and_then(Json::as_arr)
        .ok_or("missing kernel_ablation")?;
    if kernel.is_empty() {
        return Err("kernel_ablation: no entries".to_string());
    }
    for entry in kernel {
        let mode = entry
            .get("mode")
            .and_then(Json::as_str)
            .ok_or("kernel_ablation entry missing mode")?
            .to_string();
        let width = u64_field(entry, "width")?;
        let tag = format!("kernel ablation {mode} width {width}");
        if entry.get("distances_equal").and_then(Json::as_bool) != Some(true) {
            return Err(format!("{tag}: kernel variants disagree on distances"));
        }
        let sub = |name: &str| -> Result<&Json, String> {
            entry.get(name).ok_or_else(|| format!("{tag}: missing {name}"))
        };
        let (scalar, chunked, no_lrb) = (sub("scalar")?, sub("chunked")?, sub("no_lrb")?);
        let (sw, cw) =
            (u64_field(scalar, "words_touched")?, u64_field(chunked, "words_touched")?);
        if cw >= sw {
            return Err(format!(
                "{tag}: chunked touched {cw} mask words, not fewer than scalar's {sw}"
            ));
        }
        let (st, ct) =
            (u64_field(scalar, "tail_words")?, u64_field(chunked, "tail_words")?);
        if ct >= st {
            return Err(format!(
                "{tag}: chunked tail level touched {ct} words, not fewer than \
                 scalar's {st}"
            ));
        }
        if u64_field(scalar, "words_skipped")? != 0 {
            return Err(format!("{tag}: scalar kernel claims skipped words"));
        }
        if u64_field(chunked, "words_skipped")? == 0 {
            return Err(format!("{tag}: chunked kernel never skipped a word"));
        }
        let (lrb_max, flat_max) = (
            u64_field(chunked, "dispatch_max_work")?,
            u64_field(no_lrb, "dispatch_max_work")?,
        );
        if lrb_max >= flat_max {
            return Err(format!(
                "{tag}: LRB max dispatch work {lrb_max} not below the unbinned \
                 probe's {flat_max}"
            ));
        }
    }
    Ok(())
}

/// Invariants of the optional `serve_throughput.measured` subtree (live
/// wallclock numbers from the load generator). Wallclock is noisy, so
/// these are sanity checks — the fields CI's smoke asserts on must exist
/// and be internally consistent — not perf gates.
fn acceptance_measured(measured: &Json) -> Result<(), String> {
    for mode in ["baseline", "coalesced"] {
        let m = measured
            .get(mode)
            .ok_or_else(|| format!("serve measured: missing {mode}"))?;
        for key in ["completed", "p50_us", "p99_us", "qps", "mean_batch_width"] {
            m.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("serve measured {mode}: missing {key}"))?;
        }
        let completed = m.get("completed").and_then(Json::as_u64).unwrap_or(0);
        if completed == 0 {
            return Err(format!("serve measured {mode}: no completed requests"));
        }
        let p50 = m.get("p50_us").and_then(Json::as_u64).unwrap_or(0);
        let p99 = m.get("p99_us").and_then(Json::as_u64).unwrap_or(0);
        if p50 > p99 {
            return Err(format!("serve measured {mode}: p50 exceeds p99"));
        }
        if m.get("qps").and_then(Json::as_f64).unwrap_or(0.0) <= 0.0 {
            return Err(format!("serve measured {mode}: non-positive qps"));
        }
    }
    let width = measured
        .get("coalesced")
        .and_then(|m| m.get("mean_batch_width"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    if width < 1.0 {
        return Err("serve measured coalesced: mean batch width below 1".to_string());
    }
    Ok(())
}

/// Invariants of the optional top-level `kernel_ablation_measured`
/// subtree (wallclock kernel timings from `benches/batch_width.rs
/// --update`). Wallclock is noisy, so only shape and positivity are
/// checked — the deterministic counter gates live in [`acceptance`].
fn acceptance_measured_kernel(measured: &Json) -> Result<(), String> {
    let entries = measured
        .as_arr()
        .ok_or("kernel measured: must be an array of timing entries")?;
    if entries.is_empty() {
        return Err("kernel measured: no entries".to_string());
    }
    for (i, e) in entries.iter().enumerate() {
        for key in ["mode", "kernel"] {
            e.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("kernel measured[{i}]: missing {key}"))?;
        }
        e.get("width")
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("kernel measured[{i}]: missing width"))?;
        let w = e
            .get("wall_seconds")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("kernel measured[{i}]: missing wall_seconds"))?;
        if w <= 0.0 {
            return Err(format!("kernel measured[{i}]: non-positive wall_seconds"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_is_deterministic_self_consistent_and_accepted() {
        let a = engine_bench_report();
        let b = engine_bench_report();
        assert_eq!(a.render(), b.render(), "protocol must be deterministic");
        compare("$", &a, &b).unwrap();
        // The acceptance invariants are properties of the engine, not of
        // the committed file — they must hold on any fresh report.
        acceptance(&a).unwrap();
        // Schema spot checks.
        assert_eq!(a.get("protocol").unwrap().as_str(), Some(PROTOCOL_NAME));
        let configs = a.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(configs.len(), PROTOCOL_NODE_COUNTS.len());
        for c in configs {
            for d in ["topdown", "bottomup", "diropt"] {
                assert!(c.get("directions").unwrap().get(d).is_some(), "{d}");
            }
        }
        let ablation = a.get("width_ablation").unwrap().as_arr().unwrap();
        assert_eq!(ablation.len(), 2 * PROTOCOL_WIDE_WIDTHS.len());
        for entry in ablation {
            assert!(entry.get("chunked").is_some());
            let words = entry.get("lane_words").and_then(Json::as_u64).unwrap();
            let width = entry.get("width").and_then(Json::as_u64).unwrap();
            assert_eq!(words, width.div_ceil(64).next_power_of_two());
        }
        // Serve-sim schema: all latencies are integer µs (the freshness
        // compare is exact on them), and the accounting closes.
        let sim = a.get("serve_throughput").unwrap().get("sim").unwrap();
        for mode in ["baseline", "coalesced"] {
            let m = sim.get(mode).unwrap();
            for key in ["p50_us", "p99_us", "offered", "completed", "rejected"] {
                assert!(m.get(key).and_then(Json::as_u64).is_some(), "{mode}.{key}");
            }
            assert_eq!(
                m.get("offered").unwrap().as_u64().unwrap(),
                PROTOCOL_SERVE_REQUESTS as u64
            );
        }
        // Storage schema: integer byte counts, a 16-hex fingerprint, and
        // counter snapshots for all three load paths.
        let storage = a.get("storage").unwrap();
        for key in ["v1_bytes", "v2_bytes", "v2_relabeled_bytes", "block_size"] {
            assert!(storage.get(key).and_then(Json::as_u64).is_some(), "{key}");
        }
        let fp = storage.get("fingerprint").unwrap().as_str().unwrap();
        assert_eq!(fp.len(), 16, "fingerprint must be 16 hex digits: {fp:?}");
        assert!(fp.bytes().all(|b| b.is_ascii_hexdigit()), "{fp:?}");
        let counters = storage.get("load_counters").unwrap();
        for path in [
            vec!["eager"],
            vec!["cold_build", "at_load"],
            vec!["cold_build", "after_materialize"],
            vec!["warm_start", "at_load"],
            vec!["warm_start", "after_materialize"],
            vec!["two_d_cold", "at_load"],
        ] {
            let mut cur = counters;
            for key in &path {
                cur = cur.get(key).unwrap_or_else(|| panic!("{path:?}"));
            }
            for key in ["degree_entries", "edges", "blocks"] {
                assert!(cur.get(key).and_then(Json::as_u64).is_some(), "{path:?}.{key}");
            }
        }
        // Hierarchical schema: all three modes with per-class splits
        // that tile the totals, plus the static schedule subtree.
        let hier = a.get("hierarchical").unwrap();
        assert_eq!(hier.get("islands").unwrap().as_str(), Some("8x8"));
        for mode in ["1d", "2d", "hier"] {
            let m = hier.get("modes").unwrap().get(mode).unwrap();
            let get = |k: &str| m.get(k).and_then(Json::as_u64).unwrap();
            assert_eq!(get("messages"), get("intra_messages") + get("inter_messages"), "{mode}");
            assert_eq!(get("bytes"), get("intra_bytes") + get("inter_bytes"), "{mode}");
        }
        for sched in ["flat_1d", "hier"] {
            let s = hier.get("static_schedule").unwrap().get(sched).unwrap();
            let get = |k: &str| s.get(k).and_then(Json::as_u64).unwrap();
            assert_eq!(get("messages"), get("intra_messages") + get("inter_messages"), "{sched}");
        }
        // Kernel-ablation schema: full mode × width grid, per-variant
        // counter subtrees with all five committed counters.
        let kernel = a.get("kernel_ablation").unwrap().as_arr().unwrap();
        assert_eq!(kernel.len(), 3 * PROTOCOL_KERNEL_WIDTHS.len());
        for entry in kernel {
            for variant in ["scalar", "chunked", "no_lrb"] {
                let v = entry.get(variant).unwrap();
                for key in [
                    "words_touched",
                    "words_skipped",
                    "dispatches",
                    "dispatch_max_work",
                    "tail_words",
                ] {
                    assert!(v.get(key).and_then(Json::as_u64).is_some(), "{variant}.{key}");
                }
            }
            let words = entry.get("lane_words").and_then(Json::as_u64).unwrap();
            let width = entry.get("width").and_then(Json::as_u64).unwrap();
            assert_eq!(words, width.div_ceil(64).next_power_of_two());
        }
        // Relabeling stores a 4-bytes/vertex permutation (plus alignment
        // padding); the gap encoding must not degrade beyond that.
        let v2 = storage.get("v2_bytes").unwrap().as_u64().unwrap();
        let v2r = storage.get("v2_relabeled_bytes").unwrap().as_u64().unwrap();
        let n = storage.get("graph").unwrap().get("vertices").unwrap().as_u64().unwrap();
        assert!(v2r <= v2 + 4 * n + 4096, "relabeled {v2r} vs plain {v2}");
    }

    #[test]
    fn write_then_check_roundtrip() {
        let dir = std::env::temp_dir().join("bbfs_protocol_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_engine.json");
        write_engine_bench(&path).unwrap();
        check_engine_bench(&path).unwrap();
        // A perturbed integer is caught.
        let text = std::fs::read_to_string(&path).unwrap();
        let broken = text.replacen("\"sync_rounds\":", "\"sync_rounds\":1", 1);
        std::fs::write(&path, broken).unwrap();
        assert!(check_engine_bench(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn measured_subtree_is_preserved_excluded_from_compare_and_checked() {
        let dir = std::env::temp_dir().join("bbfs_protocol_measured_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_engine.json");
        write_engine_bench(&path).unwrap();
        let mode = |p50: u64| {
            Json::obj(vec![
                ("completed", Json::u(100)),
                ("p50_us", Json::u(p50)),
                ("p99_us", Json::u(2_000)),
                ("qps", Json::n(1234.5)),
                ("mean_batch_width", Json::n(4.0)),
            ])
        };
        update_measured_serve(
            &path,
            Json::obj(vec![("baseline", mode(900)), ("coalesced", mode(300))]),
        )
        .unwrap();
        // Wallclock numbers are not in the recomputation, yet the check
        // passes: measured is stripped before the compare.
        check_engine_bench(&path).unwrap();
        // Wallclock kernel timings ride the same exclusion.
        update_measured_kernel(
            &path,
            Json::Arr(vec![Json::obj(vec![
                ("mode", Json::s("1d")),
                ("width", Json::u(256)),
                ("kernel", Json::s("chunked")),
                ("wall_seconds", Json::n(0.01)),
            ])]),
        )
        .unwrap();
        check_engine_bench(&path).unwrap();
        // Regenerating the artifact keeps both measured subtrees.
        write_engine_bench(&path).unwrap();
        let kept = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(
            kept.get("serve_throughput").unwrap().get("measured").is_some(),
            "write_engine_bench must preserve measured"
        );
        assert!(
            kept.get("kernel_ablation_measured").is_some(),
            "write_engine_bench must preserve kernel_ablation_measured"
        );
        // But a malformed measured subtree still fails the check.
        update_measured_serve(&path, Json::obj(vec![("baseline", mode(900))])).unwrap();
        let err = check_engine_bench(&path).unwrap_err();
        assert!(err.contains("missing coalesced"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
