//! # ButterFly BFS
//!
//! A reproduction of *“ButterFly BFS — An Efficient Communication Pattern
//! for Multi Node Traversals”* (Oded Green, 2021) as a three-layer
//! Rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: graph ETL + partitioning,
//!   simulated multi-device compute nodes, the butterfly frontier
//!   synchronization network with configurable fanout, single-node BFS
//!   baselines (top-down / bottom-up / direction-optimizing), an
//!   interconnect simulator with DGX-2/NVSwitch presets, and the
//!   benchmarking harness reproducing the paper's Table 1 and Figs 1–3.
//! * **L2/L1 (build-time Python)** — the BLAS-formulation BFS level step
//!   (`python/compile/model.py`) with a Pallas frontier-expansion kernel,
//!   AOT-lowered to HLO text artifacts that `runtime::` loads and executes
//!   via the PJRT CPU client. Python never runs on the traversal path.
//!
//! Start with [`coordinator::engine::ButterflyBfs`] or the
//! `examples/quickstart.rs` example.

pub mod bfs;
pub mod comm;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod net;
pub mod partition;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod util;
