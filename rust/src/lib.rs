//! # ButterFly BFS
//!
//! A reproduction of *“ButterFly BFS — An Efficient Communication Pattern
//! for Multi Node Traversals”* (Oded Green, 2021) as a three-layer
//! Rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: graph ETL + partitioning (1D
//!   row slabs and the 2D checkerboard), simulated multi-device compute
//!   nodes, a multi-pattern synchronization engine (butterfly with
//!   configurable fanout, all-to-all baselines, and the 2D fold/expand
//!   exchange), single-node BFS baselines (top-down / bottom-up /
//!   direction-optimizing), an interconnect simulator with DGX-2/NVSwitch
//!   presets, and the benchmarking harness reproducing the paper's
//!   Table 1 and Figs 1–3.
//! * **L2/L1 (build-time Python)** — the BLAS-formulation BFS level step
//!   (`python/compile/model.py`) with a Pallas frontier-expansion kernel,
//!   AOT-lowered to HLO text artifacts that `runtime::` loads and executes
//!   via the PJRT CPU client. Python never runs on the traversal path.
//!
//! The engine API is split into a **build-once** immutable
//! [`coordinator::TraversalPlan`] (partition + slabs + schedule, shareable
//! across threads via `Arc`) and **per-query** [`coordinator::QuerySession`]s
//! whose `run`/`run_batch` return typed results and errors. Start with
//! [`coordinator::TraversalPlan::build`] or the `examples/quickstart.rs`
//! example.

// CI runs `cargo clippy --all-targets -- -D warnings`. Two style lints are
// deliberate idioms here rather than defects: a few Phase-2 snapshot loops
// index frozen prefixes, and the per-level metrics constructors mirror the
// paper's per-level tuple of quantities.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod bfs;
pub mod comm;
pub mod coordinator;
pub mod fault;
pub mod graph;
pub mod harness;
pub mod net;
pub mod partition;
pub mod serve;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod util;
