//! Deterministic fault injection and the recovery machinery it exercises.
//!
//! The ButterFly engine simulates a multi-node fabric; this module makes
//! that fabric *adversarial* — deterministically, from a seed — and makes
//! the rest of the stack survive it:
//!
//! * [`plan`] — the seeded [`FaultPlan`] (drop / corrupt / delay / kill,
//!   addressable by level, round, src, dst), the [`FaultInjector`] that
//!   fires it at the Phase-2 exchange seam, and the typed
//!   [`ExchangeError`] detection classes. Retry pricing flows through
//!   [`crate::net::sim::retransmit_time`] into the per-level `retries` /
//!   `retry_bytes` / `recovery_time` counters on
//!   [`crate::coordinator::LevelMetrics`].
//! * [`checksum`] — the FNV-1a hash that lets corruption be *detected*
//!   rather than silently merged.
//! * [`wire`] — concrete framed byte encodings for the four negotiated
//!   `MaskDelta` arms, checksum trailer included, with hardened typed
//!   decode paths.
//! * [`recovery`] — level-boundary [`Checkpoint`]s and the
//!   [`FaultTolerantRunner`] that re-plans onto surviving ranks when a
//!   rank dies and replays only the lost level.
//!
//! The headline invariant (CI-checked in `tests/fault_equivalence.rs`):
//! under any injected `FaultPlan` that recovery tolerates, distances are
//! bit-identical to the fault-free run — tolerated faults only ever cost
//! time and bytes, never answers.

pub mod checksum;
pub mod plan;
pub mod recovery;
pub mod wire;

pub use checksum::fnv1a64;
pub use plan::{
    ExchangeError, FaultFailure, FaultInjector, FaultKind, FaultPlan, FaultSpec, LevelRecovery,
};
pub use recovery::{degrade_config, Checkpoint, FaultTolerantRunner};
pub use wire::{WireArm, WireDelta, WireError};
