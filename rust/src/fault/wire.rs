//! Framed `MaskDelta` wire codec with an FNV-1a trailer checksum.
//!
//! The engine *simulates* its interconnect, so Phase-2 transfers normally
//! exist only as priced byte counts (the negotiated
//! [`mask_delta_bytes`](crate::bfs::msbfs::mask_delta_bytes) arms). This
//! module pins down the concrete byte format those prices describe — one
//! frame per transfer, carrying one of the four negotiated serializations
//! of a `(vertex, lane-mask)` delta — so that the fault model's `Corrupt`
//! class is a real, detectable event: every frame ends in a 64-bit FNV-1a
//! checksum ([`super::checksum::fnv1a64`]) over everything before it, and
//! [`WireDelta::decode`] verifies it before trusting a single field.
//!
//! Decoding is hardened the same way the PR-7 snapshot corpus demanded of
//! `.bbfs` files: every length is validated against the actual buffer
//! *before* any allocation, counts are cross-checked against the payload
//! they claim to describe, and every failure class is a typed
//! [`WireError`] — truncation, bit flips, oversized counts, hostile lane
//! or vertex indices — never a panic or an unbounded `Vec::with_capacity`.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! magic[2] arm[1] lane_words[1] num_vertices[4] count[8]  <payload>  fnv1a64[8]
//! ```
//!
//! `count` is the number of `(vertex, mask)` entries the payload encodes;
//! the four payload arms mirror the four negotiated pricing arms (sparse
//! entries, grouped-by-mask, per-word presence bitmaps, per-lane bitmaps).

use super::checksum::fnv1a64;
use crate::bfs::msbfs::MAX_LANE_WORDS;

/// Frame magic ("BF" for butterfly, 0x5B frame version 1).
pub const WIRE_MAGIC: [u8; 2] = [0xBF, 0x5B];

/// Frame header bytes before the payload.
pub const HEADER_BYTES: usize = 16;

/// Trailer (checksum) bytes after the payload.
pub const TRAILER_BYTES: usize = 8;

/// Which serialization the payload uses — one per negotiated pricing arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireArm {
    /// Sparse `(vertex, word-sparse mask)` entries.
    Sparse,
    /// Distinct masks, each followed by its member vertex list.
    Grouped,
    /// Per-word presence bitmap + packed nonzero mask words (the dense
    /// bottom-up form).
    Presence,
    /// Per-active-lane vertex bitmaps.
    LaneBitmaps,
}

impl WireArm {
    /// All arms, for corpus sweeps.
    pub const ALL: [WireArm; 4] =
        [WireArm::Sparse, WireArm::Grouped, WireArm::Presence, WireArm::LaneBitmaps];

    fn tag(self) -> u8 {
        match self {
            WireArm::Sparse => 0,
            WireArm::Grouped => 1,
            WireArm::Presence => 2,
            WireArm::LaneBitmaps => 3,
        }
    }

    fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => WireArm::Sparse,
            1 => WireArm::Grouped,
            2 => WireArm::Presence,
            3 => WireArm::LaneBitmaps,
            _ => return None,
        })
    }
}

/// Typed decode failure. Every hostile input lands in exactly one of
/// these; decoding never panics and never allocates from untrusted sizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ends before a required field.
    Truncated {
        /// Bytes the next field needed.
        need: usize,
        /// Bytes actually remaining.
        have: usize,
    },
    /// The first two bytes are not [`WIRE_MAGIC`].
    BadMagic {
        /// What was found instead.
        found: [u8; 2],
    },
    /// Unknown arm tag.
    BadArm {
        /// The unrecognized tag byte.
        found: u8,
    },
    /// `lane_words` outside `1..=8`.
    BadLaneWords {
        /// The rejected width.
        found: u8,
    },
    /// The FNV-1a trailer does not match the frame body — a bit flip
    /// anywhere in the frame (the fault model's `Corrupt` detection).
    ChecksumMismatch {
        /// Checksum recomputed over the body.
        expected: u64,
        /// Checksum carried in the trailer.
        found: u64,
    },
    /// A declared count could not possibly fit the remaining payload.
    CountOverflow {
        /// The declared count.
        declared: u64,
        /// Maximum the remaining bytes could hold.
        limit: u64,
    },
    /// The payload decoded to a different number of entries than the
    /// header declared.
    CountMismatch {
        /// Header entry count.
        declared: u64,
        /// Entries actually decoded.
        actual: u64,
    },
    /// A vertex id at or beyond `num_vertices`.
    VertexOutOfRange {
        /// The offending id.
        vertex: u32,
        /// The frame's vertex-space size.
        num_vertices: u32,
    },
    /// A lane index at or beyond `64·lane_words`.
    LaneOutOfRange {
        /// The offending lane.
        lane: u16,
        /// Lanes this frame's width provisions.
        lanes: u16,
    },
    /// A word-presence byte names words at or beyond `lane_words`.
    WordIndexOutOfRange {
        /// The presence byte.
        bits: u8,
        /// Words this frame's width provisions.
        lane_words: u8,
    },
    /// An entry or group carried an all-zero mask (non-canonical).
    EmptyMask {
        /// The entry's vertex (or first member for a group).
        vertex: u32,
    },
    /// A group declared zero members.
    EmptyGroup,
    /// Well-formed payload followed by extra bytes.
    TrailingBytes {
        /// How many bytes were left over.
        extra: usize,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::BadMagic { found } => {
                write!(f, "bad frame magic {found:02x?}")
            }
            WireError::BadArm { found } => write!(f, "unknown arm tag {found}"),
            WireError::BadLaneWords { found } => {
                write!(f, "lane_words {found} outside 1..=8")
            }
            WireError::ChecksumMismatch { expected, found } => write!(
                f,
                "checksum mismatch: body hashes to {expected:#018x}, trailer says {found:#018x}"
            ),
            WireError::CountOverflow { declared, limit } => {
                write!(f, "declared count {declared} exceeds payload capacity {limit}")
            }
            WireError::CountMismatch { declared, actual } => {
                write!(f, "header declared {declared} entries, payload holds {actual}")
            }
            WireError::VertexOutOfRange { vertex, num_vertices } => {
                write!(f, "vertex {vertex} out of range (num_vertices {num_vertices})")
            }
            WireError::LaneOutOfRange { lane, lanes } => {
                write!(f, "lane {lane} out of range ({lanes} lanes)")
            }
            WireError::WordIndexOutOfRange { bits, lane_words } => {
                write!(f, "presence byte {bits:#010b} names words >= lane_words {lane_words}")
            }
            WireError::EmptyMask { vertex } => {
                write!(f, "entry for vertex {vertex} carries an all-zero mask")
            }
            WireError::EmptyGroup => write!(f, "group with zero members"),
            WireError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after payload")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// A decoded (or to-be-encoded) transfer delta: `(vertex, mask)` entries
/// at a runtime lane width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireDelta {
    /// Vertex-space size entries are validated against.
    pub num_vertices: u32,
    /// Mask words per entry (1..=8).
    pub lane_words: u8,
    /// `(vertex, mask words)` pairs; every mask has `lane_words` words and
    /// at least one nonzero word, vertices strictly ascending.
    pub entries: Vec<(u32, Vec<u64>)>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(WireError::Truncated { need: n, have });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Presence byte of a mask's nonzero words.
fn presence_byte(mask: &[u64]) -> u8 {
    let mut p = 0u8;
    for (w, &m) in mask.iter().enumerate() {
        if m != 0 {
            p |= 1 << w;
        }
    }
    p
}

fn encode_mask(out: &mut Vec<u8>, mask: &[u64], lane_words: usize) {
    if lane_words == 1 {
        push_u64(out, mask[0]);
    } else {
        let p = presence_byte(mask);
        out.push(p);
        for &m in mask {
            if m != 0 {
                push_u64(out, m);
            }
        }
    }
}

fn decode_mask(c: &mut Cursor<'_>, lane_words: usize) -> Result<Vec<u64>, WireError> {
    if lane_words == 1 {
        return Ok(vec![c.u64()?]);
    }
    let p = c.u8()?;
    if p == 0 {
        // Caller maps this to EmptyMask with the right vertex attached.
        return Ok(vec![0; lane_words]);
    }
    if usize::from(8 - p.leading_zeros() as u8) > lane_words {
        return Err(WireError::WordIndexOutOfRange { bits: p, lane_words: lane_words as u8 });
    }
    let mut mask = vec![0u64; lane_words];
    for (w, slot) in mask.iter_mut().enumerate() {
        if p & (1 << w) != 0 {
            *slot = c.u64()?;
        }
    }
    Ok(mask)
}

impl WireDelta {
    /// Vertices of the presence bitmap covering this delta's vertex space,
    /// in bytes.
    fn presence_bitmap_bytes(&self) -> usize {
        (self.num_vertices as usize).div_ceil(64) * 8
    }

    /// Encode as one framed transfer using `arm`, with the FNV-1a trailer.
    pub fn encode(&self, arm: WireArm) -> Vec<u8> {
        debug_assert!((1..=MAX_LANE_WORDS).contains(&usize::from(self.lane_words)));
        let w = usize::from(self.lane_words);
        let mut out = Vec::with_capacity(HEADER_BYTES + TRAILER_BYTES + 16 * self.entries.len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.push(arm.tag());
        out.push(self.lane_words);
        push_u32(&mut out, self.num_vertices);
        push_u64(&mut out, self.entries.len() as u64);
        match arm {
            WireArm::Sparse => {
                for (v, mask) in &self.entries {
                    push_u32(&mut out, *v);
                    encode_mask(&mut out, mask, w);
                }
            }
            WireArm::Grouped => {
                // Group consecutive entries sharing a mask (the encoder's
                // job is validity, not optimality).
                let mut groups: Vec<(&Vec<u64>, Vec<u32>)> = Vec::new();
                for (v, mask) in &self.entries {
                    match groups.last_mut() {
                        Some((m, members)) if *m == mask => members.push(*v),
                        _ => groups.push((mask, vec![*v])),
                    }
                }
                push_u32(&mut out, groups.len() as u32);
                for (mask, members) in &groups {
                    encode_mask(&mut out, mask, w);
                    push_u32(&mut out, members.len() as u32);
                    for &v in members {
                        push_u32(&mut out, v);
                    }
                }
            }
            WireArm::Presence => {
                let pb = self.presence_bitmap_bytes();
                let mut active = 0u8;
                for (_, mask) in &self.entries {
                    active |= presence_byte(mask);
                }
                out.push(active);
                for word in 0..w {
                    if active & (1 << word) == 0 {
                        continue;
                    }
                    let mut bitmap = vec![0u8; pb];
                    for (v, mask) in &self.entries {
                        if mask[word] != 0 {
                            bitmap[*v as usize / 8] |= 1 << (*v % 8);
                        }
                    }
                    out.extend_from_slice(&bitmap);
                    for (_, mask) in &self.entries {
                        if mask[word] != 0 {
                            push_u64(&mut out, mask[word]);
                        }
                    }
                }
            }
            WireArm::LaneBitmaps => {
                let pb = self.presence_bitmap_bytes();
                let lanes = 64 * w;
                let mut active: Vec<u16> = Vec::new();
                for lane in 0..lanes {
                    if self.entries.iter().any(|(_, m)| m[lane / 64] >> (lane % 64) & 1 == 1) {
                        active.push(lane as u16);
                    }
                }
                push_u16(&mut out, active.len() as u16);
                for &lane in &active {
                    push_u16(&mut out, lane);
                    let mut bitmap = vec![0u8; pb];
                    for (v, mask) in &self.entries {
                        if mask[usize::from(lane) / 64] >> (usize::from(lane) % 64) & 1 == 1 {
                            bitmap[*v as usize / 8] |= 1 << (*v % 8);
                        }
                    }
                    out.extend_from_slice(&bitmap);
                }
            }
        }
        let sum = fnv1a64(&out);
        push_u64(&mut out, sum);
        out
    }

    /// Decode and fully validate one framed transfer.
    ///
    /// Validation order: frame length → magic → **checksum** (so any bit
    /// flip, including in the header, is classed as corruption first) →
    /// header fields → arm payload with per-field bounds checks → exact
    /// length and count agreement.
    pub fn decode(bytes: &[u8]) -> Result<Self, WireError> {
        if bytes.len() < HEADER_BYTES + TRAILER_BYTES {
            return Err(WireError::Truncated {
                need: HEADER_BYTES + TRAILER_BYTES,
                have: bytes.len(),
            });
        }
        let body = &bytes[..bytes.len() - TRAILER_BYTES];
        if body[0..2] != WIRE_MAGIC {
            return Err(WireError::BadMagic { found: [body[0], body[1]] });
        }
        let trailer =
            u64::from_le_bytes(bytes[bytes.len() - TRAILER_BYTES..].try_into().expect("len 8"));
        let expected = fnv1a64(body);
        if trailer != expected {
            return Err(WireError::ChecksumMismatch { expected, found: trailer });
        }
        let mut c = Cursor { buf: body, pos: 2 };
        let arm = {
            let tag = c.u8()?;
            WireArm::from_tag(tag).ok_or(WireError::BadArm { found: tag })?
        };
        let lane_words = c.u8()?;
        if !(1..=MAX_LANE_WORDS as u8).contains(&lane_words) {
            return Err(WireError::BadLaneWords { found: lane_words });
        }
        let w = usize::from(lane_words);
        let num_vertices = c.u32()?;
        let count = c.u64()?;
        let mut entries: Vec<(u32, Vec<u64>)> = Vec::new();
        let check_vertex = |v: u32| -> Result<(), WireError> {
            if v >= num_vertices {
                return Err(WireError::VertexOutOfRange { vertex: v, num_vertices });
            }
            Ok(())
        };
        match arm {
            WireArm::Sparse => {
                let min_entry = if w == 1 { 12 } else { 5 };
                let limit = (c.remaining() / min_entry) as u64;
                if count > limit {
                    return Err(WireError::CountOverflow { declared: count, limit });
                }
                entries.reserve(count as usize);
                for _ in 0..count {
                    let v = c.u32()?;
                    check_vertex(v)?;
                    let mask = decode_mask(&mut c, w)?;
                    if mask.iter().all(|&m| m == 0) {
                        return Err(WireError::EmptyMask { vertex: v });
                    }
                    entries.push((v, mask));
                }
            }
            WireArm::Grouped => {
                let limit = (c.remaining() / 4) as u64;
                if count > limit {
                    return Err(WireError::CountOverflow { declared: count, limit });
                }
                let groups = c.u32()?;
                let min_group = if w == 1 { 16 } else { 17 };
                let glimit = (c.remaining() / min_group) as u32;
                if groups > glimit {
                    return Err(WireError::CountOverflow {
                        declared: u64::from(groups),
                        limit: u64::from(glimit),
                    });
                }
                entries.reserve(count as usize);
                for _ in 0..groups {
                    let mask = decode_mask(&mut c, w)?;
                    let members = c.u32()?;
                    if members == 0 {
                        return Err(WireError::EmptyGroup);
                    }
                    let mlimit = (c.remaining() / 4) as u32;
                    if members > mlimit {
                        return Err(WireError::CountOverflow {
                            declared: u64::from(members),
                            limit: u64::from(mlimit),
                        });
                    }
                    for _ in 0..members {
                        let v = c.u32()?;
                        check_vertex(v)?;
                        if mask.iter().all(|&m| m == 0) {
                            return Err(WireError::EmptyMask { vertex: v });
                        }
                        entries.push((v, mask.clone()));
                    }
                }
            }
            WireArm::Presence => {
                let pb = (num_vertices as usize).div_ceil(64) * 8;
                let active = c.u8()?;
                if usize::from(8 - active.leading_zeros() as u8) > w {
                    return Err(WireError::WordIndexOutOfRange {
                        bits: active,
                        lane_words,
                    });
                }
                let mut map: std::collections::BTreeMap<u32, Vec<u64>> =
                    std::collections::BTreeMap::new();
                for word in 0..w {
                    if active & (1 << word) == 0 {
                        continue;
                    }
                    let bitmap = c.take(pb)?.to_vec();
                    for (byte_idx, &b) in bitmap.iter().enumerate() {
                        let mut bits = b;
                        while bits != 0 {
                            let bit = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            let v = (byte_idx * 8 + bit) as u32;
                            check_vertex(v)?;
                            let m = c.u64()?;
                            if m == 0 {
                                return Err(WireError::EmptyMask { vertex: v });
                            }
                            map.entry(v).or_insert_with(|| vec![0u64; w])[word] = m;
                        }
                    }
                }
                entries.extend(map);
            }
            WireArm::LaneBitmaps => {
                let pb = (num_vertices as usize).div_ceil(64) * 8;
                let lanes = (64 * w) as u16;
                let active = c.u16()?;
                if active > lanes {
                    return Err(WireError::LaneOutOfRange { lane: active, lanes });
                }
                let mut map: std::collections::BTreeMap<u32, Vec<u64>> =
                    std::collections::BTreeMap::new();
                for _ in 0..active {
                    let lane = c.u16()?;
                    if lane >= lanes {
                        return Err(WireError::LaneOutOfRange { lane, lanes });
                    }
                    let bitmap = c.take(pb)?.to_vec();
                    for (byte_idx, &b) in bitmap.iter().enumerate() {
                        let mut bits = b;
                        while bits != 0 {
                            let bit = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            let v = (byte_idx * 8 + bit) as u32;
                            check_vertex(v)?;
                            map.entry(v).or_insert_with(|| vec![0u64; w])
                                [usize::from(lane) / 64] |= 1u64 << (usize::from(lane) % 64);
                        }
                    }
                }
                entries.extend(map);
            }
        }
        if c.remaining() != 0 {
            return Err(WireError::TrailingBytes { extra: c.remaining() });
        }
        if entries.len() as u64 != count {
            return Err(WireError::CountMismatch {
                declared: count,
                actual: entries.len() as u64,
            });
        }
        Ok(Self { num_vertices, lane_words, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256StarStar;

    fn random_delta(rng: &mut Xoshiro256StarStar, w: usize) -> WireDelta {
        let nv = 64 + rng.next_below(400) as u32;
        let n = rng.next_below(u64::from(nv).min(40)) as usize;
        let mut verts: Vec<u32> = (0..nv).collect();
        rng.shuffle(&mut verts);
        let mut picked: Vec<u32> = verts[..n].to_vec();
        picked.sort_unstable();
        let entries = picked
            .into_iter()
            .map(|v| {
                let mut mask = vec![0u64; w];
                loop {
                    for m in mask.iter_mut() {
                        *m = if rng.next_bool(0.5) { rng.next_u64() } else { 0 };
                    }
                    if mask.iter().any(|&m| m != 0) {
                        break;
                    }
                }
                (v, mask)
            })
            .collect();
        WireDelta { num_vertices: nv, lane_words: w as u8, entries }
    }

    #[test]
    fn roundtrip_all_arms_all_widths() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(99);
        for w in [1usize, 2, 4, 8] {
            for _ in 0..20 {
                let d = random_delta(&mut rng, w);
                for arm in WireArm::ALL {
                    let bytes = d.encode(arm);
                    let back = WireDelta::decode(&bytes)
                        .unwrap_or_else(|e| panic!("{arm:?} w={w}: {e}"));
                    assert_eq!(back, d, "{arm:?} w={w}");
                }
            }
        }
    }

    #[test]
    fn empty_delta_roundtrips() {
        let d = WireDelta { num_vertices: 100, lane_words: 2, entries: vec![] };
        for arm in WireArm::ALL {
            assert_eq!(WireDelta::decode(&d.encode(arm)).unwrap(), d);
        }
    }

    #[test]
    fn every_truncation_is_typed() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(5);
        let d = random_delta(&mut rng, 4);
        for arm in WireArm::ALL {
            let bytes = d.encode(arm);
            for cut in 0..bytes.len() {
                let err = WireDelta::decode(&bytes[..cut])
                    .expect_err(&format!("{arm:?} cut={cut} must fail"));
                assert!(
                    matches!(err, WireError::Truncated { .. } | WireError::ChecksumMismatch { .. }),
                    "{arm:?} cut={cut}: {err}"
                );
            }
        }
    }

    #[test]
    fn every_bit_flip_is_detected() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(6);
        let d = random_delta(&mut rng, 2);
        for arm in WireArm::ALL {
            let bytes = d.encode(arm);
            for i in 0..bytes.len() {
                let mut evil = bytes.clone();
                evil[i] ^= 1 << (i % 8);
                match WireDelta::decode(&evil) {
                    Ok(decoded) => panic!("{arm:?} byte {i}: flipped frame decoded {decoded:?}"),
                    Err(
                        WireError::ChecksumMismatch { .. }
                        | WireError::BadMagic { .. }
                        | WireError::Truncated { .. },
                    ) => {}
                    Err(other) => panic!("{arm:?} byte {i}: unexpected class {other}"),
                }
            }
        }
    }

    #[test]
    fn oversized_counts_rejected_without_allocation() {
        let d = WireDelta {
            num_vertices: 100,
            lane_words: 1,
            entries: vec![(3, vec![1]), (7, vec![2])],
        };
        for arm in WireArm::ALL {
            let mut bytes = d.encode(arm);
            // Overwrite the header count with an absurd value, re-seal the
            // checksum so only the count is hostile.
            bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
            let body_len = bytes.len() - TRAILER_BYTES;
            let sum = fnv1a64(&bytes[..body_len]);
            bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
            let err = WireDelta::decode(&bytes).expect_err("hostile count must fail");
            assert!(
                matches!(
                    err,
                    WireError::CountOverflow { .. }
                        | WireError::CountMismatch { .. }
                        | WireError::Truncated { .. }
                ),
                "{arm:?}: {err}"
            );
        }
    }

    #[test]
    fn hostile_fields_are_typed() {
        let reseal = |mut bytes: Vec<u8>| {
            let body_len = bytes.len() - TRAILER_BYTES;
            let sum = fnv1a64(&bytes[..body_len]);
            bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
            bytes
        };
        let d = WireDelta { num_vertices: 10, lane_words: 1, entries: vec![(3, vec![1])] };
        // Bad arm tag.
        let mut b = d.encode(WireArm::Sparse);
        b[2] = 9;
        assert_eq!(WireDelta::decode(&reseal(b)).unwrap_err(), WireError::BadArm { found: 9 });
        // Bad lane words.
        let mut b = d.encode(WireArm::Sparse);
        b[3] = 0;
        assert_eq!(
            WireDelta::decode(&reseal(b)).unwrap_err(),
            WireError::BadLaneWords { found: 0 }
        );
        let mut b = d.encode(WireArm::Sparse);
        b[3] = 9;
        // lane_words=9 reinterprets the payload; accept the width error or
        // any downstream structural error, but never a success.
        assert!(WireDelta::decode(&reseal(b)).is_err());
        // Vertex out of range.
        let mut b = d.encode(WireArm::Sparse);
        b[16..20].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(
            WireDelta::decode(&reseal(b)).unwrap_err(),
            WireError::VertexOutOfRange { vertex: 99, num_vertices: 10 }
        );
        // Zero mask.
        let mut b = d.encode(WireArm::Sparse);
        b[20..28].copy_from_slice(&0u64.to_le_bytes());
        assert_eq!(
            WireDelta::decode(&reseal(b)).unwrap_err(),
            WireError::EmptyMask { vertex: 3 }
        );
        // Trailing bytes.
        let mut b = d.encode(WireArm::Sparse);
        let trailer_at = b.len() - TRAILER_BYTES;
        b.splice(trailer_at..trailer_at, [0u8; 4]);
        assert_eq!(
            WireDelta::decode(&reseal(b)).unwrap_err(),
            WireError::TrailingBytes { extra: 4 }
        );
    }

    #[test]
    fn grouped_encoder_coalesces_shared_masks() {
        let d = WireDelta {
            num_vertices: 50,
            lane_words: 1,
            entries: vec![(1, vec![5]), (2, vec![5]), (3, vec![5]), (9, vec![7])],
        };
        let grouped = d.encode(WireArm::Grouped);
        let sparse = d.encode(WireArm::Sparse);
        assert!(grouped.len() < sparse.len(), "{} !< {}", grouped.len(), sparse.len());
        assert_eq!(WireDelta::decode(&grouped).unwrap(), d);
    }
}
