//! Level-boundary checkpoints and kill-rank recovery.
//!
//! A level-boundary [`Checkpoint`] is cheap because the engine's `dist`
//! array already *is* one: entering level `L`, the frontier is exactly
//! `{v : dist[v] == L}` and the seen set is `dist != INF`, so a snapshot
//! of the distances (plus the direction-optimizer scalars and the metrics
//! accumulated so far) fully determines the rest of the traversal.
//!
//! When a [`FaultPlan`](crate::fault::plan::FaultPlan) kills a rank, the
//! session surfaces [`QueryError::RankDead`] and stashes the checkpoint it
//! captured at the top of the lost level. [`FaultTolerantRunner`] then
//! *degrades* the engine configuration onto the surviving ranks
//! ([`degrade_config`]), rebuilds the plan, and replays only the lost
//! level via [`QuerySession::resume`] / [`QuerySession::resume_batch`] —
//! the headline invariant is that the answer is bit-identical to the
//! fault-free run, because the checkpoint pins the exact per-vertex
//! distances and re-partitioning only changes *who owns* each vertex,
//! never what is discovered.

use std::sync::Arc;

use crate::coordinator::{
    BatchResult, EngineConfig, LevelMetrics, PartitionMode, PlanError, QueryError, QuerySession,
    TraversalPlan, TraversalResult,
};
use crate::fault::plan::{FaultInjector, FaultPlan};
use crate::graph::{Csr, VertexId};
use crate::net::TopologyModel;

/// A level-boundary snapshot of a traversal, sufficient to replay the
/// level it was taken at (and everything after) on *any* plan over the
/// same graph — including a re-cut plan with fewer ranks.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// The level about to be expanded when the snapshot was taken.
    pub level: u32,
    /// The query's roots (one entry for a single-root `run`).
    pub roots: Vec<VertexId>,
    /// Whether this snapshots a batched (`run_batch`) query; single-root
    /// checkpoints resume through [`QuerySession::resume`], batched ones
    /// through [`QuerySession::resume_batch`].
    pub batch: bool,
    /// Distances discovered so far: `dist[v]` for single-root snapshots,
    /// lane-major `dist[lane * num_vertices + v]` for batched ones —
    /// `u32::MAX` for unreached. The frontier entering
    /// [`level`](Self::level) is every pair with `dist == level`.
    pub dist: Vec<u32>,
    /// Direction-optimizer state: whether the previous level ran
    /// bottom-up.
    pub bottom_up: bool,
    /// Direction-optimizer state: the previous level's frontier size.
    pub prev_frontier: u64,
    /// Direction-optimizer state: unclaimed edge mass.
    pub m_unexplored: u64,
    /// Per-level metrics accumulated before this level (replay appends to
    /// these, so the merged run reports every level exactly once).
    pub levels: Vec<LevelMetrics>,
    /// Synchronization rounds accumulated before this level.
    pub sync_rounds: u64,
}

impl Checkpoint {
    /// Number of batch lanes this checkpoint carries: `roots.len()` for a
    /// batched snapshot, 0 for a single-root one.
    pub fn lanes(&self) -> usize {
        if self.batch {
            self.roots.len()
        } else {
            0
        }
    }
}

/// Shrink an engine configuration onto the ranks surviving the death of
/// `dead_rank`, or `None` when no smaller configuration exists (a single
/// surviving rank cannot lose another).
///
/// * **1D** re-cuts the edge-balanced slab partition over `n - 1` ranks.
/// * **2D** falls back to a 1D butterfly cut over `n - 1` ranks — a
///   checkerboard cannot drop one cell and stay rectangular.
/// * **Hierarchical** shrinks the island layout: every island gives up one
///   local rank (`per_island - 1`) while the island count holds, so the
///   affected island's load spreads without re-tiering the fabric; once
///   islands are singletons, a whole island is dropped instead. A
///   configured [`TopologyModel`] is re-derived with the new island width
///   so pricing stays consistent.
pub fn degrade_config(cfg: &EngineConfig, dead_rank: u32) -> Option<EngineConfig> {
    let _ = dead_rank; // the re-cut excludes the rank by shrinking the count
    if cfg.num_nodes <= 1 {
        return None;
    }
    let mut next = cfg.clone();
    match cfg.partition {
        PartitionMode::OneD => {
            next.num_nodes = cfg.num_nodes - 1;
        }
        PartitionMode::TwoD { .. } => {
            next.partition = PartitionMode::OneD;
            next.num_nodes = cfg.num_nodes - 1;
        }
        PartitionMode::Hierarchical { islands, per_island } => {
            let (islands, per_island) = if per_island > 1 {
                (islands, per_island - 1)
            } else if islands > 1 {
                (islands - 1, 1)
            } else {
                return None;
            };
            next.partition = PartitionMode::Hierarchical { islands, per_island };
            next.num_nodes = (islands * per_island) as usize;
            next.topology = cfg
                .topology
                .map(|t| TopologyModel { per_island: per_island.max(1), ..t });
        }
    }
    Some(next)
}

/// Builds a [`TraversalPlan`] for a (degraded) configuration during
/// recovery.
pub type PlanRebuild = dyn Fn(&EngineConfig) -> Result<TraversalPlan, PlanError> + Send + Sync;

/// Drives queries through detect → retry → degrade recovery: tolerated
/// drop/corrupt/delay faults are absorbed (priced) inside the session,
/// while a [`QueryError::RankDead`] triggers a re-plan onto the surviving
/// ranks and a resume from the stashed level checkpoint.
///
/// The runner holds the [`FaultInjector`] across re-plans, so per-spec
/// `max_fires` budgets persist: a kill with `max_fires: 1` fires once and
/// then stays quiet on the degraded plan. An unlimited kill naturally
/// stops firing once the degraded rank count drops at or below the dying
/// rank's index, and the degradation ladder itself is finite — so
/// recovery always terminates, either with an answer or a typed error.
pub struct FaultTolerantRunner {
    plan: Arc<TraversalPlan>,
    injector: Arc<FaultInjector>,
    rebuild: Box<PlanRebuild>,
    degraded: Option<Arc<TraversalPlan>>,
}

impl FaultTolerantRunner {
    /// Wrap an existing plan with a fault plan and a rebuild callback
    /// (invoked with the degraded [`EngineConfig`] after a rank death).
    pub fn new(plan: Arc<TraversalPlan>, faults: FaultPlan, rebuild: Box<PlanRebuild>) -> Self {
        Self {
            plan,
            injector: Arc::new(FaultInjector::new(faults)),
            rebuild,
            degraded: None,
        }
    }

    /// Convenience constructor: build the initial plan from a graph and
    /// keep a copy of the graph for rebuilds.
    pub fn from_graph(g: &Csr, config: EngineConfig, faults: FaultPlan) -> Result<Self, PlanError> {
        let plan = Arc::new(TraversalPlan::build(g, config)?);
        let graph = g.clone();
        Ok(Self::new(
            plan,
            faults,
            Box::new(move |cfg| TraversalPlan::build(&graph, cfg.clone())),
        ))
    }

    /// The shared fault injector (e.g. to inspect fired counts).
    pub fn injector(&self) -> &Arc<FaultInjector> {
        &self.injector
    }

    /// Whether a rank death forced a re-plan onto fewer ranks.
    pub fn is_degraded(&self) -> bool {
        self.degraded.is_some()
    }

    /// The degraded plan, once a rank death forced one.
    pub fn degraded_plan(&self) -> Option<&Arc<TraversalPlan>> {
        self.degraded.as_ref()
    }

    /// The plan queries currently run on: the degraded plan if a rank has
    /// died, the original otherwise.
    pub fn active_plan(&self) -> &Arc<TraversalPlan> {
        self.degraded.as_ref().unwrap_or(&self.plan)
    }

    fn armed_session(&self) -> QuerySession {
        let mut session = self.active_plan().session();
        session.arm_faults(Some(self.injector.clone()));
        session
    }

    /// Degrade onto the surviving ranks after `rank` died at `level`,
    /// returning a fresh armed session over the re-built plan. Surfaces
    /// the original [`QueryError::RankDead`] when no smaller
    /// configuration exists or the rebuild fails: recovery never
    /// substitutes a wrong answer for a typed error.
    fn degrade(&mut self, rank: u32, level: u32) -> Result<QuerySession, QueryError> {
        let died = QueryError::RankDead { rank, level };
        let next = degrade_config(self.active_plan().config(), rank).ok_or(died)?;
        let plan = (self.rebuild)(&next).map_err(|_| died)?;
        let plan = Arc::new(plan);
        self.degraded = Some(plan);
        Ok(self.armed_session())
    }

    /// Run a single-root traversal under the fault plan, recovering from
    /// rank deaths by degrade + resume.
    pub fn run(&mut self, root: VertexId) -> Result<TraversalResult, QueryError> {
        let mut session = self.armed_session();
        let mut pending: Option<Checkpoint> = None;
        loop {
            let attempt = match &pending {
                Some(ck) => session.resume(ck),
                None => session.run(root),
            };
            match attempt {
                Err(QueryError::RankDead { rank, level }) => {
                    let ck = session
                        .take_checkpoint()
                        .ok_or(QueryError::RankDead { rank, level })?;
                    session = self.degrade(rank, level)?;
                    pending = Some(ck);
                }
                other => return other,
            }
        }
    }

    /// Run a batched traversal under the fault plan, recovering from rank
    /// deaths by degrade + resume.
    pub fn run_batch(&mut self, roots: &[VertexId]) -> Result<BatchResult, QueryError> {
        let mut session = self.armed_session();
        let mut pending: Option<Checkpoint> = None;
        loop {
            let attempt = match &pending {
                Some(ck) => session.resume_batch(ck),
                None => session.run_batch(roots),
            };
            match attempt {
                Err(QueryError::RankDead { rank, level }) => {
                    let ck = session
                        .take_checkpoint()
                        .ok_or(QueryError::RankDead { rank, level })?;
                    session = self.degrade(rank, level)?;
                    pending = Some(ck);
                }
                other => return other,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::plan::{FaultKind, FaultSpec};

    fn ring(n: usize) -> Csr {
        let mut edges = Vec::new();
        for v in 0..n as VertexId {
            let w = ((v as usize + 1) % n) as VertexId;
            edges.push((v, w));
            edges.push((w, v));
        }
        Csr::from_edges(n, &edges)
    }

    fn kill_plan(rank: u32, level: u32) -> FaultPlan {
        FaultPlan {
            faults: vec![FaultSpec {
                level,
                round: 0,
                src: rank,
                dst: 0,
                kind: FaultKind::KillRank,
                max_fires: 1,
            }],
            ..FaultPlan::default()
        }
    }

    #[test]
    fn degrade_ladder_shrinks_every_mode() {
        let one_d = EngineConfig::dgx2(4, 2);
        let d = degrade_config(&one_d, 2).unwrap();
        assert_eq!(d.num_nodes, 3);
        assert_eq!(d.partition, PartitionMode::OneD);

        let two_d = EngineConfig::dgx2_2d(2, 2);
        let d = degrade_config(&two_d, 0).unwrap();
        assert_eq!(d.partition, PartitionMode::OneD);
        assert_eq!(d.num_nodes, 3);

        let hier = EngineConfig::dgx2_cluster_hier(2, 2, 2);
        let d = degrade_config(&hier, 3).unwrap();
        assert_eq!(d.partition, PartitionMode::Hierarchical { islands: 2, per_island: 1 });
        assert_eq!(d.num_nodes, 2);
        assert_eq!(d.topology.unwrap().per_island, 1);
        let d2 = degrade_config(&d, 1).unwrap();
        assert_eq!(d2.partition, PartitionMode::Hierarchical { islands: 1, per_island: 1 });
        assert_eq!(d2.num_nodes, 1);
        assert!(degrade_config(&d2, 0).is_none());
    }

    #[test]
    fn single_rank_cannot_degrade() {
        let cfg = EngineConfig::dgx2(1, 2);
        assert!(degrade_config(&cfg, 0).is_none());
    }

    #[test]
    fn killed_rank_recovers_with_identical_distances() {
        let g = ring(64);
        let cfg = EngineConfig::dgx2(4, 2);
        let baseline = {
            let plan = TraversalPlan::build(&g, cfg.clone()).unwrap();
            plan.session().run(0).unwrap().dist().to_vec()
        };
        let mut runner = FaultTolerantRunner::from_graph(&g, cfg, kill_plan(2, 3)).unwrap();
        let got = runner.run(0).unwrap();
        assert!(runner.is_degraded());
        assert_eq!(runner.active_plan().config().num_nodes, 3);
        assert_eq!(got.dist(), &baseline[..]);
    }

    #[test]
    fn killed_rank_recovers_batches_too() {
        let g = ring(48);
        let cfg = EngineConfig::dgx2(4, 2);
        let roots: Vec<VertexId> = vec![0, 7, 31];
        let baseline = {
            let plan = TraversalPlan::build(&g, cfg.clone()).unwrap();
            let r = plan.session().run_batch(&roots).unwrap();
            (0..roots.len()).map(|l| r.dist(l).to_vec()).collect::<Vec<_>>()
        };
        let mut runner = FaultTolerantRunner::from_graph(&g, cfg, kill_plan(1, 2)).unwrap();
        let got = runner.run_batch(&roots).unwrap();
        assert!(runner.is_degraded());
        for (lane, want) in baseline.iter().enumerate() {
            assert_eq!(got.dist(lane), &want[..], "lane {lane}");
        }
    }

    #[test]
    fn unrecoverable_kill_surfaces_rank_dead() {
        // A single-rank engine has no smaller configuration; the typed
        // error comes back instead of a wrong answer.
        let g = ring(16);
        let cfg = EngineConfig::dgx2(1, 2);
        let mut runner = FaultTolerantRunner::from_graph(&g, cfg, kill_plan(0, 1)).unwrap();
        match runner.run(0) {
            Err(QueryError::RankDead { rank: 0, .. }) => {}
            other => panic!("expected RankDead, got {other:?}"),
        }
    }
}
