//! Deterministic fault plans and the injector that applies them.
//!
//! A [`FaultPlan`] is a seeded, fully explicit schedule of faults against
//! the Phase-2 exchange: each [`FaultSpec`] addresses one transfer by
//! `(level, round, src, dst)` (or one rank, for [`FaultKind::KillRank`])
//! and names the failure class. Because the engine *simulates* its
//! interconnect, injection is exact and replayable: a dropped or corrupted
//! transfer is detected (checksum/ack in a real fabric, see
//! [`super::wire`]), re-sent up to [`FaultPlan::max_retries`] times with
//! exponential backoff, and the retry traffic is priced through the same
//! [`TopologyModel`] link classes as first-transmission traffic — so a
//! tolerated fault changes *counters and simulated time only*, never the
//! merged frontier, which is what makes the fault-equivalence property
//! (`distances bit-identical to the fault-free run`) hold by construction.
//!
//! Faults addressing a `(round, src, dst)` combination the schedule never
//! performs, or a transfer whose payload is empty, are inert — this keeps
//! seeded generation ([`FaultPlan::generate`]) total without knowing the
//! schedule shape. The whole module is mirrored line-for-line by the
//! Python port (`python/bench_protocol_port.py`), which regenerates the
//! committed `fault_recovery` bench section from the same arithmetic.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::comm::pattern::Schedule;
use crate::net::model::TopologyModel;
use crate::net::sim::retransmit_time;
use crate::util::json::Json;
use crate::util::prng::SplitMix64;

/// One fault class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The transfer is lost `repeat` consecutive times (detected as a
    /// missing frame; each loss costs one backoff + one retransmission).
    Drop {
        /// Consecutive losses before a delivery succeeds.
        repeat: u32,
    },
    /// The payload arrives with flipped bits `repeat` consecutive times
    /// (detected by the FNV-1a frame checksum; same retry arithmetic as
    /// [`FaultKind::Drop`]).
    Corrupt {
        /// Consecutive corruptions before a delivery succeeds.
        repeat: u32,
    },
    /// The transfer straggles: delivery is correct but `delay_us`
    /// microseconds late (no retry, pure recovery-time cost).
    Delay {
        /// Added latency in microseconds.
        delay_us: u64,
    },
    /// The rank named by [`FaultSpec::src`] dies at the spec's level. Not
    /// recoverable in-session: the session surfaces
    /// [`QueryError::RankDead`](crate::coordinator::session::QueryError::RankDead)
    /// and a [`FaultTolerantRunner`](super::recovery::FaultTolerantRunner)
    /// re-plans onto the survivors from the last level checkpoint.
    KillRank,
}

impl FaultKind {
    /// CLI/JSON spelling of the kind tag.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Drop { .. } => "drop",
            FaultKind::Corrupt { .. } => "corrupt",
            FaultKind::Delay { .. } => "delay",
            FaultKind::KillRank => "kill",
        }
    }
}

/// One addressed fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// BFS level the fault strikes at.
    pub level: u32,
    /// Schedule round within the level (ignored by [`FaultKind::KillRank`]).
    pub round: usize,
    /// Sending rank — or the dying rank for [`FaultKind::KillRank`].
    pub src: u32,
    /// Receiving rank (ignored by [`FaultKind::KillRank`]).
    pub dst: u32,
    /// Failure class.
    pub kind: FaultKind,
    /// How many times this spec may fire across the injector's lifetime;
    /// `0` means unlimited. `1` models a transient fault a retry (or a
    /// re-planned replay) sails past.
    pub max_fires: u32,
}

impl FaultSpec {
    /// JSON object form (the `--fault-plan` file format).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("level", Json::u(u64::from(self.level))),
            ("round", Json::u(self.round as u64)),
            ("kind", Json::s(self.kind.name())),
            ("fires", Json::u(u64::from(self.max_fires))),
        ];
        match self.kind {
            FaultKind::KillRank => pairs.push(("rank", Json::u(u64::from(self.src)))),
            _ => {
                pairs.push(("src", Json::u(u64::from(self.src))));
                pairs.push(("dst", Json::u(u64::from(self.dst))));
            }
        }
        match self.kind {
            FaultKind::Drop { repeat } | FaultKind::Corrupt { repeat } => {
                pairs.push(("repeat", Json::u(u64::from(repeat))));
            }
            FaultKind::Delay { delay_us } => pairs.push(("delay_us", Json::u(delay_us))),
            FaultKind::KillRank => {}
        }
        Json::obj(pairs)
    }
}

/// A deterministic fault schedule plus the recovery budget it is retried
/// under.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Retry budget per faulted transfer: a drop/corrupt streak longer
    /// than this surfaces
    /// [`QueryError::Unrecoverable`](crate::coordinator::session::QueryError::Unrecoverable).
    pub max_retries: u32,
    /// Base backoff in microseconds; attempt `k` waits
    /// `backoff_us · 2^(k-1)` before retransmitting.
    pub backoff_us: u64,
    /// The fault schedule, applied in order.
    pub faults: Vec<FaultSpec>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self { max_retries: 3, backoff_us: 10, faults: Vec::new() }
    }
}

impl FaultPlan {
    /// Expand a single seed into `count` faults addressed uniformly over
    /// `levels × rounds × ranks²` via SplitMix64, cycling the recoverable
    /// kinds (drop, corrupt, delay). Mirrored exactly by the Python port —
    /// the committed bench fault schedule comes from here.
    pub fn generate(seed: u64, count: usize, levels: u32, rounds: usize, ranks: u32) -> Self {
        let mut sm = SplitMix64::new(seed);
        let levels = u64::from(levels.max(1));
        let rounds = rounds.max(1) as u64;
        let ranks = u64::from(ranks.max(1));
        let mut faults = Vec::with_capacity(count);
        for k in 0..count {
            let level = (sm.next_u64() % levels) as u32;
            let round = (sm.next_u64() % rounds) as usize;
            let src = (sm.next_u64() % ranks) as u32;
            let dst = (sm.next_u64() % ranks) as u32;
            let kind = match k % 3 {
                0 => FaultKind::Drop { repeat: 1 },
                1 => FaultKind::Corrupt { repeat: 1 },
                _ => FaultKind::Delay { delay_us: 25 },
            };
            faults.push(FaultSpec { level, round, src, dst, kind, max_fires: 0 });
        }
        Self { faults, ..Self::default() }
    }

    /// True when any spec is a [`FaultKind::KillRank`] — sessions only
    /// pay the per-level checkpoint clone when one could actually fire.
    pub fn has_kill(&self) -> bool {
        self.faults.iter().any(|f| f.kind == FaultKind::KillRank)
    }

    /// Seconds of exponential backoff before retry attempt `k` (1-based):
    /// `backoff_us · 2^(k-1)`, exponent clamped to keep the arithmetic
    /// finite for hostile plans.
    pub fn backoff_seconds(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(20);
        self.backoff_us as f64 * 1e-6 * (1u64 << exp) as f64
    }

    /// JSON form (the `--fault-plan` file format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_retries", Json::u(u64::from(self.max_retries))),
            ("backoff_us", Json::u(self.backoff_us)),
            ("faults", Json::Arr(self.faults.iter().map(FaultSpec::to_json).collect())),
        ])
    }

    /// Parse the `--fault-plan` JSON document.
    pub fn parse_str(text: &str) -> Result<Self, String> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Decode from a parsed JSON value.
    pub fn from_json(json: &Json) -> Result<Self, String> {
        let u = |j: &Json, key: &str, default: Option<u64>| -> Result<u64, String> {
            match j.get(key) {
                Some(v) => v.as_u64().ok_or_else(|| format!("fault plan: `{key}` not a u64")),
                None => default.ok_or_else(|| format!("fault plan: missing `{key}`")),
            }
        };
        let defaults = Self::default();
        let max_retries = u(json, "max_retries", Some(u64::from(defaults.max_retries)))? as u32;
        let backoff_us = u(json, "backoff_us", Some(defaults.backoff_us))?;
        let arr = json
            .get("faults")
            .and_then(Json::as_arr)
            .ok_or("fault plan: missing `faults` array")?;
        let mut faults = Vec::with_capacity(arr.len());
        for f in arr {
            let kind_name = f
                .get("kind")
                .and_then(Json::as_str)
                .ok_or("fault plan: fault missing `kind`")?;
            let level = u(f, "level", None)? as u32;
            let max_fires = u(f, "fires", Some(0))? as u32;
            let (kind, src, dst, round) = match kind_name {
                "drop" => (
                    FaultKind::Drop { repeat: u(f, "repeat", Some(1))? as u32 },
                    u(f, "src", None)? as u32,
                    u(f, "dst", None)? as u32,
                    u(f, "round", Some(0))? as usize,
                ),
                "corrupt" => (
                    FaultKind::Corrupt { repeat: u(f, "repeat", Some(1))? as u32 },
                    u(f, "src", None)? as u32,
                    u(f, "dst", None)? as u32,
                    u(f, "round", Some(0))? as usize,
                ),
                "delay" => (
                    FaultKind::Delay { delay_us: u(f, "delay_us", Some(25))? },
                    u(f, "src", None)? as u32,
                    u(f, "dst", None)? as u32,
                    u(f, "round", Some(0))? as usize,
                ),
                "kill" => {
                    let rank = match f.get("rank") {
                        Some(v) => {
                            v.as_u64().ok_or("fault plan: `rank` not a u64")? as u32
                        }
                        None => u(f, "src", None)? as u32,
                    };
                    (FaultKind::KillRank, rank, 0, 0)
                }
                other => return Err(format!("fault plan: unknown kind `{other}`")),
            };
            faults.push(FaultSpec { level, round, src, dst, kind, max_fires });
        }
        Ok(Self { max_retries, backoff_us, faults })
    }
}

/// Typed detection outcome of a failed Phase-2 exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeError {
    /// A transfer's frame checksum kept failing past the retry budget.
    Corrupt {
        /// BFS level of the exchange.
        level: u32,
        /// Schedule round within the level.
        round: usize,
        /// Sending rank.
        src: u32,
        /// Receiving rank.
        dst: u32,
    },
    /// A transfer kept going missing (no frame at all) past the retry
    /// budget.
    Missing {
        /// BFS level of the exchange.
        level: u32,
        /// Schedule round within the level.
        round: usize,
        /// Sending rank.
        src: u32,
        /// Receiving rank.
        dst: u32,
    },
    /// A rank stopped responding entirely.
    RankDead {
        /// The dead rank.
        rank: u32,
        /// Level at which it died.
        level: u32,
    },
}

impl std::fmt::Display for ExchangeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            ExchangeError::Corrupt { level, round, src, dst } => write!(
                f,
                "corrupt transfer {src}->{dst} (level {level}, round {round})"
            ),
            ExchangeError::Missing { level, round, src, dst } => write!(
                f,
                "missing transfer {src}->{dst} (level {level}, round {round})"
            ),
            ExchangeError::RankDead { rank, level } => {
                write!(f, "rank {rank} dead at level {level}")
            }
        }
    }
}

impl std::error::Error for ExchangeError {}

/// Recovery accounting for one level's exchange: what surviving the
/// injected faults cost on top of the fault-free schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LevelRecovery {
    /// Retransmissions performed.
    pub retries: u64,
    /// Bytes re-shipped by those retransmissions.
    pub retry_bytes: u64,
    /// Simulated seconds of backoff + retransmission + straggler delay.
    pub recovery_time: f64,
}

/// An unrecoverable exchange failure: the typed error plus how many
/// retries were burned before giving up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultFailure {
    /// What was detected.
    pub error: ExchangeError,
    /// Retry attempts consumed before surfacing.
    pub attempts: u32,
}

/// Applies a [`FaultPlan`] to live exchanges, tracking per-spec fire
/// counts (so `max_fires: 1` faults are transient across serve retries
/// and re-planned replays) behind interior mutability — sessions share
/// one injector through an `Arc`.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    fired: Vec<AtomicU32>,
}

impl FaultInjector {
    /// Wrap a plan with zeroed fire counters.
    pub fn new(plan: FaultPlan) -> Self {
        let fired = plan.faults.iter().map(|_| AtomicU32::new(0)).collect();
        Self { plan, fired }
    }

    /// The wrapped plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Reset all fire counters (fresh deterministic run).
    pub fn reset(&self) {
        for c in &self.fired {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// How many times each spec has fired so far (plan order).
    pub fn fired_counts(&self) -> Vec<u32> {
        self.fired.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Number of specs that have fired at least once.
    pub fn specs_matched(&self) -> usize {
        self.fired.iter().filter(|c| c.load(Ordering::Relaxed) > 0).count()
    }

    fn try_fire(&self, idx: usize) -> bool {
        let prev = self.fired[idx].fetch_add(1, Ordering::Relaxed);
        let cap = self.plan.faults[idx].max_fires;
        cap == 0 || prev < cap
    }

    /// Apply every fault addressed at `level` against the exchange the
    /// session just performed (`payloads[round][transfer]` in the same
    /// shape `simulate_topology` prices), returning the recovery
    /// accounting, or the typed failure when the budget is exhausted or a
    /// rank dies. Specs addressing a transfer the schedule never performs,
    /// or one with an empty payload, are inert.
    pub fn apply_level(
        &self,
        level: u32,
        schedule: &Schedule,
        payloads: &[Vec<u64>],
        topo: &TopologyModel,
    ) -> Result<LevelRecovery, FaultFailure> {
        let mut rec = LevelRecovery::default();
        for (idx, spec) in self.plan.faults.iter().enumerate() {
            if spec.level != level {
                continue;
            }
            if spec.kind == FaultKind::KillRank {
                if spec.src < schedule.num_nodes && self.try_fire(idx) {
                    return Err(FaultFailure {
                        error: ExchangeError::RankDead { rank: spec.src, level },
                        attempts: 0,
                    });
                }
                continue;
            }
            let Some(round) = schedule.rounds.get(spec.round) else { continue };
            let Some(ti) =
                round.iter().position(|t| t.src == spec.src && t.dst == spec.dst)
            else {
                continue;
            };
            let bytes = payloads
                .get(spec.round)
                .and_then(|r| r.get(ti))
                .copied()
                .unwrap_or(0);
            if bytes == 0 || !self.try_fire(idx) {
                continue;
            }
            match spec.kind {
                FaultKind::Delay { delay_us } => {
                    rec.recovery_time += delay_us as f64 * 1e-6;
                }
                FaultKind::Drop { repeat } | FaultKind::Corrupt { repeat } => {
                    if repeat > self.plan.max_retries {
                        let error = match spec.kind {
                            FaultKind::Drop { .. } => ExchangeError::Missing {
                                level,
                                round: spec.round,
                                src: spec.src,
                                dst: spec.dst,
                            },
                            _ => ExchangeError::Corrupt {
                                level,
                                round: spec.round,
                                src: spec.src,
                                dst: spec.dst,
                            },
                        };
                        return Err(FaultFailure { error, attempts: self.plan.max_retries });
                    }
                    for attempt in 1..=repeat {
                        rec.retries += 1;
                        rec.retry_bytes += bytes;
                        rec.recovery_time += self.plan.backoff_seconds(attempt)
                            + retransmit_time(topo, spec.src, spec.dst, bytes);
                    }
                }
                FaultKind::KillRank => unreachable!("handled above"),
            }
        }
        Ok(rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::pattern::Transfer;
    use crate::net::model::NetModel;

    fn schedule() -> Schedule {
        Schedule {
            num_nodes: 4,
            rounds: vec![
                vec![Transfer { src: 0, dst: 1 }, Transfer { src: 2, dst: 3 }],
                vec![Transfer { src: 1, dst: 2 }],
            ],
        }
    }

    fn topo() -> TopologyModel {
        TopologyModel::uniform(NetModel::dgx2())
    }

    #[test]
    fn generate_is_deterministic_and_in_range() {
        let a = FaultPlan::generate(23, 9, 4, 2, 16);
        let b = FaultPlan::generate(23, 9, 4, 2, 16);
        assert_eq!(a, b);
        assert_eq!(a.faults.len(), 9);
        for (k, f) in a.faults.iter().enumerate() {
            assert!(f.level < 4 && f.round < 2 && f.src < 16 && f.dst < 16);
            match k % 3 {
                0 => assert!(matches!(f.kind, FaultKind::Drop { repeat: 1 })),
                1 => assert!(matches!(f.kind, FaultKind::Corrupt { repeat: 1 })),
                _ => assert!(matches!(f.kind, FaultKind::Delay { delay_us: 25 })),
            }
        }
        assert_ne!(a, FaultPlan::generate(24, 9, 4, 2, 16));
    }

    #[test]
    fn json_roundtrip() {
        let mut plan = FaultPlan::generate(7, 6, 3, 2, 8);
        plan.faults.push(FaultSpec {
            level: 2,
            round: 0,
            src: 5,
            dst: 0,
            kind: FaultKind::KillRank,
            max_fires: 1,
        });
        let text = plan.to_json().render();
        let back = FaultPlan::parse_str(&text).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse_str("not json").is_err());
        assert!(FaultPlan::parse_str("{}").is_err());
        assert!(FaultPlan::parse_str(r#"{"faults":[{"kind":"frobnicate","level":0}]}"#)
            .is_err());
        assert!(FaultPlan::parse_str(r#"{"faults":[{"kind":"drop","level":0}]}"#).is_err());
    }

    #[test]
    fn tolerated_drop_prices_backoff_plus_retransmit() {
        let plan = FaultPlan {
            faults: vec![FaultSpec {
                level: 1,
                round: 0,
                src: 0,
                dst: 1,
                kind: FaultKind::Drop { repeat: 2 },
                max_fires: 0,
            }],
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan.clone());
        let payloads = vec![vec![1000, 500], vec![250]];
        // Wrong level: inert.
        let r0 = inj.apply_level(0, &schedule(), &payloads, &topo()).unwrap();
        assert_eq!(r0, LevelRecovery::default());
        let r1 = inj.apply_level(1, &schedule(), &payloads, &topo()).unwrap();
        assert_eq!(r1.retries, 2);
        assert_eq!(r1.retry_bytes, 2000);
        let wire = 2.0e-6 + 1000.0 / 25.0e9;
        let want = (plan.backoff_seconds(1) + wire) + (plan.backoff_seconds(2) + wire);
        assert!((r1.recovery_time - want).abs() < 1e-15);
    }

    #[test]
    fn unmatched_and_empty_transfers_are_inert() {
        let plan = FaultPlan {
            faults: vec![
                // No such transfer in round 0.
                FaultSpec {
                    level: 0,
                    round: 0,
                    src: 1,
                    dst: 0,
                    kind: FaultKind::Drop { repeat: 1 },
                    max_fires: 0,
                },
                // Round out of range.
                FaultSpec {
                    level: 0,
                    round: 9,
                    src: 0,
                    dst: 1,
                    kind: FaultKind::Corrupt { repeat: 1 },
                    max_fires: 0,
                },
                // Matching transfer but empty payload.
                FaultSpec {
                    level: 0,
                    round: 1,
                    src: 1,
                    dst: 2,
                    kind: FaultKind::Drop { repeat: 1 },
                    max_fires: 0,
                },
            ],
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan);
        let payloads = vec![vec![1000, 500], vec![0]];
        let r = inj.apply_level(0, &schedule(), &payloads, &topo()).unwrap();
        assert_eq!(r, LevelRecovery::default());
        assert_eq!(inj.specs_matched(), 0);
    }

    #[test]
    fn budget_exhaustion_is_typed() {
        let plan = FaultPlan {
            max_retries: 3,
            faults: vec![FaultSpec {
                level: 0,
                round: 0,
                src: 0,
                dst: 1,
                kind: FaultKind::Corrupt { repeat: 4 },
                max_fires: 0,
            }],
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan);
        let payloads = vec![vec![1000, 500], vec![250]];
        let err = inj.apply_level(0, &schedule(), &payloads, &topo()).unwrap_err();
        assert_eq!(err.attempts, 3);
        assert!(matches!(err.error, ExchangeError::Corrupt { src: 0, dst: 1, .. }));
    }

    #[test]
    fn kill_rank_fires_then_respects_max_fires() {
        let plan = FaultPlan {
            faults: vec![FaultSpec {
                level: 1,
                round: 0,
                src: 3,
                dst: 0,
                kind: FaultKind::KillRank,
                max_fires: 1,
            }],
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan);
        let payloads = vec![vec![1000, 500], vec![250]];
        let err = inj.apply_level(1, &schedule(), &payloads, &topo()).unwrap_err();
        assert_eq!(err.error, ExchangeError::RankDead { rank: 3, level: 1 });
        // Second replay of the same level: the once-only kill is spent.
        let r = inj.apply_level(1, &schedule(), &payloads, &topo()).unwrap();
        assert_eq!(r, LevelRecovery::default());
        // reset() re-arms it.
        inj.reset();
        assert!(inj.apply_level(1, &schedule(), &payloads, &topo()).is_err());
    }

    #[test]
    fn delay_adds_pure_latency() {
        let plan = FaultPlan {
            faults: vec![FaultSpec {
                level: 0,
                round: 1,
                src: 1,
                dst: 2,
                kind: FaultKind::Delay { delay_us: 40 },
                max_fires: 0,
            }],
            ..FaultPlan::default()
        };
        let inj = FaultInjector::new(plan);
        let payloads = vec![vec![1000, 500], vec![250]];
        let r = inj.apply_level(0, &schedule(), &payloads, &topo()).unwrap();
        assert_eq!(r.retries, 0);
        assert_eq!(r.retry_bytes, 0);
        assert!((r.recovery_time - 40.0e-6).abs() < 1e-18);
    }
}
