//! FNV-1a payload checksums.
//!
//! The same 64-bit FNV-1a the `.bbfs` store uses for its container
//! fingerprint, exposed as a standalone helper so the wire codec
//! ([`super::wire`]) can frame a trailer checksum onto every transfer.
//! FNV-1a is not cryptographic — it detects the fault model's bit flips
//! and truncations (the `Corrupt` class), not an adversary.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // Public-domain FNV-1a 64 test vectors (Noll's reference tables).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn single_bit_flip_changes_hash() {
        let base = b"the quick brown fox".to_vec();
        let h0 = fnv1a64(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[i] ^= 1 << bit;
                assert_ne!(fnv1a64(&flipped), h0, "byte {i} bit {bit}");
            }
        }
    }

    #[test]
    fn truncation_changes_hash() {
        let base = b"payload-payload-payload".to_vec();
        let h0 = fnv1a64(&base);
        for cut in 0..base.len() {
            assert_ne!(fnv1a64(&base[..cut]), h0, "cut {cut}");
        }
    }
}
