//! Bit-parallel batched multi-source BFS (MS-BFS), generic over the lane
//! width.
//!
//! APSP-class analytics (closeness / betweenness centrality, reachability
//! sampling) run hundreds of traversals back-to-back — exactly the regime
//! the paper keeps a fast top-down path for, because "direction optimizing
//! BFS does not apply to all problems requiring a BFS traversal". Running
//! those traversals one at a time pays the full per-level synchronization
//! cost (schedule rounds, message latency, payload bytes) once per root.
//!
//! MS-BFS (Then et al., *The More the Merrier: Efficient Multi-Source BFS*)
//! amortizes that cost: every vertex carries a **lane mask** — bit `i` set
//! means "already seen by the traversal rooted at `roots[i]`" — and a
//! level expansion ORs frontier masks into neighbor masks. The mask is a
//! const-generic [`LaneMask<W>`] of `W ∈ {1, 2, 4, 8}` 64-bit words, so
//! up to [`MAX_LANES`] (512) traversals advance in lock-step through
//! *one* frontier sweep, and, in the distributed engine, through *one*
//! butterfly exchange per level
//! ([`crate::coordinator::session::QuerySession::run_batch`]). The
//! exchange ships `(vertex, mask-delta)` payloads priced by the negotiated
//! encoding [`mask_delta_bytes`] (the coalescing-agnostic bound is
//! [`PayloadEncoding::MaskDelta`](crate::coordinator::config::PayloadEncoding)),
//! so one round of communication serves the whole batch: schedule setup,
//! per-message latency, and dedup traffic are paid once instead of once
//! per root. Widening `W` multiplies the lanes served per exchange while
//! the per-entry wire cost grows only linearly (`4 + 8·W` bytes) and the
//! presence-bitmap term of the dense wire forms does not grow at all —
//! the amortization analysis of the distributed-BFS literature (Buluç &
//! Madduri) applied to batching.
//!
//! This module holds the single-node bit-parallel engine ([`ms_bfs`], the
//! oracle and CPU baseline — accepts any width up to [`MAX_LANES`] and
//! dispatches to the monomorphized word count internally), the per-root
//! result view ([`MsBfsResult`]), and the per-compute-node distributed
//! state ([`MsBfsNodeState`]) that `run_batch` drives through the
//! butterfly schedule.
//!
//! Semantics are identical to running [`serial_bfs`](crate::bfs::serial)
//! once per root (property-tested in `tests/msbfs_equivalence.rs`):
//! levels are synchronous, so the first level at which a lane reaches a
//! vertex is that lane's BFS distance. Duplicate roots simply occupy two
//! lanes that evolve identically.

use crate::bfs::dirop::DirOptParams;
use crate::bfs::frontier::{lane_mask_count, lane_mask_is_zero, LaneMask, MaskFrontier};
use crate::bfs::serial::INF;
use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;
use std::collections::HashSet;

/// Lanes per mask word.
pub const LANES_PER_WORD: usize = 64;

/// Maximum mask width in words the engine monomorphizes over.
pub const MAX_LANE_WORDS: usize = 8;

/// Maximum batch width: [`MAX_LANE_WORDS`] words of [`LANES_PER_WORD`]
/// lanes each.
pub const MAX_LANES: usize = MAX_LANE_WORDS * LANES_PER_WORD;

/// Maximum batch width of a *single-word* (`W = 1`) lane mask — the
/// classic MS-BFS width, kept for compatibility; the engine now batches
/// up to [`MAX_LANES`] roots via wider masks.
pub const MAX_BATCH: usize = LANES_PER_WORD;

/// Smallest supported word count whose lane capacity covers `lanes`
/// roots: `{1, 2, 4, 8}` for up to 64 / 128 / 256 / 512 lanes.
///
/// # Panics
///
/// When `lanes` is zero or exceeds [`MAX_LANES`].
pub fn words_for_lanes(lanes: usize) -> usize {
    assert!(
        lanes >= 1 && lanes <= MAX_LANES,
        "batch width must be 1..={MAX_LANES} (got {lanes})"
    );
    lanes.div_ceil(LANES_PER_WORD).next_power_of_two()
}

/// Single-word mask with the low `width` lanes set — "every lane of the
/// batch" for `W = 1` (see [`full_lane_mask`] for the wide form).
#[inline]
pub fn full_mask(width: usize) -> u64 {
    debug_assert!(width >= 1 && width <= MAX_BATCH);
    if width == MAX_BATCH {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// `W`-word mask with the low `width` lanes set — "every lane of the
/// batch".
#[inline]
pub fn full_lane_mask<const W: usize>(width: usize) -> LaneMask<W> {
    debug_assert!(
        width >= 1 && width <= W * LANES_PER_WORD,
        "width {width} exceeds {W}-word capacity"
    );
    let mut m = [0u64; W];
    for (w, word) in m.iter_mut().enumerate() {
        let lo = w * LANES_PER_WORD;
        *word = if width >= lo + LANES_PER_WORD {
            u64::MAX
        } else if width > lo {
            (1u64 << (width - lo)) - 1
        } else {
            0
        };
    }
    m
}

/// Coalescing statistics of one delta prefix — the inputs of the
/// negotiated wire pricing ([`mask_delta_bytes`]). Every field is
/// monotone non-decreasing within a level, so snapshotting `(prefix
/// length, stats)` together prices exactly that prefix.
///
/// At `W = 1` the three `*_words` fields collapse onto their counts
/// (`entry_words == entries`, `vertex_words == distinct_vertices`,
/// `group_words == distinct_masks`): a nonzero single-word mask has
/// exactly one nonzero word. That identity is what keeps the `W = 1`
/// wire bytes bit-identical to the original single-word pricing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MaskDeltaStats {
    /// Delta-list entries.
    pub entries: u64,
    /// Distinct vertices among the entries.
    pub distinct_vertices: u64,
    /// Distinct mask values among the entries.
    pub distinct_masks: u64,
    /// Population count of the OR of all masks (over all `W` words).
    pub active_lanes: u32,
    /// Nonzero *words* of the OR of all masks — how many 64-lane cohorts
    /// are active this level (1 at `W = 1` whenever any entry exists).
    pub active_words: u32,
    /// Σ nonzero mask words over entries.
    pub entry_words: u64,
    /// Distinct `(vertex, word)` cells with a nonzero accumulated mask
    /// word this level.
    pub vertex_words: u64,
    /// Σ nonzero mask words over distinct mask values.
    pub group_words: u64,
}

/// Per-mask word-presence header bytes: wide masks (`W > 1`) ship a
/// 1-byte word bitmap so all-zero words cost nothing; at `W = 1` the
/// word is implied by the entry's existence.
#[inline]
fn word_header(lane_words: usize) -> u64 {
    u64::from(lane_words > 1)
}

/// Negotiated wire cost of one MS-BFS delta message carrying
/// `lane_words`-word masks. The sender serializes its delta prefix in
/// whichever of four equivalent forms is smallest. For `W > 1` every
/// mask is shipped *word-sparse*: a 1-byte word-presence bitmap (`W <=
/// 8`) followed by only the nonzero 64-bit words — so a wide batch whose
/// lanes cluster in few words (the common case: each vertex is typically
/// reached by roots from one 64-lane cohort at a time) pays close to the
/// single-word cost, not `8·W` per mask.
///
/// 1. **Sparse pairs** — per entry a `u32` vertex id, the word-presence
///    byte, and the entry's nonzero mask words:
///    `(4 + ⟦W>1⟧)·entries + 8·entry_words` bytes.
/// 2. **Mask-grouped sparse** — entries grouped by mask value: per group
///    a word-sparse mask + count header, plus `4` bytes per entry (each
///    entry's vertex id listed once, in its group):
///    `(4 + ⟦W>1⟧)·distinct_masks + 8·group_words + 4·entries`. Lanes
///    travel together, so few distinct mask values cover many entries —
///    this is the redundancy `64·W` *separate* traversals cannot
///    exploit, and where the batch's byte win comes from.
/// 3. **Per-word presence bitmaps + packed masks** — for each *active*
///    word (64-lane cohort with any delta), a `⌈V/64⌉·8`-byte presence
///    bitmap marking which vertices gained lanes of that cohort, plus
///    `8` bytes per nonzero `(vertex, word)` cell:
///    `active_words·presence + 8·vertex_words`. This is exactly the
///    single-word arm 3 applied per cohort, so a wide batch never pays
///    for provisioned-but-idle words, and at `W = 1` it reduces to the
///    original `presence + 8·distinct_vertices`.
/// 4. **Per-active-lane bitmaps** — `(1 + active_lanes)·⌈V/64⌉·8` bytes
///    (a presence bitmap per lane that appears in the delta);
///    degenerates to the single-root bitmap bound when only one lane is
///    active, and is width-independent: the presence term never grows
///    with `W`.
pub fn mask_delta_bytes(
    s: &MaskDeltaStats,
    num_vertices: usize,
    lane_words: usize,
) -> u64 {
    if s.entries == 0 {
        return 0;
    }
    let wb = word_header(lane_words);
    let presence = (num_vertices as u64).div_ceil(64) * 8;
    let sparse = s.entries * (4 + wb) + 8 * s.entry_words;
    let grouped = s.distinct_masks * (4 + wb) + 8 * s.group_words + s.entries * 4;
    let dense = s.active_words as u64 * presence + 8 * s.vertex_words;
    let lane_bitmaps = (1 + s.active_lanes as u64) * presence;
    sparse.min(grouped).min(dense).min(lane_bitmaps)
}

/// Wire cost of a bottom-up level's delta under the *dense* (presence-
/// bitmap) forms only — arms 3 and 4 of [`mask_delta_bytes`]. A bottom-up
/// scan produces its discoveries as a dense sweep over the sender's owned
/// vertex range, so the natural wire format is a presence bitmap plus
/// either word-sparse packed per-vertex masks (arm 3) or one bitmap per
/// active lane (arm 4); the sorted sparse forms would require an extra
/// compaction pass the sender never runs.
pub fn mask_delta_bytes_dense(
    vertex_words: u64,
    active_words: u32,
    active_lanes: u32,
    num_vertices: usize,
) -> u64 {
    if vertex_words == 0 {
        return 0;
    }
    let presence = (num_vertices as u64).div_ceil(64) * 8;
    let dense = active_words as u64 * presence + 8 * vertex_words;
    let lane_bitmaps = (1 + active_lanes as u64) * presence;
    dense.min(lane_bitmaps)
}

/// Distances of a batched traversal: one full distance array per lane,
/// stored lane-major (`dist[lane * num_vertices + v]`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MsBfsResult {
    num_vertices: usize,
    num_roots: usize,
    dist: Vec<u32>,
}

impl MsBfsResult {
    /// Build from raw parts (used by the engines in this crate).
    pub(crate) fn from_parts(num_vertices: usize, num_roots: usize, dist: Vec<u32>) -> Self {
        assert_eq!(dist.len(), num_vertices * num_roots);
        Self { num_vertices, num_roots, dist }
    }

    /// Number of lanes (roots) in the batch.
    pub fn num_roots(&self) -> usize {
        self.num_roots
    }

    /// Number of vertices per lane.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Distance array of lane `i` — element `v` is the BFS distance from
    /// `roots[i]` to `v`, or [`INF`] when unreachable.
    pub fn dist(&self, lane: usize) -> &[u32] {
        assert!(lane < self.num_roots, "lane {lane} out of range");
        &self.dist[lane * self.num_vertices..(lane + 1) * self.num_vertices]
    }

    /// Total `(lane, vertex)` pairs reached.
    pub fn reached_pairs(&self) -> u64 {
        self.dist.iter().filter(|&&d| d != INF).count() as u64
    }
}

/// Stamp `dist[lane·n + v] = d` for every lane set in the `W`-word delta.
#[inline]
fn stamp_lanes<const W: usize>(dist: &mut [u32], n: usize, v: usize, delta: &LaneMask<W>, d: u32) {
    for (w, &word) in delta.iter().enumerate() {
        let mut m = word;
        while m != 0 {
            let lane = w * LANES_PER_WORD + m.trailing_zeros() as usize;
            m &= m - 1;
            dist[lane * n + v] = d;
        }
    }
}

/// Single-node bit-parallel MS-BFS over a full CSR: the oracle the
/// distributed `run_batch` is tested against, and the CPU baseline the
/// `msbfs_amortization` bench compares with.
///
/// One pass over the active frontier advances all `roots.len() <=`
/// [`MAX_LANES`] traversals: for frontier vertex `v` with pending mask
/// `m`, each neighbor `u` gains lanes `m & !seen[u]`, word-wise. The
/// word count is monomorphized internally ([`words_for_lanes`]).
pub fn ms_bfs(g: &Csr, roots: &[VertexId]) -> MsBfsResult {
    match words_for_lanes(roots.len()) {
        1 => ms_bfs_w::<1>(g, roots),
        2 => ms_bfs_w::<2>(g, roots),
        4 => ms_bfs_w::<4>(g, roots),
        _ => ms_bfs_w::<8>(g, roots),
    }
}

fn ms_bfs_w<const W: usize>(g: &Csr, roots: &[VertexId]) -> MsBfsResult {
    let n = g.num_vertices();
    let b = roots.len();
    debug_assert!(b >= 1 && b <= W * LANES_PER_WORD);
    let mut seen = vec![0u64; n * W];
    let mut visit = vec![0u64; n * W];
    let mut next = vec![0u64; n * W];
    let mut dist = vec![INF; n * b];
    for (lane, &r) in roots.iter().enumerate() {
        assert!((r as usize) < n, "root {r} out of range");
        let base = r as usize * W;
        seen[base + lane / LANES_PER_WORD] |= 1u64 << (lane % LANES_PER_WORD);
        visit[base + lane / LANES_PER_WORD] |= 1u64 << (lane % LANES_PER_WORD);
        dist[lane * n + r as usize] = 0;
    }
    let mut level = 0u32;
    loop {
        let mut any = false;
        for v in 0..n {
            let vbase = v * W;
            let mut mv = [0u64; W];
            let mut nonzero = 0u64;
            for w in 0..W {
                mv[w] = visit[vbase + w];
                nonzero |= mv[w];
            }
            if nonzero == 0 {
                continue;
            }
            for &u in g.neighbors(v as VertexId) {
                let ubase = u as usize * W;
                let mut d = [0u64; W];
                let mut found = 0u64;
                for w in 0..W {
                    d[w] = mv[w] & !seen[ubase + w];
                    found |= d[w];
                }
                if found != 0 {
                    for w in 0..W {
                        seen[ubase + w] |= d[w];
                        next[ubase + w] |= d[w];
                    }
                    stamp_lanes(&mut dist, n, u as usize, &d, level + 1);
                    any = true;
                }
            }
        }
        if !any {
            break;
        }
        std::mem::swap(&mut visit, &mut next);
        next.iter_mut().for_each(|x| *x = 0);
        level += 1;
    }
    MsBfsResult::from_parts(n, b, dist)
}

/// Phase-1 direction policy of the direction-aware oracle — mirrors the
/// engine's `DirectionMode` without depending on the coordinator layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsBfsDirection {
    /// Classic top-down expansion every level.
    TopDown,
    /// Bottom-up lane-mask expansion every level.
    BottomUp,
    /// GapBS-style α/β switching on union-frontier edge mass.
    DirOpt(DirOptParams),
}

/// Per-level accounting of a direction-aware oracle run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsBfsLevelStats {
    /// Level index.
    pub level: u32,
    /// Distinct vertices in the union frontier entering the level.
    pub frontier: u64,
    /// Edges inspected this level (top-down: full adjacency of every
    /// frontier vertex; bottom-up: neighbors probed before early exit).
    pub edges_inspected: u64,
    /// True when the level ran bottom-up.
    pub bottom_up: bool,
}

/// Result + per-level direction trace of [`ms_bfs_dir`].
#[derive(Clone, Debug)]
pub struct MsBfsDirRun {
    /// Per-lane distances (identical to [`ms_bfs`]'s for any policy —
    /// levels are synchronous, so direction cannot change distances).
    pub result: MsBfsResult,
    /// Per-level frontier/edge/direction trace.
    pub levels: Vec<MsBfsLevelStats>,
}

/// Direction-aware single-node bit-parallel MS-BFS — the oracle for the
/// batched direction-optimizing engine path
/// ([`run_batch`](crate::coordinator::session::QuerySession::run_batch)
/// with a non-top-down `DirectionMode`). Like [`ms_bfs`], accepts up to
/// [`MAX_LANES`] roots and dispatches to the monomorphized word count.
///
/// The bottom-up formulation (Then et al. §aggregated neighbor
/// processing, composed with Beamer's direction switch): a vertex `v`
/// with `seen[v] != full` scans its neighbors `u`, accumulating
/// `acc |= visit[u]` word-wise, and early-exits once `acc` covers every
/// lane still missing at `v` — one sequential read per unseen vertex
/// replaces per-edge top-down scatter at dense levels. The α/β heuristic
/// runs on *union-frontier* statistics: the frontier's edge mass is
/// `Σ deg(v)` over distinct active vertices (a vertex active in many
/// lanes still costs one adjacency read), compared against the edge mass
/// not yet claimed by any lane's traversal.
pub fn ms_bfs_dir(g: &Csr, roots: &[VertexId], direction: MsBfsDirection) -> MsBfsDirRun {
    match words_for_lanes(roots.len()) {
        1 => ms_bfs_dir_w::<1>(g, roots, direction),
        2 => ms_bfs_dir_w::<2>(g, roots, direction),
        4 => ms_bfs_dir_w::<4>(g, roots, direction),
        _ => ms_bfs_dir_w::<8>(g, roots, direction),
    }
}

fn ms_bfs_dir_w<const W: usize>(
    g: &Csr,
    roots: &[VertexId],
    direction: MsBfsDirection,
) -> MsBfsDirRun {
    let n = g.num_vertices();
    let b = roots.len();
    debug_assert!(b >= 1 && b <= W * LANES_PER_WORD);
    let full: LaneMask<W> = full_lane_mask(b);
    let mut seen = vec![0u64; n * W];
    let mut visit = vec![0u64; n * W];
    let mut next = vec![0u64; n * W];
    let mut dist = vec![INF; n * b];
    for (lane, &r) in roots.iter().enumerate() {
        assert!((r as usize) < n, "root {r} out of range");
        let base = r as usize * W;
        seen[base + lane / LANES_PER_WORD] |= 1u64 << (lane % LANES_PER_WORD);
        visit[base + lane / LANES_PER_WORD] |= 1u64 << (lane % LANES_PER_WORD);
        dist[lane * n + r as usize] = 0;
    }
    let nonzero = |masks: &[u64], v: usize| -> bool {
        masks[v * W..v * W + W].iter().any(|&w| w != 0)
    };
    let mut levels = Vec::new();
    let mut level = 0u32;
    let mut bottom_up = false;
    let mut prev_frontier = 0u64;
    let mut m_unexplored = g.num_edges();
    loop {
        let frontier = (0..n).filter(|&v| nonzero(&visit, v)).count() as u64;
        if frontier == 0 {
            break;
        }
        match direction {
            MsBfsDirection::TopDown => {}
            MsBfsDirection::BottomUp => bottom_up = true,
            MsBfsDirection::DirOpt(DirOptParams { alpha, beta }) => {
                let m_frontier: u64 = (0..n)
                    .filter(|&v| nonzero(&visit, v))
                    .map(|v| g.degree(v as VertexId) as u64)
                    .sum();
                let growing = frontier > prev_frontier;
                if !bottom_up && alpha > 0 && growing && m_frontier > m_unexplored / alpha {
                    bottom_up = true;
                } else if bottom_up
                    && beta > 0
                    && !growing
                    && frontier < (n as u64) / beta
                {
                    bottom_up = false;
                }
                prev_frontier = frontier;
            }
        }
        let mut edges = 0u64;
        let mut any = false;
        if bottom_up {
            for v in 0..n {
                let vbase = v * W;
                let mut missing = [0u64; W];
                let mut miss_any = 0u64;
                for w in 0..W {
                    missing[w] = full[w] & !seen[vbase + w];
                    miss_any |= missing[w];
                }
                if miss_any == 0 {
                    continue;
                }
                let mut acc = [0u64; W];
                for &u in g.neighbors(v as VertexId) {
                    edges += 1;
                    let ubase = u as usize * W;
                    let mut covered = true;
                    for w in 0..W {
                        acc[w] |= visit[ubase + w];
                        covered &= acc[w] & missing[w] == missing[w];
                    }
                    if covered {
                        // Every still-missing lane found a parent — the
                        // early exit that makes dense levels cheap.
                        break;
                    }
                }
                let mut d = [0u64; W];
                let mut d_any = 0u64;
                for w in 0..W {
                    d[w] = acc[w] & missing[w];
                    d_any |= d[w];
                }
                if d_any != 0 {
                    for w in 0..W {
                        seen[vbase + w] |= d[w];
                        next[vbase + w] |= d[w];
                    }
                    stamp_lanes(&mut dist, n, v, &d, level + 1);
                    any = true;
                }
            }
        } else {
            for v in 0..n {
                let vbase = v * W;
                let mut mv = [0u64; W];
                let mut mv_any = 0u64;
                for w in 0..W {
                    mv[w] = visit[vbase + w];
                    mv_any |= mv[w];
                }
                if mv_any == 0 {
                    continue;
                }
                edges += g.degree(v as VertexId) as u64;
                for &u in g.neighbors(v as VertexId) {
                    let ubase = u as usize * W;
                    let mut d = [0u64; W];
                    let mut found = 0u64;
                    for w in 0..W {
                        d[w] = mv[w] & !seen[ubase + w];
                        found |= d[w];
                    }
                    if found != 0 {
                        for w in 0..W {
                            seen[ubase + w] |= d[w];
                            next[ubase + w] |= d[w];
                        }
                        stamp_lanes(&mut dist, n, u as usize, &d, level + 1);
                        any = true;
                    }
                }
            }
        }
        levels.push(MsBfsLevelStats { level, frontier, edges_inspected: edges, bottom_up });
        if let MsBfsDirection::DirOpt(_) = direction {
            let next_edges: u64 = (0..n)
                .filter(|&v| nonzero(&next, v))
                .map(|v| g.degree(v as VertexId) as u64)
                .sum();
            m_unexplored = m_unexplored.saturating_sub(next_edges);
        }
        if !any {
            break;
        }
        std::mem::swap(&mut visit, &mut next);
        next.iter_mut().for_each(|x| *x = 0);
        level += 1;
    }
    MsBfsDirRun {
        result: MsBfsResult::from_parts(n, b, dist),
        levels,
    }
}

/// Sample `width` roots for a batch (up to [`MAX_LANES`]). Non-isolated
/// vertices are guaranteed whenever the graph has any edge: after a few
/// random retries the sampler falls back to a deterministic wrapping scan
/// for the next vertex with degree > 0 (so an unlucky lane can never land
/// on an isolated vertex, unlike a bounded-retry sampler). Duplicates are
/// allowed — MS-BFS handles them as independent lanes.
pub fn sample_batch_roots(g: &Csr, width: usize, seed: u64) -> Vec<VertexId> {
    sample_batch_roots_by(g.num_vertices(), |v| g.degree(v), width, seed)
}

/// [`sample_batch_roots`] generalized over the degree lookup, so roots
/// can be sampled without an eager CSR — e.g. from a `.bbfs` v2 store's
/// O(n) degree stream on a lazily loaded plan. Identical sampling
/// sequence for identical degrees.
pub fn sample_batch_roots_by(
    n: usize,
    degree: impl Fn(VertexId) -> u32,
    width: usize,
    seed: u64,
) -> Vec<VertexId> {
    assert!(n > 0, "empty graph");
    assert!(width >= 1 && width <= MAX_LANES);
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let mut roots = Vec::with_capacity(width);
    while roots.len() < width {
        let mut v = rng.next_usize(n) as VertexId;
        for _ in 0..8 {
            if degree(v) > 0 {
                break;
            }
            v = rng.next_usize(n) as VertexId;
        }
        if degree(v) == 0 {
            // Wrapping scan from v: first non-isolated vertex, if any.
            for off in 1..n {
                let u = ((v as usize + off) % n) as VertexId;
                if degree(u) > 0 {
                    v = u;
                    break;
                }
            }
        }
        roots.push(v);
    }
    roots
}

/// Per-compute-node state of one distributed batched traversal — the
/// MS-BFS analog of [`ComputeNode`](crate::coordinator::node::ComputeNode)'s
/// queues, created fresh by `run_batch` and driven through the same
/// butterfly schedule the single-root engine uses. Generic over the lane
/// word count `W` ([`LaneMask`]); the per-vertex mask arrays are stored
/// *flat vertex-major* (`seen[v·W + w]` is word `w` of vertex `v`'s
/// mask), the layout the width-agnostic backend kernel consumes.
///
/// The node's *global queue* analog is [`MsBfsNodeState::delta`]: every
/// `(vertex, lane-mask)` pair this node discovered or relayed this level —
/// the butterfly payload.
#[derive(Clone, Debug)]
pub struct MsBfsNodeState<const W: usize> {
    num_vertices: usize,
    /// Per-vertex lanes already seen by this node, flat vertex-major
    /// (`seen[v·W + w]` bit `i` ⇔ lane `w·64 + i` reached `v` as far as
    /// this node knows).
    pub seen: Vec<u64>,
    /// Lane-major distances, `dist[lane * V + v]` (every node records all
    /// lanes — the paper's "All CN set their d" — so agreement is
    /// checkable).
    pub dist: Vec<u32>,
    /// Pending masks of the *current* level's owned frontier vertices
    /// (flat vertex-major, like `seen`).
    pub visit: Vec<u64>,
    /// Accumulated masks for the *next* level's owned frontier (flat).
    pub next_mask: Vec<u64>,
    /// Owned vertices with a nonzero `visit` mask (current level).
    pub q_local: Vec<VertexId>,
    /// Owned vertices with a nonzero `next_mask` (next level).
    pub q_local_next: Vec<VertexId>,
    /// Everything this node learned this level — phase-1 discoveries plus
    /// butterfly-relayed deltas, each entry's mask holding only the lanes
    /// that were new to this node when it was appended.
    pub delta: MaskFrontier<W>,
    /// Edges examined by this node in the current level (metrics).
    pub edges_this_level: u64,
    /// Distinct vertices in `delta` (for [`mask_delta_bytes`] pricing).
    pub delta_distinct: u64,
    /// Distinct mask values in `delta` (pricing).
    pub mask_values: HashSet<LaneMask<W>>,
    /// OR of all masks in `delta` — which lanes are active this level
    /// (pricing).
    pub active_lanes: LaneMask<W>,
    /// Per-word entry counts: `word_entries[w]` is the number of delta
    /// entries whose word `w` is nonzero (the cohort-factored pricing's
    /// per-cohort entry count; Σ over words = nonzero mask words over all
    /// entries, the word-sparse entry cost).
    pub word_entries: [u64; W],
    /// Per-word distinct-vertex counts: `word_vertices[w]` is the number
    /// of distinct vertices whose accumulated mask word `w` is nonzero
    /// this level.
    pub word_vertices: [u64; W],
    /// Σ nonzero mask words over distinct whole-mask values (word-sparse
    /// grouped pricing).
    pub group_words: u64,
    /// Per-word distinct word-values (the cohort-factored grouped
    /// pricing's per-cohort mask-value sets).
    word_mask_values: Vec<HashSet<u64>>,
    /// Per-vertex level stamp (`level + 1` when `v` was first appended to
    /// `delta` this level) backing `delta_distinct`.
    delta_stamp: Vec<u32>,
    /// Per-`(vertex, word)` level stamp backing `word_vertices` (flat
    /// vertex-major, like `seen`).
    delta_word_stamp: Vec<u32>,
    /// The complete *current* frontier as per-vertex lane masks over ALL
    /// vertices (not just owned), flat vertex-major — what the batched
    /// bottom-up scan probes, the lane-mask analog of
    /// `ComputeNode::frontier_full`. Rebuilt at [`Self::swap_level`] from
    /// the post-exchange delta (which holds the level's complete
    /// discoveries after full coverage). Allocated only when
    /// [`Self::set_full_tracking`] enables it.
    visit_full: Vec<u64>,
    /// Vertices with a nonzero `visit_full` mask, so clearing costs
    /// O(frontier·W).
    visit_full_touched: Vec<VertexId>,
    /// Whether `swap_level` maintains `visit_full` (bottom-up-capable
    /// direction modes only; pure top-down batches skip the upkeep).
    track_full: bool,
}

impl<const W: usize> MsBfsNodeState<W> {
    /// Fresh state for a `num_vertices`-vertex graph and a batch of
    /// `num_roots <= 64·W` lanes (lanes beyond the width are simply never
    /// set).
    pub fn new(num_vertices: usize, num_roots: usize) -> Self {
        debug_assert!(num_roots <= W * LANES_PER_WORD);
        Self {
            num_vertices,
            seen: vec![0; num_vertices * W],
            dist: vec![INF; num_vertices * num_roots],
            visit: vec![0; num_vertices * W],
            next_mask: vec![0; num_vertices * W],
            q_local: Vec::new(),
            q_local_next: Vec::new(),
            delta: MaskFrontier::new(),
            edges_this_level: 0,
            delta_distinct: 0,
            mask_values: HashSet::new(),
            active_lanes: [0; W],
            word_entries: [0; W],
            word_vertices: [0; W],
            group_words: 0,
            word_mask_values: (0..W).map(|_| HashSet::new()).collect(),
            delta_stamp: vec![0; num_vertices],
            delta_word_stamp: vec![0; num_vertices * W],
            visit_full: Vec::new(),
            visit_full_touched: Vec::new(),
            track_full: false,
        }
    }

    /// Enable or disable full-frontier tracking. The batched engine turns
    /// this on for bottom-up-capable direction modes before seeding a
    /// batch; the dense mask array is allocated on first enable and kept
    /// across [`Self::reset`] (pooled reuse).
    pub fn set_full_tracking(&mut self, on: bool) {
        self.track_full = on;
        if on && self.visit_full.is_empty() {
            self.visit_full = vec![0; self.num_vertices * W];
        }
    }

    /// Seed lanes `mask` of vertex `v` into the level-0 full frontier
    /// (the batch prologue: every node knows every root).
    pub fn seed_full_frontier(&mut self, v: VertexId, mask: &LaneMask<W>) {
        debug_assert!(self.track_full, "seeding without tracking enabled");
        let base = v as usize * W;
        if self.visit_full[base..base + W].iter().all(|&x| x == 0) {
            self.visit_full_touched.push(v);
        }
        for w in 0..W {
            self.visit_full[base + w] |= mask[w];
        }
    }

    /// The complete current frontier as flat vertex-major per-vertex lane
    /// masks (empty slice unless tracking is enabled).
    pub fn full_frontier(&self) -> &[u64] {
        &self.visit_full
    }

    /// Wire cost of this node's current delta prefix of `entries` entries
    /// under the negotiated encoding, using this level's accumulated
    /// coalescing statistics (see [`mask_delta_bytes`]). The statistics are
    /// monotone within a level, so snapshotting them alongside the prefix
    /// length prices exactly that prefix's best serialization bound.
    pub fn delta_payload_bytes(&self, entries: usize) -> u64 {
        let e = entries as u64;
        let whole = mask_delta_bytes(
            &MaskDeltaStats {
                entries: e,
                distinct_vertices: self.delta_distinct.min(e),
                distinct_masks: (self.mask_values.len() as u64).min(e),
                active_lanes: lane_mask_count(&self.active_lanes),
                active_words: self.active_lanes.iter().filter(|&&w| w != 0).count()
                    as u32,
                entry_words: self.word_entries.iter().sum(),
                vertex_words: self.word_vertices.iter().sum(),
                group_words: self.group_words,
            },
            self.num_vertices,
            W,
        );
        if W == 1 {
            return whole;
        }
        whole.min(self.per_word_bytes(false))
    }

    /// The cohort-factored serialization: the wide delta shipped as up to
    /// `W` independent single-word messages, one per active 64-lane
    /// cohort, each priced by the original `W = 1` negotiation on that
    /// cohort's own statistics (`dense_only` restricts each cohort to the
    /// dense bottom-up forms). This is exactly what executing the batch
    /// as 64-root chunks would ship, so widening the lanes never prices
    /// *worse* than chunked execution — the whole-mask forms then win
    /// whenever lanes coalesce across cohorts.
    fn per_word_bytes(&self, dense_only: bool) -> u64 {
        (0..W)
            .map(|w| {
                let e = self.word_entries[w];
                let dv = self.word_vertices[w];
                let al = self.active_lanes[w].count_ones();
                if dense_only {
                    mask_delta_bytes_dense(dv, u32::from(dv > 0), al, self.num_vertices)
                } else {
                    let dm = (self.word_mask_values[w].len() as u64).min(e);
                    mask_delta_bytes(
                        &MaskDeltaStats {
                            entries: e,
                            distinct_vertices: dv.min(e),
                            distinct_masks: dm,
                            active_lanes: al,
                            active_words: u32::from(e > 0),
                            entry_words: e,
                            vertex_words: dv.min(e),
                            group_words: dm,
                        },
                        self.num_vertices,
                        1,
                    )
                }
            })
            .sum()
    }

    /// Bottom-up pricing of the current delta prefix: the dense presence-
    /// bitmap forms only (see [`mask_delta_bytes_dense`]) — the wire
    /// format of a bottom-up level, whose discoveries come out of a dense
    /// owned-range sweep rather than a sorted sparse queue.
    pub fn delta_payload_bytes_dense(&self, entries: usize) -> u64 {
        if entries == 0 {
            return 0;
        }
        let whole = mask_delta_bytes_dense(
            self.word_vertices.iter().sum(),
            self.active_lanes.iter().filter(|&&w| w != 0).count() as u32,
            lane_mask_count(&self.active_lanes),
            self.num_vertices,
        );
        if W == 1 {
            return whole;
        }
        whole.min(self.per_word_bytes(true))
    }

    /// Record that lanes `mask` reached `v` at `level + 1`; only lanes new
    /// to this node take effect. Appends the filtered delta for relay and,
    /// when `owned`, routes `v` into the next local frontier. Returns
    /// whether any lane was newly set. This is the shared inner step of
    /// Phase 1 (edge expansion) and Phase 2 (received deltas), mirroring
    /// `ComputeNode::discover`.
    #[inline]
    pub fn discover(&mut self, v: VertexId, mask: &LaneMask<W>, level: u32, owned: bool) -> bool {
        let base = v as usize * W;
        let mut d = [0u64; W];
        let mut found = 0u64;
        for w in 0..W {
            d[w] = mask[w] & !self.seen[base + w];
            found |= d[w];
        }
        if found == 0 {
            return false;
        }
        for w in 0..W {
            self.seen[base + w] |= d[w];
        }
        let nv = self.num_vertices;
        stamp_lanes(&mut self.dist, nv, v as usize, &d, level + 1);
        self.delta.push(v, d);
        // Coalescing statistics for the negotiated payload encoding.
        if self.delta_stamp[v as usize] != level + 1 {
            self.delta_stamp[v as usize] = level + 1;
            self.delta_distinct += 1;
        }
        let mut nzw = 0u64;
        for w in 0..W {
            self.active_lanes[w] |= d[w];
            if d[w] != 0 {
                nzw += 1;
                self.word_entries[w] += 1;
                self.word_mask_values[w].insert(d[w]);
                if self.delta_word_stamp[base + w] != level + 1 {
                    self.delta_word_stamp[base + w] = level + 1;
                    self.word_vertices[w] += 1;
                }
            }
        }
        if self.mask_values.insert(d) {
            self.group_words += nzw;
        }
        if owned {
            if self.next_mask[base..base + W].iter().all(|&x| x == 0) {
                self.q_local_next.push(v);
            }
            for w in 0..W {
                self.next_mask[base + w] |= d[w];
            }
        }
        true
    }

    /// Clear all traversal state so the buffers can serve a fresh batch of
    /// `num_roots` lanes — the pooled-reuse path of
    /// [`QuerySession::run_batch`](crate::coordinator::session::QuerySession::run_batch):
    /// allocations are kept (the distance array only reallocates when the
    /// batch widens). Unlike [`Self::swap_level`], this *does* zero
    /// `delta_stamp`: its stamps are level-scoped and levels restart at 0
    /// in the next batch.
    pub fn reset(&mut self, num_roots: usize) {
        debug_assert!(num_roots <= W * LANES_PER_WORD);
        self.seen.iter_mut().for_each(|x| *x = 0);
        self.dist.clear();
        self.dist.resize(self.num_vertices * num_roots, INF);
        self.visit.iter_mut().for_each(|x| *x = 0);
        self.next_mask.iter_mut().for_each(|x| *x = 0);
        self.q_local.clear();
        self.q_local_next.clear();
        self.delta.clear();
        self.edges_this_level = 0;
        self.delta_distinct = 0;
        self.mask_values.clear();
        self.active_lanes = [0; W];
        self.word_entries = [0; W];
        self.word_vertices = [0; W];
        self.group_words = 0;
        self.word_mask_values.iter_mut().for_each(|s| s.clear());
        self.delta_stamp.iter_mut().for_each(|x| *x = 0);
        self.delta_word_stamp.iter_mut().for_each(|x| *x = 0);
        // Nonzero `visit_full` entries are exactly the touched list.
        for &v in &self.visit_full_touched {
            let base = v as usize * W;
            self.visit_full[base..base + W].iter_mut().for_each(|x| *x = 0);
        }
        self.visit_full_touched.clear();
    }

    /// End-of-level rotation (the MS-BFS `SwapQueues`): the next local
    /// frontier becomes current (its pending masks move from `next_mask`
    /// to `visit`), and the level's delta list empties. With full-frontier
    /// tracking on, the post-exchange delta — the complete set of this
    /// level's `(vertex, lanes)` discoveries after full coverage — first
    /// becomes the next `visit_full`, mirroring how the single-root
    /// engine's post-sync global queue becomes `frontier_full`.
    pub fn swap_level(&mut self) {
        if self.track_full {
            for &v in &self.visit_full_touched {
                let base = v as usize * W;
                self.visit_full[base..base + W].iter_mut().for_each(|x| *x = 0);
            }
            self.visit_full_touched.clear();
            for &(v, m) in self.delta.entries() {
                let base = v as usize * W;
                if self.visit_full[base..base + W].iter().all(|&x| x == 0) {
                    self.visit_full_touched.push(v);
                }
                for w in 0..W {
                    self.visit_full[base + w] |= m[w];
                }
            }
        }
        self.q_local.clear();
        std::mem::swap(&mut self.q_local, &mut self.q_local_next);
        for &v in &self.q_local {
            let base = v as usize * W;
            for w in 0..W {
                self.visit[base + w] = self.next_mask[base + w];
                self.next_mask[base + w] = 0;
            }
        }
        self.delta.clear();
        self.delta_distinct = 0;
        self.mask_values.clear();
        self.active_lanes = [0; W];
        self.word_entries = [0; W];
        self.word_vertices = [0; W];
        self.group_words = 0;
        self.word_mask_values.iter_mut().for_each(|s| s.clear());
        // `delta_stamp` / `delta_word_stamp` need no reset: stamps are
        // `level + 1`, which never recurs in later levels.
        self.edges_this_level = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::structured::{grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    fn check_against_serial(g: &Csr, roots: &[VertexId]) {
        let r = ms_bfs(g, roots);
        assert_eq!(r.num_roots(), roots.len());
        for (lane, &root) in roots.iter().enumerate() {
            assert_eq!(
                r.dist(lane),
                &serial_bfs(g, root)[..],
                "lane {lane} root {root}"
            );
        }
    }

    #[test]
    fn single_lane_equals_serial() {
        let (g, _) = uniform_random(300, 6, 11);
        check_against_serial(&g, &[0]);
        check_against_serial(&g, &[299]);
    }

    #[test]
    fn full_width_batch_equals_serial() {
        let (g, _) = uniform_random(500, 8, 3);
        let roots: Vec<VertexId> = (0..64).map(|i| (i * 7) % 500).collect();
        check_against_serial(&g, &roots);
    }

    #[test]
    fn wide_batches_equal_serial_at_every_word_count() {
        // The tentpole: widths crossing every word boundary — 65 (2
        // words), 130 (4), 260 (8), and the full 512 — all remain
        // bit-identical to per-root serial BFS.
        let (g, _) = uniform_random(250, 6, 17);
        for width in [65usize, 128, 130, 256, 260, 512] {
            let roots: Vec<VertexId> =
                (0..width).map(|i| ((i * 13 + 5) % 250) as VertexId).collect();
            check_against_serial(&g, &roots);
        }
    }

    #[test]
    fn words_for_lanes_rounds_to_supported_widths() {
        assert_eq!(words_for_lanes(1), 1);
        assert_eq!(words_for_lanes(64), 1);
        assert_eq!(words_for_lanes(65), 2);
        assert_eq!(words_for_lanes(128), 2);
        assert_eq!(words_for_lanes(129), 4);
        assert_eq!(words_for_lanes(192), 4);
        assert_eq!(words_for_lanes(256), 4);
        assert_eq!(words_for_lanes(257), 8);
        assert_eq!(words_for_lanes(512), 8);
    }

    #[test]
    #[should_panic(expected = "batch width must be 1..=512")]
    fn words_for_lanes_rejects_past_max() {
        words_for_lanes(513);
    }

    #[test]
    fn duplicate_roots_are_independent_lanes() {
        let (g, _) = uniform_random(200, 5, 9);
        let r = ms_bfs(&g, &[4, 4, 17, 4]);
        assert_eq!(r.dist(0), r.dist(1));
        assert_eq!(r.dist(0), r.dist(3));
        assert_eq!(r.dist(0), &serial_bfs(&g, 4)[..]);
        assert_eq!(r.dist(2), &serial_bfs(&g, 17)[..]);
    }

    #[test]
    fn wide_duplicate_roots_collapse_to_one_traversal() {
        // 300 identical roots (5 words worth of lanes → W = 8): every
        // lane's distances are the one traversal's distances.
        let (g, _) = uniform_random(150, 5, 21);
        let roots = vec![7u32; 300];
        let r = ms_bfs(&g, &roots);
        let want = serial_bfs(&g, 7);
        for lane in [0usize, 63, 64, 128, 255, 299] {
            assert_eq!(r.dist(lane), &want[..], "lane {lane}");
        }
    }

    #[test]
    fn structured_graphs_mixed_batch() {
        for g in [path(30), star(40), grid2d(5, 7)] {
            let n = g.num_vertices() as VertexId;
            check_against_serial(&g, &[0, n - 1, n / 2]);
        }
    }

    #[test]
    fn disconnected_lanes_stay_inf() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(20);
        b.add_edge(0, 1);
        b.add_edge(10, 11); // island
        let (g, _) = b.build_undirected();
        let r = ms_bfs(&g, &[0, 10]);
        assert_eq!(r.dist(0)[1], 1);
        assert_eq!(r.dist(0)[10], INF);
        assert_eq!(r.dist(1)[11], 1);
        assert_eq!(r.dist(1)[0], INF);
        assert_eq!(r.reached_pairs(), 4);
    }

    #[test]
    fn sample_batch_roots_prefers_connected() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(500);
        for v in 1..50u32 {
            b.add_edge(0, v);
        }
        let (g, _) = b.build_undirected();
        for width in [64usize, 512] {
            let roots = sample_batch_roots(&g, width, 5);
            assert_eq!(roots.len(), width);
            // The graph has edges, so the fallback scan guarantees every
            // sampled root is non-isolated.
            let connected = roots.iter().filter(|&&r| g.degree(r) > 0).count();
            assert_eq!(connected, roots.len());
        }
    }

    #[test]
    fn node_state_reset_equals_fresh() {
        // Pooled session reuse depends on `reset` restoring the exact
        // fresh-state invariants — including the private level stamps,
        // which `swap_level` deliberately leaves behind.
        let mut st = MsBfsNodeState::<1>::new(60, 4);
        for v in 0..20u32 {
            st.discover(v, &[0b1011], 0, v % 2 == 0);
        }
        st.edges_this_level = 9;
        st.swap_level();
        st.discover(30, &[0b1], 1, true);
        st.reset(7);
        let fresh = MsBfsNodeState::<1>::new(60, 7);
        assert_eq!(st.seen, fresh.seen);
        assert_eq!(st.dist, fresh.dist);
        assert_eq!(st.visit, fresh.visit);
        assert_eq!(st.next_mask, fresh.next_mask);
        assert_eq!(st.delta_stamp, fresh.delta_stamp);
        assert_eq!(st.delta_word_stamp, fresh.delta_word_stamp);
        assert!(st.q_local.is_empty() && st.q_local_next.is_empty());
        assert!(st.delta.is_empty());
        assert_eq!(st.edges_this_level, 0);
        assert_eq!(st.delta_distinct, 0);
        assert_eq!(st.active_lanes, [0]);
        assert!(st.mask_values.is_empty());
        assert_eq!((st.word_entries, st.word_vertices, st.group_words), ([0], [0], 0));
    }

    #[test]
    fn word_sparse_statistics_track_nonzero_words() {
        let mut st = MsBfsNodeState::<4>::new(30, 256);
        let lo = crate::bfs::frontier::lane_bit::<4>(3);
        let hi = crate::bfs::frontier::lane_bit::<4>(200);
        let mut both = lo;
        both[3] |= hi[3];
        // Entry 1: one nonzero word; entry 2 (same vertex, other word):
        // one more (vertex, word) cell; entry 3: a two-word mask at a new
        // vertex.
        st.discover(5, &lo, 0, true);
        st.discover(5, &hi, 0, true);
        st.discover(9, &both, 0, true);
        assert_eq!(st.delta_distinct, 2);
        assert_eq!(st.word_entries, [2, 0, 0, 2], "per-cohort entry counts");
        assert_eq!(
            st.word_vertices,
            [2, 0, 0, 2],
            "cells (5,w0) (5,w3) (9,w0) (9,w3)"
        );
        assert_eq!(st.mask_values.len(), 3);
        assert_eq!(st.group_words, 4, "1 + 1 + 2 over distinct whole masks");
        // A repeated whole-mask value adds entry cells but no group words.
        st.discover(11, &lo, 0, true);
        assert_eq!(st.word_entries, [3, 0, 0, 2]);
        assert_eq!(st.group_words, 4);
        assert_eq!(st.word_vertices, [3, 0, 0, 2]);
    }

    #[test]
    fn cohort_factored_pricing_never_beats_whole_but_bounds_chunked() {
        // A node whose delta holds two independent cohorts prices no
        // worse than the two single-word messages a chunked execution
        // would ship; a coalesced cross-cohort mask prices strictly
        // better than the factored form.
        let mut st = MsBfsNodeState::<2>::new(1000, 128);
        for v in 0..50u32 {
            let mut m = [0u64; 2];
            m[(v % 2) as usize] = 0b11;
            st.discover(v, &m, 0, true);
        }
        let factored = st.delta_payload_bytes(st.delta.len());
        // Each cohort: 25 entries, 1 distinct mask → grouped 12 + 100.
        assert_eq!(factored, 2 * (12 + 100));
        // Coalesced: every vertex gains the same two-word mask.
        let mut co = MsBfsNodeState::<2>::new(1000, 128);
        let m = [0b11u64, 0b11u64];
        for v in 0..50u32 {
            co.discover(v, &m, 0, true);
        }
        // Whole-mask grouped: one (5 + 16)-byte header + 4·50 vertex ids,
        // beating the factored 2 × (12 + 100).
        assert_eq!(co.delta_payload_bytes(co.delta.len()), 5 + 16 + 200);
    }

    #[test]
    fn wide_node_state_discover_and_reset() {
        let mut st = MsBfsNodeState::<4>::new(50, 200);
        // Lane 150 lives in word 2; discovering it twice filters to once.
        let m = crate::bfs::frontier::lane_bit::<4>(150);
        assert!(st.discover(9, &m, 0, true));
        assert!(!st.discover(9, &m, 0, true), "already seen");
        assert_eq!(st.dist[150 * 50 + 9], 1);
        assert_eq!(st.delta.len(), 1);
        assert_eq!(st.active_lanes, m);
        assert_eq!(st.q_local_next, vec![9]);
        st.reset(130);
        let fresh = MsBfsNodeState::<4>::new(50, 130);
        assert_eq!(st.seen, fresh.seen);
        assert_eq!(st.dist, fresh.dist);
        assert_eq!(st.active_lanes, [0; 4]);
    }

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(3), 0b111);
        assert_eq!(full_mask(63), u64::MAX >> 1);
        assert_eq!(full_mask(64), u64::MAX);
    }

    #[test]
    fn full_lane_mask_widths() {
        assert_eq!(full_lane_mask::<1>(5), [0b11111]);
        assert_eq!(full_lane_mask::<2>(64), [u64::MAX, 0]);
        assert_eq!(full_lane_mask::<2>(65), [u64::MAX, 1]);
        assert_eq!(full_lane_mask::<4>(200), [u64::MAX, u64::MAX, u64::MAX, 0xFF]);
        assert_eq!(full_lane_mask::<8>(512), [u64::MAX; 8]);
    }

    /// Convenience: stats with the `W = 1` identities filled in from the
    /// counts (one nonzero word per nonzero mask) unless overridden.
    fn stats(e: u64, dv: u64, dm: u64, al: u32) -> MaskDeltaStats {
        MaskDeltaStats {
            entries: e,
            distinct_vertices: dv,
            distinct_masks: dm,
            active_lanes: al,
            active_words: 1,
            entry_words: e,
            vertex_words: dv,
            group_words: dm,
        }
    }

    #[test]
    fn dense_pricing_is_the_dense_arms_of_the_negotiation() {
        // 640 vertices => presence bitmap = 80 bytes.
        assert_eq!(mask_delta_bytes_dense(0, 0, 0, 640), 0);
        // Arm 3: active_words·presence + 8·cells; arm 4: (1+lanes)·presence.
        assert_eq!(mask_delta_bytes_dense(10, 1, 63, 640), 80 + 80);
        assert_eq!(mask_delta_bytes_dense(500, 1, 1, 640), 2 * 80);
        // Wide: one presence bitmap per active 64-lane cohort — idle
        // provisioned words cost nothing.
        assert_eq!(mask_delta_bytes_dense(25, 4, 255, 640), 4 * 80 + 200);
        assert_eq!(mask_delta_bytes_dense(500, 8, 1, 640), 2 * 80);
        // The dense forms are always an upper bound on the full
        // negotiation (which may also pick a sparse arm).
        for words in [1usize, 2, 4, 8] {
            for (e, dv, dm, al) in [(5u64, 5u64, 2u64, 7u32), (300, 200, 40, 64)] {
                assert!(
                    mask_delta_bytes(&stats(e, dv, dm, al), 640, words)
                        <= mask_delta_bytes_dense(dv, 1, al, 640)
                );
            }
        }
    }

    #[test]
    fn mask_delta_bytes_reprices_every_arm_for_width() {
        // Pin each arm at a width where it wins.
        // Sparse, W = 2: 4-byte id + word byte + one nonzero word each.
        assert_eq!(mask_delta_bytes(&stats(3, 3, 3, 100), 10_000, 2), 3 * (5 + 8));
        // Sparse, W = 2, both words nonzero per entry (and per distinct
        // mask, so the grouped arm pays the same word cost).
        let two_words = MaskDeltaStats {
            active_words: 2,
            entry_words: 6,
            group_words: 6,
            ..stats(3, 3, 3, 100)
        };
        assert_eq!(mask_delta_bytes(&two_words, 10_000, 2), 3 * 5 + 48);
        // Grouped: many entries, one mask value (W = 8 word-sparse header
        // with 8 nonzero words = 5 + 64 B).
        let grouped = MaskDeltaStats {
            active_words: 8,
            group_words: 8,
            ..stats(100, 100, 1, 512)
        };
        assert_eq!(mask_delta_bytes(&grouped, 1 << 20, 8), 5 + 64 + 400);
        // Per-word presence + packed masks: 2 active cohorts at 640
        // vertices, 8 cells.
        let presence = (640u64).div_ceil(64) * 8;
        let dense = MaskDeltaStats {
            active_words: 2,
            vertex_words: 8,
            ..stats(600, 2, 600, 512)
        };
        assert_eq!(mask_delta_bytes(&dense, 640, 4), 2 * presence + 64);
        // Lane bitmaps: one active lane in a wide batch still prices at
        // two bitmaps (width-independent arm).
        assert_eq!(mask_delta_bytes(&stats(600, 600, 600, 1), 640, 8), 2 * presence);
        // W = 1 is exactly the legacy pricing (12·dm + 4·e grouped arm).
        assert_eq!(
            mask_delta_bytes(&stats(10, 8, 3, 7), 640, 1),
            (3 * 12 + 10 * 4).min(120)
        );
    }

    #[test]
    fn ms_bfs_dir_all_policies_match_topdown_oracle() {
        let (g, _) = uniform_random(400, 8, 21);
        let roots: Vec<VertexId> = (0..48).map(|i| (i * 5) % 400).collect();
        let want = ms_bfs(&g, &roots);
        for dir in [
            MsBfsDirection::TopDown,
            MsBfsDirection::BottomUp,
            MsBfsDirection::DirOpt(DirOptParams::default()),
        ] {
            let r = ms_bfs_dir(&g, &roots, dir);
            for lane in 0..roots.len() {
                assert_eq!(r.result.dist(lane), want.dist(lane), "{dir:?} lane {lane}");
            }
        }
    }

    #[test]
    fn ms_bfs_dir_wide_batches_match_serial() {
        let (g, _) = uniform_random(200, 6, 31);
        for width in [96usize, 140, 300] {
            let roots: Vec<VertexId> =
                (0..width).map(|i| ((i * 11 + 1) % 200) as VertexId).collect();
            for dir in [
                MsBfsDirection::TopDown,
                MsBfsDirection::BottomUp,
                MsBfsDirection::DirOpt(DirOptParams::default()),
            ] {
                let r = ms_bfs_dir(&g, &roots, dir);
                for (lane, &root) in roots.iter().enumerate() {
                    assert_eq!(
                        r.result.dist(lane),
                        &serial_bfs(&g, root)[..],
                        "{dir:?} width {width} lane {lane}"
                    );
                }
            }
        }
    }

    #[test]
    fn ms_bfs_dir_bottom_up_inspects_fewer_edges_on_dense_levels() {
        // A star's level 1 (from the center) is the densest possible
        // frontier: top-down scatters every leaf edge per active lane
        // pass, while bottom-up early-exits after one probe per leaf.
        let g = star(800);
        let roots = vec![0u32; 32];
        let td = ms_bfs_dir(&g, &roots, MsBfsDirection::TopDown);
        let bu = ms_bfs_dir(&g, &roots, MsBfsDirection::BottomUp);
        for lane in 0..roots.len() {
            assert_eq!(td.result.dist(lane), bu.result.dist(lane));
        }
        let td_edges: u64 = td.levels.iter().map(|l| l.edges_inspected).sum();
        let bu_edges: u64 = bu.levels.iter().map(|l| l.edges_inspected).sum();
        assert!(bu_edges < td_edges, "BU {bu_edges} vs TD {td_edges}");
        assert!(bu.levels.iter().all(|l| l.bottom_up));
        assert!(td.levels.iter().all(|l| !l.bottom_up));
    }

    #[test]
    fn ms_bfs_dir_diropt_switches_and_matches() {
        let (g, _) = uniform_random(2000, 16, 6);
        let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 31) % 2000).collect();
        let run = ms_bfs_dir(&g, &roots, MsBfsDirection::DirOpt(DirOptParams::default()));
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(run.result.dist(lane), want.dist(lane));
        }
        // A dense small-world batch must actually switch bottom-up…
        assert!(run.levels.iter().any(|l| l.bottom_up), "{:?}", run.levels);
        // …and save edges against pure top-down.
        let td = ms_bfs_dir(&g, &roots, MsBfsDirection::TopDown);
        let do_edges: u64 = run.levels.iter().map(|l| l.edges_inspected).sum();
        let td_edges: u64 = td.levels.iter().map(|l| l.edges_inspected).sum();
        assert!(do_edges < td_edges, "DO {do_edges} vs TD {td_edges}");
    }

    #[test]
    fn node_state_full_frontier_tracking() {
        let mut st = MsBfsNodeState::<1>::new(40, 8);
        st.set_full_tracking(true);
        st.seed_full_frontier(3, &[0b1]);
        st.seed_full_frontier(3, &[0b10]);
        assert_eq!(st.full_frontier()[3], 0b11);
        // A level's post-exchange delta becomes the next full frontier.
        st.discover(7, &[0b101], 0, true);
        st.discover(9, &[0b1], 0, false);
        st.swap_level();
        assert_eq!(st.full_frontier()[3], 0, "previous frontier cleared");
        assert_eq!(st.full_frontier()[7], 0b101);
        assert_eq!(st.full_frontier()[9], 0b1);
        // Reset restores the all-zero frontier without reallocating.
        st.reset(8);
        assert!(st.full_frontier().iter().all(|&m| m == 0));
    }

    #[test]
    fn wide_node_state_full_frontier_tracking() {
        let mut st = MsBfsNodeState::<2>::new(20, 100);
        st.set_full_tracking(true);
        let hi = crate::bfs::frontier::lane_bit::<2>(99);
        st.seed_full_frontier(3, &hi);
        assert_eq!(st.full_frontier()[3 * 2 + 1], 1 << 35);
        st.discover(7, &hi, 0, true);
        st.swap_level();
        assert_eq!(st.full_frontier()[3 * 2 + 1], 0, "previous frontier cleared");
        assert_eq!(st.full_frontier()[7 * 2 + 1], 1 << 35);
    }

    #[test]
    fn property_msbfs_dir_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "ms_bfs_dir == serial per lane", |rng| {
            let n = gen::usize_in(rng, 5, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let b = gen::usize_in(rng, 1, 64);
            let dir = match rng.next_below(3) {
                0 => MsBfsDirection::TopDown,
                1 => MsBfsDirection::BottomUp,
                _ => MsBfsDirection::DirOpt(DirOptParams::default()),
            };
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let r = ms_bfs_dir(&g, &roots, dir);
            let ok = roots
                .iter()
                .enumerate()
                .all(|(lane, &root)| r.result.dist(lane) == &serial_bfs(&g, root)[..]);
            (ok, format!("n={n} ef={ef} b={b} {dir:?}"))
        });
    }

    #[test]
    fn property_msbfs_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "ms_bfs == serial per lane", |rng| {
            let n = gen::usize_in(rng, 5, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            // Bias toward single-word widths but cross the word boundary
            // regularly.
            let b = gen::usize_in(rng, 1, 150);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let r = ms_bfs(&g, &roots);
            let ok = roots
                .iter()
                .enumerate()
                .all(|(lane, &root)| r.dist(lane) == &serial_bfs(&g, root)[..]);
            (ok, format!("n={n} ef={ef} b={b}"))
        });
    }

    use crate::graph::csr::Csr;
}
