//! Bit-parallel batched multi-source BFS (MS-BFS).
//!
//! APSP-class analytics (closeness / betweenness centrality, reachability
//! sampling) run hundreds of traversals back-to-back — exactly the regime
//! the paper keeps a fast top-down path for, because "direction optimizing
//! BFS does not apply to all problems requiring a BFS traversal". Running
//! those traversals one at a time pays the full per-level synchronization
//! cost (schedule rounds, message latency, payload bytes) once per root.
//!
//! MS-BFS (Then et al., *The More the Merrier: Efficient Multi-Source BFS*)
//! amortizes that cost: every vertex carries a 64-bit **lane mask** — bit
//! `i` set means "already seen by the traversal rooted at `roots[i]`" —
//! and a level expansion ORs frontier masks into neighbor masks. Up to 64
//! traversals advance in lock-step through *one* frontier sweep, and, in
//! the distributed engine, through *one* butterfly exchange per level
//! ([`crate::coordinator::session::QuerySession::run_batch`]). The exchange
//! ships `(vertex, mask-delta)` payloads priced by the negotiated encoding
//! [`mask_delta_bytes`] (the coalescing-agnostic bound is
//! [`PayloadEncoding::MaskDelta`](crate::coordinator::config::PayloadEncoding)),
//! so one round of communication serves the whole batch: schedule setup,
//! per-message latency, and dedup traffic are paid once instead of 64
//! times.
//!
//! This module holds the single-node bit-parallel engine ([`ms_bfs`], the
//! oracle and CPU baseline), the per-root result view ([`MsBfsResult`]),
//! and the per-compute-node distributed state ([`MsBfsNodeState`]) that
//! `run_batch` drives through the butterfly schedule.
//!
//! Semantics are identical to running [`serial_bfs`](crate::bfs::serial)
//! once per root (property-tested in `tests/msbfs_equivalence.rs`):
//! levels are synchronous, so the first level at which a lane reaches a
//! vertex is that lane's BFS distance. Duplicate roots simply occupy two
//! lanes that evolve identically.

use crate::bfs::dirop::DirOptParams;
use crate::bfs::frontier::MaskFrontier;
use crate::bfs::serial::INF;
use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;
use std::collections::HashSet;

/// Maximum batch width: one lane per bit of the `u64` mask.
pub const MAX_BATCH: usize = 64;

/// Mask with the low `width` lanes set — "every lane of the batch".
#[inline]
pub fn full_mask(width: usize) -> u64 {
    debug_assert!(width >= 1 && width <= MAX_BATCH);
    if width == MAX_BATCH {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Negotiated wire cost of one MS-BFS delta message. The sender serializes
/// its delta prefix in whichever of four equivalent forms is smallest:
///
/// 1. **Sparse pairs** — `12` bytes per entry (`u32` vertex + `u64` mask).
/// 2. **Mask-grouped sparse** — entries grouped by mask value: per group a
///    mask + count header (`12` bytes) plus `4` bytes per entry (each
///    entry's vertex id listed once, in its group). Lanes travel
///    together, so few distinct mask values cover many entries — this is
///    the redundancy 64 *separate* traversals cannot exploit, and where
///    the batch's byte win comes from.
/// 3. **Presence bitmap + packed masks** — `⌈V/64⌉·8` bytes marking which
///    vertices changed, plus `8` bytes per distinct changed vertex.
/// 4. **Per-active-lane bitmaps** — `(1 + active_lanes)·⌈V/64⌉·8` bytes
///    (a presence bitmap per lane that appears in the delta); degenerates
///    to the single-root bitmap bound when only one lane is active.
///
/// `entries` counts delta-list entries, `distinct_vertices` the distinct
/// vertices among them, `distinct_masks` the distinct mask values, and
/// `active_lanes` the population count of the OR of all masks.
pub fn mask_delta_bytes(
    entries: u64,
    distinct_vertices: u64,
    distinct_masks: u64,
    active_lanes: u32,
    num_vertices: usize,
) -> u64 {
    if entries == 0 {
        return 0;
    }
    let presence = (num_vertices as u64).div_ceil(64) * 8;
    let sparse = entries * MaskFrontier::ENTRY_BYTES;
    let grouped = distinct_masks * 12 + entries * 4;
    let dense = presence + distinct_vertices * 8;
    let lane_bitmaps = (1 + active_lanes as u64) * presence;
    sparse.min(grouped).min(dense).min(lane_bitmaps)
}

/// Wire cost of a bottom-up level's delta under the *dense* (presence-
/// bitmap) forms only — arms 3 and 4 of [`mask_delta_bytes`]. A bottom-up
/// scan produces its discoveries as a dense sweep over the sender's owned
/// vertex range, so the natural wire format is a presence bitmap plus
/// either packed per-vertex masks (arm 3) or one bitmap per active lane
/// (arm 4); the sorted sparse forms would require an extra compaction
/// pass the sender never runs.
pub fn mask_delta_bytes_dense(
    distinct_vertices: u64,
    active_lanes: u32,
    num_vertices: usize,
) -> u64 {
    if distinct_vertices == 0 {
        return 0;
    }
    let presence = (num_vertices as u64).div_ceil(64) * 8;
    let dense = presence + distinct_vertices * 8;
    let lane_bitmaps = (1 + active_lanes as u64) * presence;
    dense.min(lane_bitmaps)
}

/// Distances of a batched traversal: one full distance array per lane,
/// stored lane-major (`dist[lane * num_vertices + v]`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MsBfsResult {
    num_vertices: usize,
    num_roots: usize,
    dist: Vec<u32>,
}

impl MsBfsResult {
    /// Build from raw parts (used by the engines in this crate).
    pub(crate) fn from_parts(num_vertices: usize, num_roots: usize, dist: Vec<u32>) -> Self {
        assert_eq!(dist.len(), num_vertices * num_roots);
        Self { num_vertices, num_roots, dist }
    }

    /// Number of lanes (roots) in the batch.
    pub fn num_roots(&self) -> usize {
        self.num_roots
    }

    /// Number of vertices per lane.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Distance array of lane `i` — element `v` is the BFS distance from
    /// `roots[i]` to `v`, or [`INF`] when unreachable.
    pub fn dist(&self, lane: usize) -> &[u32] {
        assert!(lane < self.num_roots, "lane {lane} out of range");
        &self.dist[lane * self.num_vertices..(lane + 1) * self.num_vertices]
    }

    /// Total `(lane, vertex)` pairs reached.
    pub fn reached_pairs(&self) -> u64 {
        self.dist.iter().filter(|&&d| d != INF).count() as u64
    }
}

/// Single-node bit-parallel MS-BFS over a full CSR: the oracle the
/// distributed `run_batch` is tested against, and the CPU baseline the
/// `msbfs_amortization` bench compares with.
///
/// One pass over the active frontier advances all `roots.len() <= 64`
/// traversals: for frontier vertex `v` with pending mask `m`, each
/// neighbor `u` gains lanes `m & !seen[u]`.
pub fn ms_bfs(g: &Csr, roots: &[VertexId]) -> MsBfsResult {
    let n = g.num_vertices();
    let b = roots.len();
    assert!(b >= 1 && b <= MAX_BATCH, "batch width must be 1..=64 (got {b})");
    let mut seen = vec![0u64; n];
    let mut visit = vec![0u64; n];
    let mut next = vec![0u64; n];
    let mut dist = vec![INF; n * b];
    for (lane, &r) in roots.iter().enumerate() {
        assert!((r as usize) < n, "root {r} out of range");
        let bit = 1u64 << lane;
        seen[r as usize] |= bit;
        visit[r as usize] |= bit;
        dist[lane * n + r as usize] = 0;
    }
    let mut level = 0u32;
    loop {
        let mut any = false;
        for v in 0..n {
            let mv = visit[v];
            if mv == 0 {
                continue;
            }
            for &u in g.neighbors(v as VertexId) {
                let d = mv & !seen[u as usize];
                if d != 0 {
                    seen[u as usize] |= d;
                    next[u as usize] |= d;
                    let mut m = d;
                    while m != 0 {
                        let lane = m.trailing_zeros() as usize;
                        m &= m - 1;
                        dist[lane * n + u as usize] = level + 1;
                    }
                    any = true;
                }
            }
        }
        if !any {
            break;
        }
        std::mem::swap(&mut visit, &mut next);
        next.iter_mut().for_each(|x| *x = 0);
        level += 1;
    }
    MsBfsResult::from_parts(n, b, dist)
}

/// Phase-1 direction policy of the direction-aware oracle — mirrors the
/// engine's `DirectionMode` without depending on the coordinator layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsBfsDirection {
    /// Classic top-down expansion every level.
    TopDown,
    /// Bottom-up lane-mask expansion every level.
    BottomUp,
    /// GapBS-style α/β switching on union-frontier edge mass.
    DirOpt(DirOptParams),
}

/// Per-level accounting of a direction-aware oracle run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsBfsLevelStats {
    /// Level index.
    pub level: u32,
    /// Distinct vertices in the union frontier entering the level.
    pub frontier: u64,
    /// Edges inspected this level (top-down: full adjacency of every
    /// frontier vertex; bottom-up: neighbors probed before early exit).
    pub edges_inspected: u64,
    /// True when the level ran bottom-up.
    pub bottom_up: bool,
}

/// Result + per-level direction trace of [`ms_bfs_dir`].
#[derive(Clone, Debug)]
pub struct MsBfsDirRun {
    /// Per-lane distances (identical to [`ms_bfs`]'s for any policy —
    /// levels are synchronous, so direction cannot change distances).
    pub result: MsBfsResult,
    /// Per-level frontier/edge/direction trace.
    pub levels: Vec<MsBfsLevelStats>,
}

/// Direction-aware single-node bit-parallel MS-BFS — the oracle for the
/// batched direction-optimizing engine path
/// ([`run_batch`](crate::coordinator::session::QuerySession::run_batch)
/// with a non-top-down `DirectionMode`).
///
/// The bottom-up formulation (Then et al. §aggregated neighbor
/// processing, composed with Beamer's direction switch): a vertex `v`
/// with `seen[v] != full` scans its neighbors `u`, accumulating
/// `acc |= visit[u]`, and early-exits once `acc` covers every lane still
/// missing at `v` — one sequential read per unseen vertex replaces
/// per-edge top-down scatter at dense levels. The α/β heuristic runs on
/// *union-frontier* statistics: the frontier's edge mass is
/// `Σ deg(v)` over distinct active vertices (a vertex active in many
/// lanes still costs one adjacency read), compared against the edge mass
/// not yet claimed by any lane's traversal.
pub fn ms_bfs_dir(g: &Csr, roots: &[VertexId], direction: MsBfsDirection) -> MsBfsDirRun {
    let n = g.num_vertices();
    let b = roots.len();
    assert!(b >= 1 && b <= MAX_BATCH, "batch width must be 1..=64 (got {b})");
    let full = full_mask(b);
    let mut seen = vec![0u64; n];
    let mut visit = vec![0u64; n];
    let mut next = vec![0u64; n];
    let mut dist = vec![INF; n * b];
    for (lane, &r) in roots.iter().enumerate() {
        assert!((r as usize) < n, "root {r} out of range");
        let bit = 1u64 << lane;
        seen[r as usize] |= bit;
        visit[r as usize] |= bit;
        dist[lane * n + r as usize] = 0;
    }
    let mut levels = Vec::new();
    let mut level = 0u32;
    let mut bottom_up = false;
    let mut prev_frontier = 0u64;
    let mut m_unexplored = g.num_edges();
    loop {
        let frontier = visit.iter().filter(|&&m| m != 0).count() as u64;
        if frontier == 0 {
            break;
        }
        match direction {
            MsBfsDirection::TopDown => {}
            MsBfsDirection::BottomUp => bottom_up = true,
            MsBfsDirection::DirOpt(DirOptParams { alpha, beta }) => {
                let m_frontier: u64 = visit
                    .iter()
                    .enumerate()
                    .filter(|&(_, &m)| m != 0)
                    .map(|(v, _)| g.degree(v as VertexId) as u64)
                    .sum();
                let growing = frontier > prev_frontier;
                if !bottom_up && alpha > 0 && growing && m_frontier > m_unexplored / alpha {
                    bottom_up = true;
                } else if bottom_up
                    && beta > 0
                    && !growing
                    && frontier < (n as u64) / beta
                {
                    bottom_up = false;
                }
                prev_frontier = frontier;
            }
        }
        let mut edges = 0u64;
        let mut any = false;
        if bottom_up {
            for v in 0..n {
                let missing = full & !seen[v];
                if missing == 0 {
                    continue;
                }
                let mut acc = 0u64;
                for &u in g.neighbors(v as VertexId) {
                    edges += 1;
                    acc |= visit[u as usize];
                    if acc & missing == missing {
                        // Every still-missing lane found a parent — the
                        // early exit that makes dense levels cheap.
                        break;
                    }
                }
                let d = acc & missing;
                if d != 0 {
                    seen[v] |= d;
                    next[v] |= d;
                    let mut m = d;
                    while m != 0 {
                        let lane = m.trailing_zeros() as usize;
                        m &= m - 1;
                        dist[lane * n + v] = level + 1;
                    }
                    any = true;
                }
            }
        } else {
            for v in 0..n {
                let mv = visit[v];
                if mv == 0 {
                    continue;
                }
                edges += g.degree(v as VertexId) as u64;
                for &u in g.neighbors(v as VertexId) {
                    let d = mv & !seen[u as usize];
                    if d != 0 {
                        seen[u as usize] |= d;
                        next[u as usize] |= d;
                        let mut m = d;
                        while m != 0 {
                            let lane = m.trailing_zeros() as usize;
                            m &= m - 1;
                            dist[lane * n + u as usize] = level + 1;
                        }
                        any = true;
                    }
                }
            }
        }
        levels.push(MsBfsLevelStats { level, frontier, edges_inspected: edges, bottom_up });
        if let MsBfsDirection::DirOpt(_) = direction {
            let next_edges: u64 = next
                .iter()
                .enumerate()
                .filter(|&(_, &m)| m != 0)
                .map(|(v, _)| g.degree(v as VertexId) as u64)
                .sum();
            m_unexplored = m_unexplored.saturating_sub(next_edges);
        }
        if !any {
            break;
        }
        std::mem::swap(&mut visit, &mut next);
        next.iter_mut().for_each(|x| *x = 0);
        level += 1;
    }
    MsBfsDirRun {
        result: MsBfsResult::from_parts(n, b, dist),
        levels,
    }
}

/// Sample `width` roots for a batch. Non-isolated vertices are
/// guaranteed whenever the graph has any edge: after a few random
/// retries the sampler falls back to a deterministic wrapping scan for
/// the next vertex with degree > 0 (so an unlucky lane can never land on
/// an isolated vertex, unlike a bounded-retry sampler). Duplicates are
/// allowed — MS-BFS handles them as independent lanes.
pub fn sample_batch_roots(g: &Csr, width: usize, seed: u64) -> Vec<VertexId> {
    let n = g.num_vertices();
    assert!(n > 0, "empty graph");
    assert!(width >= 1 && width <= MAX_BATCH);
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let mut roots = Vec::with_capacity(width);
    while roots.len() < width {
        let mut v = rng.next_usize(n) as VertexId;
        for _ in 0..8 {
            if g.degree(v) > 0 {
                break;
            }
            v = rng.next_usize(n) as VertexId;
        }
        if g.degree(v) == 0 {
            // Wrapping scan from v: first non-isolated vertex, if any.
            for off in 1..n {
                let u = ((v as usize + off) % n) as VertexId;
                if g.degree(u) > 0 {
                    v = u;
                    break;
                }
            }
        }
        roots.push(v);
    }
    roots
}

/// Per-compute-node state of one distributed batched traversal — the
/// MS-BFS analog of [`ComputeNode`](crate::coordinator::node::ComputeNode)'s
/// queues, created fresh by `run_batch` and driven through the same
/// butterfly schedule the single-root engine uses.
///
/// The node's *global queue* analog is [`MsBfsNodeState::delta`]: every
/// `(vertex, lane-mask)` pair this node discovered or relayed this level —
/// the butterfly payload.
#[derive(Clone, Debug)]
pub struct MsBfsNodeState {
    num_vertices: usize,
    /// Per-vertex lanes already seen by this node (`seen[v]` bit `i` ⇔
    /// lane `i` reached `v` as far as this node knows).
    pub seen: Vec<u64>,
    /// Lane-major distances, `dist[lane * V + v]` (every node records all
    /// lanes — the paper's "All CN set their d" — so agreement is
    /// checkable).
    pub dist: Vec<u32>,
    /// Pending masks of the *current* level's owned frontier vertices.
    pub visit: Vec<u64>,
    /// Accumulated masks for the *next* level's owned frontier.
    pub next_mask: Vec<u64>,
    /// Owned vertices with a nonzero `visit` mask (current level).
    pub q_local: Vec<VertexId>,
    /// Owned vertices with a nonzero `next_mask` (next level).
    pub q_local_next: Vec<VertexId>,
    /// Everything this node learned this level — phase-1 discoveries plus
    /// butterfly-relayed deltas, each entry's mask holding only the lanes
    /// that were new to this node when it was appended.
    pub delta: MaskFrontier,
    /// Edges examined by this node in the current level (metrics).
    pub edges_this_level: u64,
    /// Distinct vertices in `delta` (for [`mask_delta_bytes`] pricing).
    pub delta_distinct: u64,
    /// Distinct mask values in `delta` (pricing).
    pub mask_values: HashSet<u64>,
    /// OR of all masks in `delta` — which lanes are active this level
    /// (pricing).
    pub active_lanes: u64,
    /// Per-vertex level stamp (`level + 1` when `v` was first appended to
    /// `delta` this level) backing `delta_distinct`.
    delta_stamp: Vec<u32>,
    /// The complete *current* frontier as per-vertex lane masks over ALL
    /// vertices (not just owned) — what the batched bottom-up scan probes,
    /// the lane-mask analog of `ComputeNode::frontier_full`. Rebuilt at
    /// [`Self::swap_level`] from the post-exchange delta (which holds the
    /// level's complete discoveries after full coverage). Allocated only
    /// when [`Self::set_full_tracking`] enables it.
    visit_full: Vec<u64>,
    /// Nonzero entries of `visit_full`, so clearing costs O(frontier).
    visit_full_touched: Vec<VertexId>,
    /// Whether `swap_level` maintains `visit_full` (bottom-up-capable
    /// direction modes only; pure top-down batches skip the upkeep).
    track_full: bool,
}

impl MsBfsNodeState {
    /// Fresh state for a `num_vertices`-vertex graph and a batch of
    /// `num_roots` lanes (lanes beyond the width are simply never set).
    pub fn new(num_vertices: usize, num_roots: usize) -> Self {
        Self {
            num_vertices,
            seen: vec![0; num_vertices],
            dist: vec![INF; num_vertices * num_roots],
            visit: vec![0; num_vertices],
            next_mask: vec![0; num_vertices],
            q_local: Vec::new(),
            q_local_next: Vec::new(),
            delta: MaskFrontier::new(),
            edges_this_level: 0,
            delta_distinct: 0,
            mask_values: HashSet::new(),
            active_lanes: 0,
            delta_stamp: vec![0; num_vertices],
            visit_full: Vec::new(),
            visit_full_touched: Vec::new(),
            track_full: false,
        }
    }

    /// Enable or disable full-frontier tracking. The batched engine turns
    /// this on for bottom-up-capable direction modes before seeding a
    /// batch; the dense mask array is allocated on first enable and kept
    /// across [`Self::reset`] (pooled reuse).
    pub fn set_full_tracking(&mut self, on: bool) {
        self.track_full = on;
        if on && self.visit_full.is_empty() {
            self.visit_full = vec![0; self.num_vertices];
        }
    }

    /// Seed lanes `mask` of vertex `v` into the level-0 full frontier
    /// (the batch prologue: every node knows every root).
    pub fn seed_full_frontier(&mut self, v: VertexId, mask: u64) {
        debug_assert!(self.track_full, "seeding without tracking enabled");
        if self.visit_full[v as usize] == 0 {
            self.visit_full_touched.push(v);
        }
        self.visit_full[v as usize] |= mask;
    }

    /// The complete current frontier as per-vertex lane masks (empty slice
    /// unless tracking is enabled).
    pub fn full_frontier(&self) -> &[u64] {
        &self.visit_full
    }

    /// Wire cost of this node's current delta prefix of `entries` entries
    /// under the negotiated encoding, using this level's accumulated
    /// coalescing statistics (see [`mask_delta_bytes`]). The statistics are
    /// monotone within a level, so snapshotting them alongside the prefix
    /// length prices exactly that prefix's best serialization bound.
    pub fn delta_payload_bytes(&self, entries: usize) -> u64 {
        mask_delta_bytes(
            entries as u64,
            self.delta_distinct.min(entries as u64),
            (self.mask_values.len() as u64).min(entries as u64),
            self.active_lanes.count_ones(),
            self.num_vertices,
        )
    }

    /// Bottom-up pricing of the current delta prefix: the dense presence-
    /// bitmap forms only (see [`mask_delta_bytes_dense`]) — the wire
    /// format of a bottom-up level, whose discoveries come out of a dense
    /// owned-range sweep rather than a sorted sparse queue.
    pub fn delta_payload_bytes_dense(&self, entries: usize) -> u64 {
        if entries == 0 {
            return 0;
        }
        mask_delta_bytes_dense(
            self.delta_distinct.min(entries as u64),
            self.active_lanes.count_ones(),
            self.num_vertices,
        )
    }

    /// Record that lanes `mask` reached `v` at `level + 1`; only lanes new
    /// to this node take effect. Appends the filtered delta for relay and,
    /// when `owned`, routes `v` into the next local frontier. Returns the
    /// newly-set lanes (0 when everything was already known). This is the
    /// shared inner step of Phase 1 (edge expansion) and Phase 2 (received
    /// deltas), mirroring `ComputeNode::discover`.
    #[inline]
    pub fn discover(&mut self, v: VertexId, mask: u64, level: u32, owned: bool) -> u64 {
        let d = mask & !self.seen[v as usize];
        if d == 0 {
            return 0;
        }
        self.seen[v as usize] |= d;
        let nv = self.num_vertices;
        let mut m = d;
        while m != 0 {
            let lane = m.trailing_zeros() as usize;
            m &= m - 1;
            self.dist[lane * nv + v as usize] = level + 1;
        }
        self.delta.push(v, d);
        // Coalescing statistics for the negotiated payload encoding.
        if self.delta_stamp[v as usize] != level + 1 {
            self.delta_stamp[v as usize] = level + 1;
            self.delta_distinct += 1;
        }
        self.active_lanes |= d;
        self.mask_values.insert(d);
        if owned {
            if self.next_mask[v as usize] == 0 {
                self.q_local_next.push(v);
            }
            self.next_mask[v as usize] |= d;
        }
        d
    }

    /// Clear all traversal state so the buffers can serve a fresh batch of
    /// `num_roots` lanes — the pooled-reuse path of
    /// [`QuerySession::run_batch`](crate::coordinator::session::QuerySession::run_batch):
    /// allocations are kept (the distance array only reallocates when the
    /// batch widens). Unlike [`Self::swap_level`], this *does* zero
    /// `delta_stamp`: its stamps are level-scoped and levels restart at 0
    /// in the next batch.
    pub fn reset(&mut self, num_roots: usize) {
        self.seen.iter_mut().for_each(|x| *x = 0);
        self.dist.clear();
        self.dist.resize(self.num_vertices * num_roots, INF);
        self.visit.iter_mut().for_each(|x| *x = 0);
        self.next_mask.iter_mut().for_each(|x| *x = 0);
        self.q_local.clear();
        self.q_local_next.clear();
        self.delta.clear();
        self.edges_this_level = 0;
        self.delta_distinct = 0;
        self.mask_values.clear();
        self.active_lanes = 0;
        self.delta_stamp.iter_mut().for_each(|x| *x = 0);
        // Nonzero `visit_full` entries are exactly the touched list.
        for &v in &self.visit_full_touched {
            self.visit_full[v as usize] = 0;
        }
        self.visit_full_touched.clear();
    }

    /// End-of-level rotation (the MS-BFS `SwapQueues`): the next local
    /// frontier becomes current (its pending masks move from `next_mask`
    /// to `visit`), and the level's delta list empties. With full-frontier
    /// tracking on, the post-exchange delta — the complete set of this
    /// level's `(vertex, lanes)` discoveries after full coverage — first
    /// becomes the next `visit_full`, mirroring how the single-root
    /// engine's post-sync global queue becomes `frontier_full`.
    pub fn swap_level(&mut self) {
        if self.track_full {
            for &v in &self.visit_full_touched {
                self.visit_full[v as usize] = 0;
            }
            self.visit_full_touched.clear();
            for &(v, m) in self.delta.entries() {
                if self.visit_full[v as usize] == 0 {
                    self.visit_full_touched.push(v);
                }
                self.visit_full[v as usize] |= m;
            }
        }
        self.q_local.clear();
        std::mem::swap(&mut self.q_local, &mut self.q_local_next);
        for &v in &self.q_local {
            self.visit[v as usize] = self.next_mask[v as usize];
            self.next_mask[v as usize] = 0;
        }
        self.delta.clear();
        self.delta_distinct = 0;
        self.mask_values.clear();
        self.active_lanes = 0;
        // `delta_stamp` needs no reset: stamps are `level + 1`, which never
        // recurs in later levels.
        self.edges_this_level = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::structured::{grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    fn check_against_serial(g: &Csr, roots: &[VertexId]) {
        let r = ms_bfs(g, roots);
        assert_eq!(r.num_roots(), roots.len());
        for (lane, &root) in roots.iter().enumerate() {
            assert_eq!(
                r.dist(lane),
                &serial_bfs(g, root)[..],
                "lane {lane} root {root}"
            );
        }
    }

    #[test]
    fn single_lane_equals_serial() {
        let (g, _) = uniform_random(300, 6, 11);
        check_against_serial(&g, &[0]);
        check_against_serial(&g, &[299]);
    }

    #[test]
    fn full_width_batch_equals_serial() {
        let (g, _) = uniform_random(500, 8, 3);
        let roots: Vec<VertexId> = (0..64).map(|i| (i * 7) % 500).collect();
        check_against_serial(&g, &roots);
    }

    #[test]
    fn duplicate_roots_are_independent_lanes() {
        let (g, _) = uniform_random(200, 5, 9);
        let r = ms_bfs(&g, &[4, 4, 17, 4]);
        assert_eq!(r.dist(0), r.dist(1));
        assert_eq!(r.dist(0), r.dist(3));
        assert_eq!(r.dist(0), &serial_bfs(&g, 4)[..]);
        assert_eq!(r.dist(2), &serial_bfs(&g, 17)[..]);
    }

    #[test]
    fn structured_graphs_mixed_batch() {
        for g in [path(30), star(40), grid2d(5, 7)] {
            let n = g.num_vertices() as VertexId;
            check_against_serial(&g, &[0, n - 1, n / 2]);
        }
    }

    #[test]
    fn disconnected_lanes_stay_inf() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(20);
        b.add_edge(0, 1);
        b.add_edge(10, 11); // island
        let (g, _) = b.build_undirected();
        let r = ms_bfs(&g, &[0, 10]);
        assert_eq!(r.dist(0)[1], 1);
        assert_eq!(r.dist(0)[10], INF);
        assert_eq!(r.dist(1)[11], 1);
        assert_eq!(r.dist(1)[0], INF);
        assert_eq!(r.reached_pairs(), 4);
    }

    #[test]
    fn sample_batch_roots_prefers_connected() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(500);
        for v in 1..50u32 {
            b.add_edge(0, v);
        }
        let (g, _) = b.build_undirected();
        let roots = sample_batch_roots(&g, 64, 5);
        assert_eq!(roots.len(), 64);
        // The graph has edges, so the fallback scan guarantees every
        // sampled root is non-isolated.
        let connected = roots.iter().filter(|&&r| g.degree(r) > 0).count();
        assert_eq!(connected, roots.len());
    }

    #[test]
    fn node_state_reset_equals_fresh() {
        // Pooled session reuse depends on `reset` restoring the exact
        // fresh-state invariants — including the private level stamps,
        // which `swap_level` deliberately leaves behind.
        let mut st = MsBfsNodeState::new(60, 4);
        for v in 0..20u32 {
            st.discover(v, 0b1011, 0, v % 2 == 0);
        }
        st.edges_this_level = 9;
        st.swap_level();
        st.discover(30, 0b1, 1, true);
        st.reset(7);
        let fresh = MsBfsNodeState::new(60, 7);
        assert_eq!(st.seen, fresh.seen);
        assert_eq!(st.dist, fresh.dist);
        assert_eq!(st.visit, fresh.visit);
        assert_eq!(st.next_mask, fresh.next_mask);
        assert_eq!(st.delta_stamp, fresh.delta_stamp);
        assert!(st.q_local.is_empty() && st.q_local_next.is_empty());
        assert!(st.delta.is_empty());
        assert_eq!(st.edges_this_level, 0);
        assert_eq!(st.delta_distinct, 0);
        assert_eq!(st.active_lanes, 0);
        assert!(st.mask_values.is_empty());
    }

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(3), 0b111);
        assert_eq!(full_mask(63), u64::MAX >> 1);
        assert_eq!(full_mask(64), u64::MAX);
    }

    #[test]
    fn dense_pricing_is_the_dense_arms_of_the_negotiation() {
        // 640 vertices => presence bitmap = 80 bytes.
        assert_eq!(mask_delta_bytes_dense(0, 5, 640), 0);
        // Arm 3: presence + 8·distinct; arm 4: (1+lanes)·presence.
        assert_eq!(mask_delta_bytes_dense(10, 63, 640), 80 + 80);
        assert_eq!(mask_delta_bytes_dense(500, 1, 640), 2 * 80);
        // The dense forms are always an upper bound on the full
        // negotiation (which may also pick a sparse arm).
        for (e, dv, dm, al) in [(5u64, 5u64, 2u64, 7u32), (300, 200, 40, 64)] {
            assert!(
                mask_delta_bytes(e, dv, dm, al, 640)
                    <= mask_delta_bytes_dense(dv, al, 640)
            );
        }
    }

    #[test]
    fn ms_bfs_dir_all_policies_match_topdown_oracle() {
        let (g, _) = uniform_random(400, 8, 21);
        let roots: Vec<VertexId> = (0..48).map(|i| (i * 5) % 400).collect();
        let want = ms_bfs(&g, &roots);
        for dir in [
            MsBfsDirection::TopDown,
            MsBfsDirection::BottomUp,
            MsBfsDirection::DirOpt(DirOptParams::default()),
        ] {
            let r = ms_bfs_dir(&g, &roots, dir);
            for lane in 0..roots.len() {
                assert_eq!(r.result.dist(lane), want.dist(lane), "{dir:?} lane {lane}");
            }
        }
    }

    #[test]
    fn ms_bfs_dir_bottom_up_inspects_fewer_edges_on_dense_levels() {
        // A star's level 1 (from the center) is the densest possible
        // frontier: top-down scatters every leaf edge per active lane
        // pass, while bottom-up early-exits after one probe per leaf.
        let g = star(800);
        let roots = vec![0u32; 32];
        let td = ms_bfs_dir(&g, &roots, MsBfsDirection::TopDown);
        let bu = ms_bfs_dir(&g, &roots, MsBfsDirection::BottomUp);
        for lane in 0..roots.len() {
            assert_eq!(td.result.dist(lane), bu.result.dist(lane));
        }
        let td_edges: u64 = td.levels.iter().map(|l| l.edges_inspected).sum();
        let bu_edges: u64 = bu.levels.iter().map(|l| l.edges_inspected).sum();
        assert!(bu_edges < td_edges, "BU {bu_edges} vs TD {td_edges}");
        assert!(bu.levels.iter().all(|l| l.bottom_up));
        assert!(td.levels.iter().all(|l| !l.bottom_up));
    }

    #[test]
    fn ms_bfs_dir_diropt_switches_and_matches() {
        let (g, _) = uniform_random(2000, 16, 6);
        let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 31) % 2000).collect();
        let run = ms_bfs_dir(&g, &roots, MsBfsDirection::DirOpt(DirOptParams::default()));
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(run.result.dist(lane), want.dist(lane));
        }
        // A dense small-world batch must actually switch bottom-up…
        assert!(run.levels.iter().any(|l| l.bottom_up), "{:?}", run.levels);
        // …and save edges against pure top-down.
        let td = ms_bfs_dir(&g, &roots, MsBfsDirection::TopDown);
        let do_edges: u64 = run.levels.iter().map(|l| l.edges_inspected).sum();
        let td_edges: u64 = td.levels.iter().map(|l| l.edges_inspected).sum();
        assert!(do_edges < td_edges, "DO {do_edges} vs TD {td_edges}");
    }

    #[test]
    fn node_state_full_frontier_tracking() {
        let mut st = MsBfsNodeState::new(40, 8);
        st.set_full_tracking(true);
        st.seed_full_frontier(3, 0b1);
        st.seed_full_frontier(3, 0b10);
        assert_eq!(st.full_frontier()[3], 0b11);
        // A level's post-exchange delta becomes the next full frontier.
        st.discover(7, 0b101, 0, true);
        st.discover(9, 0b1, 0, false);
        st.swap_level();
        assert_eq!(st.full_frontier()[3], 0, "previous frontier cleared");
        assert_eq!(st.full_frontier()[7], 0b101);
        assert_eq!(st.full_frontier()[9], 0b1);
        // Reset restores the all-zero frontier without reallocating.
        st.reset(8);
        assert!(st.full_frontier().iter().all(|&m| m == 0));
    }

    #[test]
    fn property_msbfs_dir_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "ms_bfs_dir == serial per lane", |rng| {
            let n = gen::usize_in(rng, 5, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let b = gen::usize_in(rng, 1, 64);
            let dir = match rng.next_below(3) {
                0 => MsBfsDirection::TopDown,
                1 => MsBfsDirection::BottomUp,
                _ => MsBfsDirection::DirOpt(DirOptParams::default()),
            };
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let r = ms_bfs_dir(&g, &roots, dir);
            let ok = roots
                .iter()
                .enumerate()
                .all(|(lane, &root)| r.result.dist(lane) == &serial_bfs(&g, root)[..]);
            (ok, format!("n={n} ef={ef} b={b} {dir:?}"))
        });
    }

    #[test]
    fn property_msbfs_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "ms_bfs == serial per lane", |rng| {
            let n = gen::usize_in(rng, 5, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let b = gen::usize_in(rng, 1, 64);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let r = ms_bfs(&g, &roots);
            let ok = roots
                .iter()
                .enumerate()
                .all(|(lane, &root)| r.dist(lane) == &serial_bfs(&g, root)[..]);
            (ok, format!("n={n} ef={ef} b={b}"))
        });
    }

    use crate::graph::csr::Csr;
}
