//! Trivially correct serial BFS — the oracle every other engine in the
//! repository is tested against.

use crate::graph::csr::{Csr, VertexId};
use std::collections::VecDeque;

/// Distance value for unreachable vertices.
pub const INF: u32 = u32::MAX;

/// Textbook queue-based BFS; returns the distance array (`INF` =
/// unreachable).
pub fn serial_bfs(g: &Csr, root: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![INF; n];
    if n == 0 {
        return dist;
    }
    assert!((root as usize) < n, "root {root} out of range");
    let mut q = VecDeque::new();
    dist[root as usize] = 0;
    q.push_back(root);
    while let Some(v) = q.pop_front() {
        let dv = dist[v as usize];
        for &u in g.neighbors(v) {
            if dist[u as usize] == INF {
                dist[u as usize] = dv + 1;
                q.push_back(u);
            }
        }
    }
    dist
}

/// Parent-pointer BFS used to validate traversal trees: returns
/// `parent[v]` (self for the root, `INF` cast to u32::MAX sentinel for
/// unreachable).
pub fn serial_bfs_parents(g: &Csr, root: VertexId) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut parent = vec![VertexId::MAX; n];
    if n == 0 {
        return parent;
    }
    let mut q = VecDeque::new();
    parent[root as usize] = root;
    q.push_back(root);
    while let Some(v) = q.pop_front() {
        for &u in g.neighbors(v) {
            if parent[u as usize] == VertexId::MAX {
                parent[u as usize] = v;
                q.push_back(u);
            }
        }
    }
    parent
}

/// Number of edges a top-down traversal from `root` touches (sum of
/// degrees of reachable vertices) — the denominator of *honest* TEPS, as
/// opposed to the Graph500 |E|/time convention the paper critiques.
pub fn traversed_edges(g: &Csr, dist: &[u32]) -> u64 {
    dist.iter()
        .enumerate()
        .filter(|(_, &d)| d != INF)
        .map(|(v, _)| g.degree(v as VertexId) as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen::structured::{binary_tree, path};

    #[test]
    fn unreachable_stay_inf() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        let (g, _) = b.build_undirected();
        let d = serial_bfs(&g, 0);
        assert_eq!(d, vec![0, 1, INF, INF]);
    }

    #[test]
    fn parents_form_valid_tree() {
        let g = binary_tree(31);
        let p = serial_bfs_parents(&g, 0);
        let d = serial_bfs(&g, 0);
        assert_eq!(p[0], 0);
        for v in 1..31usize {
            let pv = p[v] as usize;
            assert!(g.has_edge(p[v], v as u32));
            assert_eq!(d[v], d[pv] + 1, "parent one level up");
        }
    }

    #[test]
    fn traversed_edges_path() {
        let g = path(10); // 18 arcs total
        let d = serial_bfs(&g, 0);
        assert_eq!(traversed_edges(&g, &d), 18);
    }

    #[test]
    fn empty_graph_ok() {
        let g = Csr::from_edges(0, &[]);
        assert!(serial_bfs(&g, 0).is_empty());
    }

    use crate::graph::csr::Csr;
}
