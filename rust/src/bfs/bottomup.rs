//! Bottom-up BFS step (Beamer et al., SC'12).
//!
//! In a bottom-up step every *undiscovered* vertex scans its neighbors for
//! a parent in the current frontier and stops at the first hit. When the
//! frontier is a large fraction of the graph this examines far fewer edges
//! than top-down. The paper's algorithm is communication-compatible with
//! bottom-up (contribution 3): the traversal phase and the butterfly
//! synchronization are independent, which `coordinator::engine` exploits.

use super::frontier::Bitmap;
use super::serial::INF;
use crate::graph::csr::{Csr, VertexId};

/// One bottom-up level: for every unvisited vertex, look for a neighbor in
/// `frontier`; on a hit, set distance and join the next frontier.
/// Returns `(next_frontier, edges_examined)`.
pub fn bottomup_step(
    g: &Csr,
    frontier: &Bitmap,
    dist: &mut [u32],
    level: u32,
) -> (Bitmap, u64) {
    let n = g.num_vertices();
    let mut next = Bitmap::new(n);
    let mut edges = 0u64;
    for v in 0..n as VertexId {
        if dist[v as usize] != INF {
            continue;
        }
        for &u in g.neighbors(v) {
            edges += 1;
            if frontier.get(u) {
                dist[v as usize] = level + 1;
                next.set(v);
                break; // early exit: first parent wins
            }
        }
    }
    (next, edges)
}

/// Full bottom-up-only BFS (mainly a test vehicle; production use is via
/// the direction-optimizing driver).
pub fn bottomup_bfs(g: &Csr, root: VertexId) -> (Vec<u32>, u64) {
    let n = g.num_vertices();
    let mut dist = vec![INF; n];
    if n == 0 {
        return (dist, 0);
    }
    dist[root as usize] = 0;
    let mut frontier = Bitmap::new(n);
    frontier.set(root);
    let mut level = 0;
    let mut edges = 0;
    while !frontier.is_empty() {
        let (next, e) = bottomup_step(g, &frontier, &mut dist, level);
        edges += e;
        frontier = next;
        level += 1;
    }
    (dist, edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{complete, grid2d, path};
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn matches_serial() {
        let graphs = vec![
            path(40),
            complete(30),
            grid2d(6, 7),
            kronecker(KroneckerParams::graph500(9, 8), 7).0,
            uniform_random(400, 8, 2).0,
        ];
        for (i, g) in graphs.iter().enumerate() {
            let want = serial_bfs(g, 0);
            let (got, _) = bottomup_bfs(g, 0);
            assert_eq!(got, want, "graph {i}");
        }
    }

    #[test]
    fn early_exit_saves_edges_on_dense_graphs() {
        // On K_n from any root, bottom-up level 1 examines exactly one edge
        // per undiscovered vertex (first neighbor check hits the root's
        // frontier immediately for neighbors ordered after... actually the
        // first scanned neighbor is vertex 0 == root for all v > 0).
        let g = complete(50);
        let (_, edges_bu) = bottomup_bfs(&g, 0);
        let td = crate::bfs::topdown::topdown_bfs(&g, 0, false);
        assert!(
            edges_bu < td.edges_examined / 10,
            "bottom-up {edges_bu} vs top-down {}",
            td.edges_examined
        );
    }

    #[test]
    fn unreachable_vertices_stay_inf() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(5);
        b.add_edge(0, 1);
        let (g, _) = b.build_undirected();
        let (d, _) = bottomup_bfs(&g, 0);
        assert_eq!(d[2], INF);
        assert_eq!(d[4], INF);
    }
}
