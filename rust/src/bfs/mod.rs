//! BFS traversal engines: the serial oracle, single-node top-down /
//! bottom-up / direction-optimizing baselines (the paper's CPU columns),
//! frontier representations, and the LRB load balancer.

pub mod bottomup;
pub mod dirop;
pub mod frontier;
pub mod kernels;
pub mod lrb;
pub mod msbfs;
pub mod serial;
pub mod topdown;

pub use frontier::{Bitmap, Frontier, LaneMask, MaskFrontier};
pub use kernels::{KernelVariant, KernelWork};
pub use msbfs::{
    mask_delta_bytes, ms_bfs, words_for_lanes, MaskDeltaStats, MsBfsResult, MAX_BATCH,
    MAX_LANES,
};
pub use serial::{serial_bfs, INF};
pub use topdown::{topdown_bfs, BfsResult};
