//! Direction-optimizing BFS (Beamer et al.) — the GapBS-equivalent CPU
//! baseline of Table 1's "CPU (DO)" columns.
//!
//! Each level picks top-down or bottom-up using GapBS's two heuristics
//! with the same default constants (α = 15, β = 18):
//!
//! * switch TD → BU when `m_f > m_u / α` (edges from the frontier exceed
//!   1/α of the edges from unexplored vertices);
//! * switch BU → TD when `n_f < n / β` (frontier shrinks below |V|/β).

use super::bottomup::bottomup_step;
use super::frontier::Bitmap;
use super::serial::INF;
use super::topdown::LevelStats;
use crate::graph::csr::{Csr, VertexId};

/// Tuning constants (GapBS defaults; the paper notes per-graph tuning
/// helps but uses the defaults, as do we).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DirOptParams {
    /// TD→BU switch threshold divisor (`0` disables bottom-up entirely,
    /// degrading to classic top-down — the "CPU (TD)" baseline).
    pub alpha: u64,
    /// BU→TD switch threshold divisor.
    pub beta: u64,
}

impl Default for DirOptParams {
    fn default() -> Self {
        Self { alpha: 15, beta: 18 }
    }
}

/// Which direction a level ran in (for the metrics/ablation output).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Classic parent-finds-child.
    TopDown,
    /// Child-finds-parent.
    BottomUp,
}

/// Result of a direction-optimizing traversal.
#[derive(Clone, Debug)]
pub struct DirOptResult {
    /// Distance array.
    pub dist: Vec<u32>,
    /// Per-level stats.
    pub levels: Vec<LevelStats>,
    /// Direction chosen per level.
    pub directions: Vec<Direction>,
    /// Total edges examined (the *honest* traversal count; the Graph500
    /// convention divides |E| by time instead — see `util::stats::gteps`).
    pub edges_examined: u64,
}

/// Direction-optimizing BFS.
pub fn diropt_bfs(g: &Csr, root: VertexId, p: DirOptParams) -> DirOptResult {
    let n = g.num_vertices();
    let mut dist = vec![INF; n];
    let mut levels = Vec::new();
    let mut directions = Vec::new();
    let mut edges_total = 0u64;
    if n == 0 {
        return DirOptResult { dist, levels, directions, edges_examined: 0 };
    }
    dist[root as usize] = 0;
    let mut frontier: Vec<VertexId> = vec![root];
    // m_u: edges incident to unexplored vertices (upper bound, decremented
    // as vertices are discovered) — GapBS bookkeeping.
    let mut m_unexplored: u64 = g.num_edges();
    let mut level = 0u32;
    let mut bottom_up = false;
    let mut prev_n_frontier = 0u64;
    while !frontier.is_empty() {
        let m_frontier: u64 = frontier.iter().map(|&v| g.degree(v) as u64).sum();
        let n_frontier = frontier.len() as u64;
        // GapBS hysteresis: enter bottom-up only while the frontier is
        // *growing* (prevents flapping on plateau/band frontiers, where
        // each bottom-up entry costs a full unvisited scan), leave it only
        // once the frontier is *shrinking* below |V|/β.
        let growing = n_frontier > prev_n_frontier;
        if !bottom_up && p.alpha > 0 && growing && m_frontier > m_unexplored / p.alpha {
            bottom_up = true;
        } else if bottom_up
            && p.beta > 0
            && !growing
            && n_frontier < (n as u64) / p.beta
        {
            bottom_up = false;
        }
        prev_n_frontier = n_frontier;
        let mut stats = LevelStats { frontier_size: n_frontier, ..Default::default() };
        if bottom_up {
            directions.push(Direction::BottomUp);
            let fb = Bitmap::from_queue(n, &frontier);
            let (next, e) = bottomup_step(g, &fb, &mut dist, level);
            stats.edges_examined = e;
            stats.discovered = next.count();
            frontier = next.to_queue();
        } else {
            directions.push(Direction::TopDown);
            let mut next = Vec::new();
            for &v in &frontier {
                for &u in g.neighbors(v) {
                    stats.edges_examined += 1;
                    if dist[u as usize] == INF {
                        dist[u as usize] = level + 1;
                        next.push(u);
                        stats.discovered += 1;
                    }
                }
            }
            frontier = next;
        }
        for &v in &frontier {
            m_unexplored = m_unexplored.saturating_sub(g.degree(v) as u64);
        }
        edges_total += stats.edges_examined;
        levels.push(stats);
        level += 1;
    }
    DirOptResult { dist, levels, directions, edges_examined: edges_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::bfs::topdown::topdown_bfs;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{grid2d, path};
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn matches_serial_everywhere() {
        let graphs = vec![
            path(64),
            grid2d(8, 8),
            kronecker(KroneckerParams::graph500(11, 16), 3).0,
            uniform_random(2000, 16, 9).0,
        ];
        for (i, g) in graphs.iter().enumerate() {
            for root in [0u32, 7u32.min(g.num_vertices() as u32 - 1)] {
                let want = serial_bfs(g, root);
                let got = diropt_bfs(g, root, DirOptParams::default());
                assert_eq!(got.dist, want, "graph {i} root {root}");
            }
        }
    }

    #[test]
    fn small_world_uses_bottom_up_and_saves_edges() {
        // Kron/urand small-world graphs: the middle (huge) levels should
        // run bottom-up and examine far fewer edges than pure top-down.
        let (g, _) = uniform_random(4000, 16, 4);
        let td = topdown_bfs(&g, 0, false);
        let dor = diropt_bfs(&g, 0, DirOptParams::default());
        assert!(
            dor.directions.contains(&Direction::BottomUp),
            "expected a bottom-up level: {:?}",
            dor.directions
        );
        assert!(
            dor.edges_examined < td.edges_examined,
            "DO {} vs TD {}",
            dor.edges_examined,
            td.edges_examined
        );
    }

    #[test]
    fn high_diameter_mostly_top_down() {
        // A path frontier never exceeds 1 vertex: the heuristic may flip
        // to bottom-up briefly near the tail (when few unexplored edges
        // remain), but the overwhelming majority of levels stay top-down
        // — the §5 Webbase-2001 discussion.
        let g = path(200);
        let dor = diropt_bfs(&g, 0, DirOptParams::default());
        let bu = dor
            .directions
            .iter()
            .filter(|&&d| d == Direction::BottomUp)
            .count();
        assert!(
            bu * 10 < dor.directions.len(),
            "{bu}/{} levels bottom-up",
            dor.directions.len()
        );
    }

    #[test]
    fn directions_len_matches_levels() {
        let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 2);
        let r = diropt_bfs(&g, 0, DirOptParams::default());
        assert_eq!(r.directions.len(), r.levels.len());
    }

    #[test]
    fn custom_params_change_switching() {
        let (g, _) = uniform_random(4000, 16, 4);
        // alpha=0 disables bottom-up.
        let never = diropt_bfs(&g, 0, DirOptParams { alpha: 0, beta: 18 });
        assert!(never.directions.iter().all(|&d| d == Direction::TopDown));
    }
}
