//! Level-synchronous top-down BFS (Alg. 1 of the paper) — the single
//! compute-node baseline, and the per-node Phase-1 engine of the
//! distributed algorithm.

use super::frontier::Bitmap;
use super::lrb::bin_frontier;
use super::serial::INF;
use crate::graph::csr::{Csr, VertexId};

/// Per-level statistics (for the metrics pipeline and the honest-TEPS
/// accounting the paper discusses).
#[derive(Clone, Copy, Debug, Default)]
pub struct LevelStats {
    /// Vertices in the frontier entering this level.
    pub frontier_size: u64,
    /// Edges examined this level.
    pub edges_examined: u64,
    /// Vertices newly discovered this level.
    pub discovered: u64,
}

/// Result of a full traversal.
#[derive(Clone, Debug)]
pub struct BfsResult {
    /// Distance array (`INF` = unreachable).
    pub dist: Vec<u32>,
    /// Per-level stats.
    pub levels: Vec<LevelStats>,
    /// Total edges examined.
    pub edges_examined: u64,
}

impl BfsResult {
    /// Number of levels (eccentricity of the root + 1 frontiers).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Number of reachable vertices.
    pub fn reached(&self) -> u64 {
        self.dist.iter().filter(|&&d| d != INF).count() as u64
    }
}

/// Top-down BFS with queue frontiers and LRB-ordered edge processing.
///
/// `use_lrb` toggles Logarithmic Radix Binning of each frontier: on real
/// accelerators this is the load balancer; here it also fixes the edge
/// examination order, making runs bit-reproducible regardless of frontier
/// discovery order.
pub fn topdown_bfs(g: &Csr, root: VertexId, use_lrb: bool) -> BfsResult {
    let n = g.num_vertices();
    let mut dist = vec![INF; n];
    let mut levels = Vec::new();
    let mut edges_total = 0u64;
    if n == 0 {
        return BfsResult { dist, levels, edges_examined: 0 };
    }
    assert!((root as usize) < n, "root out of range");
    dist[root as usize] = 0;
    let mut frontier = vec![root];
    let mut next = Vec::new();
    let mut level = 0u32;
    while !frontier.is_empty() {
        let mut stats = LevelStats {
            frontier_size: frontier.len() as u64,
            ..Default::default()
        };
        let order: Vec<VertexId> = if use_lrb {
            let binned = bin_frontier(&frontier, |v| g.degree(v));
            binned
                .dispatch_order()
                .into_iter()
                .flat_map(|b| binned.bin(b).to_vec())
                .collect()
        } else {
            std::mem::take(&mut frontier)
        };
        for v in order {
            for &u in g.neighbors(v) {
                stats.edges_examined += 1;
                if dist[u as usize] == INF {
                    dist[u as usize] = level + 1;
                    stats.discovered += 1;
                    next.push(u);
                }
            }
        }
        edges_total += stats.edges_examined;
        levels.push(stats);
        frontier = std::mem::take(&mut next);
        level += 1;
    }
    BfsResult { dist, levels, edges_examined: edges_total }
}

/// Bitmap-frontier top-down step over a *slab* (used by the distributed
/// engine's Phase 1): expand every owned vertex in `local_frontier`,
/// recording discoveries against `visited` (global bitmap). Returns
/// `(discovered_queue, edges_examined)`.
///
/// Mirrors Alg. 2 Phase 1: discoveries go to the node's **global queue**
/// regardless of ownership; `visited` here is the node's local view
/// (`d_local != INF`).
pub fn expand_slab(
    slab: &crate::graph::csr::CsrSlab,
    local_frontier: &[VertexId],
    visited: &mut Bitmap,
    discovered: &mut Vec<VertexId>,
) -> u64 {
    let mut edges = 0u64;
    for &v in local_frontier {
        debug_assert!(slab.owns(v));
        for &u in slab.neighbors_global(v) {
            edges += 1;
            if visited.test_and_set(u) {
                discovered.push(u);
            }
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn matches_serial_on_suite() {
        let graphs: Vec<Csr> = vec![
            path(64),
            star(128),
            grid2d(9, 13),
            kronecker(KroneckerParams::graph500(10, 8), 3).0,
            uniform_random(700, 6, 4).0,
        ];
        for (i, g) in graphs.iter().enumerate() {
            for root in [0u32, (g.num_vertices() / 2) as u32] {
                let want = serial_bfs(g, root);
                for lrb in [false, true] {
                    let got = topdown_bfs(g, root, lrb);
                    assert_eq!(got.dist, want, "graph {i} root {root} lrb {lrb}");
                }
            }
        }
    }

    #[test]
    fn level_stats_consistent() {
        let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 5);
        let r = topdown_bfs(&g, 0, true);
        let total_discovered: u64 = r.levels.iter().map(|l| l.discovered).sum();
        assert_eq!(total_discovered + 1, r.reached()); // +1 for the root
        let sum_edges: u64 = r.levels.iter().map(|l| l.edges_examined).sum();
        assert_eq!(sum_edges, r.edges_examined);
        // Level 0 frontier is exactly the root.
        assert_eq!(r.levels[0].frontier_size, 1);
    }

    #[test]
    fn depth_equals_eccentricity_plus_one() {
        let g = path(10);
        let r = topdown_bfs(&g, 0, false);
        // Levels 0..9 each have a nonempty frontier = 10 frontiers.
        assert_eq!(r.depth(), 10);
    }

    #[test]
    fn expand_slab_discovers_each_vertex_once() {
        let (g, _) = uniform_random(200, 8, 9);
        let slab = g.row_slice(0, 200);
        let mut visited = Bitmap::new(200);
        visited.set(0);
        let mut disc = Vec::new();
        let edges = expand_slab(&slab, &[0], &mut visited, &mut disc);
        assert_eq!(edges, g.degree(0) as u64);
        let mut sorted = disc.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), disc.len(), "no duplicates");
        for v in disc {
            assert!(g.has_edge(0, v));
        }
    }
}
