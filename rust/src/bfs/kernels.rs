//! Kernel-variant selection and deterministic work accounting for the
//! wide-lane mask kernels.
//!
//! The engine's hot loops — the batched bottom-up accumulate in
//! `coordinator::backend`, the dense merge fallback in the session's
//! Phase 2, and the dense/sparse frontier conversions in
//! [`frontier`](super::frontier) — are word-wise `[u64; W]` sweeps over
//! per-vertex lane masks. Two shapes of the same loop are offered:
//!
//! * **Scalar** — the straight-line sweep: visit every vertex, read its
//!   `W` mask words, act on the nonzero ones. Simple, branch-light, and
//!   what the autovectorizer sees best when the data is dense.
//! * **Chunked** — a 64-vertex-chunk summary pass in front of the sweep:
//!   one summary word per chunk records which vertices still carry work,
//!   so fully-settled chunks are skipped without touching their `W·64`
//!   mask words. This is the SIMD shape a real lane-parallel device wants
//!   (test a predicate register, skip the whole tile) and it wins exactly
//!   when the mask array is sparse — the long tail levels of a bottom-up
//!   traversal where almost every vertex has already been claimed by
//!   every lane.
//!
//! Both shapes are **bit-identical** in output: chunked only elides
//! vertices whose per-vertex work is provably zero (an all-lanes-seen
//! mask, an all-zero delta), which the scalar sweep would visit and then
//! ignore. The difference is *accounted*, not guessed: every kernel
//! reports the deterministic [`KernelWork`] counters (words touched,
//! words skipped, dispatches issued, per-dispatch max work) which thread
//! through `LevelMetrics`/`RunMetrics`/`BatchMetrics` into the bench
//! protocol, where CI gates `chunked.words_touched <
//! scalar.words_touched` on the committed sparse tails.

/// Which mask-kernel shape the engine runs (the `--kernel` knob on the
/// CLI, [`EngineConfig::kernel`](crate::coordinator::config::EngineConfig::kernel)
/// in the library).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelVariant {
    /// Let the engine pick (currently resolves to [`KernelVariant::Chunked`],
    /// the shape that dominates on the bottom-up tails the batch engine
    /// spends its levels in).
    #[default]
    Auto,
    /// Straight-line per-vertex sweep, no summary pass.
    Scalar,
    /// 64-vertex chunk-summary sweep that skips settled chunks.
    Chunked,
}

/// Vertices per chunk of the [`KernelVariant::Chunked`] kernels: one
/// `u64` summary word covers exactly this many vertices.
pub const CHUNK_VERTICES: usize = 64;

impl KernelVariant {
    /// Resolve [`KernelVariant::Auto`] to the concrete shape the engine
    /// runs (idempotent on the other variants).
    pub fn resolved(self) -> KernelVariant {
        match self {
            KernelVariant::Auto => KernelVariant::Chunked,
            v => v,
        }
    }

    /// True when the resolved shape is the chunked kernel.
    pub fn is_chunked(self) -> bool {
        self.resolved() == KernelVariant::Chunked
    }

    /// Display name (`"auto"` / `"scalar"` / `"chunked"`).
    pub fn name(&self) -> &'static str {
        match self {
            KernelVariant::Auto => "auto",
            KernelVariant::Scalar => "scalar",
            KernelVariant::Chunked => "chunked",
        }
    }

    /// Parse a CLI spelling (the inverse of [`KernelVariant::name`]).
    pub fn parse(s: &str) -> Option<KernelVariant> {
        match s {
            "auto" => Some(KernelVariant::Auto),
            "scalar" => Some(KernelVariant::Scalar),
            "chunked" => Some(KernelVariant::Chunked),
            _ => None,
        }
    }
}

/// Deterministic per-kernel work counters. All quantities are exact
/// integer models of the memory traffic and dispatch structure — no
/// wallclock — so they compare bit-for-bit across machines and between
/// the Rust engine and its Python port.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelWork {
    /// 64-bit mask (or summary) words the kernel actually read or wrote.
    pub words_touched: u64,
    /// Mask words the kernel *avoided* via a chunk summary or occupancy
    /// test (always 0 for the scalar shape).
    pub words_skipped: u64,
    /// Kernel dispatches issued (one per flat sweep, one per non-empty
    /// LRB bin when binning is composed in).
    pub dispatches: u64,
    /// Largest single-dispatch work item (in words of lane-mask traffic)
    /// — the load-balance signal LRB binning exists to shrink.
    pub dispatch_max_work: u64,
}

impl KernelWork {
    /// Zero all counters (keeps the value usable as an accumulator).
    pub fn clear(&mut self) {
        *self = KernelWork::default();
    }

    /// Record one dispatch of `work` words.
    pub fn record_dispatch(&mut self, work: u64) {
        self.dispatches += 1;
        self.dispatch_max_work = self.dispatch_max_work.max(work);
    }

    /// Fold `other` in: word and dispatch counts add, the per-dispatch
    /// max takes the max (dispatches in different nodes/levels never
    /// merge into one).
    pub fn absorb(&mut self, other: &KernelWork) {
        self.words_touched += other.words_touched;
        self.words_skipped += other.words_skipped;
        self.dispatches += other.dispatches;
        self.dispatch_max_work = self.dispatch_max_work.max(other.dispatch_max_work);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_resolution_and_names() {
        assert_eq!(KernelVariant::default(), KernelVariant::Auto);
        assert_eq!(KernelVariant::Auto.resolved(), KernelVariant::Chunked);
        assert_eq!(KernelVariant::Scalar.resolved(), KernelVariant::Scalar);
        assert_eq!(KernelVariant::Chunked.resolved(), KernelVariant::Chunked);
        assert!(KernelVariant::Auto.is_chunked());
        assert!(!KernelVariant::Scalar.is_chunked());
        for v in [KernelVariant::Auto, KernelVariant::Scalar, KernelVariant::Chunked] {
            assert_eq!(KernelVariant::parse(v.name()), Some(v));
        }
        assert_eq!(KernelVariant::parse("simd"), None);
    }

    #[test]
    fn work_accumulation() {
        let mut w = KernelWork::default();
        w.words_touched += 10;
        w.record_dispatch(7);
        w.record_dispatch(3);
        assert_eq!(w.dispatches, 2);
        assert_eq!(w.dispatch_max_work, 7);
        let mut total = KernelWork::default();
        total.absorb(&w);
        total.absorb(&KernelWork {
            words_touched: 5,
            words_skipped: 2,
            dispatches: 1,
            dispatch_max_work: 9,
        });
        assert_eq!(total.words_touched, 15);
        assert_eq!(total.words_skipped, 2);
        assert_eq!(total.dispatches, 3);
        assert_eq!(total.dispatch_max_work, 9);
        total.clear();
        assert_eq!(total, KernelWork::default());
    }
}
