//! Logarithmic Radix Binning (LRB) — the paper's per-device load balancer
//! (Green et al., HPEC'18/'19).
//!
//! LRB groups frontier vertices into ~32/64 bins by `ceil(log2(degree))`:
//! all vertices in a bin have adjacency lists within 2× of each other, so
//! one "kernel dispatch" per bin does uniform work. On the GPU each bin got
//! its own thread-block shape; in this simulator the bins give (a) a
//! deterministic dispatch order (largest work first — better tail latency)
//! and (b) the per-bin batching structure the XLA backend consumes.

use crate::graph::csr::VertexId;

/// Number of bins: degree fits in u32, so 33 bins cover every degree
/// (bin b holds degrees in [2^(b-1), 2^b), bin 0 holds degree 0 and 1).
pub const NUM_BINS: usize = 33;

/// The result of binning one frontier.
#[derive(Clone, Debug)]
pub struct Binned {
    /// Vertices grouped by bin, concatenated: bin `b` occupies
    /// `starts[b]..starts[b+1]`.
    pub vertices: Vec<VertexId>,
    /// Bin boundaries (length `NUM_BINS + 1`).
    pub starts: Vec<u32>,
}

impl Binned {
    /// Vertices of bin `b`.
    pub fn bin(&self, b: usize) -> &[VertexId] {
        &self.vertices[self.starts[b] as usize..self.starts[b + 1] as usize]
    }

    /// Indices of non-empty bins, largest degree class first (the dispatch
    /// order: schedule the biggest work items first). Allocation-free:
    /// the order lives in a fixed [`NUM_BINS`]-slot array (a `Vec` per
    /// frontier level showed up as pure overhead once LRB composed with
    /// the per-level wide bottom-up scan).
    pub fn dispatch_order(&self) -> DispatchOrder {
        let mut order = DispatchOrder { order: [0; NUM_BINS], len: 0 };
        for b in (0..NUM_BINS).rev() {
            if self.starts[b + 1] > self.starts[b] {
                order.order[order.len] = b;
                order.len += 1;
            }
        }
        order
    }

    /// Total number of binned vertices.
    pub fn len(&self) -> usize {
        self.vertices.len()
    }

    /// True when no vertex was binned.
    pub fn is_empty(&self) -> bool {
        self.vertices.is_empty()
    }
}

/// The largest-first dispatch order of one binned frontier: a fixed
/// [`NUM_BINS`]-slot inline array plus a length, so computing the order
/// never allocates. Derefs to the `[usize]` slice of non-empty bin
/// indices and iterates by value.
#[derive(Clone, Copy, Debug)]
pub struct DispatchOrder {
    order: [usize; NUM_BINS],
    len: usize,
}

impl std::ops::Deref for DispatchOrder {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        &self.order[..self.len]
    }
}

impl IntoIterator for DispatchOrder {
    type Item = usize;
    type IntoIter = std::iter::Take<std::array::IntoIter<usize, NUM_BINS>>;

    fn into_iter(self) -> Self::IntoIter {
        self.order.into_iter().take(self.len)
    }
}

/// Bin index for a degree: 0 for degree ≤ 1, else `ceil(log2(d))`.
#[inline]
pub fn bin_of_degree(d: u32) -> usize {
    if d <= 1 {
        0
    } else {
        (32 - (d - 1).leading_zeros()) as usize
    }
}

/// Bin `frontier` by vertex degree (two-pass counting sort — exactly the
/// GPU formulation, which needs stable O(frontier) work). The degree
/// callback runs **once** per vertex: the first pass caches each
/// vertex's bin index (a byte), which the scatter pass replays —
/// `degree` can be a CSR offset subtraction, but through the slab seam
/// it is a bounds-checked double lookup that used to run twice.
pub fn bin_frontier<F: Fn(VertexId) -> u32>(frontier: &[VertexId], degree: F) -> Binned {
    let mut counts = [0u32; NUM_BINS];
    let mut bins: Vec<u8> = Vec::with_capacity(frontier.len());
    for &v in frontier {
        let b = bin_of_degree(degree(v));
        bins.push(b as u8);
        counts[b] += 1;
    }
    let mut starts = vec![0u32; NUM_BINS + 1];
    for b in 0..NUM_BINS {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut cursor = starts.clone();
    let mut vertices = vec![0 as VertexId; frontier.len()];
    for (&v, &b) in frontier.iter().zip(&bins) {
        vertices[cursor[b as usize] as usize] = v;
        cursor[b as usize] += 1;
    }
    Binned { vertices, starts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};

    #[test]
    fn bin_of_degree_boundaries() {
        assert_eq!(bin_of_degree(0), 0);
        assert_eq!(bin_of_degree(1), 0);
        assert_eq!(bin_of_degree(2), 1);
        assert_eq!(bin_of_degree(3), 2);
        assert_eq!(bin_of_degree(4), 2);
        assert_eq!(bin_of_degree(5), 3);
        assert_eq!(bin_of_degree(8), 3);
        assert_eq!(bin_of_degree(9), 4);
        assert_eq!(bin_of_degree(u32::MAX), 32);
    }

    #[test]
    fn within_bin_degrees_within_2x() {
        // The paper's LRB invariant: within a bin, no adjacency list is
        // more than twice as big (or small) as any other.
        let (g, _) = kronecker(KroneckerParams::graph500(12, 8), 17);
        let frontier: Vec<VertexId> = (0..g.num_vertices() as u32).collect();
        let binned = bin_frontier(&frontier, |v| g.degree(v));
        for b in 1..NUM_BINS {
            let vs = binned.bin(b);
            if vs.len() < 2 {
                continue;
            }
            let degs: Vec<u32> = vs.iter().map(|&v| g.degree(v)).collect();
            let (min, max) = (
                *degs.iter().min().unwrap(),
                *degs.iter().max().unwrap(),
            );
            assert!(max <= min * 2, "bin {b}: min {min} max {max}");
        }
    }

    #[test]
    fn binning_is_a_permutation() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 23);
        let frontier: Vec<VertexId> = (0..g.num_vertices() as u32).step_by(3).collect();
        let binned = bin_frontier(&frontier, |v| g.degree(v));
        assert_eq!(binned.len(), frontier.len());
        let mut a = binned.vertices.clone();
        let mut b = frontier.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn dispatch_order_largest_first() {
        let degrees = [1u32, 2, 100, 5];
        let frontier = [0u32, 1, 2, 3];
        let binned = bin_frontier(&frontier, |v| degrees[v as usize]);
        let order = binned.dispatch_order();
        assert_eq!(order[0], bin_of_degree(100));
        assert_eq!(*order.last().unwrap(), 0);
    }

    #[test]
    fn empty_frontier() {
        let binned = bin_frontier(&[], |_| 0);
        assert!(binned.is_empty());
        assert!(binned.dispatch_order().is_empty());
    }

    #[test]
    fn degree_evaluated_once_per_vertex() {
        // The first counting pass caches bin indices; the scatter pass
        // replays them instead of re-evaluating `degree`.
        use std::cell::Cell;
        let calls = Cell::new(0usize);
        let frontier: Vec<VertexId> = (0..100).collect();
        let binned = bin_frontier(&frontier, |v| {
            calls.set(calls.get() + 1);
            (v % 17) + 1
        });
        assert_eq!(calls.get(), frontier.len());
        assert_eq!(binned.len(), frontier.len());
    }

    #[test]
    fn dispatch_order_is_a_slice_and_iterates_by_value() {
        let degrees = [1u32, 2, 100, 5, 0, 9];
        let frontier: Vec<VertexId> = (0..degrees.len() as u32).collect();
        let binned = bin_frontier(&frontier, |v| degrees[v as usize]);
        let order = binned.dispatch_order();
        // Slice view (Deref) and by-value iteration agree.
        let via_slice: Vec<usize> = order.to_vec();
        let via_iter: Vec<usize> = order.into_iter().collect();
        assert_eq!(via_slice, via_iter);
        // Exactly the non-empty bins, strictly descending.
        let want: Vec<usize> = (0..NUM_BINS)
            .rev()
            .filter(|&b| !binned.bin(b).is_empty())
            .collect();
        assert_eq!(via_slice, want);
        assert!(via_slice.windows(2).all(|w| w[0] > w[1]));
    }
}
