//! Frontier representations: vertex queue and bitmap, with conversions,
//! plus the const-generic wide lane mask the batched MS-BFS subsystem is
//! built on.
//!
//! Top-down traversals want a queue (work ∝ frontier size); bottom-up and
//! the butterfly exchange want bitmaps (fixed O(V/8) payloads, constant-
//! time dedup). The paper's tight memory bound on communication buffers
//! (contribution 4) is what [`Bitmap`] provides: a frontier is never larger
//! than `ceil(V/64)` words regardless of how many vertices it contains.

use crate::graph::csr::VertexId;

/// A `W`-word lane mask: bit `i` of the mask (word `i / 64`, bit
/// `i % 64`) refers to the traversal rooted at `roots[i]` of a batch, so
/// one mask tracks up to `64·W` concurrent traversals. `W = 1` is the
/// classic MS-BFS single-word mask; the engine monomorphizes over
/// `W ∈ {1, 2, 4, 8}` ([`BatchWidth`]) to batch up to 512 roots per
/// butterfly exchange — the amortization knob for centrality-scale
/// workloads (one exchange per level serves the whole batch regardless
/// of `W`, while per-entry wire cost grows only linearly:
/// [`MaskFrontier::ENTRY_BYTES`] `= 4 + 8·W`).
///
/// Masks are plain word arrays so every layer — the bit-parallel oracle,
/// the per-node engine state, the bottom-up backend kernel, and the wire
/// pricing — operates word-wise with compile-time-unrolled `W`-loops.
/// Helper predicates live alongside: [`lane_mask_is_zero`],
/// [`lane_mask_count`], [`lane_bit`].
///
/// [`BatchWidth`]: crate::coordinator::config::BatchWidth
pub type LaneMask<const W: usize> = [u64; W];

/// True when no lane bit is set in `m`.
#[inline]
pub fn lane_mask_is_zero<const W: usize>(m: &LaneMask<W>) -> bool {
    m.iter().all(|&w| w == 0)
}

/// Number of set lane bits across all `W` words of `m`.
#[inline]
pub fn lane_mask_count<const W: usize>(m: &LaneMask<W>) -> u32 {
    m.iter().map(|w| w.count_ones()).sum()
}

/// The single-lane mask with only bit `lane` set (`lane < 64·W`).
#[inline]
pub fn lane_bit<const W: usize>(lane: usize) -> LaneMask<W> {
    debug_assert!(lane < 64 * W, "lane {lane} out of range for {W} words");
    let mut m = [0u64; W];
    m[lane / 64] = 1u64 << (lane % 64);
    m
}

/// A dense bitmap over vertex ids.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    /// Empty bitmap over `len` vertices.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Number of vertices covered.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no bit is set.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Test bit `v`.
    #[inline]
    pub fn get(&self, v: VertexId) -> bool {
        debug_assert!((v as usize) < self.len);
        (self.words[(v / 64) as usize] >> (v % 64)) & 1 == 1
    }

    /// Set bit `v`.
    #[inline]
    pub fn set(&mut self, v: VertexId) {
        debug_assert!((v as usize) < self.len);
        self.words[(v / 64) as usize] |= 1 << (v % 64);
    }

    /// Clear bit `v`.
    #[inline]
    pub fn clear(&mut self, v: VertexId) {
        debug_assert!((v as usize) < self.len);
        self.words[(v / 64) as usize] &= !(1 << (v % 64));
    }

    /// Set bit `v`, returning whether it was previously clear (compare-and-
    /// set used for first-discovery semantics).
    #[inline]
    pub fn test_and_set(&mut self, v: VertexId) -> bool {
        let w = (v / 64) as usize;
        let mask = 1u64 << (v % 64);
        let was = self.words[w] & mask;
        self.words[w] |= mask;
        was == 0
    }

    /// Number of set bits.
    pub fn count(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Zero all bits (keeps allocation).
    pub fn reset(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// `self |= other`; returns the number of *newly* set bits.
    pub fn union_in(&mut self, other: &Bitmap) -> u64 {
        assert_eq!(self.len, other.len, "bitmap length mismatch");
        let mut new_bits = 0;
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            new_bits += (b & !*a).count_ones() as u64;
            *a |= b;
        }
        new_bits
    }

    /// Iterate over set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = VertexId> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let b = w.trailing_zeros();
                w &= w - 1;
                Some((wi as u32) * 64 + b)
            })
        })
    }

    /// Collect set bits into a vector.
    pub fn to_queue(&self) -> Vec<VertexId> {
        self.iter().collect()
    }

    /// Build from a queue of vertex ids.
    pub fn from_queue(len: usize, q: &[VertexId]) -> Self {
        let mut b = Self::new(len);
        for &v in q {
            b.set(v);
        }
        b
    }

    /// Raw words (for serialization into transfer buffers).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Payload size in bytes when shipped over the interconnect.
    pub fn payload_bytes(&self) -> u64 {
        (self.words.len() * 8) as u64
    }
}

/// A batched (multi-source) frontier delta: sparse `(vertex, lane-mask)`
/// pairs, the payload unit of the MS-BFS butterfly exchange
/// (`bfs::msbfs`). Bit `i` of a [`LaneMask`] refers to the traversal
/// rooted at `roots[i]` of the batch. On the wire an entry costs
/// [`MaskFrontier::ENTRY_BYTES`] `= 4 + 8·W` (a `u32` vertex id plus `W`
/// mask words), so a level's payload is `(4 + 8W)·|entries|` bytes —
/// amortized over up to `64·W` concurrent traversals, versus `4·|queue|`
/// *per traversal* for the single-root queue encoding.
///
/// Dense conversions ([`Self::to_masks`] / [`Self::accumulate_prefix`] /
/// [`Self::accumulate_range`] / [`Self::from_masks`]) operate on *flat*
/// vertex-major word arrays of length `len·W` (`masks[v·W + w]` is word
/// `w` of vertex `v`'s mask) — the layout the engine's dense merge
/// snapshots and the backend's bottom-up kernel share.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MaskFrontier<const W: usize> {
    entries: Vec<(VertexId, LaneMask<W>)>,
}

impl<const W: usize> MaskFrontier<W> {
    /// Wire cost of one entry: 4-byte vertex id + `W` 8-byte mask words.
    pub const ENTRY_BYTES: u64 = 4 + 8 * W as u64;

    /// Empty delta list.
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    /// Append a delta: lanes `mask` newly reached `v`. Masks must be
    /// nonzero — zero deltas are filtered by the caller.
    #[inline]
    pub fn push(&mut self, v: VertexId, mask: LaneMask<W>) {
        debug_assert!(!lane_mask_is_zero(&mask), "empty delta for vertex {v}");
        self.entries.push((v, mask));
    }

    /// Number of entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no deltas are recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop all entries (keeps allocation).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// The raw entries in insertion order.
    #[inline]
    pub fn entries(&self) -> &[(VertexId, LaneMask<W>)] {
        &self.entries
    }

    /// Payload size in bytes when shipped over the interconnect.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.len() as u64 * Self::ENTRY_BYTES
    }

    /// Accumulate into a dense per-vertex mask array (entries OR in):
    /// flat vertex-major words, `len·W` long.
    pub fn to_masks(&self, len: usize) -> Vec<u64> {
        let mut masks = vec![0u64; len * W];
        self.accumulate_prefix(self.entries.len(), &mut masks);
        masks
    }

    /// OR the first `take` entries into `masks` (`W` words per vertex,
    /// flat) — the dense round-start snapshot of a delta *prefix*, used
    /// by the engine's dense merge fallback (`CopyFrontier` semantics
    /// freeze the prefix length, not the whole list).
    pub fn accumulate_prefix(&self, take: usize, masks: &mut [u64]) {
        self.accumulate_range(0, take, masks);
    }

    /// OR entries `from..to` into `masks` (flat vertex-major words). The
    /// delta list only grows within a level, so a caller holding masks
    /// for `0..from` extends them to `0..to` without replaying the shared
    /// prefix (the engine's per-round incremental dense snapshot).
    ///
    /// The inner OR is a fixed-`W` slice zip — the shape the
    /// autovectorizer turns into one wide OR per entry instead of `W`
    /// bounds-checked scalar ORs.
    pub fn accumulate_range(&self, from: usize, to: usize, masks: &mut [u64]) {
        for &(v, m) in &self.entries[from..to] {
            let base = v as usize * W;
            let dst = &mut masks[base..base + W];
            for (d, &s) in dst.iter_mut().zip(m.iter()) {
                *d |= s;
            }
        }
    }

    /// [`Self::accumulate_range`] that also maintains a per-vertex
    /// *occupancy bitmap* (`occ` bit `v` set ⇔ vertex `v`'s accumulated
    /// mask is nonzero — entries are nonzero by construction, so every
    /// accumulated vertex is occupied). The occupancy words are the
    /// chunk-summary structure the chunked dense-merge kernel scans in
    /// place of the full `len·W` mask array.
    pub fn accumulate_range_occ(
        &self,
        from: usize,
        to: usize,
        masks: &mut [u64],
        occ: &mut [u64],
    ) {
        for &(v, m) in &self.entries[from..to] {
            let base = v as usize * W;
            let dst = &mut masks[base..base + W];
            for (d, &s) in dst.iter_mut().zip(m.iter()) {
                *d |= s;
            }
            occ[v as usize / 64] |= 1u64 << (v % 64);
        }
    }

    /// Build from a flat vertex-major dense mask array (length a multiple
    /// of `W`), skipping all-zero masks.
    ///
    /// The zero test is an OR-reduction over the `W`-word chunk (one
    /// vector reduce, no early-exit branch chain) — measurably better
    /// shaped for autovectorization than the word-by-word `all(== 0)`
    /// predicate at `W ≥ 4`.
    pub fn from_masks(masks: &[u64]) -> Self {
        debug_assert_eq!(masks.len() % W.max(1), 0);
        let mut f = Self::new();
        for (v, chunk) in masks.chunks_exact(W).enumerate() {
            let any = chunk.iter().fold(0u64, |a, &b| a | b);
            if any != 0 {
                let m: LaneMask<W> = chunk.try_into().expect("chunk of W words");
                f.push(v as VertexId, m);
            }
        }
        f
    }

    /// Chunked-kernel counterpart of [`Self::from_masks`]: walk the
    /// occupancy bitmap (as maintained by [`Self::accumulate_range_occ`])
    /// and read only occupied vertices' mask words, skipping settled
    /// 64-vertex chunks wholesale. Bit-identical to [`Self::from_masks`]
    /// whenever `occ` covers every nonzero mask (extra occupancy bits
    /// over zero masks are filtered). Returns the frontier plus `(words
    /// touched, words skipped)` — summary words count as touched.
    pub fn from_masks_occ(masks: &[u64], occ: &[u64]) -> (Self, u64, u64) {
        debug_assert_eq!(masks.len() % W.max(1), 0);
        let len = masks.len() / W.max(1);
        debug_assert!(occ.len() * 64 >= len);
        let mut f = Self::new();
        let mut touched = occ.len() as u64;
        let mut skipped = 0u64;
        for (wi, &word) in occ.iter().enumerate() {
            let in_range = (len - (wi * 64).min(len)).min(64) as u64;
            let occupied = (word.count_ones() as u64).min(in_range);
            skipped += (in_range - occupied) * W as u64;
            let mut w = word;
            while w != 0 {
                let v = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                if v >= len {
                    break;
                }
                touched += W as u64;
                let chunk = &masks[v * W..(v + 1) * W];
                let any = chunk.iter().fold(0u64, |a, &b| a | b);
                if any != 0 {
                    let m: LaneMask<W> = chunk.try_into().expect("chunk of W words");
                    f.push(v as VertexId, m);
                }
            }
        }
        (f, touched, skipped)
    }
}

/// A frontier in whichever representation is currently cheaper, mirroring
/// the queue/bitmap duality the direction-optimizing literature uses.
#[derive(Clone, Debug)]
pub enum Frontier {
    /// Sparse: explicit vertex list.
    Queue(Vec<VertexId>),
    /// Dense: bitmap over all vertices.
    Dense(Bitmap),
}

impl Frontier {
    /// Number of active vertices.
    pub fn active(&self) -> u64 {
        match self {
            Frontier::Queue(q) => q.len() as u64,
            Frontier::Dense(b) => b.count(),
        }
    }

    /// True when the frontier has no active vertices.
    pub fn is_empty(&self) -> bool {
        match self {
            Frontier::Queue(q) => q.is_empty(),
            Frontier::Dense(b) => b.is_empty(),
        }
    }

    /// Convert to a queue representation (clone-free when already sparse).
    pub fn into_queue(self) -> Vec<VertexId> {
        match self {
            Frontier::Queue(q) => q,
            Frontier::Dense(b) => b.to_queue(),
        }
    }

    /// Convert to a dense representation over `len` vertices.
    pub fn into_dense(self, len: usize) -> Bitmap {
        match self {
            Frontier::Queue(q) => Bitmap::from_queue(len, &q),
            Frontier::Dense(b) => {
                assert_eq!(b.len(), len);
                b
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = Bitmap::new(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert_eq!(b.count(), 3);
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn test_and_set_semantics() {
        let mut b = Bitmap::new(10);
        assert!(b.test_and_set(3));
        assert!(!b.test_and_set(3));
        assert!(b.get(3));
    }

    #[test]
    fn union_counts_new_bits() {
        let mut a = Bitmap::from_queue(100, &[1, 2, 3]);
        let b = Bitmap::from_queue(100, &[3, 4, 99]);
        let new_bits = a.union_in(&b);
        assert_eq!(new_bits, 2);
        assert_eq!(a.count(), 5);
        assert!(a.get(99));
    }

    #[test]
    fn iter_ascending_roundtrip() {
        let q = vec![5u32, 63, 64, 65, 127, 128];
        let b = Bitmap::from_queue(200, &q);
        assert_eq!(b.to_queue(), q);
    }

    #[test]
    fn payload_is_fixed_size() {
        // The paper's bounded-buffer property: payload depends only on V.
        let empty = Bitmap::new(1000);
        let mut full = Bitmap::new(1000);
        for v in 0..1000u32 {
            full.set(v);
        }
        assert_eq!(empty.payload_bytes(), full.payload_bytes());
        assert_eq!(empty.payload_bytes(), 1000u64.div_ceil(64) * 8);
    }

    #[test]
    fn frontier_conversions() {
        let f = Frontier::Queue(vec![1, 5, 9]);
        assert_eq!(f.active(), 3);
        let d = f.into_dense(16);
        assert!(d.get(5));
        let f2 = Frontier::Dense(d);
        assert_eq!(f2.into_queue(), vec![1, 5, 9]);
    }

    #[test]
    fn reset_keeps_len() {
        let mut b = Bitmap::from_queue(75, &[0, 74]);
        b.reset();
        assert!(b.is_empty());
        assert_eq!(b.len(), 75);
    }

    #[test]
    fn lane_mask_helpers() {
        assert!(lane_mask_is_zero(&[0u64; 4]));
        assert!(!lane_mask_is_zero(&[0, 0, 1, 0]));
        assert_eq!(lane_mask_count(&[0b101u64, 1 << 63]), 3);
        let b: LaneMask<4> = lane_bit(130);
        assert_eq!(b, [0, 0, 1 << 2, 0]);
        assert_eq!(lane_bit::<1>(63), [1u64 << 63]);
    }

    #[test]
    fn mask_frontier_roundtrip_and_bytes() {
        let mut f = MaskFrontier::<1>::new();
        assert!(f.is_empty());
        f.push(3, [0b101]);
        f.push(9, [1 << 63]);
        f.push(3, [0b010]); // second delta for the same vertex ORs in densely
        assert_eq!(f.len(), 3);
        assert_eq!(MaskFrontier::<1>::ENTRY_BYTES, 12);
        assert_eq!(f.payload_bytes(), 36);
        let dense = f.to_masks(16);
        assert_eq!(dense[3], 0b111);
        assert_eq!(dense[9], 1 << 63);
        let g = MaskFrontier::<1>::from_masks(&dense);
        assert_eq!(g.entries(), &[(3, [0b111]), (9, [1 << 63])]);
        assert_eq!(g.payload_bytes(), 24);
    }

    #[test]
    fn wide_mask_frontier_roundtrip_and_entry_bytes() {
        // The W-word generalization: entry cost scales as 4 + 8·W, and
        // the flat vertex-major dense layout round-trips.
        assert_eq!(MaskFrontier::<2>::ENTRY_BYTES, 20);
        assert_eq!(MaskFrontier::<4>::ENTRY_BYTES, 36);
        assert_eq!(MaskFrontier::<8>::ENTRY_BYTES, 68);
        let mut f = MaskFrontier::<4>::new();
        f.push(2, lane_bit(70)); // word 1
        f.push(5, lane_bit(255)); // word 3
        f.push(2, lane_bit(0)); // word 0, same vertex
        assert_eq!(f.payload_bytes(), 3 * 36);
        let dense = f.to_masks(8);
        assert_eq!(dense[2 * 4], 1);
        assert_eq!(dense[2 * 4 + 1], 1 << 6);
        assert_eq!(dense[5 * 4 + 3], 1 << 63);
        let g = MaskFrontier::<4>::from_masks(&dense);
        assert_eq!(g.len(), 2, "two distinct vertices");
        assert_eq!(g.entries()[0].0, 2);
        assert_eq!(g.entries()[0].1, [1, 1 << 6, 0, 0]);
        assert_eq!(g.entries()[1], (5, lane_bit(255)));
    }

    #[test]
    fn accumulate_prefix_respects_take() {
        let mut f = MaskFrontier::<1>::new();
        f.push(1, [0b01]);
        f.push(2, [0b10]);
        f.push(1, [0b100]);
        let mut masks = vec![0u64; 4];
        f.accumulate_prefix(2, &mut masks);
        assert_eq!(masks, vec![0, 0b01, 0b10, 0]);
        f.accumulate_prefix(3, &mut masks);
        assert_eq!(masks[1], 0b101);
    }

    #[test]
    fn accumulate_range_is_incremental() {
        let mut f = MaskFrontier::<2>::new();
        f.push(0, [1, 0]);
        f.push(1, [0, 2]);
        f.push(0, [4, 8]);
        let mut masks = vec![0u64; 2 * 2];
        f.accumulate_range(0, 2, &mut masks);
        assert_eq!(masks, vec![1, 0, 0, 2]);
        // Extending the prefix folds in only the new entries.
        f.accumulate_range(2, 3, &mut masks);
        assert_eq!(masks, vec![5, 8, 0, 2]);
    }

    #[test]
    fn accumulate_range_occ_tracks_occupancy() {
        let mut f = MaskFrontier::<2>::new();
        f.push(3, [1, 0]);
        f.push(70, [0, 2]);
        f.push(3, [4, 8]);
        let mut masks = vec![0u64; 80 * 2];
        let mut occ = vec![0u64; 2];
        f.accumulate_range_occ(0, 2, &mut masks, &mut occ);
        assert_eq!(occ[0], 1 << 3);
        assert_eq!(occ[1], 1 << 6);
        f.accumulate_range_occ(2, 3, &mut masks, &mut occ);
        assert_eq!(masks[3 * 2], 5);
        assert_eq!(masks[3 * 2 + 1], 8);
        // Occupancy equals the nonzero-mask set.
        for v in 0..80usize {
            let nz = masks[v * 2] | masks[v * 2 + 1] != 0;
            assert_eq!((occ[v / 64] >> (v % 64)) & 1 == 1, nz, "v={v}");
        }
    }

    #[test]
    fn from_masks_occ_bit_identical_to_from_masks() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(48), "from_masks_occ == from_masks", |rng| {
            let len = gen::usize_in(rng, 1, 150);
            let mut masks = vec![0u64; len * 4];
            let mut occ = vec![0u64; len.div_ceil(64)];
            for _ in 0..gen::usize_in(rng, 0, 80) {
                let v = rng.next_usize(len);
                let w = rng.next_usize(4);
                masks[v * 4 + w] |= 1u64 << rng.next_usize(64);
                occ[v / 64] |= 1u64 << (v % 64);
            }
            // Sprinkle occupancy bits over zero masks: they must filter.
            for _ in 0..3 {
                let v = rng.next_usize(len);
                occ[v / 64] |= 1u64 << (v % 64);
            }
            let scalar = MaskFrontier::<4>::from_masks(&masks);
            let (chunked, touched, skipped) = MaskFrontier::<4>::from_masks_occ(&masks, &occ);
            let occupied: u64 = occ.iter().map(|w| w.count_ones() as u64).sum();
            let ok = scalar == chunked
                && touched == occ.len() as u64 + 4 * occupied
                && skipped == 4 * (len as u64 - occupied);
            (ok, format!("len={len} occupied={occupied}"))
        });
    }

    #[test]
    fn bitmap_property_union_is_or() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(64), "union_in == bitwise or", |rng| {
            let n = gen::usize_in(rng, 1, 300);
            let qa: Vec<u32> =
                gen::vec_below(rng, 40, n as u64).iter().map(|&x| x as u32).collect();
            let qb: Vec<u32> =
                gen::vec_below(rng, 40, n as u64).iter().map(|&x| x as u32).collect();
            let mut a = Bitmap::from_queue(n, &qa);
            let b = Bitmap::from_queue(n, &qb);
            let before = a.count();
            let newb = a.union_in(&b);
            let ok = (0..n as u32).all(|v| a.get(v) == (qa.contains(&v) || qb.contains(&v)))
                && a.count() == before + newb;
            (ok, format!("n={n}"))
        });
    }
}
