//! `butterfly-bfs` — the command-line launcher.
//!
//! Subcommands:
//! * `run`       — traverse a graph with the distributed BFS engine
//!                 (simulated multi-node, DGX-2 timing model); `--mode 1d`
//!                 (butterfly/all-to-all), `--mode 2d --grid RxC`
//!                 (checkerboard fold/expand), or `--mode hier
//!                 --islands AxB` (butterfly inside islands + a
//!                 representative exchange across them, priced per link
//!                 class under `--net dgx2-cluster`).
//! * `batch`     — batched multi-source BFS: up to 512 roots through one
//!                 exchange per level (`run_batch`, const-generic wide
//!                 lane masks), in either mode.
//! * `baseline`  — run the single-node CPU baselines (top-down /
//!                 direction-optimizing), the paper's GapBS comparators.
//! * `generate`  — generate a suite graph and write it to disk (a
//!                 `.bbfs` destination gets the compressed v2 store by
//!                 default; `--v1` keeps the legacy raw snapshot).
//! * `inspect`   — print graph properties (|V|, |E|, degrees, diameter).
//! * `schedule`  — print a butterfly/all-to-all schedule and its costs.
//! * `serve`     — long-running TCP query service with cross-request
//!                 batch coalescing (newline-delimited JSON protocol).
//!
//! Run `butterfly-bfs <subcommand> --help` for options.

use butterfly_bfs::bfs::dirop::{diropt_bfs, DirOptParams};
use butterfly_bfs::bfs::topdown::topdown_bfs;
use butterfly_bfs::comm::{Butterfly, CommPattern, ConcurrentAllToAll, IterativeAllToAll};
use butterfly_bfs::coordinator::config::{DirectionMode, PartitionMode};
use butterfly_bfs::coordinator::{
    BatchWidth, EngineConfig, KernelVariant, PatternKind, PayloadEncoding, TraversalPlan,
};
use butterfly_bfs::fault::{FaultInjector, FaultPlan, FaultTolerantRunner};
use butterfly_bfs::partition::relabel::{apply_relabeling, Relabeling};
use butterfly_bfs::partition::Partition2D;
use butterfly_bfs::graph::csr::Csr;
use butterfly_bfs::graph::gen::{table1_suite, GraphSpec};
use butterfly_bfs::graph::store::{self, GraphStore, StoreWriteOptions};
use butterfly_bfs::graph::{io, props};
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::net::model::{NetModel, TopologyModel};
use butterfly_bfs::net::sim::simulate_uniform;
use butterfly_bfs::util::cli::{parse_pair, Args, CliError};
use butterfly_bfs::util::stats::gteps;
use std::path::Path;

/// Boxed-error result (the offline crate set has no `anyhow`). The
/// defaulted error parameter lets signatures name a concrete error type,
/// mirroring `anyhow::Result`.
type Result<T, E = Box<dyn std::error::Error>> = std::result::Result<T, E>;

/// `anyhow::bail!` stand-in: early-return a formatted error.
macro_rules! bail {
    ($($t:tt)*) => {
        return Err(format!($($t)*).into())
    };
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let rest = argv[1..].to_vec();
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "batch" => cmd_batch(rest),
        "baseline" => cmd_baseline(rest),
        "convert" => cmd_convert(rest),
        "generate" => cmd_generate(rest),
        "inspect" => cmd_inspect(rest),
        "schedule" => cmd_schedule(rest),
        "serve" => cmd_serve(rest),
        "bench-protocol" => cmd_bench_protocol(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (see --help)"),
    }
}

fn print_usage() {
    println!(
        "butterfly-bfs — multi-node BFS with butterfly frontier synchronization\n\n\
         Subcommands:\n\
         \x20 run       distributed ButterFly BFS on a suite graph or file\n\
         \x20 batch     batched multi-source BFS (up to 512 roots per exchange)\n\
         \x20 baseline  single-node CPU top-down / direction-optimizing BFS\n\
         \x20 convert   write a graph as a compressed .bbfs v2 store\n\
         \x20 generate  generate a suite graph to a file\n\
         \x20 inspect   print graph properties\n\
         \x20 schedule  print a communication schedule and its costs\n\
         \x20 serve     TCP query service with cross-request batch coalescing\n\
         \x20 bench-protocol  write or check the committed BENCH_engine.json\n"
    );
}

fn handle_help(r: Result<Args, CliError>, spec: &Args) -> Result<Args> {
    match r {
        Ok(a) => Ok(a),
        Err(CliError::HelpRequested) => {
            println!("{}", spec.help_text());
            std::process::exit(0);
        }
        Err(e) => Err(e.into()),
    }
}

/// Resolve `--graph` into a CSR: a suite name (`kron-like`, …), or a path
/// to a `.bbfs` (v1 or v2) / edge-list / MatrixMarket file. A relabeled
/// v2 store is unmapped back to original ids, so eager loading is
/// transparent regardless of how the file was converted.
fn load_graph(name: &str, scale_delta: i32) -> Result<Csr> {
    if let Some(spec) = suite_spec(name) {
        return Ok(spec.generate_scaled(scale_delta));
    }
    let p = Path::new(name);
    if !p.exists() {
        bail!(
            "graph {name:?} is neither a suite name ({}) nor a file",
            table1_suite()
                .iter()
                .map(|s| s.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    let ext = p.extension().and_then(|e| e.to_str()).unwrap_or("");
    Ok(match ext {
        "bbfs" => match io::snapshot_kind(p)? {
            io::SnapshotKind::V1 => io::read_binary(p)?,
            io::SnapshotKind::V2 => {
                let s = GraphStore::open(p)?;
                let g = s.to_csr()?;
                match s.relabeling() {
                    // Invert the stored permutation: the decoded graph is
                    // in relabeled ids, callers expect original ids.
                    Some(r) => apply_relabeling(
                        &g,
                        &Relabeling { new_id: r.old_id.clone(), old_id: r.new_id.clone() },
                    ),
                    None => g,
                }
            }
            io::SnapshotKind::Unknown => bail!("{name}: not a .bbfs snapshot (bad magic)"),
        },
        "mtx" => io::read_matrix_market(p)?.0,
        _ => io::read_edge_list(p, None)?.0,
    })
}

/// A plan plus where it came from — shared by `run`/`batch`/`serve`.
struct PlanSource {
    plan: TraversalPlan,
    /// The eagerly loaded CSR, when `--graph` was used.
    graph: Option<Csr>,
    /// The open v2 store, when `--graph-file` pointed at one.
    store: Option<std::sync::Arc<GraphStore>>,
    /// True when the plan warm-started from a valid `--plan-cache`.
    warm: bool,
}

/// Build the traversal plan from either `--graph` (suite name or eagerly
/// loaded file) or `--graph-file` (store-backed `.bbfs`, v2 enabling lazy
/// slabs + `--plan-cache` warm-start). The returned plan is always
/// materialized: corrupt stores surface here as typed errors, and
/// `session()` construction afterwards cannot fail.
fn build_plan(a: &Args, cfg: EngineConfig) -> Result<PlanSource> {
    let graph = a.get("graph");
    let graph_file = a.get("graph-file");
    let plan_cache = a.get("plan-cache");
    if graph.is_empty() == graph_file.is_empty() {
        bail!("pass exactly one of --graph <suite|file> or --graph-file <path.bbfs>");
    }
    if graph_file.is_empty() {
        if !plan_cache.is_empty() {
            bail!("--plan-cache requires --graph-file with a .bbfs v2 store (run convert first)");
        }
        let g = load_graph(&graph, a.get_parse::<i32>("scale-delta")?)?;
        let plan = TraversalPlan::build(&g, cfg)?;
        return Ok(PlanSource { plan, graph: Some(g), store: None, warm: false });
    }
    let p = Path::new(&graph_file);
    match io::snapshot_kind(p)? {
        io::SnapshotKind::V1 => {
            if !plan_cache.is_empty() {
                bail!("--plan-cache requires a .bbfs v2 store; {graph_file} is v1 (run convert)");
            }
            let g = io::read_binary(p)?;
            let plan = TraversalPlan::build(&g, cfg)?;
            Ok(PlanSource { plan, graph: Some(g), store: None, warm: false })
        }
        io::SnapshotKind::V2 => {
            let store = std::sync::Arc::new(if a.get_flag("mmap") {
                GraphStore::open_mmap(p)?
            } else {
                GraphStore::open(p)?
            });
            let mut warm = false;
            let plan = if !plan_cache.is_empty() && Path::new(&plan_cache).exists() {
                match TraversalPlan::load_cache(
                    std::sync::Arc::clone(&store),
                    cfg.clone(),
                    Path::new(&plan_cache),
                ) {
                    Ok(plan) => {
                        warm = true;
                        plan
                    }
                    Err(e) => {
                        eprintln!("plan cache {plan_cache} ignored ({e}); rebuilding");
                        TraversalPlan::build_from_store(std::sync::Arc::clone(&store), cfg)?
                    }
                }
            } else {
                TraversalPlan::build_from_store(std::sync::Arc::clone(&store), cfg)?
            };
            if !plan_cache.is_empty() && !warm {
                plan.save_cache(Path::new(&plan_cache))?;
                eprintln!("plan cache written to {plan_cache}");
            }
            // Force lazy slabs now: a corrupt data section becomes a
            // typed error here instead of a panic inside session().
            plan.materialize()?;
            Ok(PlanSource { plan, graph: None, store: Some(store), warm })
        }
        io::SnapshotKind::Unknown => bail!("{graph_file}: not a .bbfs snapshot (bad magic)"),
    }
}

fn suite_spec(name: &str) -> Option<GraphSpec> {
    table1_suite().into_iter().find(|s| s.name == name)
}

/// Parse `--fault-plan FILE` into a [`FaultPlan`] (empty flag → `None`).
fn load_fault_plan(path: &str) -> Result<Option<FaultPlan>> {
    if path.is_empty() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("--fault-plan {path}: {e}"))?;
    let plan = FaultPlan::parse_str(&text).map_err(|e| format!("--fault-plan {path}: {e}"))?;
    Ok(Some(plan))
}

/// Wrap a built plan in a [`FaultTolerantRunner`] whose rebuild callback
/// re-cuts the partition from the same source the plan came from — the
/// eagerly loaded CSR or the open v2 store.
fn fault_runner(src: PlanSource, faults: FaultPlan) -> Result<FaultTolerantRunner> {
    let PlanSource { plan, graph, store, .. } = src;
    let plan = std::sync::Arc::new(plan);
    let rebuild: Box<butterfly_bfs::fault::recovery::PlanRebuild> = match (graph, store) {
        (Some(g), _) => Box::new(move |cfg| TraversalPlan::build(&g, cfg.clone())),
        (None, Some(store)) => Box::new(move |cfg| {
            let p = TraversalPlan::build_from_store(std::sync::Arc::clone(&store), cfg.clone())?;
            p.materialize()?;
            Ok(p)
        }),
        (None, None) => bail!("internal: plan has no rebuildable graph source"),
    };
    Ok(FaultTolerantRunner::new(plan, faults, rebuild))
}

fn cmd_run(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs run", "distributed ButterFly BFS traversal")
        .opt("graph", "", "suite graph name or path (.bbfs/.mtx/edge list), loaded eagerly")
        .opt("graph-file", "", "store-backed .bbfs path (v2 enables lazy load + --plan-cache)")
        .opt("plan-cache", "", "plan cache path: warm-start when valid, written after cold build")
        .flag("mmap", "map a v2 store with mmap(2) instead of pread")
        .opt("nodes", "16", "number of simulated compute nodes")
        .opt("mode", "1d", "partition mode: 1d (butterfly) | 2d (fold/expand) | hier (islands)")
        .opt("grid", "auto", "2d processor grid RxC (rows*cols must equal --nodes) or auto")
        .opt("islands", "auto", "hier island grid AxB (islands x nodes-per-island) or auto")
        .opt("fanout", "4", "butterfly fanout (1 = classic butterfly)")
        .opt("pattern", "butterfly", "butterfly | alltoall | iterative (1d mode)")
        .opt("payload", "auto", "payload encoding: queue | bitmap | auto | maskdelta")
        .opt("root", "0", "BFS root vertex")
        .opt("scale-delta", "0", "suite graph scale adjustment (+/- log2)")
        .opt("net", "dgx2", "interconnect: dgx2 | dgx-a100 | pcie3 | dyn-alloc | dgx2-cluster")
        .opt("direction", "topdown", "phase-1 direction: topdown | bottomup | diropt")
        .opt("kernel", "auto", "mask kernel variant: auto | scalar | chunked")
        .opt("fault-plan", "", "JSON fault schedule to inject (detect → retry → degrade recovery)")
        .flag("no-lrb", "disable LRB load balancing")
        .flag("parallel", "run Phase 1 on threads")
        .flag("parallel-sync", "run the Phase-2 merges on threads")
        .flag("json", "dump metrics as JSON");
    let a = handle_help(spec.clone().parse(argv), &spec)?;

    let nodes = a.get_usize("nodes")?;
    let pattern = match a.get("pattern").as_str() {
        "butterfly" => PatternKind::Butterfly { fanout: a.get_parse("fanout")? },
        "alltoall" => PatternKind::AllToAllConcurrent,
        "iterative" => PatternKind::AllToAllIterative,
        p => bail!("unknown pattern {p:?}"),
    };
    let payload = parse_payload(&a.get("payload"))?;
    let direction = parse_direction(&a.get("direction"))?;
    let partition = parse_partition_mode(&a.get("mode"), &a.get("grid"), &a.get("islands"), nodes)?;
    let (net, topology) = resolve_net(&a.get("net"), partition, nodes)?;
    let cfg = EngineConfig {
        num_nodes: nodes,
        partition,
        pattern,
        payload,
        use_lrb: !a.get_flag("no-lrb"),
        kernel: parse_kernel(&a.get("kernel"))?,
        direction,
        parallel_phase1: a.get_flag("parallel"),
        parallel_phase2: a.get_flag("parallel-sync"),
        net,
        topology,
        ..EngineConfig::dgx2(nodes, 1)
    };
    // Invalid layouts (grid too large for the graph, more nodes than
    // vertices, mismatched grid) surface as typed `PlanError`s and print
    // as clean CLI errors.
    let src = build_plan(&a, cfg)?;
    if src.warm {
        eprintln!("warm start: plan loaded from cache (no cold partition build)");
    }
    let root = a.get_parse::<u32>("root")?;
    // On a relabeled store the engine runs in permuted id space: map the
    // root in (aggregate outputs are permutation-invariant).
    let exec_root = match src.plan.relabeling() {
        Some(r) if (root as usize) < r.new_id.len() => r.new_id[root as usize],
        _ => root,
    };
    let faults = load_fault_plan(&a.get("fault-plan"))?;
    let faulted = faults.is_some();
    let (plan, result) = match faults {
        Some(fp) => {
            let mut runner = fault_runner(src, fp)?;
            let result = runner.run(exec_root)?;
            if runner.is_degraded() {
                eprintln!(
                    "rank death tolerated: degraded to {} nodes, lost level replayed",
                    runner.active_plan().config().num_nodes
                );
            }
            (std::sync::Arc::clone(runner.active_plan()), result)
        }
        None => {
            let plan = std::sync::Arc::new(src.plan);
            let mut session = plan.session();
            let result = session.run(exec_root)?;
            session
                .assert_agreement()
                .map_err(|e| format!("node disagreement: {e}"))?;
            (plan, result)
        }
    };
    let m = result.metrics();

    if a.get_flag("json") {
        println!("{}", m.to_json().render());
        return Ok(());
    }
    println!(
        "graph: |V|={} |E|={}  nodes={nodes} mode={} pattern={}",
        count(plan.num_vertices() as u64),
        count(plan.graph_edges()),
        partition.name(),
        match partition {
            PartitionMode::OneD => plan.config().pattern.name(),
            PartitionMode::TwoD { .. } => "fold-expand".to_string(),
            PartitionMode::Hierarchical { .. } => "grid-of-islands".to_string(),
        }
    );
    println!(
        "reached {} vertices in {} levels; examined {} edges",
        count(m.reached),
        m.depth(),
        count(m.edges_examined())
    );
    println!(
        "wall {:.3} ms | sim-device {:.3} ms ({:.1}% comm) | sim GTEPS {:.2} (|E|/t) {:.2} (honest)",
        m.wall_seconds * 1e3,
        m.sim_seconds() * 1e3,
        m.sim_comm_fraction() * 100.0,
        m.sim_gteps(),
        m.sim_honest_gteps()
    );
    println!(
        "comm: {} messages, {} bytes over {} levels",
        count(m.messages()),
        count(m.bytes()),
        m.depth()
    );
    if faulted {
        println!(
            "recovery: {} retries, {} bytes retransmitted, {:.3} ms recovery time",
            count(m.retries()),
            count(m.retry_bytes()),
            m.recovery_time() * 1e3
        );
    }
    if !matches!(direction, DirectionMode::TopDown) {
        println!(
            "direction: {}/{} levels bottom-up ({} of {} edges inspected bottom-up)",
            m.bottom_up_levels(),
            m.depth(),
            count(m.bottom_up_edges()),
            count(m.edges_examined())
        );
    }
    if let PartitionMode::TwoD { .. } = partition {
        println!(
            "  fold (rows): {} messages, {} bytes | expand (cols): {} messages, {} bytes",
            count(m.fold_messages()),
            count(m.fold_bytes()),
            count(m.expand_messages()),
            count(m.expand_bytes())
        );
    }
    if let PartitionMode::Hierarchical { islands, per_island } = partition {
        println!(
            "  islands {islands}x{per_island} | intra: {} messages, {} bytes | inter: {} messages, {} bytes",
            count(m.intra_messages()),
            count(m.intra_bytes()),
            count(m.inter_messages()),
            count(m.inter_bytes())
        );
    }
    Ok(())
}

/// Resolve `--mode` / `--grid` / `--islands` into a [`PartitionMode`].
/// `--grid auto` and `--islands auto` pick the most-square factorization
/// of `nodes`. Whether the layout fits the graph (grid covers `--nodes`,
/// axes fit the vertex count) is validated by [`TraversalPlan::build`],
/// whose typed `PlanError`s print as CLI errors.
fn parse_partition_mode(
    mode: &str,
    grid: &str,
    islands: &str,
    nodes: usize,
) -> Result<PartitionMode> {
    Ok(match mode {
        "1d" => PartitionMode::OneD,
        "2d" => {
            let (rows, cols) = if grid == "auto" {
                Partition2D::near_square_grid(nodes as u32)
            } else {
                let Some(rc) = parse_pair(grid, 'x') else {
                    bail!("--grid must be RxC (e.g. 4x4) or auto, got {grid:?}");
                };
                rc
            };
            PartitionMode::TwoD { rows, cols }
        }
        "hier" => {
            let (islands, per_island) = if islands == "auto" {
                Partition2D::near_square_grid(nodes as u32)
            } else {
                let Some(ab) = parse_pair(islands, 'x') else {
                    bail!("--islands must be AxB (e.g. 8x8) or auto, got {islands:?}");
                };
                ab
            };
            PartitionMode::Hierarchical { islands, per_island }
        }
        m => bail!("unknown mode {m:?} (1d | 2d | hier)"),
    })
}

/// Resolve `--net` into the flat [`NetModel`] plus, for `dgx2-cluster`,
/// the two-class [`TopologyModel`] (NVLink-class links inside an island,
/// a shared ~10x-slower uplink between islands). Flat modes derive the
/// island size from the same near-square factorization `--islands auto`
/// would pick, so `1d`/`2d`/`hier` runs at equal `--nodes` are priced
/// under an identical physical cluster and stay comparable.
fn resolve_net(
    name: &str,
    partition: PartitionMode,
    nodes: usize,
) -> Result<(NetModel, Option<TopologyModel>)> {
    if name == "dgx2-cluster" {
        let per_island = match partition {
            PartitionMode::Hierarchical { per_island, .. } => per_island,
            _ => Partition2D::near_square_grid(nodes as u32).1,
        };
        return Ok((NetModel::dgx2(), Some(TopologyModel::dgx2_cluster(per_island))));
    }
    Ok((net_by_name(name)?, None))
}

fn net_by_name(name: &str) -> Result<NetModel> {
    Ok(match name {
        "dgx2" => NetModel::dgx2(),
        "dgx-a100" => NetModel::dgx_a100(),
        "pcie3" => NetModel::pcie_gen3(),
        "dyn-alloc" => NetModel::dynamic_alloc_baseline(),
        n => bail!("unknown net model {n:?}"),
    })
}

fn parse_payload(name: &str) -> Result<PayloadEncoding> {
    Ok(match name {
        "queue" => PayloadEncoding::Queue,
        "bitmap" => PayloadEncoding::Bitmap,
        "auto" => PayloadEncoding::Auto,
        "maskdelta" => PayloadEncoding::MaskDelta,
        p => bail!("unknown payload {p:?}"),
    })
}

fn parse_direction(name: &str) -> Result<DirectionMode> {
    Ok(match name {
        "topdown" => DirectionMode::TopDown,
        "bottomup" => DirectionMode::BottomUp,
        "diropt" => DirectionMode::diropt(),
        d => bail!("unknown direction {d:?}"),
    })
}

fn parse_kernel(name: &str) -> Result<KernelVariant> {
    match KernelVariant::parse(name) {
        Some(k) => Ok(k),
        None => bail!("unknown kernel {name:?} (expected auto | scalar | chunked)"),
    }
}

/// Batched multi-source BFS: sample (or take) up to 512 roots and push
/// them through one `run_batch` — the lane mask widens with the batch
/// (`--width`), so one exchange per level serves the whole batch —
/// reporting the amortization against what the same roots would have
/// cost sequentially.
fn cmd_batch(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs batch", "batched multi-source BFS (MS-BFS)")
        .opt("graph", "", "suite graph name or path (.bbfs/.mtx/edge list), loaded eagerly")
        .opt("graph-file", "", "store-backed .bbfs path (v2 enables lazy load + --plan-cache)")
        .opt("plan-cache", "", "plan cache path: warm-start when valid, written after cold build")
        .flag("mmap", "map a v2 store with mmap(2) instead of pread")
        .opt("nodes", "16", "number of simulated compute nodes")
        .opt("mode", "1d", "partition mode: 1d (butterfly) | 2d (fold/expand) | hier (islands)")
        .opt("grid", "auto", "2d processor grid RxC or auto")
        .opt("islands", "auto", "hier island grid AxB (islands x nodes-per-island) or auto")
        .opt("fanout", "4", "butterfly fanout (1 = classic butterfly)")
        .opt("width", "64", "batch width (1..=512 random non-isolated roots)")
        .opt("seed", "7", "root sampling seed")
        .opt("scale-delta", "0", "suite graph scale adjustment (+/- log2)")
        .opt("net", "dgx2", "interconnect: dgx2 | dgx-a100 | pcie3 | dyn-alloc | dgx2-cluster")
        .opt("direction", "topdown", "phase-1 direction: topdown | bottomup | diropt")
        .opt("kernel", "auto", "mask kernel variant: auto | scalar | chunked")
        .opt("fault-plan", "", "JSON fault schedule to inject (detect → retry → degrade recovery)")
        .flag("parallel", "step nodes on the thread pool")
        .flag("parallel-sync", "run the Phase-2 merges on threads")
        .flag("compare", "also run the roots sequentially and report the ratio");
    let a = handle_help(spec.clone().parse(argv), &spec)?;

    let nodes = a.get_usize("nodes")?;
    let fanout: u32 = a.get_parse("fanout")?;
    let width = a.get_usize("width")?;
    let Some(batch_width) = BatchWidth::for_lanes(width) else {
        bail!("--width must be in 1..=512 (got {width})");
    };
    let partition = parse_partition_mode(&a.get("mode"), &a.get("grid"), &a.get("islands"), nodes)?;
    let (net, topology) = resolve_net(&a.get("net"), partition, nodes)?;
    let direction = parse_direction(&a.get("direction"))?;
    let cfg = EngineConfig {
        partition,
        direction,
        kernel: parse_kernel(&a.get("kernel"))?,
        batch_width,
        parallel_phase1: a.get_flag("parallel"),
        parallel_phase2: a.get_flag("parallel-sync"),
        net,
        topology,
        ..EngineConfig::dgx2(nodes, fanout)
    };
    let src = build_plan(&a, cfg)?;
    if src.warm {
        eprintln!("warm start: plan loaded from cache (no cold partition build)");
    }
    let seed = a.get_u64("seed")?;
    // Store-backed plans have no eager CSR to sample from; degrees come
    // from the store's O(n) degree stream instead. (On a relabeled store
    // the roots are sampled in relabeled space — batch output is
    // aggregate-only, so ids never surface.)
    let roots = match &src.store {
        Some(store) => {
            let prefix = store.degree_prefix()?;
            butterfly_bfs::bfs::msbfs::sample_batch_roots_by(
                src.plan.num_vertices(),
                |v| (prefix[v as usize + 1] - prefix[v as usize]) as u32,
                width,
                seed,
            )
        }
        None => {
            let g = src.graph.as_ref().expect("eager plan keeps its graph");
            butterfly_bfs::bfs::msbfs::sample_batch_roots(g, width, seed)
        }
    };
    let faults = load_fault_plan(&a.get("fault-plan"))?;
    let faulted = faults.is_some();
    let (plan, batch) = match faults {
        Some(fp) => {
            let mut runner = fault_runner(src, fp)?;
            let batch = runner.run_batch(&roots)?;
            if runner.is_degraded() {
                eprintln!(
                    "rank death tolerated: degraded to {} nodes, lost level replayed",
                    runner.active_plan().config().num_nodes
                );
            }
            (std::sync::Arc::clone(runner.active_plan()), batch)
        }
        None => {
            let plan = std::sync::Arc::new(src.plan);
            let mut session = plan.session();
            let batch = session.run_batch(&roots)?;
            session
                .assert_batch_agreement()
                .map_err(|e| format!("node disagreement: {e}"))?;
            (plan, batch)
        }
    };
    let bm = batch.metrics();
    println!(
        "graph: |V|={} |E|={}  nodes={nodes} mode={} fanout={fanout} batch={}",
        count(plan.num_vertices() as u64),
        count(plan.graph_edges()),
        plan.config().partition.name(),
        batch.num_roots()
    );
    println!(
        "batch: {} levels, {} sync rounds, {} messages, {} bytes, sim {:.3} ms",
        bm.depth(),
        bm.sync_rounds,
        count(bm.messages()),
        count(bm.bytes()),
        bm.sim_seconds() * 1e3
    );
    println!(
        "lanes: {} mask words ({} lanes/exchange, {} B sparse entries)",
        bm.lane_words,
        bm.lanes_per_exchange(),
        bm.entry_bytes()
    );
    if let PartitionMode::Hierarchical { islands, per_island } = partition {
        println!(
            "islands {islands}x{per_island} | intra: {} messages, {} bytes | inter: {} messages, {} bytes",
            count(bm.intra_messages()),
            count(bm.intra_bytes()),
            count(bm.inter_messages()),
            count(bm.inter_bytes())
        );
    }
    println!(
        "phase 1: {} edges inspected; direction {}: {}/{} levels bottom-up ({} edges)",
        count(bm.edges_examined()),
        a.get("direction"),
        bm.bottom_up_levels(),
        bm.depth(),
        count(bm.bottom_up_edges())
    );
    println!(
        "kernel {}: {} mask words touched, {} skipped, {} dispatches (max work {})",
        plan.config().kernel.name(),
        count(bm.words_touched()),
        count(bm.words_skipped()),
        count(bm.dispatches()),
        count(bm.dispatch_max_work())
    );
    if faulted {
        println!(
            "recovery: {} retries, {} bytes retransmitted, {:.3} ms recovery time",
            count(bm.retries()),
            count(bm.retry_bytes()),
            bm.recovery_time() * 1e3
        );
    }
    if a.get_flag("compare") {
        let seq = plan.session().sequential_baseline(&roots)?;
        println!(
            "sequential: {} sync rounds, {} bytes, sim {:.3} ms",
            seq.sync_rounds,
            count(seq.bytes),
            seq.sim_seconds * 1e3
        );
        println!(
            "amortization: {:.1}x fewer rounds, {:.1}x fewer bytes, {:.1}x sim speedup",
            seq.sync_rounds as f64 / bm.sync_rounds.max(1) as f64,
            seq.bytes as f64 / bm.bytes().max(1) as f64,
            seq.sim_seconds / bm.sim_seconds().max(1e-12)
        );
    }
    Ok(())
}

/// Long-running TCP query service over one shared plan. Single-root
/// requests arriving within `--coalesce-window-us` are coalesced into
/// one wide `run_batch` (up to `--max-batch` lanes — the MS-BFS
/// amortization applied across clients); the admission queue is bounded
/// (`--queue-depth`, typed `overloaded` past it) and per-request
/// deadlines answer `timeout`. Send `{"op":"shutdown"}` to stop; the
/// final metrics report prints as one JSON line on stdout.
fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs serve", "TCP query service with batch coalescing")
        .opt("graph", "", "suite graph name or path (.bbfs/.mtx/edge list), loaded eagerly")
        .opt("graph-file", "", "store-backed .bbfs path (v2 enables lazy load + --plan-cache)")
        .opt("plan-cache", "", "plan cache path: warm-start restart is O(mmap), not O(E)")
        .flag("mmap", "map a v2 store with mmap(2) instead of pread")
        .opt("addr", "127.0.0.1:0", "bind address (port 0 = ephemeral, printed on start)")
        .opt("nodes", "16", "number of simulated compute nodes")
        .opt("mode", "1d", "partition mode: 1d (butterfly) | 2d (fold/expand) | hier (islands)")
        .opt("grid", "auto", "2d processor grid RxC or auto")
        .opt("islands", "auto", "hier island grid AxB (islands x nodes-per-island) or auto")
        .opt("fanout", "4", "butterfly fanout (1 = classic butterfly)")
        .opt("scale-delta", "0", "suite graph scale adjustment (+/- log2)")
        .opt("net", "dgx2", "interconnect: dgx2 | dgx-a100 | pcie3 | dyn-alloc | dgx2-cluster")
        .opt("direction", "topdown", "phase-1 direction: topdown | bottomup | diropt")
        .opt("workers", "2", "worker threads executing coalesced batches")
        .opt("coalesce-window-us", "200", "how long a lone request waits for co-travellers")
        .opt("max-batch", "64", "max coalesced batch width (1..=512)")
        .opt("queue-depth", "1024", "admission-queue bound (overloaded past it)")
        .opt("timeout-us", "0", "default per-request deadline in us (0 = none)")
        .opt("fault-plan", "", "JSON fault schedule armed on every worker session");
    let a = handle_help(spec.clone().parse(argv), &spec)?;

    let max_batch = a.get_usize("max-batch")?;
    // The serve-side face of the for_lanes width-clamp fix: an over-wide
    // --max-batch is a config-time error echoing the requested width,
    // never a silently narrower service.
    let Some(batch_width) = BatchWidth::for_lanes(max_batch) else {
        bail!("--max-batch must be in 1..=512 (got {max_batch})");
    };
    let nodes = a.get_usize("nodes")?;
    let partition = parse_partition_mode(&a.get("mode"), &a.get("grid"), &a.get("islands"), nodes)?;
    let (net, topology) = resolve_net(&a.get("net"), partition, nodes)?;
    let cfg = EngineConfig {
        partition,
        direction: parse_direction(&a.get("direction"))?,
        batch_width,
        net,
        topology,
        ..EngineConfig::dgx2(nodes, a.get_parse("fanout")?)
    };
    let src = build_plan(&a, cfg)?;
    if src.warm {
        eprintln!("warm start: plan loaded from cache (no cold partition build)");
    }
    let plan = std::sync::Arc::new(src.plan);
    let timeout = a.get_u64("timeout-us")?;
    let serve_cfg = butterfly_bfs::serve::ServeConfig {
        addr: a.get("addr"),
        workers: a.get_usize("workers")?,
        coalesce_window_us: a.get_u64("coalesce-window-us")?,
        max_batch,
        queue_depth: a.get_usize("queue-depth")?,
        default_timeout_us: (timeout > 0).then_some(timeout),
    };
    let mut server = butterfly_bfs::serve::Server::bind(plan, serve_cfg)?;
    if let Some(fp) = load_fault_plan(&a.get("fault-plan"))? {
        server.arm_faults(std::sync::Arc::new(FaultInjector::new(fp)));
        eprintln!("fault plan armed: worker sessions inject + retry deterministically");
    }
    println!("serving on {}", server.local_addr()?);
    let report = server.run()?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_baseline(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs baseline", "single-node CPU BFS baselines")
        .req("graph", "suite graph name or path")
        .opt("root", "0", "BFS root vertex")
        .opt("scale-delta", "0", "suite graph scale adjustment")
        .opt("algo", "both", "topdown | diropt | both");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let g = load_graph(&a.get("graph"), a.get_parse::<i32>("scale-delta")?)?;
    let root = a.get_parse::<u32>("root")?;
    let algo = a.get("algo");

    let mut t = Table::new(&["algo", "time_ms", "gteps(|E|/t)", "edges_examined", "depth"]);
    if algo == "topdown" || algo == "both" {
        let t0 = std::time::Instant::now();
        let r = topdown_bfs(&g, root, true);
        let dt = t0.elapsed().as_secs_f64();
        t.row(vec![
            "topdown".into(),
            ms(dt),
            f2(gteps(g.num_edges(), dt)),
            count(r.edges_examined),
            r.depth().to_string(),
        ]);
    }
    if algo == "diropt" || algo == "both" {
        let t0 = std::time::Instant::now();
        let r = diropt_bfs(&g, root, DirOptParams::default());
        let dt = t0.elapsed().as_secs_f64();
        t.row(vec![
            "diropt".into(),
            ms(dt),
            f2(gteps(g.num_edges(), dt)),
            count(r.edges_examined),
            r.levels.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Convert any loadable graph into the compressed `.bbfs` v2 store (or,
/// with `--v1`, the legacy raw-CSR snapshot), reporting the compression
/// ratio against the v1 byte size.
fn cmd_convert(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs convert", "write a graph as a .bbfs v2 store")
        .req("graph", "suite graph name or input path (.bbfs/.mtx/edge list)")
        .req("out", "output .bbfs path")
        .opt("scale-delta", "0", "suite graph scale adjustment (+/- log2)")
        .opt("block-size", "1024", "vertices per compressed block")
        .flag("relabel", "degree-sort relabel before encoding (stores the permutation)")
        .flag("v1", "write the legacy uncompressed v1 snapshot instead");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let g = load_graph(&a.get("graph"), a.get_parse::<i32>("scale-delta")?)?;
    let out = a.get("out");
    let p = Path::new(&out);
    let v1_bytes = store::v1_snapshot_bytes(&g);
    if a.get_flag("v1") {
        io::write_binary(&g, p)?;
        println!(
            "wrote {out} (v1, {} bytes, |V|={}, |E|={})",
            count(v1_bytes),
            count(g.num_vertices() as u64),
            count(g.num_edges())
        );
        return Ok(());
    }
    let opts = StoreWriteOptions {
        relabel: a.get_flag("relabel"),
        block_size: a.get_parse::<u32>("block-size")?,
    };
    let enc = store::write_store(&g, p, opts)?;
    let v2_bytes = enc.bytes.len() as u64;
    println!(
        "wrote {out} (v2{}, |V|={}, |E|={})",
        if enc.relabeling.is_some() { ", degree-sort relabeled" } else { "" },
        count(g.num_vertices() as u64),
        count(g.num_edges())
    );
    println!(
        "size: {} bytes vs {} v1 — {:.2}x smaller",
        count(v2_bytes),
        count(v1_bytes),
        v1_bytes as f64 / v2_bytes.max(1) as f64
    );
    Ok(())
}

/// Generate a suite graph to disk. A `.bbfs` destination gets the
/// compressed v2 store (the format every other subcommand prefers:
/// lazy slabs, `--plan-cache`, mmap) unless `--v1` asks for the legacy
/// raw-CSR snapshot; any other extension gets a text edge list.
fn cmd_generate(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs generate", "generate a suite graph")
        .req("graph", "suite graph name")
        .req("out", "output path (.bbfs store or .txt edge list)")
        .opt("scale-delta", "0", "scale adjustment")
        .opt("block-size", "1024", "vertices per compressed block (.bbfs v2)")
        .flag("relabel", "degree-sort relabel before encoding (stores the permutation)")
        .flag("v1", "write the legacy uncompressed v1 snapshot instead of the v2 store");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let g = load_graph(&a.get("graph"), a.get_parse::<i32>("scale-delta")?)?;
    let out = a.get("out");
    let p = Path::new(&out);
    let kind = if out.ends_with(".bbfs") {
        if a.get_flag("v1") {
            io::write_binary(&g, p)?;
            "v1 snapshot"
        } else {
            let opts = StoreWriteOptions {
                relabel: a.get_flag("relabel"),
                block_size: a.get_parse::<u32>("block-size")?,
            };
            store::write_store(&g, p, opts)?;
            "v2 store"
        }
    } else {
        io::write_edge_list(&g, p)?;
        "edge list"
    };
    println!(
        "wrote {} ({}, |V|={}, |E|={})",
        out,
        kind,
        count(g.num_vertices() as u64),
        count(g.num_edges())
    );
    Ok(())
}

fn cmd_inspect(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs inspect", "print graph properties")
        .req("graph", "suite graph name or path")
        .opt("scale-delta", "0", "scale adjustment");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let g = load_graph(&a.get("graph"), a.get_parse::<i32>("scale-delta")?)?;
    let ds = props::degree_stats(&g);
    let cc = props::connected_components(&g);
    let diam = props::pseudo_diameter(&g, 0);
    println!("vertices:      {}", count(g.num_vertices() as u64));
    println!("arcs:          {}", count(g.num_edges()));
    println!("degree:        min {} mean {:.2} max {}", ds.min, ds.mean, ds.max);
    println!("components:    {} (largest {:.1}%)", cc.count(), cc.largest_fraction() * 100.0);
    println!("pseudo-diam:   {diam}");
    println!("log2 degree histogram: {:?}", ds.log2_hist);
    Ok(())
}

fn cmd_schedule(argv: Vec<String>) -> Result<()> {
    let spec = Args::new("butterfly-bfs schedule", "print a communication schedule")
        .opt("nodes", "16", "number of compute nodes")
        .opt("fanout", "1", "butterfly fanout")
        .opt("pattern", "butterfly", "butterfly | alltoall | iterative")
        .opt("payload-mb", "1", "per-message payload (MB) for pricing")
        .opt("net", "dgx2", "interconnect model")
        .flag("verbose", "print every transfer");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let cn = a.get_parse::<u32>("nodes")?;
    let pattern: Box<dyn CommPattern> = match a.get("pattern").as_str() {
        "butterfly" => Box::new(Butterfly::new(a.get_parse("fanout")?)),
        "alltoall" => Box::new(ConcurrentAllToAll),
        "iterative" => Box::new(IterativeAllToAll),
        p => bail!("unknown pattern {p:?}"),
    };
    let s = pattern.schedule(cn);
    s.validate()?;
    butterfly_bfs::comm::analysis::verify_full_coverage(&s)?;
    let payload = (a.get_f64("payload-mb")? * 1024.0 * 1024.0) as u64;
    let net = net_by_name(&a.get("net"))?;
    let timing = simulate_uniform(&s, &net, payload);
    println!(
        "{} over {cn} nodes: {} rounds, {} messages, max sends/round {}, max recvs/round {}",
        pattern.name(),
        s.depth(),
        s.total_messages(),
        s.max_sends_per_round(),
        s.max_recvs_per_round(),
    );
    println!(
        "simulated on {}: total {:.3} ms ({} bytes)",
        net.name,
        timing.total() * 1e3,
        count(timing.total_bytes)
    );
    for (i, (round, t)) in s.rounds.iter().zip(&timing.round_times).enumerate() {
        println!("  round {i}: {} transfers, {:.3} ms", round.len(), t * 1e3);
        if a.get_flag("verbose") {
            for tr in round {
                println!("    {} -> {}", tr.src, tr.dst);
            }
        }
    }
    Ok(())
}

/// Write or verify the committed perf-trajectory artifact
/// (`BENCH_engine.json`): deterministic direction-ablation counters for
/// the fixed RMAT batch configs at p ∈ {16, 64} — see
/// `harness::protocol`. `--check` recomputes the protocol and fails when
/// the committed file is stale (integer counters compare exactly, float
/// fields within tolerance).
fn cmd_bench_protocol(argv: Vec<String>) -> Result<()> {
    let spec = Args::new(
        "butterfly-bfs bench-protocol",
        "write or check the committed BENCH_engine.json artifact",
    )
    .opt("out", "BENCH_engine.json", "artifact path (the repo root copy is committed)")
    .flag("check", "verify the committed artifact instead of writing");
    let a = handle_help(spec.clone().parse(argv), &spec)?;
    let path = a.get("out");
    let p = Path::new(&path);
    if a.get_flag("check") {
        butterfly_bfs::harness::protocol::check_engine_bench(p)?;
        println!("{path}: fresh (matches the recomputed protocol)");
    } else {
        butterfly_bfs::harness::protocol::write_engine_bench(p)?;
        println!("wrote {path}");
    }
    Ok(())
}
