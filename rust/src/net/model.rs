//! Interconnect models: the hardware parameters that price a communication
//! schedule into time.
//!
//! The paper's testbed is NVSwitch: "each GPU has six incoming and
//! outgoing links at 25 GB/s (each) … a GPU can send and receive 150 GB/s
//! concurrently", with uniform latency between all pairs (§4 DGX-2). The
//! presets capture that, plus the architectures the related work ran on
//! (PCIe shared bus for the Gunrock/Groute era, a ring, and DGX-A100).

/// How concurrent messages from one node share the interconnect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fabric {
    /// Per-node point-to-point links through a non-blocking switch
    /// (NVSwitch): each node owns `ports` full-duplex links; different
    /// nodes never contend with each other.
    Switched,
    /// One bus shared by every node (PCI-E era): all traffic in a round is
    /// serialized over the single shared capacity.
    SharedBus,
}

/// An interconnect model.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Human-readable preset name.
    pub name: &'static str,
    /// Sharing discipline.
    pub fabric: Fabric,
    /// Bandwidth of one link in bytes/second (25 GB/s per NVLink).
    pub link_bandwidth: f64,
    /// Full-duplex links per node (6 on a DGX-2 V100).
    pub ports_per_node: u32,
    /// Per-message latency in seconds (setup + switch traversal).
    pub latency: f64,
    /// Per-message software overhead for *dynamically allocated* receive
    /// buffers, in seconds. 0 for preallocated buffers (the paper's
    /// design); > 0 models Gunrock/Groute-style `cudaMalloc`-per-level
    /// behavior (§5 "Both Gunrock and Groute need to use dynamic memory
    /// allocations for the buffers used for transferring the frontiers").
    pub alloc_overhead: f64,
}

impl NetModel {
    /// DGX-2 / NVSwitch: 6 × 25 GB/s per V100, ~2 µs message latency.
    pub fn dgx2() -> Self {
        Self {
            name: "dgx2-nvswitch",
            fabric: Fabric::Switched,
            link_bandwidth: 25.0e9,
            ports_per_node: 6,
            latency: 2.0e-6,
            alloc_overhead: 0.0,
        }
    }

    /// DGX-A100: 12 × 50 GB/s NVLink3 per A100.
    pub fn dgx_a100() -> Self {
        Self {
            name: "dgx-a100-nvswitch",
            fabric: Fabric::Switched,
            link_bandwidth: 50.0e9,
            ports_per_node: 12,
            latency: 2.0e-6,
            alloc_overhead: 0.0,
        }
    }

    /// PCI-E gen3 ×16 shared bus (the multi-GPU era the related work ran
    /// on): 16 GB/s shared by everyone, 10 µs latency.
    pub fn pcie_gen3() -> Self {
        Self {
            name: "pcie3-shared",
            fabric: Fabric::SharedBus,
            link_bandwidth: 16.0e9,
            ports_per_node: 1,
            latency: 10.0e-6,
            alloc_overhead: 0.0,
        }
    }

    /// A Gunrock/Groute-style configuration: switched NVLink-class fabric
    /// but with dynamic buffer allocation on every message (§5's
    /// explanation for their negative scaling).
    pub fn dynamic_alloc_baseline() -> Self {
        Self {
            name: "nvswitch-dynamic-alloc",
            alloc_overhead: 150.0e-6, // ~cudaMalloc+free cost per buffer
            ..Self::dgx2()
        }
    }

    /// Aggregate send (or receive) bandwidth of one node.
    pub fn node_bandwidth(&self) -> f64 {
        self.link_bandwidth * self.ports_per_node as f64
    }

    /// The slow inter-island uplink of a clustered topology: each island
    /// fronts the cluster network with 2 × 2.5 GB/s links at 20 µs — a
    /// 10:1 per-link speed ratio against [`NetModel::dgx2`], the regime
    /// the hierarchical experiments run under.
    pub fn island_uplink() -> Self {
        Self {
            name: "island-uplink",
            fabric: Fabric::Switched,
            link_bandwidth: 2.5e9,
            ports_per_node: 2,
            latency: 20.0e-6,
            alloc_overhead: 0.0,
        }
    }
}

/// A two-class interconnect topology: islands of `per_island` consecutive
/// ranks whose members talk over the fast `intra` model, stitched
/// together by the slow `inter` model.
///
/// The class of a transfer is structural — `src` and `dst` in the same
/// island (`rank / per_island`) makes it intra, otherwise inter. The two
/// classes differ not only in link parameters but in *contention
/// granularity*: intra transfers contend per **rank** (every GPU owns its
/// NVLink ports), while inter transfers contend per **island** (all of an
/// island's cross-boundary traffic funnels through the island's shared
/// uplink NIC — the physical reason flat schedules collapse on clusters).
/// [`simulate_topology`](crate::net::sim::simulate_topology) prices both
/// classes per round and takes the max.
///
/// A [`uniform`](TopologyModel::uniform) topology puts every rank in one
/// island, reproducing the flat single-[`NetModel`] behavior exactly.
#[derive(Clone, Copy, Debug)]
pub struct TopologyModel {
    /// Human-readable preset name (bench tables, CLI `--net`).
    pub name: &'static str,
    /// Consecutive ranks per island; island of rank `r` is
    /// `r / per_island`.
    pub per_island: u32,
    /// Link model within an island (per-rank contention).
    pub intra: NetModel,
    /// Link model across islands (per-island uplink contention).
    pub inter: NetModel,
}

impl TopologyModel {
    /// A flat topology: one island spans every rank, so all transfers are
    /// intra and priced exactly like `net` alone.
    pub fn uniform(net: NetModel) -> Self {
        Self { name: net.name, per_island: u32::MAX, intra: net, inter: net }
    }

    /// A cluster of DGX-2-style islands: NVSwitch inside
    /// ([`NetModel::dgx2`]), 10:1-slower shared uplinks between
    /// ([`NetModel::island_uplink`]).
    pub fn dgx2_cluster(per_island: u32) -> Self {
        Self {
            name: "dgx2-cluster",
            per_island: per_island.max(1),
            intra: NetModel::dgx2(),
            inter: NetModel::island_uplink(),
        }
    }

    /// A uniform topology that still *classifies* transfers by island —
    /// both classes priced with `net`, but per-class counters reported.
    /// This is what a hierarchical run under a flat `--net` uses, so the
    /// intra/inter accounting stays meaningful.
    pub fn classified(net: NetModel, per_island: u32) -> Self {
        Self { name: net.name, per_island: per_island.max(1), intra: net, inter: net }
    }

    /// Island index of a rank.
    #[inline]
    pub fn island_of(&self, rank: u32) -> u32 {
        rank / self.per_island
    }

    /// Whether a transfer stays within one island.
    #[inline]
    pub fn is_intra(&self, src: u32, dst: u32) -> bool {
        self.island_of(src) == self.island_of(dst)
    }

    /// Number of islands covering `num_nodes` ranks.
    pub fn num_islands(&self, num_nodes: u32) -> usize {
        (num_nodes as u64).div_ceil(u64::from(self.per_island)) as usize
    }

    /// Per-link intra:inter bandwidth ratio (10.0 for
    /// [`dgx2_cluster`](Self::dgx2_cluster)).
    pub fn speed_ratio(&self) -> f64 {
        self.intra.link_bandwidth / self.inter.link_bandwidth
    }
}

/// Compute-side device model: prices Phase-1 traversal work into time, so
/// simulated end-to-end level times = compute + communication.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Preset name.
    pub name: &'static str,
    /// Sustainable edge-examination rate (edges/second) for one device.
    pub edge_rate: f64,
    /// Per-level fixed overhead (kernel launches, LRB binning dispatch).
    pub level_overhead: f64,
    /// Cost multiplier for *bottom-up* edge examinations: the child-finds-
    /// parent probe is a dependent random access into the frontier bitmap
    /// with an unpredictable early exit — several times the cost of a
    /// streamed top-down adjacency read. This is why the paper's measured
    /// CPU DO/TD gains (Table 1: 1.07-10.5x) sit well below the raw
    /// examined-edge reduction.
    pub bu_edge_factor: f64,
}

impl DeviceModel {
    /// NVIDIA V100 (SXM3) running an LRB-balanced top-down kernel.
    ///
    /// Calibrated from the paper's own GAP_kron row: 4.22 B arcs in
    /// 0.01 s on 16 GPUs ⇒ ≈26 GTEPS sustained per GPU; we use 22 GTEPS
    /// (HBM2-bound: 900 GB/s ÷ ~40 B of amortized traffic per examined
    /// edge with LRB-coalesced adjacency reads).
    pub fn v100() -> Self {
        Self {
            name: "v100",
            edge_rate: 22.0e9,
            level_overhead: 12.0e-6,
            bu_edge_factor: 3.0,
        }
    }

    /// A 48-core Skylake server (the paper's CPU comparator, all cores).
    ///
    /// Calibrated from the paper's GAP_kron CPU-TD row: 4.22 B arcs in
    /// 3.04 s ⇒ ≈1.4 GTEPS examined across 96 threads.
    pub fn xeon_8168_dual() -> Self {
        Self {
            name: "2x-xeon-8168",
            edge_rate: 1.4e9,
            level_overhead: 8.0e-6,
            bu_edge_factor: 4.0,
        }
    }

    /// Time to examine `edges` edges in one top-down level on this device.
    pub fn level_time(&self, edges: u64) -> f64 {
        self.level_time_dir(edges, false)
    }

    /// Time for one level, direction-aware (bottom-up edges pay
    /// [`DeviceModel::bu_edge_factor`]).
    pub fn level_time_dir(&self, edges: u64, bottom_up: bool) -> f64 {
        let factor = if bottom_up { self.bu_edge_factor } else { 1.0 };
        self.level_overhead + edges as f64 * factor / self.edge_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgx2_matches_published_numbers() {
        let m = NetModel::dgx2();
        // §4: "a GPU can send and receive 150GB/s concurrently".
        assert!((m.node_bandwidth() - 150.0e9).abs() < 1.0);
        assert_eq!(m.ports_per_node, 6);
        assert_eq!(m.fabric, Fabric::Switched);
    }

    #[test]
    fn pcie_is_shared_and_slower() {
        let p = NetModel::pcie_gen3();
        let d = NetModel::dgx2();
        assert_eq!(p.fabric, Fabric::SharedBus);
        assert!(p.node_bandwidth() < d.node_bandwidth() / 5.0);
    }

    #[test]
    fn device_level_time_scales_with_edges() {
        let v = DeviceModel::v100();
        let t1 = v.level_time(1_000_000);
        let t2 = v.level_time(2_000_000);
        assert!(t2 > t1);
        assert!(v.level_time(0) == v.level_overhead);
    }

    #[test]
    fn dynamic_alloc_has_positive_overhead() {
        assert!(NetModel::dynamic_alloc_baseline().alloc_overhead > 0.0);
        assert_eq!(NetModel::dgx2().alloc_overhead, 0.0);
    }

    #[test]
    fn dgx2_cluster_has_ten_to_one_ratio() {
        let t = TopologyModel::dgx2_cluster(8);
        assert_eq!(t.per_island, 8);
        assert!((t.speed_ratio() - 10.0).abs() < 1e-12);
        assert!(t.inter.latency > t.intra.latency);
        assert!(t.inter.ports_per_node < t.intra.ports_per_node);
    }

    #[test]
    fn topology_classification() {
        let t = TopologyModel::dgx2_cluster(8);
        assert!(t.is_intra(0, 7));
        assert!(!t.is_intra(7, 8));
        assert_eq!(t.island_of(63), 7);
        assert_eq!(t.num_islands(64), 8);
        assert_eq!(t.num_islands(60), 8); // ragged last island
        let u = TopologyModel::uniform(NetModel::dgx2());
        assert!(u.is_intra(0, 1_000_000));
        assert_eq!(u.num_islands(64), 1);
        let c = TopologyModel::classified(NetModel::dgx2(), 4);
        assert!(!c.is_intra(3, 4));
        assert!((c.speed_ratio() - 1.0).abs() < 1e-12);
    }
}
