//! Interconnect and device models + the timing simulator that prices
//! communication schedules (DESIGN.md §2: the NVSwitch substitution).

pub mod model;
pub mod sim;

pub use model::{DeviceModel, Fabric, NetModel, TopologyModel};
pub use sim::{simulate_schedule, simulate_topology, simulate_uniform, CommTiming};
