//! Interconnect timing simulator: prices a [`Schedule`]'s rounds into
//! seconds under a [`NetModel`] or a two-class [`TopologyModel`].
//!
//! Round time under the **switched** fabric = the slowest node's
//! serialization: a node sending `k` messages over `p` ports pays
//! `latency·ceil(k/p)` of setup plus `max(largest single message /
//! link_bw, total bytes / (p·link_bw))` of wire time; receive side is
//! symmetric (full duplex). This is what turns the Fig 1(f) hotspot
//! (node 8 serving 8 messages with 6 ports) into the 8→9-GPU slowdown the
//! paper shows in Fig 3.
//!
//! Under the **shared bus**, everything in the round serializes:
//! `latency·max_msgs_per_node + total_round_bytes / link_bw`.
//!
//! [`simulate_topology`] generalizes this to a clustered fabric: each
//! transfer is classed intra- or inter-island, the intra class is priced
//! per *rank* and the inter class per *island* (an island's cross-boundary
//! traffic shares its uplink NIC), and the round takes the max of the two
//! class times. A [`TopologyModel::uniform`] topology reproduces the flat
//! pricing bit-for-bit.

use super::model::{Fabric, NetModel, TopologyModel};
use crate::comm::pattern::Schedule;

/// Timing breakdown of a simulated synchronization, with per-link-class
/// accounting: `intra_*`/`inter_*` split `total_*` by whether each
/// transfer stayed inside an island (under a flat [`NetModel`] everything
/// counts as intra).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommTiming {
    /// Per-round times in seconds.
    pub round_times: Vec<f64>,
    /// Total bytes shipped.
    pub total_bytes: u64,
    /// Total messages.
    pub total_messages: u64,
    /// Bytes that stayed on intra-island links.
    pub intra_bytes: u64,
    /// Messages that stayed on intra-island links.
    pub intra_messages: u64,
    /// Bytes that crossed the slow island boundary.
    pub inter_bytes: u64,
    /// Messages that crossed the slow island boundary.
    pub inter_messages: u64,
}

impl CommTiming {
    /// Total synchronization time.
    pub fn total(&self) -> f64 {
        self.round_times.iter().sum()
    }
}

/// One class's transfers within a round, in endpoint id space (ranks for
/// the intra class, islands for the inter class).
type ClassTransfer = (usize, usize, u64);

/// Price one round of one link class: the flat per-endpoint contention
/// formula over `num_endpoints` endpoints. Returns 0 for an empty class.
fn price_round(num_endpoints: usize, transfers: &[ClassTransfer], net: &NetModel) -> f64 {
    let mut send_bytes = vec![0u64; num_endpoints];
    let mut recv_bytes = vec![0u64; num_endpoints];
    let mut send_msgs = vec![0u32; num_endpoints];
    let mut recv_msgs = vec![0u32; num_endpoints];
    let mut max_payload = vec![0u64; num_endpoints];
    let mut round_bytes = 0u64;
    for &(src, dst, bytes) in transfers {
        send_bytes[src] += bytes;
        recv_bytes[dst] += bytes;
        send_msgs[src] += 1;
        recv_msgs[dst] += 1;
        max_payload[src] = max_payload[src].max(bytes);
        max_payload[dst] = max_payload[dst].max(bytes);
        round_bytes += bytes;
    }
    let ports = net.ports_per_node as f64;
    match net.fabric {
        Fabric::Switched => (0..num_endpoints)
            .map(|g| {
                let setup_send = net.latency * (send_msgs[g] as f64 / ports).ceil();
                let setup_recv = net.latency * (recv_msgs[g] as f64 / ports).ceil();
                let alloc = net.alloc_overhead * recv_msgs[g] as f64;
                // Messages are discrete: a node with k messages over p
                // links needs ceil(k/p) serialized slots per link (the
                // Fig 1(f) makespan), lower-bounded by the aggregate
                // bandwidth limit.
                let makespan = |msgs: u32, bytes: u64| -> f64 {
                    let slots = (msgs as f64 / ports).ceil();
                    (bytes as f64 / net.node_bandwidth())
                        .max(slots * max_payload[g] as f64 / net.link_bandwidth)
                };
                let wire_send = makespan(send_msgs[g], send_bytes[g]);
                let wire_recv = makespan(recv_msgs[g], recv_bytes[g]);
                (setup_send + wire_send).max(setup_recv + wire_recv) + alloc
            })
            .fold(0.0, f64::max),
        Fabric::SharedBus => {
            if transfers.is_empty() {
                return 0.0;
            }
            let max_msgs = send_msgs.iter().copied().max().unwrap_or(0) as f64;
            let alloc: f64 =
                recv_msgs.iter().map(|&m| net.alloc_overhead * m as f64).sum();
            net.latency * max_msgs + round_bytes as f64 / net.link_bandwidth + alloc
        }
    }
}

/// Price one *retransmission* of a single transfer under the topology's
/// link class for that pair: per-message latency plus serialization of the
/// payload over one link. Retries are point-to-point re-sends outside the
/// bulk round structure (the rest of the round already completed), so they
/// pay no port contention — this is the unit the fault-recovery machinery
/// ([`crate::fault`]) uses to price `retry_bytes` into `recovery_time`,
/// and the Python port mirrors it exactly.
pub fn retransmit_time(topo: &TopologyModel, src: u32, dst: u32, bytes: u64) -> f64 {
    let class = if topo.is_intra(src, dst) { &topo.intra } else { &topo.inter };
    class.latency + bytes as f64 / class.link_bandwidth
}

/// Price `schedule` under a two-class topology, with per-transfer payload
/// sizes supplied by `payload_bytes(round, transfer_index)`.
///
/// Per round, intra transfers contend per rank under `topo.intra`, inter
/// transfers are re-addressed to their island endpoints and contend per
/// island under `topo.inter`; the round costs the max of the two class
/// times (the classes use disjoint physical links and overlap). Per-class
/// byte/message totals land in the returned [`CommTiming`].
pub fn simulate_topology<F>(s: &Schedule, topo: &TopologyModel, mut payload_bytes: F) -> CommTiming
where
    F: FnMut(usize, usize) -> u64,
{
    let num_islands = topo.num_islands(s.num_nodes);
    let mut timing = CommTiming::default();
    let mut intra: Vec<ClassTransfer> = Vec::new();
    let mut inter: Vec<ClassTransfer> = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        intra.clear();
        inter.clear();
        for (ti, t) in round.iter().enumerate() {
            let bytes = payload_bytes(ri, ti);
            timing.total_bytes += bytes;
            if topo.is_intra(t.src, t.dst) {
                timing.intra_bytes += bytes;
                timing.intra_messages += 1;
                intra.push((t.src as usize, t.dst as usize, bytes));
            } else {
                timing.inter_bytes += bytes;
                timing.inter_messages += 1;
                inter.push((
                    topo.island_of(t.src) as usize,
                    topo.island_of(t.dst) as usize,
                    bytes,
                ));
            }
        }
        timing.total_messages += round.len() as u64;
        let t_intra = price_round(s.num_nodes as usize, &intra, &topo.intra);
        let t_inter = price_round(num_islands, &inter, &topo.inter);
        timing.round_times.push(t_intra.max(t_inter));
    }
    timing
}

/// Price `schedule` with per-transfer payload sizes supplied by
/// `payload_bytes(round, transfer_index)` (the engine passes real measured
/// queue/bitmap sizes; analyses pass a constant). Flat single-class
/// pricing: equivalent to [`simulate_topology`] under
/// [`TopologyModel::uniform`], so every byte counts as intra.
pub fn simulate_schedule<F>(s: &Schedule, net: &NetModel, payload_bytes: F) -> CommTiming
where
    F: FnMut(usize, usize) -> u64,
{
    simulate_topology(s, &TopologyModel::uniform(*net), payload_bytes)
}

/// Price a schedule with a constant per-message payload (bitmap mode:
/// every frontier message is `ceil(V/64)·8` bytes).
pub fn simulate_uniform(s: &Schedule, net: &NetModel, payload: u64) -> CommTiming {
    simulate_schedule(s, net, |_, _| payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::alltoall::ConcurrentAllToAll;
    use crate::comm::butterfly::Butterfly;
    use crate::comm::fold_expand::FoldExpand;
    use crate::comm::hierarchical::GridOfIslands;
    use crate::comm::pattern::CommPattern;
    use crate::net::model::NetModel;

    const MB: u64 = 1 << 20;

    #[test]
    fn single_message_wire_time() {
        // One 25 MB message over one 25 GB/s link ≈ 1 ms + latency.
        let s = Butterfly::new(1).schedule(2);
        let t = simulate_uniform(&s, &NetModel::dgx2(), 25 * MB);
        assert_eq!(t.total_messages, 2);
        let expect = 2.0e-6 + 25.0 * MB as f64 / 25.0e9;
        assert!((t.total() - expect).abs() / expect < 1e-6, "{}", t.total());
    }

    #[test]
    fn eight_to_nine_gpu_regression_fanout1() {
        // The paper's Fig 3 pathology: fanout-1 at 9 nodes is *slower*
        // than at 8 nodes despite more compute, because node 8 serves
        // everyone in the last round.
        let net = NetModel::dgx2();
        let t8 = simulate_uniform(&Butterfly::new(1).schedule(8), &net, MB).total();
        let t9 = simulate_uniform(&Butterfly::new(1).schedule(9), &net, MB).total();
        assert!(t9 > t8 * 1.5, "t8={t8} t9={t9}");
        // ... and fanout 4 does not regress nearly as hard (§5 "This
        // bottleneck does not happen for the larger fanout four").
        let f8 = simulate_uniform(&Butterfly::new(4).schedule(8), &net, MB).total();
        let f9 = simulate_uniform(&Butterfly::new(4).schedule(9), &net, MB).total();
        assert!(f9 / f8 < t9 / t8, "f4 ratio {} vs f1 ratio {}", f9 / f8, t9 / t8);
    }

    #[test]
    fn fanout4_faster_than_fanout1_at_16_nodes() {
        // §5 Fanout Difference: at 16 GPUs fanout 4 needs 2 rounds vs 4,
        // and wins on synchronization time.
        let net = NetModel::dgx2();
        let f1 = simulate_uniform(&Butterfly::new(1).schedule(16), &net, MB).total();
        let f4 = simulate_uniform(&Butterfly::new(4).schedule(16), &net, MB).total();
        assert!(f4 < f1, "f4={f4} f1={f1}");
    }

    #[test]
    fn butterfly_beats_concurrent_alltoall_on_shared_bus() {
        // On a shared bus the message count dominates; butterfly's
        // CN·log CN wins over CN².
        let net = NetModel::pcie_gen3();
        let bf = simulate_uniform(&Butterfly::new(1).schedule(16), &net, MB).total();
        let aa = simulate_uniform(&ConcurrentAllToAll.schedule(16), &net, MB).total();
        assert!(bf < aa, "bf={bf} aa={aa}");
    }

    #[test]
    fn dynamic_alloc_overhead_dominates_small_payloads() {
        // Gunrock/Groute-style dynamic allocation makes many-message
        // patterns catastrophically slower for small frontiers.
        let fast = NetModel::dgx2();
        let slow = NetModel::dynamic_alloc_baseline();
        let s = ConcurrentAllToAll.schedule(16);
        let t_fast = simulate_uniform(&s, &fast, 4096).total();
        let t_slow = simulate_uniform(&s, &slow, 4096).total();
        assert!(t_slow > t_fast * 50.0, "fast={t_fast} slow={t_slow}");
    }

    #[test]
    fn empty_schedule_zero_time() {
        let s = Butterfly::new(1).schedule(1);
        let t = simulate_uniform(&s, &NetModel::dgx2(), MB);
        assert_eq!(t.total(), 0.0);
        assert_eq!(t.total_bytes, 0);
    }

    #[test]
    fn bytes_accounting() {
        let s = Butterfly::new(4).schedule(16); // 96 messages
        let t = simulate_uniform(&s, &NetModel::dgx2(), 1000);
        assert_eq!(t.total_bytes, 96_000);
        assert_eq!(t.total_messages, 96);
        assert_eq!(t.round_times.len(), 2);
        // Flat pricing classes everything intra.
        assert_eq!(t.intra_bytes, 96_000);
        assert_eq!(t.intra_messages, 96);
        assert_eq!(t.inter_bytes, 0);
        assert_eq!(t.inter_messages, 0);
    }

    #[test]
    fn uniform_topology_identical_to_flat() {
        let net = NetModel::dgx2();
        for cn in [5u32, 9, 16] {
            let s = Butterfly::new(2).schedule(cn);
            let flat = simulate_schedule(&s, &net, |r, t| (r * 31 + t * 7 + 100) as u64);
            let topo = simulate_topology(&s, &TopologyModel::uniform(net), |r, t| {
                (r * 31 + t * 7 + 100) as u64
            });
            assert_eq!(flat, topo, "cn={cn}");
            assert_eq!(flat.inter_messages, 0);
        }
    }

    #[test]
    fn per_class_split_sums_to_totals() {
        let g = GridOfIslands::new(4, 4, 1);
        let s = g.schedule(16);
        let topo = TopologyModel::dgx2_cluster(4);
        let t = simulate_topology(&s, &topo, |_, _| 1000);
        assert_eq!(t.intra_bytes + t.inter_bytes, t.total_bytes);
        assert_eq!(t.intra_messages + t.inter_messages, t.total_messages);
        // From the schedule structure: 2 inter rounds of 4 rep messages.
        assert_eq!(t.inter_messages, 8);
        assert_eq!(t.inter_bytes, 8_000);
    }

    #[test]
    fn inter_class_is_priced_slower() {
        // Same message shape, different class: one cross-island transfer
        // must cost more than one within-island transfer under 10:1.
        let topo = TopologyModel::dgx2_cluster(8);
        let s_intra = Schedule {
            num_nodes: 16,
            rounds: vec![vec![crate::comm::pattern::Transfer { src: 0, dst: 1 }]],
        };
        let s_inter = Schedule {
            num_nodes: 16,
            rounds: vec![vec![crate::comm::pattern::Transfer { src: 0, dst: 8 }]],
        };
        let t_intra = simulate_topology(&s_intra, &topo, |_, _| MB).total();
        let t_inter = simulate_topology(&s_inter, &topo, |_, _| MB).total();
        assert!(t_inter > t_intra * 5.0, "intra={t_intra} inter={t_inter}");
    }

    #[test]
    fn island_uplink_contention_is_per_island() {
        // 8 ranks of island 0 each send one message across the boundary:
        // all 8 funnel through island 0's 2-port uplink (4 slots), so the
        // round costs ~4× a single rep's message, not ~1×.
        let topo = TopologyModel::dgx2_cluster(8);
        let fan: Vec<_> = (0..8u32)
            .map(|i| crate::comm::pattern::Transfer { src: i, dst: 8 + i })
            .collect();
        let s_fan = Schedule { num_nodes: 16, rounds: vec![fan] };
        let one = Schedule {
            num_nodes: 16,
            rounds: vec![vec![crate::comm::pattern::Transfer { src: 0, dst: 8 }]],
        };
        let t_fan = simulate_topology(&s_fan, &topo, |_, _| MB).total();
        let t_one = simulate_topology(&one, &topo, |_, _| MB).total();
        assert!(t_fan > t_one * 3.0, "fan={t_fan} one={t_one}");
    }

    #[test]
    fn retransmit_time_uses_the_pair_link_class() {
        let topo = TopologyModel::dgx2_cluster(8);
        let fast = retransmit_time(&topo, 0, 1, 1 << 20);
        let slow = retransmit_time(&topo, 0, 8, 1 << 20);
        let want_fast = 2.0e-6 + (1u64 << 20) as f64 / 25.0e9;
        let want_slow = 20.0e-6 + (1u64 << 20) as f64 / 2.5e9;
        assert!((fast - want_fast).abs() < 1e-15);
        assert!((slow - want_slow).abs() < 1e-15);
        assert!(slow > fast * 5.0);
    }

    #[test]
    fn hierarchical_beats_flat_at_p64_ten_to_one() {
        // The ROADMAP acceptance shape: at p = 64 under the 10:1 cluster
        // topology, grid-of-islands beats both the flat butterfly and the
        // flat 2D fold/expand on simulated time (uniform payloads here;
        // the engine-level version with real frontier payloads is the
        // bench protocol's `hierarchical` section).
        let topo = TopologyModel::dgx2_cluster(8);
        let hier = GridOfIslands::new(8, 8, 4).schedule(64);
        let flat1d = Butterfly::new(4).schedule(64);
        let flat2d = FoldExpand::new(8, 8).schedule(64);
        for payload in [4 * 1024u64, MB, 16 * MB] {
            let t_h = simulate_topology(&hier, &topo, |_, _| payload).total();
            let t_1 = simulate_topology(&flat1d, &topo, |_, _| payload).total();
            let t_2 = simulate_topology(&flat2d, &topo, |_, _| payload).total();
            assert!(t_h < t_1, "payload={payload}: hier={t_h} 1d={t_1}");
            assert!(t_h < t_2, "payload={payload}: hier={t_h} 2d={t_2}");
        }
    }
}
