//! Interconnect timing simulator: prices a [`Schedule`]'s rounds into
//! seconds under a [`NetModel`].
//!
//! Round time under the **switched** fabric = the slowest node's
//! serialization: a node sending `k` messages over `p` ports pays
//! `latency·ceil(k/p)` of setup plus `max(largest single message /
//! link_bw, total bytes / (p·link_bw))` of wire time; receive side is
//! symmetric (full duplex). This is what turns the Fig 1(f) hotspot
//! (node 8 serving 8 messages with 6 ports) into the 8→9-GPU slowdown the
//! paper shows in Fig 3.
//!
//! Under the **shared bus**, everything in the round serializes:
//! `latency·max_msgs_per_node + total_round_bytes / link_bw`.

use super::model::{Fabric, NetModel};
use crate::comm::pattern::Schedule;

/// Timing breakdown of a simulated synchronization.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommTiming {
    /// Per-round times in seconds.
    pub round_times: Vec<f64>,
    /// Total bytes shipped.
    pub total_bytes: u64,
    /// Total messages.
    pub total_messages: u64,
}

impl CommTiming {
    /// Total synchronization time.
    pub fn total(&self) -> f64 {
        self.round_times.iter().sum()
    }
}

/// Price `schedule` with per-transfer payload sizes supplied by
/// `payload_bytes(round, transfer_index)` (the engine passes real measured
/// queue/bitmap sizes; analyses pass a constant).
pub fn simulate_schedule<F>(s: &Schedule, net: &NetModel, mut payload_bytes: F) -> CommTiming
where
    F: FnMut(usize, usize) -> u64,
{
    let mut timing = CommTiming::default();
    for (ri, round) in s.rounds.iter().enumerate() {
        let mut send_bytes = vec![0u64; s.num_nodes as usize];
        let mut recv_bytes = vec![0u64; s.num_nodes as usize];
        let mut send_msgs = vec![0u32; s.num_nodes as usize];
        let mut recv_msgs = vec![0u32; s.num_nodes as usize];
        let mut max_payload = vec![0u64; s.num_nodes as usize];
        let mut round_bytes = 0u64;
        for (ti, t) in round.iter().enumerate() {
            let bytes = payload_bytes(ri, ti);
            send_bytes[t.src as usize] += bytes;
            recv_bytes[t.dst as usize] += bytes;
            send_msgs[t.src as usize] += 1;
            recv_msgs[t.dst as usize] += 1;
            max_payload[t.src as usize] = max_payload[t.src as usize].max(bytes);
            max_payload[t.dst as usize] = max_payload[t.dst as usize].max(bytes);
            round_bytes += bytes;
        }
        timing.total_bytes += round_bytes;
        timing.total_messages += round.len() as u64;
        let ports = net.ports_per_node as f64;
        let t_round = match net.fabric {
            Fabric::Switched => (0..s.num_nodes as usize)
                .map(|g| {
                    let setup_send =
                        net.latency * (send_msgs[g] as f64 / ports).ceil();
                    let setup_recv =
                        net.latency * (recv_msgs[g] as f64 / ports).ceil();
                    let alloc = net.alloc_overhead * recv_msgs[g] as f64;
                    // Messages are discrete: a node with k messages over p
                    // links needs ceil(k/p) serialized slots per link (the
                    // Fig 1(f) makespan), lower-bounded by the aggregate
                    // bandwidth limit.
                    let makespan = |msgs: u32, bytes: u64| -> f64 {
                        let slots = (msgs as f64 / ports).ceil();
                        (bytes as f64 / net.node_bandwidth())
                            .max(slots * max_payload[g] as f64 / net.link_bandwidth)
                    };
                    let wire_send = makespan(send_msgs[g], send_bytes[g]);
                    let wire_recv = makespan(recv_msgs[g], recv_bytes[g]);
                    (setup_send + wire_send).max(setup_recv + wire_recv) + alloc
                })
                .fold(0.0, f64::max),
            Fabric::SharedBus => {
                let max_msgs = send_msgs.iter().copied().max().unwrap_or(0) as f64;
                let alloc: f64 = recv_msgs
                    .iter()
                    .map(|&m| net.alloc_overhead * m as f64)
                    .sum();
                net.latency * max_msgs
                    + round_bytes as f64 / net.link_bandwidth
                    + alloc
            }
        };
        timing.round_times.push(t_round);
    }
    timing
}

/// Price a schedule with a constant per-message payload (bitmap mode:
/// every frontier message is `ceil(V/64)·8` bytes).
pub fn simulate_uniform(s: &Schedule, net: &NetModel, payload: u64) -> CommTiming {
    simulate_schedule(s, net, |_, _| payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::alltoall::ConcurrentAllToAll;
    use crate::comm::butterfly::Butterfly;
    use crate::comm::pattern::CommPattern;
    use crate::net::model::NetModel;

    const MB: u64 = 1 << 20;

    #[test]
    fn single_message_wire_time() {
        // One 25 MB message over one 25 GB/s link ≈ 1 ms + latency.
        let s = Butterfly::new(1).schedule(2);
        let t = simulate_uniform(&s, &NetModel::dgx2(), 25 * MB);
        assert_eq!(t.total_messages, 2);
        let expect = 2.0e-6 + 25.0 * MB as f64 / 25.0e9;
        assert!((t.total() - expect).abs() / expect < 1e-6, "{}", t.total());
    }

    #[test]
    fn eight_to_nine_gpu_regression_fanout1() {
        // The paper's Fig 3 pathology: fanout-1 at 9 nodes is *slower*
        // than at 8 nodes despite more compute, because node 8 serves
        // everyone in the last round.
        let net = NetModel::dgx2();
        let t8 = simulate_uniform(&Butterfly::new(1).schedule(8), &net, MB).total();
        let t9 = simulate_uniform(&Butterfly::new(1).schedule(9), &net, MB).total();
        assert!(t9 > t8 * 1.5, "t8={t8} t9={t9}");
        // ... and fanout 4 does not regress nearly as hard (§5 "This
        // bottleneck does not happen for the larger fanout four").
        let f8 = simulate_uniform(&Butterfly::new(4).schedule(8), &net, MB).total();
        let f9 = simulate_uniform(&Butterfly::new(4).schedule(9), &net, MB).total();
        assert!(f9 / f8 < t9 / t8, "f4 ratio {} vs f1 ratio {}", f9 / f8, t9 / t8);
    }

    #[test]
    fn fanout4_faster_than_fanout1_at_16_nodes() {
        // §5 Fanout Difference: at 16 GPUs fanout 4 needs 2 rounds vs 4,
        // and wins on synchronization time.
        let net = NetModel::dgx2();
        let f1 = simulate_uniform(&Butterfly::new(1).schedule(16), &net, MB).total();
        let f4 = simulate_uniform(&Butterfly::new(4).schedule(16), &net, MB).total();
        assert!(f4 < f1, "f4={f4} f1={f1}");
    }

    #[test]
    fn butterfly_beats_concurrent_alltoall_on_shared_bus() {
        // On a shared bus the message count dominates; butterfly's
        // CN·log CN wins over CN².
        let net = NetModel::pcie_gen3();
        let bf = simulate_uniform(&Butterfly::new(1).schedule(16), &net, MB).total();
        let aa = simulate_uniform(&ConcurrentAllToAll.schedule(16), &net, MB).total();
        assert!(bf < aa, "bf={bf} aa={aa}");
    }

    #[test]
    fn dynamic_alloc_overhead_dominates_small_payloads() {
        // Gunrock/Groute-style dynamic allocation makes many-message
        // patterns catastrophically slower for small frontiers.
        let fast = NetModel::dgx2();
        let slow = NetModel::dynamic_alloc_baseline();
        let s = ConcurrentAllToAll.schedule(16);
        let t_fast = simulate_uniform(&s, &fast, 4096).total();
        let t_slow = simulate_uniform(&s, &slow, 4096).total();
        assert!(t_slow > t_fast * 50.0, "fast={t_fast} slow={t_slow}");
    }

    #[test]
    fn empty_schedule_zero_time() {
        let s = Butterfly::new(1).schedule(1);
        let t = simulate_uniform(&s, &NetModel::dgx2(), MB);
        assert_eq!(t.total(), 0.0);
        assert_eq!(t.total_bytes, 0);
    }

    #[test]
    fn bytes_accounting() {
        let s = Butterfly::new(4).schedule(16); // 96 messages
        let t = simulate_uniform(&s, &NetModel::dgx2(), 1000);
        assert_eq!(t.total_bytes, 96_000);
        assert_eq!(t.total_messages, 96);
        assert_eq!(t.round_times.len(), 2);
    }
}
