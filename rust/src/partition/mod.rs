//! Graph partitioning across compute nodes.
//!
//! The paper deliberately uses "a straightforward 1D partitioning scheme
//! where we divide the vertices to the multiple GPUs such that each GPU
//! gets a near equal number of edges and the vertices are consecutive in
//! their ids" (§4 Graph Partitioning). [`one_d`] is that scheme; [`relabel`]
//! implements the degree-sort vertex relabeling the paper defers to future
//! work (built here as an ablation).

pub mod one_d;
pub mod relabel;
pub mod two_d;

pub use one_d::{partition_1d, Partition1D};
pub use two_d::Partition2D;
