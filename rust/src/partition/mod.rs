//! Graph partitioning across compute nodes.
//!
//! The paper deliberately uses "a straightforward 1D partitioning scheme
//! where we divide the vertices to the multiple GPUs such that each GPU
//! gets a near equal number of edges and the vertices are consecutive in
//! their ids" (§4 Graph Partitioning). [`one_d`] is that scheme; [`two_d`]
//! is the checkerboard alternative the paper is pitched against (Buluç &
//! Madduri's fold/expand layout), which the engine's
//! [`PartitionMode::TwoD`](crate::coordinator::config::PartitionMode) mode
//! runs head-to-head against 1D+butterfly; [`relabel`] implements the
//! degree-sort vertex relabeling the paper defers to future work (built
//! here as an ablation).

use crate::graph::csr::Csr;

pub mod one_d;
pub mod relabel;
pub mod two_d;

pub use one_d::{partition_1d, Partition1D};
pub use two_d::Partition2D;

/// The partition a running engine was built over — 1D row slabs or a 2D
/// processor grid. This is the layout half of the coordinator's
/// multi-pattern seam (the other half is the synchronization
/// [`Schedule`](crate::comm::Schedule) paired with it).
#[derive(Clone, Debug, PartialEq)]
pub enum PartitionSpec {
    /// Contiguous edge-balanced vertex ranges (the paper's layout).
    OneD(Partition1D),
    /// `rows × cols` checkerboard edge blocks (fold/expand layout).
    TwoD(Partition2D),
}

impl PartitionSpec {
    /// The 1D partition, when this is one.
    pub fn as_one_d(&self) -> Option<&Partition1D> {
        match self {
            PartitionSpec::OneD(p) => Some(p),
            PartitionSpec::TwoD(_) => None,
        }
    }

    /// The 2D partition, when this is one.
    pub fn as_two_d(&self) -> Option<&Partition2D> {
        match self {
            PartitionSpec::OneD(_) => None,
            PartitionSpec::TwoD(p) => Some(p),
        }
    }

    /// Edge-balance ratio: max per-node edges / mean (1.0 = perfect).
    pub fn imbalance(&self, g: &Csr) -> f64 {
        match self {
            PartitionSpec::OneD(p) => p.imbalance(g),
            PartitionSpec::TwoD(p) => p.imbalance(g),
        }
    }

    /// Short display name — delegates to
    /// [`PartitionMode::name`](crate::coordinator::config::PartitionMode::name)
    /// so the `"1d"` / `"2d-RxC"` format has a single definition.
    pub fn name(&self) -> String {
        self.mode().name()
    }

    /// The [`PartitionMode`](crate::coordinator::config::PartitionMode)
    /// this spec instantiates.
    pub fn mode(&self) -> crate::coordinator::config::PartitionMode {
        match self {
            PartitionSpec::OneD(_) => crate::coordinator::config::PartitionMode::OneD,
            PartitionSpec::TwoD(p) => crate::coordinator::config::PartitionMode::TwoD {
                rows: p.grid_rows,
                cols: p.grid_cols,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn spec_accessors_and_names() {
        let (g, _) = uniform_random(120, 4, 7);
        let one = PartitionSpec::OneD(partition_1d(&g, 4));
        let two = PartitionSpec::TwoD(Partition2D::new(&g, 2, 3));
        assert!(one.as_one_d().is_some() && one.as_two_d().is_none());
        assert!(two.as_two_d().is_some() && two.as_one_d().is_none());
        assert_eq!(one.name(), "1d");
        assert_eq!(two.name(), "2d-2x3");
        use crate::coordinator::config::PartitionMode;
        assert_eq!(one.mode(), PartitionMode::OneD);
        assert_eq!(two.mode(), PartitionMode::TwoD { rows: 2, cols: 3 });
        assert!(one.imbalance(&g) >= 1.0);
        assert!(two.imbalance(&g) >= 1.0);
    }
}
