//! 1D contiguous edge-balanced partitioning (§4 Graph Partitioning).
//!
//! Vertices keep consecutive ids; cut points are chosen so each compute
//! node owns a near-equal number of *edges* (not vertices — the paper is
//! explicit that "the number of vertices on each of the GPUs can be quite
//! different"). Ownership lookup (`owner_of`) is the routing primitive of
//! Alg. 2's `u ∈ myVertices[g]` test.

use crate::graph::csr::{Csr, CsrSlab, VertexId};

/// A 1D partition: `cuts[p]..cuts[p+1]` is the vertex range of node `p`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition1D {
    /// Cut points, length `parts + 1`; `cuts[0] = 0`,
    /// `cuts[parts] = num_vertices`.
    pub cuts: Vec<VertexId>,
}

impl Partition1D {
    /// Number of parts.
    pub fn parts(&self) -> usize {
        self.cuts.len() - 1
    }

    /// Vertex range of part `p`.
    pub fn range(&self, p: usize) -> (VertexId, VertexId) {
        (self.cuts[p], self.cuts[p + 1])
    }

    /// Owner of vertex `v` (binary search over cut points — O(log P), the
    /// hot routing path of the distributed engine).
    #[inline]
    pub fn owner_of(&self, v: VertexId) -> u32 {
        debug_assert!(v < *self.cuts.last().unwrap());
        // partition_point: first cut > v, minus one.
        (self.cuts.partition_point(|&c| c <= v) - 1) as u32
    }

    /// Number of vertices owned by part `p`.
    pub fn part_vertices(&self, p: usize) -> u32 {
        self.cuts[p + 1] - self.cuts[p]
    }

    /// Edges owned by each part, computed against a graph.
    pub fn part_edges(&self, g: &Csr) -> Vec<u64> {
        (0..self.parts())
            .map(|p| {
                let (lo, hi) = self.range(p);
                g.offsets()[hi as usize] - g.offsets()[lo as usize]
            })
            .collect()
    }

    /// Edge-balance ratio: max part edges / mean part edges (1.0 = perfect).
    pub fn imbalance(&self, g: &Csr) -> f64 {
        let per = self.part_edges(g);
        let max = *per.iter().max().unwrap_or(&0) as f64;
        let mean = per.iter().sum::<u64>() as f64 / per.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Materialize the per-node adjacency slabs.
    pub fn slabs(&self, g: &Csr) -> Vec<CsrSlab> {
        (0..self.parts())
            .map(|p| {
                let (lo, hi) = self.range(p);
                g.row_slice(lo, hi)
            })
            .collect()
    }
}

/// Build an edge-balanced contiguous partition into `parts` ranges.
///
/// Greedy prefix scan: part `p` ends at the first vertex where the running
/// edge count reaches `(p+1)·m/parts`. Every part is non-empty when
/// `parts <= num_vertices`. The CSR offsets array *is* the out-edge
/// prefix-weight array, so this delegates to the shared greedy.
pub fn partition_1d(g: &Csr, parts: usize) -> Partition1D {
    Partition1D { cuts: balanced_cuts_from_prefix(g.offsets(), parts) }
}

/// The one greedy cut policy behind every contiguous balanced partition
/// axis: given `prefix[v]` = total weight of vertices `0..v` (length
/// `n + 1`, monotone), cut into `parts` non-empty ranges of near-equal
/// weight. Range `p` ends at the first vertex where the running weight
/// reaches `(p+1)·total/parts`, always leaving at least one vertex per
/// remaining range. The 1D row cuts use the CSR offsets (out-edges); the
/// 2D column cuts use an in-degree prefix
/// ([`Partition2D::new`](crate::partition::Partition2D)) — one
/// implementation, so the two axes can never drift apart.
pub fn balanced_cuts_from_prefix(prefix: &[u64], parts: usize) -> Vec<VertexId> {
    assert!(parts >= 1, "parts must be >= 1");
    assert!(!prefix.is_empty(), "prefix must have n + 1 entries");
    let n = prefix.len() - 1;
    assert!(
        parts <= n.max(1),
        "more parts ({parts}) than vertices ({n})"
    );
    let total = prefix[n] as f64;
    let mut cuts = Vec::with_capacity(parts + 1);
    cuts.push(0 as VertexId);
    let mut v = 0usize;
    for p in 1..parts {
        let target = total * p as f64 / parts as f64;
        // Advance to the first vertex whose prefix weight >= target, but
        // always leave enough vertices for the remaining parts.
        let max_v = n - (parts - p); // leave >= 1 vertex per remaining part
        while v < max_v && (prefix[v + 1] as f64) < target {
            v += 1;
        }
        // Ensure strictly increasing cuts (non-empty parts).
        let prev = *cuts.last().unwrap() as usize;
        v = v.max(prev + 1).min(max_v);
        cuts.push(v as VertexId);
    }
    cuts.push(n as VertexId);
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{path, star};
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn covers_all_vertices_no_overlap() {
        let (g, _) = uniform_random(1000, 8, 1);
        let p = partition_1d(&g, 7);
        assert_eq!(p.parts(), 7);
        assert_eq!(p.cuts[0], 0);
        assert_eq!(*p.cuts.last().unwrap(), 1000);
        for i in 0..7 {
            assert!(p.cuts[i] < p.cuts[i + 1], "empty part {i}");
        }
    }

    #[test]
    fn owner_of_consistent_with_ranges() {
        let (g, _) = uniform_random(500, 6, 2);
        let p = partition_1d(&g, 5);
        for v in 0..500u32 {
            let o = p.owner_of(v) as usize;
            let (lo, hi) = p.range(o);
            assert!(v >= lo && v < hi, "v={v} owner={o} range={lo}..{hi}");
        }
    }

    #[test]
    fn edge_balance_on_uniform_graph() {
        let (g, _) = uniform_random(10_000, 16, 3);
        let p = partition_1d(&g, 16);
        assert!(p.imbalance(&g) < 1.1, "imbalance {}", p.imbalance(&g));
    }

    #[test]
    fn edge_balance_reasonable_on_skewed_graph() {
        let (g, _) = kronecker(KroneckerParams::graph500(13, 16), 4);
        let p = partition_1d(&g, 8);
        // Skewed graphs can't be perfect, but greedy prefix should stay
        // within 2x of mean unless one hub dominates.
        assert!(p.imbalance(&g) < 2.0, "imbalance {}", p.imbalance(&g));
    }

    #[test]
    fn star_graph_extreme_case_still_partitions() {
        let g = star(100);
        let p = partition_1d(&g, 4);
        // The center (vertex 0, degree 99) makes part 0 heavy; all parts
        // still exist and cover the range.
        assert_eq!(p.parts(), 4);
        assert_eq!(*p.cuts.last().unwrap(), 100);
        let edges = p.part_edges(&g);
        assert_eq!(edges.iter().sum::<u64>(), g.num_edges());
    }

    #[test]
    fn single_part_owns_everything() {
        let g = path(10);
        let p = partition_1d(&g, 1);
        assert_eq!(p.parts(), 1);
        assert_eq!(p.range(0), (0, 10));
        assert_eq!(p.owner_of(9), 0);
    }

    #[test]
    fn parts_equal_vertices_ok() {
        let g = path(5);
        let p = partition_1d(&g, 5);
        for v in 0..5u32 {
            assert_eq!(p.owner_of(v), v);
        }
    }

    #[test]
    fn slabs_reconstruct_graph() {
        let (g, _) = uniform_random(300, 8, 9);
        let p = partition_1d(&g, 6);
        let slabs = p.slabs(&g);
        let total_edges: u64 = slabs.iter().map(|s| s.num_edges()).sum();
        assert_eq!(total_edges, g.num_edges());
        for (i, s) in slabs.iter().enumerate() {
            let (lo, hi) = p.range(i);
            assert_eq!(s.first_vertex, lo);
            assert_eq!(s.end_vertex(), hi);
            for v in lo..hi {
                assert_eq!(s.neighbors_global(v), g.neighbors(v));
            }
        }
    }

    #[test]
    fn partition_property_roundtrip() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(40), "1d partition invariants", |rng| {
            let n = gen::usize_in(rng, 4, 400);
            let ef = gen::usize_in(rng, 1, 8) as u32;
            let parts = gen::usize_in(rng, 1, n.min(20));
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let p = partition_1d(&g, parts);
            let sum_v: u64 = (0..parts).map(|i| p.part_vertices(i) as u64).sum();
            let sum_e: u64 = p.part_edges(&g).iter().sum();
            let ok = p.parts() == parts
                && sum_v == n as u64
                && sum_e == g.num_edges()
                && (0..n as u32).all(|v| {
                    let o = p.owner_of(v) as usize;
                    let (lo, hi) = p.range(o);
                    v >= lo && v < hi
                });
            (ok, format!("n={n} parts={parts}"))
        });
    }
}
