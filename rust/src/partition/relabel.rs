//! Degree-sort vertex relabeling — the paper's future-work item ("In
//! future work, we will investigate the benefit of graph partitioning and
//! vertex relabeling"), implemented here as an ablation.
//!
//! Relabeling by descending degree clusters the hubs at low ids, which
//! interacts with the contiguous 1D partitioner: cut points land right
//! after the hub block, so per-node edge balance improves on skewed
//! graphs. `benches/fanout_ablation.rs` measures the effect.

use crate::graph::csr::{Csr, VertexId};

/// A vertex relabeling: `new_id[v]` is the new id of old vertex `v`, and
/// `old_id` the inverse.
#[derive(Clone, Debug)]
pub struct Relabeling {
    /// Old id → new id.
    pub new_id: Vec<VertexId>,
    /// New id → old id.
    pub old_id: Vec<VertexId>,
}

impl Relabeling {
    /// Identity relabeling.
    pub fn identity(n: usize) -> Self {
        let ids: Vec<VertexId> = (0..n as VertexId).collect();
        Self { new_id: ids.clone(), old_id: ids }
    }

    /// Translate a distance array computed on the relabeled graph back to
    /// original vertex ids.
    pub fn unmap_dist(&self, dist_new: &[u32]) -> Vec<u32> {
        let mut out = vec![0u32; dist_new.len()];
        for (old, &new) in self.new_id.iter().enumerate() {
            out[old] = dist_new[new as usize];
        }
        out
    }
}

/// Build the descending-degree relabeling for `g`.
pub fn degree_sort_relabeling(g: &Csr) -> Relabeling {
    let n = g.num_vertices();
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    // Stable sort by descending degree keeps ties in id order
    // (deterministic output).
    order.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    let mut new_id = vec![0 as VertexId; n];
    for (new, &old) in order.iter().enumerate() {
        new_id[old as usize] = new as VertexId;
    }
    Relabeling { new_id, old_id: order }
}

/// Apply a relabeling, producing the permuted graph.
pub fn apply_relabeling(g: &Csr, r: &Relabeling) -> Csr {
    let n = g.num_vertices();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(g.num_edges() as usize);
    for u in 0..n as VertexId {
        let nu = r.new_id[u as usize];
        for &v in g.neighbors(u) {
            edges.push((nu, r.new_id[v as usize]));
        }
    }
    Csr::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::partition::one_d::partition_1d;

    #[test]
    fn relabeling_is_a_bijection() {
        let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 5);
        let r = degree_sort_relabeling(&g);
        for old in 0..g.num_vertices() {
            assert_eq!(r.old_id[r.new_id[old] as usize] as usize, old);
        }
    }

    #[test]
    fn degrees_descending_after_relabel() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 6);
        let r = degree_sort_relabeling(&g);
        let h = apply_relabeling(&g, &r);
        for v in 1..h.num_vertices() as u32 {
            assert!(h.degree(v - 1) >= h.degree(v), "v={v}");
        }
    }

    #[test]
    fn bfs_distances_invariant_under_relabeling() {
        let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 7);
        let r = degree_sort_relabeling(&g);
        let h = apply_relabeling(&g, &r);
        let root_old = 3u32;
        let d_g = serial_bfs(&g, root_old);
        let d_h = serial_bfs(&h, r.new_id[root_old as usize]);
        assert_eq!(d_g, r.unmap_dist(&d_h));
    }

    #[test]
    fn relabeling_preserves_edge_count_and_improves_balance() {
        let (g, _) = kronecker(KroneckerParams::graph500(12, 16), 8);
        let r = degree_sort_relabeling(&g);
        let h = apply_relabeling(&g, &r);
        assert_eq!(g.num_edges(), h.num_edges());
        let before = partition_1d(&g, 8).imbalance(&g);
        let after = partition_1d(&h, 8).imbalance(&h);
        // Degree sort should not make balance dramatically worse; usually
        // it improves. Allow slack for small graphs.
        assert!(after <= before * 1.25, "before={before} after={after}");
    }

    #[test]
    fn identity_is_noop() {
        let (g, _) = kronecker(KroneckerParams::graph500(8, 4), 9);
        let r = Relabeling::identity(g.num_vertices());
        let h = apply_relabeling(&g, &r);
        assert_eq!(g, h);
    }
}
