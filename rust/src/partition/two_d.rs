//! 2D (grid) partitioning analysis.
//!
//! The paper's §2 cites Yoo et al.'s BlueGene/L result that 2D
//! partitioning "can help reduce the number of messages from P to √P",
//! and §4 notes Alg. 2 "can also work with 2D partitioning" while the
//! implementation deliberately stays 1D. This module makes that
//! discussion executable: a rectangular processor-grid partition of the
//! adjacency matrix, its ownership/routing rules, and closed-form
//! synchronization-cost comparisons against 1D — used by the ablation
//! bench and tests, matching the paper's scoping (analysis, not the
//! engine's layout).

use crate::graph::csr::{Csr, VertexId};

/// A `rows × cols` processor grid over the adjacency matrix: processor
/// `(i, j)` owns the edge blocks with source range `i` and target range
/// `j`; vertex `v` is *primarily* owned by the diagonal holder of its
/// range.
#[derive(Clone, Debug)]
pub struct Partition2D {
    /// Processor-grid rows.
    pub grid_rows: u32,
    /// Processor-grid columns.
    pub grid_cols: u32,
    /// Vertex-range cut points (length `max(grid_rows, grid_cols) + 1`
    /// conceptually; we use a single 1D range split reused on both axes).
    pub cuts: Vec<VertexId>,
}

impl Partition2D {
    /// Build a 2D partition over `g` with a `rows × cols` grid
    /// (vertex ranges split evenly by vertex count on both axes).
    pub fn new(g: &Csr, rows: u32, cols: u32) -> Self {
        assert!(rows >= 1 && cols >= 1);
        let n = g.num_vertices();
        let ranges = rows.max(cols) as usize;
        assert!(ranges <= n.max(1), "grid larger than vertex count");
        let mut cuts = Vec::with_capacity(ranges + 1);
        for i in 0..=ranges {
            cuts.push((n * i / ranges) as VertexId);
        }
        Self { grid_rows: rows, grid_cols: cols, cuts }
    }

    /// Number of processors.
    pub fn processors(&self) -> u32 {
        self.grid_rows * self.grid_cols
    }

    /// Vertex-range index of `v`.
    fn range_of(&self, v: VertexId) -> u32 {
        (self.cuts.partition_point(|&c| c <= v) - 1) as u32
    }

    /// Processor owning edge block `(u → w)`: row range of `u`, column
    /// range of `w` (folded into the grid).
    pub fn edge_owner(&self, u: VertexId, w: VertexId) -> (u32, u32) {
        (
            self.range_of(u) % self.grid_rows,
            self.range_of(w) % self.grid_cols,
        )
    }

    /// Per-level message count for a 2D-partitioned BFS: each processor
    /// exchanges along its row (fold) and column (expand) — `√P − 1`
    /// partners each for a square grid (Yoo et al.).
    pub fn messages_per_level(&self) -> u64 {
        let p = self.processors() as u64;
        let row_msgs = (self.grid_cols as u64 - 1) * p;
        let col_msgs = (self.grid_rows as u64 - 1) * p;
        row_msgs + col_msgs
    }

    /// The 1D all-to-all comparator: `P·(P−1)` messages per level.
    pub fn messages_per_level_1d_alltoall(&self) -> u64 {
        let p = self.processors() as u64;
        p * (p - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn square_grid_reduces_messages_sqrt_p() {
        let (g, _) = uniform_random(1000, 4, 1);
        // P = 16 as a 4x4 grid: 2·(4−1)·16 = 96 messages vs 240 all-to-all.
        let p2 = Partition2D::new(&g, 4, 4);
        assert_eq!(p2.processors(), 16);
        assert_eq!(p2.messages_per_level(), 96);
        assert_eq!(p2.messages_per_level_1d_alltoall(), 240);
        assert!(p2.messages_per_level() < p2.messages_per_level_1d_alltoall());
    }

    #[test]
    fn degenerate_1xp_grid_is_1d() {
        let (g, _) = uniform_random(100, 4, 2);
        let p2 = Partition2D::new(&g, 1, 8);
        // 1×P grid: row exchange = (P−1)·P = the all-to-all count.
        assert_eq!(p2.messages_per_level(), 7 * 8);
    }

    #[test]
    fn edge_owner_in_grid() {
        let (g, _) = uniform_random(160, 4, 3);
        let p2 = Partition2D::new(&g, 4, 4);
        for u in (0..160).step_by(13) {
            for w in (0..160).step_by(17) {
                let (r, c) = p2.edge_owner(u as VertexId, w as VertexId);
                assert!(r < 4 && c < 4);
            }
        }
    }

    #[test]
    fn ranges_cover_all_vertices() {
        let (g, _) = uniform_random(97, 4, 4); // prime count: uneven cuts
        let p2 = Partition2D::new(&g, 3, 3);
        assert_eq!(p2.cuts[0], 0);
        assert_eq!(*p2.cuts.last().unwrap(), 97);
        for v in 0..97u32 {
            let r = p2.range_of(v);
            assert!(v >= p2.cuts[r as usize] && v < p2.cuts[r as usize + 1]);
        }
    }

    #[test]
    fn butterfly_still_beats_2d_on_messages_at_dgx2_scale() {
        // The paper's implicit claim: at P = 16, butterfly fanout-1 (64
        // messages over 4 rounds) undercuts even the 2D scheme's 96.
        use crate::comm::{Butterfly, CommPattern};
        let (g, _) = uniform_random(1000, 4, 5);
        let p2 = Partition2D::new(&g, 4, 4);
        let bf = Butterfly::new(1).schedule(16).total_messages();
        assert!(bf < p2.messages_per_level(), "{bf} vs {}", p2.messages_per_level());
    }
}
