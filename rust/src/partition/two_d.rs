//! 2D (checkerboard) partitioning of the adjacency matrix.
//!
//! The paper's §2 cites Yoo et al.'s BlueGene/L result that 2D
//! partitioning "can help reduce the number of messages from P to √P",
//! and the classical fold/expand formulation is Buluç & Madduri's
//! distributed-memory BFS. A `rows × cols` processor grid blocks the
//! adjacency matrix: processor `(i, j)` owns the edge block with sources
//! in row range `i` (edge-balanced, like the 1D cuts) and targets in
//! column range `j` (vertex-balanced). Every edge `(u, w)` belongs to
//! exactly one block, so Phase-1 work partitions exactly; the per-level
//! exchange is **fold** along processor rows followed by **expand** along
//! processor columns ([`crate::comm::FoldExpand`]), `cols − 1 + rows − 1`
//! partners per processor instead of the 1D all-to-all's `P − 1`.
//!
//! This module is the layout/routing layer the engine's 2D mode
//! ([`PartitionMode::TwoD`](crate::coordinator::config::PartitionMode))
//! consumes, plus the closed-form message-volume model the measured
//! counts are tested against.

use crate::graph::csr::{Csr, CsrSlab, VertexId};
use crate::partition::one_d::partition_1d;

/// A `rows × cols` processor grid over the adjacency matrix: processor
/// `(i, j)` (rank `i·cols + j`) owns the edge block
/// `row_range(i) × col_range(j)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition2D {
    /// Processor-grid rows (source-axis split).
    pub grid_rows: u32,
    /// Processor-grid columns (target-axis split).
    pub grid_cols: u32,
    /// Source-axis cut points, length `grid_rows + 1` (edge-balanced by
    /// out-edges — Phase-1 expansion work is proportional to block edges).
    pub row_cuts: Vec<VertexId>,
    /// Target-axis cut points, length `grid_cols + 1` (edge-balanced by
    /// *in*-edges: a processor column's work is receiving/scattering the
    /// edges that target its vertex range, so vertex-balanced cuts load
    /// one column with every hub of a skewed graph — the same argument
    /// the paper makes for the 1D row cuts).
    pub col_cuts: Vec<VertexId>,
}

impl Partition2D {
    /// Build a 2D partition over `g` with a `rows × cols` grid. Requires
    /// `rows <= |V|` and `cols <= |V|` (every range non-empty); the
    /// processor count `rows·cols` may exceed `|V|`.
    pub fn new(g: &Csr, rows: u32, cols: u32) -> Self {
        assert!(rows >= 1 && cols >= 1);
        let n = g.num_vertices();
        assert!(
            rows as usize <= n.max(1) && cols as usize <= n.max(1),
            "grid {rows}x{cols} larger than vertex count {n}"
        );
        let row_cuts = partition_1d(g, rows as usize).cuts;
        // In-degree mass per target vertex: one pass over the arc array.
        let mut in_deg = vec![0u64; n];
        for &w in g.edges() {
            in_deg[w as usize] += 1;
        }
        let col_cuts = weight_balanced_cuts(&in_deg, cols as usize);
        Self { grid_rows: rows, grid_cols: cols, row_cuts, col_cuts }
    }

    /// Number of processors.
    pub fn processors(&self) -> u32 {
        self.grid_rows * self.grid_cols
    }

    /// Grid rank of processor `(i, j)` (row-major).
    #[inline]
    pub fn rank(&self, i: u32, j: u32) -> u32 {
        debug_assert!(i < self.grid_rows && j < self.grid_cols);
        i * self.grid_cols + j
    }

    /// Grid coordinates `(i, j)` of `rank`.
    #[inline]
    pub fn coords(&self, rank: u32) -> (u32, u32) {
        debug_assert!(rank < self.processors());
        (rank / self.grid_cols, rank % self.grid_cols)
    }

    /// Source-axis (processor-row) range index of `u`.
    #[inline]
    pub fn row_of(&self, u: VertexId) -> u32 {
        debug_assert!(u < *self.row_cuts.last().unwrap());
        (self.row_cuts.partition_point(|&c| c <= u) - 1) as u32
    }

    /// Target-axis (processor-column) range index of `w`.
    #[inline]
    pub fn col_of(&self, w: VertexId) -> u32 {
        debug_assert!(w < *self.col_cuts.last().unwrap());
        (self.col_cuts.partition_point(|&c| c <= w) - 1) as u32
    }

    /// Source vertex range of processor row `i`.
    pub fn row_range(&self, i: u32) -> (VertexId, VertexId) {
        (self.row_cuts[i as usize], self.row_cuts[i as usize + 1])
    }

    /// Target vertex range of processor column `j`.
    pub fn col_range(&self, j: u32) -> (VertexId, VertexId) {
        (self.col_cuts[j as usize], self.col_cuts[j as usize + 1])
    }

    /// Rank of the unique processor owning edge `(u → w)`: row range of
    /// `u` crossed with column range of `w`.
    #[inline]
    pub fn owner_of_edge(&self, u: VertexId, w: VertexId) -> u32 {
        self.rank(self.row_of(u), self.col_of(w))
    }

    /// Materialize processor `(i, j)`'s adjacency block as a [`CsrSlab`]:
    /// rows are `row_range(i)`, adjacency filtered to `col_range(j)`
    /// (neighbor lists are sorted, so the filter is a range slice).
    pub fn block_slab(&self, g: &Csr, i: u32, j: u32) -> CsrSlab {
        let (rlo, rhi) = self.row_range(i);
        let (clo, chi) = self.col_range(j);
        let mut offsets = Vec::with_capacity((rhi - rlo) as usize + 1);
        let mut edges = Vec::new();
        offsets.push(0u64);
        for u in rlo..rhi {
            let ns = g.neighbors(u);
            let s = ns.partition_point(|&w| w < clo);
            let e = ns.partition_point(|&w| w < chi);
            edges.extend_from_slice(&ns[s..e]);
            offsets.push(edges.len() as u64);
        }
        CsrSlab { first_vertex: rlo, offsets, edges }
    }

    /// All block slabs in rank order — the 2D analog of
    /// [`Partition1D::slabs`](crate::partition::one_d::Partition1D::slabs).
    /// Across the grid every edge of `g` appears in exactly one slab.
    pub fn block_slabs(&self, g: &Csr) -> Vec<CsrSlab> {
        (0..self.processors())
            .map(|r| {
                let (i, j) = self.coords(r);
                self.block_slab(g, i, j)
            })
            .collect()
    }

    /// Edges owned by each processor block, in rank order.
    pub fn block_edges(&self, g: &Csr) -> Vec<u64> {
        self.block_slabs(g).iter().map(|s| s.num_edges()).collect()
    }

    /// In-edges targeting each processor column's vertex range, in column
    /// order — the quantity the column cuts balance.
    pub fn col_in_edges(&self, g: &Csr) -> Vec<u64> {
        let mut per = vec![0u64; self.grid_cols as usize];
        for &w in g.edges() {
            per[self.col_of(w) as usize] += 1;
        }
        per
    }

    /// Column in-edge balance ratio: max column in-edges / mean (1.0 =
    /// perfect). The edge-balanced cuts keep this near 1 on skewed graphs
    /// where vertex-balanced cuts would load one processor column with
    /// every hub.
    pub fn col_imbalance(&self, g: &Csr) -> f64 {
        let per = self.col_in_edges(g);
        let max = *per.iter().max().unwrap_or(&0) as f64;
        let mean = per.iter().sum::<u64>() as f64 / per.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Edge-balance ratio: max block edges / mean block edges (1.0 =
    /// perfect; the column filter makes blocks less balanced than the 1D
    /// row cuts alone).
    pub fn imbalance(&self, g: &Csr) -> f64 {
        let per = self.block_edges(g);
        let max = *per.iter().max().unwrap_or(&0) as f64;
        let mean = per.iter().sum::<u64>() as f64 / per.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Per-level message count of the fold/expand exchange: every
    /// processor sends to its `cols − 1` row peers (fold) and its
    /// `rows − 1` column peers (expand) — `2·(√P − 1)·P` for a square
    /// grid (Yoo et al.), versus `P·(P − 1)` for the 1D all-to-all.
    pub fn messages_per_level(&self) -> u64 {
        let p = self.processors() as u64;
        let row_msgs = (self.grid_cols as u64 - 1) * p;
        let col_msgs = (self.grid_rows as u64 - 1) * p;
        row_msgs + col_msgs
    }

    /// The analytical message-volume model for a `levels`-deep traversal:
    /// the fold/expand schedule runs once per level, so the total is
    /// `levels · messages_per_level()`. The equivalence suite asserts the
    /// engine's *measured* 2D message count equals this model exactly.
    pub fn message_volume(&self, levels: u64) -> u64 {
        levels * self.messages_per_level()
    }

    /// The 1D all-to-all comparator: `P·(P−1)` messages per level.
    pub fn messages_per_level_1d_alltoall(&self) -> u64 {
        let p = self.processors() as u64;
        p * (p - 1)
    }

    /// The most-square factorization `rows × cols = p` with `rows <=
    /// cols` — the default grid for `--mode 2d --grid auto` (primes
    /// degenerate to `1 × p`, i.e. a single fold round).
    pub fn near_square_grid(p: u32) -> (u32, u32) {
        assert!(p >= 1);
        let mut rows = (p as f64).sqrt() as u32;
        while rows > 1 && p % rows != 0 {
            rows -= 1;
        }
        (rows.max(1), p / rows.max(1))
    }
}

/// Contiguous cuts over `weights` into `parts` non-empty ranges with
/// near-equal weight per range: builds the prefix-weight array and
/// delegates to the shared greedy
/// ([`balanced_cuts_from_prefix`](crate::partition::one_d::balanced_cuts_from_prefix)
/// — the exact policy the 1D row cuts use, so the two axes follow one
/// implementation).
fn weight_balanced_cuts(weights: &[u64], parts: usize) -> Vec<VertexId> {
    let mut prefix = Vec::with_capacity(weights.len() + 1);
    prefix.push(0u64);
    for &w in weights {
        prefix.push(prefix.last().unwrap() + w);
    }
    crate::partition::one_d::balanced_cuts_from_prefix(&prefix, parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn square_grid_reduces_messages_sqrt_p() {
        let (g, _) = uniform_random(1000, 4, 1);
        // P = 16 as a 4x4 grid: 2·(4−1)·16 = 96 messages vs 240 all-to-all.
        let p2 = Partition2D::new(&g, 4, 4);
        assert_eq!(p2.processors(), 16);
        assert_eq!(p2.messages_per_level(), 96);
        assert_eq!(p2.messages_per_level_1d_alltoall(), 240);
        assert!(p2.messages_per_level() < p2.messages_per_level_1d_alltoall());
        assert_eq!(p2.message_volume(7), 7 * 96);
    }

    #[test]
    fn degenerate_1xp_grid_is_1d() {
        let (g, _) = uniform_random(100, 4, 2);
        let p2 = Partition2D::new(&g, 1, 8);
        // 1×P grid: row exchange = (P−1)·P = the all-to-all count.
        assert_eq!(p2.messages_per_level(), 7 * 8);
    }

    #[test]
    fn edge_owner_consistent_with_ranges() {
        let (g, _) = uniform_random(160, 4, 3);
        let p2 = Partition2D::new(&g, 4, 4);
        for u in (0..160).step_by(13) {
            for w in (0..160).step_by(17) {
                let r = p2.owner_of_edge(u as VertexId, w as VertexId);
                let (i, j) = p2.coords(r);
                assert_eq!(p2.rank(i, j), r);
                let (rlo, rhi) = p2.row_range(i);
                let (clo, chi) = p2.col_range(j);
                assert!(rlo <= u && u < rhi);
                assert!(clo <= w && w < chi);
            }
        }
    }

    #[test]
    fn ranges_cover_all_vertices() {
        let (g, _) = uniform_random(97, 4, 4); // prime count: uneven cuts
        let p2 = Partition2D::new(&g, 3, 3);
        for cuts in [&p2.row_cuts, &p2.col_cuts] {
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), 97);
            assert!(cuts.windows(2).all(|w| w[0] < w[1]), "{cuts:?}");
        }
        for v in 0..97u32 {
            let i = p2.row_of(v) as usize;
            assert!(v >= p2.row_cuts[i] && v < p2.row_cuts[i + 1]);
            let j = p2.col_of(v) as usize;
            assert!(v >= p2.col_cuts[j] && v < p2.col_cuts[j + 1]);
        }
    }

    #[test]
    fn block_slabs_partition_every_edge() {
        let (g, _) = uniform_random(300, 6, 9);
        let p2 = Partition2D::new(&g, 3, 5);
        let slabs = p2.block_slabs(&g);
        let total: u64 = slabs.iter().map(|s| s.num_edges()).sum();
        assert_eq!(total, g.num_edges(), "blocks partition the edge set");
        // Per-row union over the processor row reconstructs the full
        // adjacency (sorted neighbor lists concatenate across columns).
        for i in 0..3u32 {
            let (rlo, rhi) = p2.row_range(i);
            for u in rlo..rhi {
                let mut merged = Vec::new();
                for j in 0..5u32 {
                    merged.extend_from_slice(
                        slabs[p2.rank(i, j) as usize].neighbors_global(u),
                    );
                }
                assert_eq!(merged, g.neighbors(u), "row {u}");
            }
        }
    }

    #[test]
    fn col_cuts_adapt_to_in_edge_skew() {
        use crate::graph::gen::structured::star;
        // A 64-leaf star: symmetrized, vertex 0 carries half of all arcs.
        // Vertex-balanced cuts would give column 0 the hub *plus* 31
        // leaves (~75% of in-edges); edge-balanced cuts end column 0
        // right after the hub.
        let g = star(64);
        let p2 = Partition2D::new(&g, 1, 2);
        assert_eq!(p2.col_cuts, vec![0, 1, 64], "hub isolated in column 0");
        let per = p2.col_in_edges(&g);
        assert_eq!(per.iter().sum::<u64>(), g.num_edges());
        assert!(p2.col_imbalance(&g) < 1.1, "imbalance {}", p2.col_imbalance(&g));
    }

    #[test]
    fn col_cuts_edge_balanced_on_skewed_kronecker() {
        use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
        let (g, _) = kronecker(KroneckerParams::graph500(12, 16), 9);
        let p2 = Partition2D::new(&g, 2, 8);
        // Same bound the 1D row cuts promise on the same family: greedy
        // prefix stays within 2x of the mean unless one hub dominates.
        assert!(p2.col_imbalance(&g) < 2.0, "imbalance {}", p2.col_imbalance(&g));
        // A vertex-balanced split of the same graph is measurably worse
        // (this is the regression the edge-balanced cuts fix).
        let n = g.num_vertices();
        let vertex_cuts: Vec<VertexId> =
            (0..=8usize).map(|j| (n * j / 8) as VertexId).collect();
        let mut per = vec![0u64; 8];
        for &w in g.edges() {
            let j = vertex_cuts.partition_point(|&c| c <= w) - 1;
            per[j] += 1;
        }
        let vmax = *per.iter().max().unwrap() as f64;
        let vmean = per.iter().sum::<u64>() as f64 / 8.0;
        assert!(
            p2.col_imbalance(&g) < vmax / vmean,
            "edge-balanced {} vs vertex-balanced {}",
            p2.col_imbalance(&g),
            vmax / vmean
        );
    }

    #[test]
    fn weight_balanced_cuts_degenerate_inputs() {
        // All-zero weights: unit ranges from the front (same shape the 1D
        // greedy produces on an empty graph).
        assert_eq!(weight_balanced_cuts(&[0, 0, 0, 0], 3), vec![0, 1, 2, 4]);
        // Single part spans everything; parts == n isolates every vertex.
        assert_eq!(weight_balanced_cuts(&[5, 1, 3], 1), vec![0, 3]);
        assert_eq!(weight_balanced_cuts(&[5, 1, 3], 3), vec![0, 1, 2, 3]);
        // One dominant weight: it gets its own range as soon as possible.
        assert_eq!(weight_balanced_cuts(&[100, 1, 1, 1, 1], 2), vec![0, 1, 5]);
    }

    #[test]
    fn near_square_grids() {
        assert_eq!(Partition2D::near_square_grid(16), (4, 4));
        assert_eq!(Partition2D::near_square_grid(64), (8, 8));
        assert_eq!(Partition2D::near_square_grid(12), (3, 4));
        assert_eq!(Partition2D::near_square_grid(7), (1, 7));
        assert_eq!(Partition2D::near_square_grid(1), (1, 1));
    }

    #[test]
    fn butterfly_still_beats_2d_on_messages_at_dgx2_scale() {
        // The paper's implicit claim: at P = 16, butterfly fanout-1 (64
        // messages over 4 rounds) undercuts even the 2D scheme's 96.
        use crate::comm::{Butterfly, CommPattern};
        let (g, _) = uniform_random(1000, 4, 5);
        let p2 = Partition2D::new(&g, 4, 4);
        let bf = Butterfly::new(1).schedule(16).total_messages();
        assert!(bf < p2.messages_per_level(), "{bf} vs {}", p2.messages_per_level());
    }
}
