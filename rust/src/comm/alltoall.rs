//! All-to-all baselines (§3's two "widely used and naive approaches").
//!
//! * [`ConcurrentAllToAll`] — one bulk round: every node sends to every
//!   other node simultaneously. Lowest depth, `CN·(CN−1)` messages, worst
//!   congestion, and an unbounded receive buffer (`O(CN·V)`).
//! * [`IterativeAllToAll`] — `CN−1` ring-shifted rounds: in round `k` node
//!   `g` sends to `(g+k+1) mod CN`. Same message count, `O(V)` buffer,
//!   `CN−1` rounds of latency.
//!
//! These are the comparators for the message/volume/time benches, and
//! [`ConcurrentAllToAll`] doubles as the Gunrock/Groute-style baseline when
//! priced with dynamic-allocation overhead in `net::sim`.

use super::pattern::{CommPattern, Schedule, Transfer};

/// Single-round bulk all-to-all.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConcurrentAllToAll;

impl CommPattern for ConcurrentAllToAll {
    fn name(&self) -> &'static str {
        "alltoall-concurrent"
    }

    fn schedule(&self, cn: u32) -> Schedule {
        let mut round = Vec::with_capacity((cn as usize) * (cn as usize - 1));
        for src in 0..cn {
            for dst in 0..cn {
                if src != dst {
                    round.push(Transfer { src, dst });
                }
            }
        }
        let rounds = if round.is_empty() { vec![] } else { vec![round] };
        Schedule { num_nodes: cn, rounds }
    }
}

/// `CN−1` ring-shifted pairwise rounds.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterativeAllToAll;

impl CommPattern for IterativeAllToAll {
    fn name(&self) -> &'static str {
        "alltoall-iterative"
    }

    fn schedule(&self, cn: u32) -> Schedule {
        let mut rounds = Vec::new();
        for k in 1..cn {
            let round = (0..cn)
                .map(|g| Transfer { src: g, dst: (g + k) % cn })
                .collect();
            rounds.push(round);
        }
        Schedule { num_nodes: cn, rounds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::analysis::verify_full_coverage;

    #[test]
    fn concurrent_counts() {
        let s = ConcurrentAllToAll.schedule(16);
        // Paper: all-to-all requires CN^2 messages (CN·(CN−1) exactly).
        assert_eq!(s.total_messages(), 16 * 15);
        assert_eq!(s.depth(), 1);
        assert_eq!(s.max_recvs_per_round(), 15);
        s.validate().unwrap();
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn iterative_counts() {
        let s = IterativeAllToAll.schedule(16);
        assert_eq!(s.total_messages(), 16 * 15);
        assert_eq!(s.depth(), 15);
        // One send and one receive per node per round: O(V) buffers.
        assert_eq!(s.max_recvs_per_round(), 1);
        assert_eq!(s.max_sends_per_round(), 1);
        s.validate().unwrap();
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn single_node_degenerate() {
        assert_eq!(ConcurrentAllToAll.schedule(1).total_messages(), 0);
        assert_eq!(IterativeAllToAll.schedule(1).total_messages(), 0);
    }

    #[test]
    fn coverage_property() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(40), "all-to-all covers", |rng| {
            let cn = gen::usize_in(rng, 1, 40) as u32;
            let ok = verify_full_coverage(&ConcurrentAllToAll.schedule(cn)).is_ok()
                && verify_full_coverage(&IterativeAllToAll.schedule(cn)).is_ok();
            (ok, format!("cn={cn}"))
        });
    }
}
