//! Executable versions of the paper's §3 complexity analysis: knowledge
//! propagation, coverage verification, message/volume/buffer accounting.
//!
//! `verify_full_coverage` is the correctness invariant of every pattern:
//! running the schedule with allgather semantics must leave every node
//! knowing every node's frontier. `CommCosts` turns a schedule into the
//! closed-form quantities the paper trades off (messages, rounds, buffer
//! bound, data volume), which `net::sim` prices into time.

use super::pattern::Schedule;
use crate::net::model::TopologyModel;

/// Simulate knowledge propagation: `knowledge[g]` is the set of nodes
/// whose frontier `g` holds (as a bitset; supports up to 128 nodes which
/// covers every experiment — the DGX-2 has 16).
pub fn propagate_knowledge(s: &Schedule) -> Vec<u128> {
    assert!(s.num_nodes <= 128, "knowledge bitset supports <= 128 nodes");
    let mut know: Vec<u128> = (0..s.num_nodes).map(|g| 1u128 << g).collect();
    for round in &s.rounds {
        // Transfers within a round are concurrent: merge from a snapshot.
        let snap = know.clone();
        for t in round {
            know[t.dst as usize] |= snap[t.src as usize];
        }
    }
    know
}

/// Verify that after the schedule every node knows every node's frontier.
pub fn verify_full_coverage(s: &Schedule) -> Result<(), String> {
    let want: u128 = if s.num_nodes == 128 {
        u128::MAX
    } else {
        (1u128 << s.num_nodes) - 1
    };
    for (g, k) in propagate_knowledge(s).iter().enumerate() {
        if *k != want {
            return Err(format!(
                "node {g} knows {:#b}, wants {:#b} ({} of {} nodes)",
                k,
                want,
                k.count_ones(),
                s.num_nodes
            ));
        }
    }
    Ok(())
}

/// Closed-form-style cost accounting for a schedule, assuming each
/// transfer ships the sender's accumulated knowledge as a fixed-size
/// bitmap payload of `payload_bytes_per_frontier` (the paper's bounded
/// O(V)-per-message regime).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommCosts {
    /// Total messages across all rounds.
    pub messages: u64,
    /// Rounds of synchronization (network depth).
    pub rounds: u64,
    /// Total bytes shipped.
    pub volume_bytes: u64,
    /// Receive-buffer bound: max messages into one node in one round ×
    /// payload — the paper's `O(f·V)` (contribution 4).
    pub buffer_bytes: u64,
    /// Max messages sent by one node in one round (Fig 1(f) hotspot).
    pub max_fanout: u64,
}

/// Compute [`CommCosts`] for a schedule with a fixed per-message payload.
pub fn comm_costs(s: &Schedule, payload_bytes: u64) -> CommCosts {
    CommCosts {
        messages: s.total_messages(),
        rounds: s.depth() as u64,
        volume_bytes: s.total_messages() * payload_bytes,
        buffer_bytes: s.max_recvs_per_round() * payload_bytes,
        max_fanout: s.max_sends_per_round(),
    }
}

/// Measured-vs-modeled message volume of one engine run — the executable
/// check that the analytical per-level models (butterfly schedule counts,
/// [`Partition2D::message_volume`](crate::partition::Partition2D::message_volume))
/// describe what the engine *actually* shipped. Built by
/// `benches/mode_comparison.rs` and the 2D equivalence suite from run
/// metrics plus the mode's closed-form model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModeVolume {
    /// Mode label (e.g. `"1d butterfly-f4"`, `"2d-8x8 fold-expand"`).
    pub mode: String,
    /// Levels the traversal ran (schedule executions).
    pub levels: u64,
    /// Messages the analytical model predicts for `levels` executions.
    pub modeled_messages: u64,
    /// Messages the engine measured.
    pub measured_messages: u64,
    /// Bytes the engine measured (no closed form — payloads are
    /// frontier-dependent; this is the "measured, not just modeled" half).
    pub measured_bytes: u64,
}

impl ModeVolume {
    /// True when the measured message count equals the model exactly.
    pub fn model_matches(&self) -> bool {
        self.modeled_messages == self.measured_messages
    }

    /// One-line report for bench tables.
    pub fn render(&self) -> String {
        format!(
            "{}: {} levels, messages {} (model {}, {}), bytes {}",
            self.mode,
            self.levels,
            self.measured_messages,
            self.modeled_messages,
            if self.model_matches() { "match" } else { "MISMATCH" },
            self.measured_bytes
        )
    }
}

/// Link-class split of a schedule's message count under a topology model
/// — the *modeled* side of the per-class accounting the engine measures
/// into its level metrics (`intra_messages` / `inter_messages`). Because
/// schedules are static, this is exact per schedule execution: a
/// traversal of `L` levels measures `L ×` these counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassVolume {
    /// Messages whose endpoints share an island.
    pub intra_messages: u64,
    /// Messages crossing an island boundary (the shared-uplink class).
    pub inter_messages: u64,
}

impl ClassVolume {
    /// Total messages (both classes).
    pub fn total(&self) -> u64 {
        self.intra_messages + self.inter_messages
    }
}

/// Classify every transfer of `s` by island under `topo` — the
/// closed-form companion of
/// [`simulate_topology`](crate::net::simulate_topology)'s measured
/// counters. A hierarchical schedule's whole point is driving
/// `inter_messages` down to the representative exchange; compare a flat
/// butterfly's split against [`GridOfIslands`](super::GridOfIslands)'s at
/// the same node count to see the reduction.
pub fn class_volume(s: &Schedule, topo: &TopologyModel) -> ClassVolume {
    let mut v = ClassVolume::default();
    for round in &s.rounds {
        for t in round {
            if topo.is_intra(t.src, t.dst) {
                v.intra_messages += 1;
            } else {
                v.inter_messages += 1;
            }
        }
    }
    v
}

/// The paper's approximate message-count formula `CN · f · log_f(CN)`
/// (§3). Exposed so benches can print "paper formula" next to measured.
pub fn paper_message_formula(cn: u32, fanout: u32) -> f64 {
    if cn <= 1 {
        return 0.0;
    }
    let f = fanout.max(2) as f64; // log_1 undefined; paper uses log2 for f=1
    cn as f64 * fanout as f64 * (cn as f64).log(f).ceil()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::alltoall::{ConcurrentAllToAll, IterativeAllToAll};
    use crate::comm::butterfly::Butterfly;
    use crate::comm::pattern::CommPattern;

    #[test]
    fn butterfly_buffer_bound_matches_paper() {
        // Contribution 4: buffer is O(f·V) — for radix r the bound is
        // (r−1) messages × O(V) payload, independent of CN.
        let payload = 1_000_000; // pretend V/8 = 1 MB
        for cn in [16u32, 32, 64] {
            let c1 = comm_costs(&Butterfly::new(1).schedule(cn), payload);
            assert_eq!(c1.buffer_bytes, payload, "f=1 cn={cn}"); // 1 msg/round
            let c4 = comm_costs(&Butterfly::new(4).schedule(cn), payload);
            assert_eq!(c4.buffer_bytes, 3 * payload, "f=4 cn={cn}");
        }
        // All-to-all concurrent has NO CN-independent bound:
        let ca = comm_costs(&ConcurrentAllToAll.schedule(64), payload);
        assert_eq!(ca.buffer_bytes, 63 * payload);
    }

    #[test]
    fn butterfly_beats_alltoall_on_messages() {
        // §3: butterfly reduces messages vs all-to-all for CN >= 8.
        for cn in [8u32, 16, 32, 64] {
            let bf = Butterfly::new(1).schedule(cn).total_messages();
            let a2a = ConcurrentAllToAll.schedule(cn).total_messages();
            assert!(bf < a2a, "cn={cn}: {bf} vs {a2a}");
        }
    }

    #[test]
    fn fanout_tradeoff_rounds_vs_messages() {
        // §3: higher fanout => fewer rounds, more messages (16 nodes).
        let f1 = Butterfly::new(1).schedule(16);
        let f4 = Butterfly::new(4).schedule(16);
        assert!(f4.depth() < f1.depth());
        assert!(f4.total_messages() > f1.total_messages());
    }

    #[test]
    fn paper_formula_examples() {
        // §3: fanout 1, 16 CN -> 64; fanout 4, 16 CN -> 128.
        assert_eq!(paper_message_formula(16, 1) as u64, 64);
        assert_eq!(paper_message_formula(16, 4) as u64, 128);
    }

    #[test]
    fn paper_formula_upper_bounds_measured() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(60), "formula >= measured", |rng| {
            let cn = gen::usize_in(rng, 2, 64) as u32;
            let f = gen::usize_in(rng, 1, 8) as u32;
            let measured = Butterfly::new(f).schedule(cn).total_messages() as f64;
            // The paper's formula assumes f sends per round; actual radix
            // exchange sends r−1 ≤ f, plus padded-virtual extras which stay
            // within one extra round's worth.
            let bound = paper_message_formula(cn, f)
                + (cn as f64) * (f.max(2) as f64); // slack for padding round
            (measured <= bound, format!("cn={cn} f={f} measured={measured} bound={bound}"))
        });
    }

    #[test]
    fn knowledge_monotone_nondecreasing() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(40), "knowledge only grows", |rng| {
            let cn = gen::usize_in(rng, 2, 48) as u32;
            let f = gen::usize_in(rng, 1, 6) as u32;
            let s = Butterfly::new(f).schedule(cn);
            let mut know: Vec<u128> = (0..cn).map(|g| 1u128 << g).collect();
            let mut ok = true;
            for round in &s.rounds {
                let snap = know.clone();
                for t in round {
                    know[t.dst as usize] |= snap[t.src as usize];
                }
                for g in 0..cn as usize {
                    ok &= (snap[g] & !know[g]) == 0;
                }
            }
            (ok, format!("cn={cn} f={f}"))
        });
    }

    #[test]
    fn mode_volume_match_and_render() {
        let v = ModeVolume {
            mode: "2d-4x4 fold-expand".to_string(),
            levels: 7,
            modeled_messages: 7 * 96,
            measured_messages: 7 * 96,
            measured_bytes: 1234,
        };
        assert!(v.model_matches());
        assert!(v.render().contains("match"));
        let bad = ModeVolume { measured_messages: 5, ..v };
        assert!(!bad.model_matches());
        assert!(bad.render().contains("MISMATCH"));
    }

    #[test]
    fn class_volume_splits_and_hierarchical_reduces_inter() {
        use crate::comm::hierarchical::GridOfIslands;
        use crate::net::model::TopologyModel;
        let topo = TopologyModel::dgx2_cluster(8);
        // Under a uniform topology everything is intra.
        let flat = Butterfly::new(4).schedule(64);
        let uni = class_volume(&flat, &TopologyModel::uniform(crate::net::NetModel::dgx2()));
        assert_eq!(uni.inter_messages, 0);
        assert_eq!(uni.total(), flat.total_messages());
        // Same schedule under the 8-rank-island cluster crosses islands
        // heavily; the grid-of-islands composition confines crossings to
        // the representative exchange.
        let flat_split = class_volume(&flat, &topo);
        let hier = GridOfIslands::new(8, 8, 4).schedule(64);
        let hier_split = class_volume(&hier, &topo);
        assert_eq!(flat_split.total(), flat.total_messages());
        assert_eq!(hier_split.total(), hier.total_messages());
        assert!(hier_split.inter_messages > 0);
        assert!(
            hier_split.inter_messages * 4 < flat_split.inter_messages,
            "hier {} vs flat {} inter messages",
            hier_split.inter_messages,
            flat_split.inter_messages
        );
    }

    #[test]
    fn iterative_alltoall_costs() {
        let c = comm_costs(&IterativeAllToAll.schedule(9), 100);
        assert_eq!(c.messages, 72);
        assert_eq!(c.rounds, 8);
        assert_eq!(c.buffer_bytes, 100);
        assert_eq!(c.max_fanout, 1);
    }
}
