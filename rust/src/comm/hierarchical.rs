//! Hierarchical grid-of-islands synchronization: butterfly *within* each
//! island, a representative exchange *across* islands.
//!
//! Real multi-node clusters are not the flat NVSwitch fabric the paper's
//! butterfly assumes: they are islands of fast links (NVLink inside a
//! DGX-2) stitched together by a much slower inter-node network — the
//! regime of Pan/Pearce/Owens' GPU-cluster BFS and Bisson et al.'s
//! Kepler-cluster BFS (PAPERS.md). A flat schedule ships most of its
//! accumulated-frontier payloads straight across that slow boundary; the
//! hierarchical schedule makes locality structural instead:
//!
//! 1. **Aggregate (intra)** — a butterfly over each island's
//!    `per_island` members. After `ceil(log_r per_island)` rounds every
//!    member holds its whole island's frontier knowledge. All transfers
//!    stay on fast intra-island links.
//! 2. **Exchange (inter)** — each island's *representative* (its lowest
//!    rank) runs a butterfly over the `islands` axis. Only
//!    representatives touch the slow boundary, and they cross it with
//!    island-aggregated payloads: `islands·(r−1)·ceil(log_r islands)`
//!    inter-island messages total, instead of the flat all-to-all's
//!    `p·(p−1)` or the flat butterfly's mostly-inter high-stride rounds.
//! 3. **Broadcast (intra)** — one final round in which each
//!    representative ships the now-global knowledge to its
//!    `per_island − 1` island peers over fast links.
//!
//! The result is emitted as a perfectly ordinary [`Schedule`], so
//! [`validate`](Schedule::validate),
//! [`verify_full_coverage`](crate::comm::analysis::verify_full_coverage),
//! and both engine phases work unchanged; only
//! [`net::TopologyModel`](crate::net::TopologyModel) prices the two link
//! classes differently.
//!
//! Degenerate grids collapse to the flat pattern: `islands = 1` is a
//! plain butterfly over `per_island` nodes (phases 2–3 vanish), and
//! `per_island = 1` makes every node its own representative (phase 1 and
//! 3 vanish — a plain butterfly over `islands` nodes).

use super::butterfly::Butterfly;
use super::pattern::{CommPattern, Schedule, Transfer};

/// The hierarchical grid-of-islands pattern: `islands × per_island`
/// compute nodes in island-major rank order (`rank = island · per_island
/// + local`), synchronized by butterfly-within-island, representative
/// butterfly across islands, and a representative broadcast round.
///
/// The `fanout` is the paper's butterfly fanout, applied to *both*
/// butterflies (`1` ⇒ radix 2). Non-power-of-radix axes use the paper's
/// virtual-node padding within each axis, so any `islands × per_island`
/// shape is valid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridOfIslands {
    /// Number of islands (the slow axis).
    pub islands: u32,
    /// Compute nodes per island (the fast axis).
    pub per_island: u32,
    /// Butterfly fanout used on both axes (`1` ⇒ classic radix 2).
    pub fanout: u32,
}

impl GridOfIslands {
    /// Create a grid-of-islands pattern. Both axes must be ≥ 1.
    pub fn new(islands: u32, per_island: u32, fanout: u32) -> Self {
        assert!(islands >= 1, "need at least one island");
        assert!(per_island >= 1, "need at least one node per island");
        assert!(fanout >= 1, "fanout must be >= 1");
        Self { islands, per_island, fanout }
    }

    /// Total compute nodes covered: `islands · per_island`.
    pub fn num_nodes(&self) -> u32 {
        self.islands * self.per_island
    }

    /// Island index of a rank (island-major layout).
    #[inline]
    pub fn island_of(&self, rank: u32) -> u32 {
        rank / self.per_island
    }

    /// Representative rank of an island: its lowest member.
    #[inline]
    pub fn representative(&self, island: u32) -> u32 {
        island * self.per_island
    }

    /// Whether a transfer crosses the slow island boundary.
    #[inline]
    pub fn is_inter(&self, t: &Transfer) -> bool {
        self.island_of(t.src) != self.island_of(t.dst)
    }

    /// Rounds of the intra-island aggregation butterfly:
    /// `ceil(log_r per_island)`.
    pub fn intra_rounds(&self) -> usize {
        Butterfly::new(self.fanout).depth_for(self.per_island) as usize
    }

    /// Rounds of the cross-island representative butterfly:
    /// `ceil(log_r islands)`.
    pub fn inter_rounds(&self) -> usize {
        Butterfly::new(self.fanout).depth_for(self.islands) as usize
    }

    /// Broadcast rounds: 1 when both axes are non-degenerate (the
    /// representatives learned something their peers have not), else 0.
    pub fn broadcast_rounds(&self) -> usize {
        usize::from(self.islands > 1 && self.per_island > 1)
    }

    /// Total schedule depth.
    pub fn depth(&self) -> usize {
        self.intra_rounds() + self.inter_rounds() + self.broadcast_rounds()
    }
}

impl CommPattern for GridOfIslands {
    fn name(&self) -> &'static str {
        "grid-of-islands"
    }

    fn schedule(&self, cn: u32) -> Schedule {
        assert_eq!(
            cn,
            self.num_nodes(),
            "grid {}x{} does not cover {cn} nodes",
            self.islands,
            self.per_island
        );
        let bf = Butterfly::new(self.fanout);
        let mut rounds: Vec<Vec<Transfer>> = Vec::with_capacity(self.depth());

        // Phase 1 — aggregate: the same island-local butterfly round runs
        // in every island concurrently, offset by the island's rank base.
        let intra = bf.schedule(self.per_island);
        for local_round in &intra.rounds {
            let mut round = Vec::with_capacity(local_round.len() * self.islands as usize);
            for island in 0..self.islands {
                let base = self.representative(island);
                for t in local_round {
                    round.push(Transfer { src: base + t.src, dst: base + t.dst });
                }
            }
            round.sort_by_key(|t| (t.src, t.dst));
            rounds.push(round);
        }

        // Phase 2 — exchange: a butterfly over the island axis, executed
        // by the representatives (virtual-island blocks are held by the
        // last island's representative, mirroring the flat padding rule).
        let inter = bf.schedule(self.islands);
        for island_round in &inter.rounds {
            let mut round: Vec<Transfer> = island_round
                .iter()
                .map(|t| Transfer {
                    src: self.representative(t.src),
                    dst: self.representative(t.dst),
                })
                .collect();
            round.sort_by_key(|t| (t.src, t.dst));
            rounds.push(round);
        }

        // Phase 3 — broadcast: each representative ships the global
        // knowledge to its island peers.
        if self.broadcast_rounds() == 1 {
            let mut round = Vec::with_capacity(cn as usize - self.islands as usize);
            for island in 0..self.islands {
                let rep = self.representative(island);
                for local in 1..self.per_island {
                    round.push(Transfer { src: rep, dst: rep + local });
                }
            }
            rounds.push(round);
        }

        Schedule { num_nodes: cn, rounds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::analysis::verify_full_coverage;

    #[test]
    fn covers_and_validates_all_small_grids() {
        for islands in 1..=8u32 {
            for per_island in 1..=8u32 {
                for fanout in [1u32, 2, 4] {
                    let g = GridOfIslands::new(islands, per_island, fanout);
                    let s = g.schedule(g.num_nodes());
                    s.validate().unwrap_or_else(|e| {
                        panic!("{islands}x{per_island} f={fanout}: {e}")
                    });
                    verify_full_coverage(&s).unwrap_or_else(|e| {
                        panic!("{islands}x{per_island} f={fanout}: {e}")
                    });
                    assert_eq!(s.depth(), g.depth(), "{islands}x{per_island} f={fanout}");
                }
            }
        }
    }

    #[test]
    fn property_random_grids_cover() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(60), "grid-of-islands covers all nodes", |rng| {
            let islands = gen::usize_in(rng, 1, 8) as u32;
            let per_island = gen::usize_in(rng, 1, 8) as u32;
            let fanout = gen::usize_in(rng, 1, 6) as u32;
            let g = GridOfIslands::new(islands, per_island, fanout);
            let s = g.schedule(g.num_nodes());
            let ok = s.validate().is_ok() && verify_full_coverage(&s).is_ok();
            (ok, format!("islands={islands} per_island={per_island} fanout={fanout}"))
        });
    }

    #[test]
    fn degenerate_grids_are_flat_butterflies() {
        // 1 island: the intra butterfly alone, identical to the flat one.
        let one_island = GridOfIslands::new(1, 9, 1).schedule(9);
        assert_eq!(one_island, Butterfly::new(1).schedule(9));
        // 1 node per island: every node is its own representative.
        let singletons = GridOfIslands::new(9, 1, 1).schedule(9);
        assert_eq!(singletons, Butterfly::new(1).schedule(9));
    }

    #[test]
    fn phase_structure_4x4_fanout1() {
        let g = GridOfIslands::new(4, 4, 1);
        let s = g.schedule(16);
        // radix 2: 2 intra rounds + 2 inter rounds + 1 broadcast.
        assert_eq!(g.intra_rounds(), 2);
        assert_eq!(g.inter_rounds(), 2);
        assert_eq!(g.broadcast_rounds(), 1);
        assert_eq!(s.depth(), 5);
        // Intra rounds: 4 islands × (4 nodes × 1 partner) = 16 transfers,
        // all within islands. Inter rounds: 4 reps × 1 partner = 4
        // transfers, all across. Broadcast: 4 reps × 3 peers = 12.
        let inter_per_round: Vec<u64> = s
            .rounds
            .iter()
            .map(|r| r.iter().filter(|t| g.is_inter(t)).count() as u64)
            .collect();
        assert_eq!(inter_per_round, vec![0, 0, 4, 4, 0]);
        assert_eq!(s.total_messages(), 16 + 16 + 4 + 4 + 12);
        // The slow boundary carries 8 messages; the flat radix-2
        // butterfly over 16 nodes ships 64 total, 32 of them inter
        // (strides 4 and 8 always leave a 4-node island).
        let flat = Butterfly::new(1).schedule(16);
        let flat_inter: u64 = flat
            .rounds
            .iter()
            .flatten()
            .filter(|t| g.island_of(t.src) != g.island_of(t.dst))
            .count() as u64;
        assert_eq!(flat_inter, 32);
    }

    #[test]
    fn inter_messages_only_representatives() {
        let g = GridOfIslands::new(8, 8, 4);
        let s = g.schedule(64);
        for round in &s.rounds {
            for t in round {
                if g.is_inter(t) {
                    assert_eq!(t.src % 8, 0, "inter sender must be a representative");
                    assert_eq!(t.dst % 8, 0, "inter receiver must be a representative");
                }
            }
        }
        // 8 islands under radix 4 need 2 exchange rounds.
        assert_eq!(g.depth(), 2 + 2 + 1);
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn message_count_formula_power_of_radix() {
        // Exact per-phase counts when both axes are powers of the radix:
        // islands·per_island·(r−1)·log_r(per_island) intra-butterfly +
        // islands·(r−1)·log_r(islands) inter + islands·(per_island−1).
        let g = GridOfIslands::new(4, 16, 4);
        let s = g.schedule(64);
        let intra_bf = 4 * 16 * 3 * 2; // 4 islands, 2 rounds of 16×3
        let inter_bf = 4 * 3; // 1 round of 4×3
        let broadcast = 4 * 15;
        assert_eq!(s.total_messages() as u64, (intra_bf + inter_bf + broadcast) as u64);
        let inter: u64 =
            s.rounds.iter().flatten().filter(|t| g.is_inter(t)).count() as u64;
        assert_eq!(inter, inter_bf as u64);
    }

    #[test]
    fn island_major_layout_helpers() {
        let g = GridOfIslands::new(3, 5, 1);
        assert_eq!(g.num_nodes(), 15);
        assert_eq!(g.island_of(0), 0);
        assert_eq!(g.island_of(4), 0);
        assert_eq!(g.island_of(5), 1);
        assert_eq!(g.island_of(14), 2);
        assert_eq!(g.representative(0), 0);
        assert_eq!(g.representative(2), 10);
        assert!(g.is_inter(&Transfer { src: 4, dst: 5 }));
        assert!(!g.is_inter(&Transfer { src: 0, dst: 4 }));
    }

    #[test]
    fn single_node_needs_no_rounds() {
        let s = GridOfIslands::new(1, 1, 1).schedule(1);
        assert_eq!(s.depth(), 0);
        assert_eq!(s.total_messages(), 0);
    }

    #[test]
    #[should_panic(expected = "does not cover")]
    fn schedule_rejects_mismatched_node_count() {
        GridOfIslands::new(2, 4, 1).schedule(9);
    }
}
