//! The butterfly synchronization network — the paper's core contribution.
//!
//! For radix `r` (the paper's *fanout*; `fanout 1` means the classic
//! radix-2 butterfly), round `i` groups nodes whose base-`r` ids differ
//! only in digit `i`; group members exchange their accumulated frontier
//! knowledge. After `ceil(log_r CN)` rounds every node holds every node's
//! frontier — the all-to-all outcome with
//! `CN·(r−1)·ceil(log_r CN)` messages instead of `CN·(CN−1)`.
//!
//! **Non-power-of-`r` node counts** use the paper's padded scheme: the id
//! space is padded to `r^depth`, and a virtual node's accumulated block is
//! held by the *last real node* (`CN−1`). This exactly reproduces the
//! Fig 1(f) pathology the paper reports: with 9 nodes and fanout 1, node 8
//! must serve nodes 1–7 in the final round (8 sends from one NIC), which
//! `net::sim` then prices as the 8→9-GPU regression visible in Fig 3.

use super::pattern::{CommPattern, Schedule, Transfer};

/// Butterfly pattern with a configurable fanout.
#[derive(Clone, Copy, Debug)]
pub struct Butterfly {
    /// The paper's fanout parameter. `1` ⇒ classic radix-2 butterfly;
    /// `f ≥ 2` ⇒ radix-`f` digit-group exchange; `f = CN` degenerates to
    /// single-round all-to-all (§3 "it is possible to set the fanout
    /// f = CN").
    pub fanout: u32,
}

impl Butterfly {
    /// Create a butterfly pattern with the given fanout (≥ 1).
    pub fn new(fanout: u32) -> Self {
        assert!(fanout >= 1, "fanout must be >= 1");
        Self { fanout }
    }

    /// Effective radix: fanout 1 means radix 2 (one partner per round).
    pub fn radix(&self) -> u32 {
        self.fanout.max(2)
    }

    /// Schedule depth for `cn` nodes: `ceil(log_radix cn)`.
    pub fn depth_for(&self, cn: u32) -> u32 {
        depth(cn, self.radix())
    }

    /// The paper's `ButterflyDirection()` oracle: the set of *real* source
    /// nodes that node `g` receives from in round `i`.
    pub fn butterfly_direction(&self, cn: u32, g: u32, round: u32) -> Vec<u32> {
        let r = self.radix() as u64;
        let stride = r.pow(round);
        let digit = (g as u64 / stride) % r;
        let base = g as u64 - digit * stride;
        let mut srcs = Vec::new();
        for j in 0..r {
            if j == digit {
                continue;
            }
            let partner = base + j * stride;
            // Virtual partners' blocks are held by the last real node.
            let holder = if partner >= cn as u64 { cn - 1 } else { partner as u32 };
            if holder != g && !srcs.contains(&holder) {
                srcs.push(holder);
            }
        }
        srcs
    }
}

/// `ceil(log_r cn)` with `depth(1) = 0`.
fn depth(cn: u32, radix: u32) -> u32 {
    assert!(radix >= 2);
    let mut d = 0;
    let mut span: u64 = 1;
    while span < cn as u64 {
        span *= radix as u64;
        d += 1;
    }
    d
}

impl CommPattern for Butterfly {
    fn name(&self) -> &'static str {
        "butterfly"
    }

    fn schedule(&self, cn: u32) -> Schedule {
        assert!(cn >= 1, "need at least one node");
        let t = self.depth_for(cn);
        let mut rounds = Vec::with_capacity(t as usize);
        for i in 0..t {
            let mut round = Vec::new();
            for g in 0..cn {
                for src in self.butterfly_direction(cn, g, i) {
                    round.push(Transfer { src, dst: g });
                }
            }
            // Deterministic order; dedup identical (src,dst) pairs that can
            // arise when several virtual partners share a holder.
            round.sort_by_key(|tr| (tr.src, tr.dst));
            round.dedup();
            rounds.push(round);
        }
        Schedule { num_nodes: cn, rounds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::analysis::verify_full_coverage;

    #[test]
    fn fanout1_16_nodes_matches_paper() {
        // Paper §3: "For a fanout of 1 and 16 compute-nodes, a total
        // number of 64 messages are necessary", depth log2(16) = 4.
        let s = Butterfly::new(1).schedule(16);
        assert_eq!(s.depth(), 4);
        assert_eq!(s.total_messages(), 64);
        s.validate().unwrap();
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn fanout4_16_nodes_two_rounds() {
        // Paper: fanout 4 with 16 GPUs needs two rounds (vs four for f=1).
        let s = Butterfly::new(4).schedule(16);
        assert_eq!(s.depth(), 2);
        // Radix-4 digit exchange: 16 nodes × 3 partners × 2 rounds = 96
        // messages (the paper's f·log_f formula rounds this up to 128).
        assert_eq!(s.total_messages(), 96);
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn fig1_coverage_growth_for_node0() {
        // Fig 1 (b)-(f): node 0's knowledge doubles each round:
        // {0} -> {0,1} -> {0..3} -> {0..7} -> {0..15}.
        let bf = Butterfly::new(1);
        let cn = 16;
        let mut know: u64 = 1; // node 0 knows itself
        let mut all_know: Vec<u64> = (0..cn).map(|g| 1u64 << g).collect();
        for round in 0..4 {
            let mut next = all_know.clone();
            for g in 0..cn {
                for src in bf.butterfly_direction(cn as u32, g as u32, round) {
                    next[g] |= all_know[src as usize];
                }
            }
            all_know = next;
            know = all_know[0];
            let expect_count = 1u64 << (round + 1);
            assert_eq!(know.count_ones() as u64, expect_count, "round {round}");
        }
        assert_eq!(know, 0xFFFF);
    }

    #[test]
    fn fig2_fanout4_first_round_groups_of_four() {
        // Fig 2(c): after one round node 0 has synchronized against 0-3.
        let bf = Butterfly::new(4);
        let srcs = bf.butterfly_direction(16, 0, 0);
        assert_eq!(srcs, vec![1, 2, 3]);
        // Fig 2(d): round 1 brings 4, 8, 12 (holding 4-7, 8-11, 12-15).
        let srcs = bf.butterfly_direction(16, 0, 1);
        assert_eq!(srcs, vec![4, 8, 12]);
    }

    #[test]
    fn nine_nodes_fanout1_last_round_bottleneck() {
        // Paper Fig 1(f): with 9 nodes, node 8 communicates with 8
        // different nodes in the last round.
        let s = Butterfly::new(1).schedule(9);
        assert_eq!(s.depth(), 4);
        let last = s.rounds.last().unwrap();
        let sends_from_8 = last.iter().filter(|t| t.src == 8).count();
        assert_eq!(sends_from_8, 8, "node 8 must serve all others: {last:?}");
        verify_full_coverage(&s).unwrap();
        // Contrast: 8 nodes have no such hotspot.
        let s8 = Butterfly::new(1).schedule(8);
        assert_eq!(s8.max_sends_per_round(), 1);
    }

    #[test]
    fn fanout_cn_is_single_round_alltoall() {
        let s = Butterfly::new(8).schedule(8);
        assert_eq!(s.depth(), 1);
        assert_eq!(s.total_messages(), 8 * 7);
        verify_full_coverage(&s).unwrap();
    }

    #[test]
    fn one_node_needs_no_communication() {
        let s = Butterfly::new(1).schedule(1);
        assert_eq!(s.depth(), 0);
        assert_eq!(s.total_messages(), 0);
    }

    #[test]
    fn coverage_for_all_cn_and_fanout() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(80), "butterfly covers all nodes", |rng| {
            let cn = gen::usize_in(rng, 1, 48) as u32;
            let f = gen::usize_in(rng, 1, 9) as u32;
            let s = Butterfly::new(f).schedule(cn);
            let ok = s.validate().is_ok() && verify_full_coverage(&s).is_ok();
            (ok, format!("cn={cn} fanout={f}"))
        });
    }

    #[test]
    fn message_count_formula_power_of_radix() {
        // Exact count for cn = r^t: cn·(r−1)·t.
        for (f, cn) in [(1u32, 32u32), (2, 32), (4, 64), (8, 64)] {
            let s = Butterfly::new(f).schedule(cn);
            let r = f.max(2) as u64;
            let t = s.depth() as u64;
            assert_eq!(
                s.total_messages(),
                cn as u64 * (r - 1) * t,
                "f={f} cn={cn}"
            );
        }
    }

    #[test]
    fn depth_matches_log() {
        assert_eq!(Butterfly::new(1).depth_for(16), 4);
        assert_eq!(Butterfly::new(4).depth_for(16), 2);
        assert_eq!(Butterfly::new(4).depth_for(17), 3);
        assert_eq!(Butterfly::new(2).depth_for(9), 4);
        assert_eq!(Butterfly::new(16).depth_for(16), 1);
    }
}
