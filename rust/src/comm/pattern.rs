//! Communication-schedule abstraction.
//!
//! A [`Schedule`] is a list of synchronization *rounds*; each round is a set
//! of [`Transfer`]s that may proceed concurrently. The semantics of a
//! transfer are allgather-style: **`src` ships its entire accumulated
//! frontier knowledge to `dst`**, and `dst` merges it. After the final
//! round every node must know every node's frontier — the invariant
//! [`crate::comm::analysis::verify_full_coverage`] checks for every pattern.

/// One directed message within a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Transfer {
    /// Sending compute node.
    pub src: u32,
    /// Receiving compute node.
    pub dst: u32,
}

/// A complete per-level synchronization schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    /// Number of compute nodes.
    pub num_nodes: u32,
    /// Rounds of concurrent transfers.
    pub rounds: Vec<Vec<Transfer>>,
}

impl Schedule {
    /// Total number of messages across all rounds.
    pub fn total_messages(&self) -> u64 {
        self.rounds.iter().map(|r| r.len() as u64).sum()
    }

    /// Depth (number of rounds).
    pub fn depth(&self) -> usize {
        self.rounds.len()
    }

    /// Maximum number of messages any single node *sends* in any round —
    /// the paper's Fig 1(f) bottleneck metric.
    pub fn max_sends_per_round(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| {
                let mut counts = std::collections::HashMap::new();
                for t in r {
                    *counts.entry(t.src).or_insert(0u64) += 1;
                }
                counts.into_values()
            })
            .max()
            .unwrap_or(0)
    }

    /// Maximum number of messages any single node *receives* in any round
    /// — bounds the preallocated receive buffer (`O(f·V)`, contribution 4).
    pub fn max_recvs_per_round(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| {
                let mut counts = std::collections::HashMap::new();
                for t in r {
                    *counts.entry(t.dst).or_insert(0u64) += 1;
                }
                counts.into_values()
            })
            .max()
            .unwrap_or(0)
    }

    /// Sanity checks: src/dst in range, no self-messages, no duplicate
    /// (src,dst) pair within one round.
    pub fn validate(&self) -> Result<(), String> {
        for (i, round) in self.rounds.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for t in round {
                if t.src >= self.num_nodes || t.dst >= self.num_nodes {
                    return Err(format!("round {i}: transfer {t:?} out of range"));
                }
                if t.src == t.dst {
                    return Err(format!("round {i}: self-message {t:?}"));
                }
                if !seen.insert((t.src, t.dst)) {
                    return Err(format!("round {i}: duplicate transfer {t:?}"));
                }
            }
        }
        Ok(())
    }
}

/// A synchronization-pattern generator.
pub trait CommPattern {
    /// Human-readable name (used in bench tables).
    fn name(&self) -> &'static str;
    /// Build the schedule for `num_nodes` compute nodes.
    fn schedule(&self, num_nodes: u32) -> Schedule;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(rounds: Vec<Vec<(u32, u32)>>) -> Schedule {
        Schedule {
            num_nodes: 4,
            rounds: rounds
                .into_iter()
                .map(|r| r.into_iter().map(|(src, dst)| Transfer { src, dst }).collect())
                .collect(),
        }
    }

    #[test]
    fn counters() {
        let s = sched(vec![vec![(0, 1), (2, 3)], vec![(0, 2), (0, 3), (1, 0)]]);
        assert_eq!(s.total_messages(), 5);
        assert_eq!(s.depth(), 2);
        assert_eq!(s.max_sends_per_round(), 2); // node 0 in round 1
        assert_eq!(s.max_recvs_per_round(), 1);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn validate_rejects_self_message() {
        let s = sched(vec![vec![(1, 1)]]);
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let s = sched(vec![vec![(0, 9)]]);
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_duplicates_in_round() {
        let s = sched(vec![vec![(0, 1), (0, 1)]]);
        assert!(s.validate().is_err());
    }
}
