//! The 2D fold/expand exchange pattern (Buluç & Madduri; Yoo et al.).
//!
//! For a `rows × cols` processor grid, each BFS level synchronizes in two
//! rounds:
//!
//! 1. **Fold** (round 0, when `cols > 1`) — every processor ships its
//!    accumulated discoveries to its `cols − 1` *row* peers. After the
//!    round, each processor knows everything its processor row discovered
//!    this level (the row's target ranges tile the whole vertex set, so
//!    this aggregates the row's frontier segments).
//! 2. **Expand** (when `rows > 1`) — every processor broadcasts the
//!    row-merged frontier to its `rows − 1` *column* peers. Each column
//!    contains one processor from every row, so after the round every
//!    processor holds the complete deduped level frontier.
//!
//! Under the engine's allgather transfer semantics this two-round
//! schedule achieves full coverage (verified by
//! [`verify_full_coverage`](crate::comm::analysis::verify_full_coverage)
//! like every other pattern) with `cols − 1 + rows − 1` partners per
//! processor — `2(√P − 1)` for a square grid versus the 1D all-to-all's
//! `P − 1`. That is the classical "P to √P" message reduction the paper's
//! butterfly is pitched against;
//! [`messages_per_level`](crate::partition::Partition2D::messages_per_level)
//! is the matching closed-form count.

use super::pattern::{CommPattern, Schedule, Transfer};

/// The fold/expand pattern for a `rows × cols` grid (ranks row-major:
/// processor `(i, j)` is rank `i·cols + j`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldExpand {
    /// Processor-grid rows.
    pub rows: u32,
    /// Processor-grid columns.
    pub cols: u32,
}

impl FoldExpand {
    /// Create the pattern for a `rows × cols` grid.
    pub fn new(rows: u32, cols: u32) -> Self {
        assert!(rows >= 1 && cols >= 1);
        Self { rows, cols }
    }

    /// Number of fold rounds in the schedule (0 when `cols == 1`).
    pub fn fold_rounds(&self) -> usize {
        usize::from(self.cols > 1)
    }

    /// Number of expand rounds in the schedule (0 when `rows == 1`).
    pub fn expand_rounds(&self) -> usize {
        usize::from(self.rows > 1)
    }
}

impl CommPattern for FoldExpand {
    fn name(&self) -> &'static str {
        "fold-expand"
    }

    /// Build the two-round schedule. `num_nodes` must equal `rows·cols`.
    fn schedule(&self, num_nodes: u32) -> Schedule {
        assert_eq!(
            num_nodes,
            self.rows * self.cols,
            "fold/expand needs num_nodes == rows*cols ({}x{})",
            self.rows,
            self.cols
        );
        let rank = |i: u32, j: u32| i * self.cols + j;
        let mut rounds = Vec::with_capacity(2);
        if self.cols > 1 {
            let mut fold = Vec::with_capacity((num_nodes * (self.cols - 1)) as usize);
            for i in 0..self.rows {
                for j in 0..self.cols {
                    for j2 in 0..self.cols {
                        if j2 != j {
                            fold.push(Transfer { src: rank(i, j), dst: rank(i, j2) });
                        }
                    }
                }
            }
            rounds.push(fold);
        }
        if self.rows > 1 {
            let mut expand = Vec::with_capacity((num_nodes * (self.rows - 1)) as usize);
            for i in 0..self.rows {
                for j in 0..self.cols {
                    for i2 in 0..self.rows {
                        if i2 != i {
                            expand.push(Transfer { src: rank(i, j), dst: rank(i2, j) });
                        }
                    }
                }
            }
            rounds.push(expand);
        }
        Schedule { num_nodes, rounds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::analysis::verify_full_coverage;

    #[test]
    fn full_coverage_for_exhaustive_grids() {
        for rows in 1..=8u32 {
            for cols in 1..=8u32 {
                let s = FoldExpand::new(rows, cols).schedule(rows * cols);
                s.validate().unwrap_or_else(|e| panic!("{rows}x{cols}: {e}"));
                verify_full_coverage(&s)
                    .unwrap_or_else(|e| panic!("{rows}x{cols}: {e}"));
            }
        }
    }

    #[test]
    fn message_count_matches_model() {
        for (rows, cols) in [(4u32, 4u32), (2, 8), (8, 2), (1, 6), (6, 1), (3, 5)] {
            let p = (rows * cols) as u64;
            let s = FoldExpand::new(rows, cols).schedule(rows * cols);
            let want = p * (cols as u64 - 1) + p * (rows as u64 - 1);
            assert_eq!(s.total_messages(), want, "{rows}x{cols}");
        }
    }

    #[test]
    fn round_structure_and_fanout() {
        let fe = FoldExpand::new(4, 4);
        let s = fe.schedule(16);
        assert_eq!(s.depth(), 2);
        assert_eq!(fe.fold_rounds(), 1);
        assert_eq!(fe.expand_rounds(), 1);
        // Each round: every node sends to and receives from exactly 3 peers.
        assert_eq!(s.max_sends_per_round(), 3);
        assert_eq!(s.max_recvs_per_round(), 3);
        assert_eq!(s.rounds[0].len(), 16 * 3);
        assert_eq!(s.rounds[1].len(), 16 * 3);
        // Fold transfers stay within a processor row.
        for t in &s.rounds[0] {
            assert_eq!(t.src / 4, t.dst / 4, "{t:?} crosses rows in fold");
        }
        // Expand transfers stay within a processor column.
        for t in &s.rounds[1] {
            assert_eq!(t.src % 4, t.dst % 4, "{t:?} crosses cols in expand");
        }
    }

    #[test]
    fn degenerate_grids_drop_empty_rounds() {
        let row_only = FoldExpand::new(1, 8).schedule(8);
        assert_eq!(row_only.depth(), 1);
        assert_eq!(row_only.total_messages(), 8 * 7);
        let col_only = FoldExpand::new(8, 1).schedule(8);
        assert_eq!(col_only.depth(), 1);
        assert_eq!(col_only.total_messages(), 8 * 7);
        let single = FoldExpand::new(1, 1).schedule(1);
        assert_eq!(single.depth(), 0);
        assert_eq!(single.total_messages(), 0);
    }

    #[test]
    #[should_panic(expected = "num_nodes == rows*cols")]
    fn wrong_node_count_panics() {
        FoldExpand::new(4, 4).schedule(15);
    }
}
