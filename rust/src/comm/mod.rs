//! Frontier-synchronization communication patterns: the paper's butterfly
//! network, all-to-all baselines, and the executable complexity analysis.

pub mod alltoall;
pub mod analysis;
pub mod butterfly;
pub mod pattern;

pub use alltoall::{ConcurrentAllToAll, IterativeAllToAll};
pub use butterfly::Butterfly;
pub use pattern::{CommPattern, Schedule, Transfer};
