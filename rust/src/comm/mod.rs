//! Frontier-synchronization communication patterns: the paper's butterfly
//! network, all-to-all baselines, the 2D fold/expand exchange, the
//! hierarchical grid-of-islands composition, and the executable
//! complexity analysis.

pub mod alltoall;
pub mod analysis;
pub mod butterfly;
pub mod fold_expand;
pub mod hierarchical;
pub mod pattern;

pub use alltoall::{ConcurrentAllToAll, IterativeAllToAll};
pub use analysis::{class_volume, ClassVolume};
pub use butterfly::Butterfly;
pub use fold_expand::FoldExpand;
pub use hierarchical::GridOfIslands;
pub use pattern::{CommPattern, Schedule, Transfer};
