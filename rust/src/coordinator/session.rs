//! The mutable half of the engine: [`QuerySession`] plus its typed
//! results ([`TraversalResult`], [`BatchResult`]) and [`QueryError`].
//!
//! A session owns everything one in-flight query needs — per-node
//! [`ComputeNode`] state (distance arrays, queues, bitmaps), Phase-1
//! backends and scratch, batched MS-BFS lane state, and the worker pool —
//! while the expensive artifacts (slabs, schedule, partition) stay in the
//! shared immutable [`TraversalPlan`]. Any number of sessions over one
//! plan run concurrently and independently; one session runs any number
//! of queries back to back, reusing its buffers (a pooled
//! [`reset`](QuerySession::reset) between queries, never a reallocation
//! of the per-vertex arrays).
//!
//! Each level of a query runs the paper's two strictly separated phases:
//!
//! 1. **Traversal** — every compute node expands its owned frontier over
//!    its adjacency slab (via its [`ComputeBackend`]), discovering
//!    vertices into its global queue and distance array. With
//!    `parallel_phase1` set, the per-node steps run on the persistent
//!    [`ThreadPool`] (the per-node state is disjoint, so pooled results
//!    are bit-identical to sequential stepping).
//! 2. **Synchronization** — the plan's schedule rounds execute with
//!    allgather semantics: each transfer ships the sender's accumulated
//!    global queue (snapshotted at round start, the paper's
//!    `CopyFrontier`); receivers dedup against their distance array,
//!    extend their own global queue (so later rounds relay), and route
//!    owned vertices into their next local queue.
//!
//! The partition mode picks the (layout, schedule) pair at plan build
//! time: 1D row slabs + butterfly/all-to-all, or the 2D checkerboard +
//! fold/expand (with per-phase byte/message accounting). The session also
//! keeps the simulated clock: Phase-1 compute is priced by the
//! [`DeviceModel`](crate::net::model::DeviceModel) (slowest node wins —
//! the bulk-synchronous barrier), Phase-2 by the interconnect simulator
//! with the *actual measured payloads* of every message.
//!
//! Results are returned, not scraped: [`QuerySession::run`] hands back a
//! [`TraversalResult`] that owns its distances and metrics, and
//! [`QuerySession::run_batch`] a [`BatchResult`] with per-lane distances
//! — both `Send`, so a service can hand them off while the session moves
//! on to the next query. Metrics-only hot loops (harness sweeps, bench
//! timing) use [`QuerySession::run_metrics_only`] /
//! [`QuerySession::run_batch_metrics_only`] to skip the owned distance
//! copy. Invalid inputs are values ([`QueryError`]), not panics.

use super::backend::{BatchExpandOutput, ComputeBackend, ExpandOutput, NativeCsr};
use super::config::{DirectionMode, EngineConfig, PartitionMode};
use super::metrics::{BatchMetrics, LevelMetrics, RunMetrics, SequentialBaseline};
use super::node::ComputeNode;
use super::plan::TraversalPlan;
use crate::bfs::frontier::{lane_bit, lane_mask_count, lane_mask_is_zero, LaneMask, MaskFrontier};
use crate::bfs::kernels::KernelWork;
use crate::bfs::msbfs::{full_lane_mask, words_for_lanes, MsBfsNodeState, MAX_LANES};
use crate::bfs::serial::INF;
use crate::comm::pattern::Schedule;
use crate::fault::plan::{ExchangeError, FaultFailure, FaultInjector, LevelRecovery};
use crate::fault::recovery::Checkpoint;
use crate::graph::csr::VertexId;
use crate::net::model::TopologyModel;
use crate::net::sim::simulate_topology;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Why a query could not run. Every invalid input to
/// [`QuerySession::run`] / [`QuerySession::run_batch`] surfaces as one of
/// these values — never a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueryError {
    /// The requested root is not a vertex of the planned graph.
    RootOutOfRange {
        /// The offending root.
        root: VertexId,
        /// Vertices in the planned graph.
        num_vertices: usize,
    },
    /// `run_batch` was called with no roots.
    EmptyBatch,
    /// `run_batch` was called with more roots than the widest supported
    /// lane mask holds. Duplicate roots are *not* an error — each
    /// occupies its own lane — and a batch wider than the configured
    /// [`BatchWidth`](super::config::BatchWidth) automatically widens,
    /// so the only hard cap is [`MAX_LANES`] (512).
    WidthTooLarge {
        /// Requested batch width.
        got: usize,
        /// The lane limit ([`MAX_LANES`]).
        max: usize,
    },
    /// An injected exchange fault exhausted the armed
    /// [`FaultPlan`](crate::fault::FaultPlan)'s retry budget. The query is
    /// aborted rather than ever returning a wrong answer.
    Unrecoverable {
        /// What the exchange detected.
        error: ExchangeError,
        /// Retry attempts consumed before giving up.
        attempts: u32,
    },
    /// A rank died mid-query (injected
    /// [`FaultKind::KillRank`](crate::fault::FaultKind::KillRank)). The
    /// session stashes a level checkpoint retrievable via
    /// [`QuerySession::take_checkpoint`]; a
    /// [`FaultTolerantRunner`](crate::fault::FaultTolerantRunner) re-plans
    /// onto the survivors and resumes from it.
    RankDead {
        /// The dead rank.
        rank: u32,
        /// Level at which it died.
        level: u32,
    },
    /// A [`Checkpoint`] incompatible with this session was passed to
    /// [`QuerySession::resume`] / [`QuerySession::resume_batch`].
    CheckpointMismatch {
        /// Which quantity disagreed (`"lanes"` or `"vertices"`).
        what: &'static str,
        /// The value this session requires.
        expected: usize,
        /// The value the checkpoint carries.
        got: usize,
    },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::RootOutOfRange { root, num_vertices } => {
                write!(f, "root {root} out of range for a {num_vertices}-vertex graph")
            }
            QueryError::EmptyBatch => write!(f, "batch contains no roots"),
            QueryError::WidthTooLarge { got, max } => {
                write!(f, "batch of {got} roots exceeds the {max}-lane limit")
            }
            QueryError::Unrecoverable { error, attempts } => {
                write!(f, "unrecoverable exchange fault after {attempts} retries: {error}")
            }
            QueryError::RankDead { rank, level } => {
                write!(f, "rank {rank} died at level {level}; re-plan required")
            }
            QueryError::CheckpointMismatch { what, expected, got } => {
                write!(f, "checkpoint {what} mismatch: session needs {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for QueryError {}

/// Outcome of one single-root traversal: the distances and metrics are
/// *owned* by the result (no post-hoc scraping from the engine), so the
/// session is immediately free for the next query.
#[derive(Clone, Debug)]
pub struct TraversalResult {
    root: VertexId,
    dist: Vec<u32>,
    metrics: RunMetrics,
}

impl TraversalResult {
    /// The root this traversal started from.
    pub fn root(&self) -> VertexId {
        self.root
    }

    /// Distance of every vertex from the root ([`INF`] = unreachable).
    pub fn dist(&self) -> &[u32] {
        &self.dist
    }

    /// Consume the result, keeping only the distance array.
    pub fn into_dist(self) -> Vec<u32> {
        self.dist
    }

    /// Number of vertices reached (root included).
    pub fn reached(&self) -> u64 {
        self.metrics.reached
    }

    /// Number of BFS levels.
    pub fn depth(&self) -> usize {
        self.metrics.depth()
    }

    /// Full per-level metrics of the traversal.
    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    /// Consume the result, keeping only the metrics.
    pub fn into_metrics(self) -> RunMetrics {
        self.metrics
    }
}

/// Outcome of one batched multi-source traversal: per-lane distances
/// (lane `i` corresponds to `roots()[i]`) plus the shared batch metrics.
#[derive(Clone, Debug)]
pub struct BatchResult {
    roots: Vec<VertexId>,
    num_vertices: usize,
    /// Lane-major distances: `dist[lane * num_vertices + v]`.
    dist: Vec<u32>,
    metrics: BatchMetrics,
}

impl BatchResult {
    /// The batch's roots, in lane order.
    pub fn roots(&self) -> &[VertexId] {
        &self.roots
    }

    /// Number of lanes in the batch.
    pub fn num_roots(&self) -> usize {
        self.roots.len()
    }

    /// Distance array of lane `lane` (the traversal rooted at
    /// `roots()[lane]`).
    ///
    /// # Panics
    ///
    /// Like slice indexing, panics when `lane >= num_roots()`; use
    /// [`Self::lane_dist`] for a checked lookup.
    pub fn dist(&self, lane: usize) -> &[u32] {
        match self.lane_dist(lane) {
            Some(d) => d,
            None => panic!(
                "lane {lane} out of range for a {}-root batch",
                self.roots.len()
            ),
        }
    }

    /// Checked variant of [`Self::dist`].
    pub fn lane_dist(&self, lane: usize) -> Option<&[u32]> {
        if lane >= self.roots.len() {
            return None;
        }
        Some(&self.dist[lane * self.num_vertices..(lane + 1) * self.num_vertices])
    }

    /// Total `(root, vertex)` pairs reached.
    pub fn reached_pairs(&self) -> u64 {
        self.metrics.reached_pairs
    }

    /// Number of levels (the max depth over the batch's lanes).
    pub fn depth(&self) -> usize {
        self.metrics.depth()
    }

    /// Full per-level metrics of the batch.
    pub fn metrics(&self) -> &BatchMetrics {
        &self.metrics
    }

    /// Consume the result, keeping only the metrics.
    pub fn into_metrics(self) -> BatchMetrics {
        self.metrics
    }
}

/// One query's worth of mutable engine state over a shared
/// [`TraversalPlan`] — see the [module docs](self) for the phase
/// structure.
///
/// ```
/// use butterfly_bfs::coordinator::{EngineConfig, QueryError, TraversalPlan};
/// use butterfly_bfs::graph::gen::structured::path;
///
/// let g = path(6);
/// let plan = TraversalPlan::build(&g, EngineConfig::dgx2(2, 1))?;
/// let mut session = plan.session();
/// // Invalid input is a typed error, not a panic:
/// assert!(matches!(session.run(99).unwrap_err(), QueryError::RootOutOfRange { .. }));
/// // Results own their distances:
/// let batch = session.run_batch(&[0, 5])?;
/// assert_eq!(batch.dist(1)[0], 5);
/// # Ok::<(), Box<dyn std::error::Error>>(())
/// ```
pub struct QuerySession {
    config: EngineConfig,
    /// The link-class pricing model every Phase-2 simulation runs under —
    /// resolved once from the config ([`EngineConfig::resolved_topology`]):
    /// uniform for flat modes, per-island classified for hierarchical
    /// mode, or whatever heterogeneous model the config pins explicitly.
    topology: TopologyModel,
    schedule: Arc<Schedule>,
    /// Leading schedule rounds that are the 2D fold phase (0 in 1D mode).
    fold_rounds: usize,
    num_vertices: usize,
    graph_edges: u64,
    nodes: Vec<ComputeNode>,
    backends: Vec<Box<dyn ComputeBackend>>,
    scratch: Vec<ExpandOutput>,
    /// Persistent worker pool for Phase-1 stepping — created lazily on
    /// the first query that wants it (`parallel_phase1` set, more than
    /// one node), so sequential sessions never spawn threads.
    pool: Option<ThreadPool>,
    /// Pooled per-node MS-BFS state, reset (not reallocated) per batch;
    /// the enum variant is the lane width the last batch monomorphized
    /// over (a width change rebuilds the states).
    batch_lanes: BatchLanes,
    /// Per-node scratch for batched bottom-up Phase-1 steps.
    batch_scratch: Vec<BatchExpandOutput>,
    /// Per-round destination buckets of the schedule — the pooled
    /// Phase-2 merge plan, a pure function of the (immutable) schedule:
    /// computed lazily once, shared by both query kinds, no per-round
    /// allocation on the merge hot path.
    pooled_buckets: Option<Arc<RoundBuckets>>,
    /// Lane count of the most recent batch.
    batch_width: usize,
    /// Hoisted Phase-2 merge scratch (round snapshots, dense mask/bitmap
    /// accumulators, occupancy words) — reused clear-in-place across
    /// levels and queries so the steady-state level loop allocates
    /// nothing ([`Self::scratch_alloc_events`] counts growth events).
    merge_scratch: MergeScratch,
    /// Armed fault injection ([`Self::arm_faults`]): `None` (the default)
    /// runs fault-free with zero overhead on the level loop.
    fault: Option<FaultArm>,
}

/// The session's hoisted Phase-2 scratch buffers. Everything here used to
/// be a per-`phase2`-call local, costing one round of allocations per
/// *level*; now each buffer is cleared in place and only grows when a
/// bigger graph/width/node-count demands it — every growth bumps
/// `alloc_events`, which the zero-alloc regression test pins at 0 for a
/// repeated identical batch.
#[derive(Default)]
struct MergeScratch {
    /// Single-root per-round queue-length snapshot (one slot per node).
    snap_len: Vec<usize>,
    /// Single-root dense bitmap snapshot (flat, `words` per node).
    bit_snap: Vec<u64>,
    /// Single-root pooled sparse sender prefixes (frozen by copy).
    sparse_snap: Vec<Vec<VertexId>>,
    /// Batched per-round `(prefix length, priced bytes)` snapshot.
    snap: Vec<(usize, u64)>,
    /// Batched dense lane-mask snapshot (flat, `V·W` words per node),
    /// built incrementally across rounds.
    mask_snap: Vec<u64>,
    /// Batched occupancy bitmap per sender (`⌈V/64⌉` words each): bit `v`
    /// set once vertex `v` entered the sender's accumulated snapshot —
    /// the chunked merge kernel walks these instead of all `V` rows.
    mask_occ: Vec<u64>,
    /// Batched per-sender accumulated snapshot prefix (entries folded in).
    mask_done: Vec<usize>,
    /// Batched pooled sparse sender prefixes, width-erased: vertices …
    sparse_snap_v: Vec<Vec<VertexId>>,
    /// … and flat masks (`W` words per entry), parallel to `sparse_snap_v`.
    sparse_snap_m: Vec<Vec<u64>>,
    /// Buffer-growth events (allocations) since the session was built.
    alloc_events: u64,
}

impl MergeScratch {
    /// Bump the growth counter when `buf` is about to grow past its
    /// current capacity.
    fn will_grow<T>(events: &mut u64, buf: &Vec<T>, need: usize) {
        if buf.capacity() < need {
            *events += 1;
        }
    }
}

/// A session's armed fault state: the shared injector plus the level
/// checkpoint stashed when a rank dies mid-query.
struct FaultArm {
    injector: Arc<FaultInjector>,
    checkpoint: Option<Checkpoint>,
}

/// One merge plan per schedule round: for each destination that receives
/// anything, the sources it receives from, in schedule order.
type RoundBuckets = Vec<Vec<(usize, Vec<usize>)>>;

/// Run `$body` with `$s` bound to the pooled lane-state vector of
/// whichever width the slot currently holds — the width-erasure seam of
/// the monomorphized batch engine. The body may only touch
/// width-agnostic state (`dist`, lengths); width-specific work goes
/// through [`LaneSlot`] + [`QuerySession::run_batch_w`].
macro_rules! for_lanes {
    ($lanes:expr, $s:ident => $body:expr) => {
        match $lanes {
            BatchLanes::W1($s) => $body,
            BatchLanes::W2($s) => $body,
            BatchLanes::W4($s) => $body,
            BatchLanes::W8($s) => $body,
        }
    };
}

/// Width-erased storage for the pooled per-node MS-BFS lane states: one
/// variant per monomorphized word count `W ∈ {1, 2, 4, 8}` (64–512
/// lanes). `run_batch` picks the variant from the batch width and the
/// configured [`BatchWidth`](super::config::BatchWidth) floor; reusing a
/// session at the same width resets the states in place (allocations
/// kept), while a width change rebuilds them.
enum BatchLanes {
    /// Single-word lanes (up to 64 roots).
    W1(Vec<MsBfsNodeState<1>>),
    /// Two-word lanes (up to 128 roots).
    W2(Vec<MsBfsNodeState<2>>),
    /// Four-word lanes (up to 256 roots).
    W4(Vec<MsBfsNodeState<4>>),
    /// Eight-word lanes (up to 512 roots).
    W8(Vec<MsBfsNodeState<8>>),
}

impl BatchLanes {
    /// The no-batch-yet slot (an empty single-word vector).
    fn empty() -> Self {
        BatchLanes::W1(Vec::new())
    }

    /// Node 0's lane-major distance array, if a batch has run.
    fn node0_dist(&self) -> Option<&[u32]> {
        for_lanes!(self, s => s.first().map(|st| st.dist.as_slice()))
    }
}

/// The take/put seam between the width-erased [`BatchLanes`] slot and the
/// monomorphized batch loop: implemented for exactly the four supported
/// `MsBfsNodeState` widths, so `run_batch_w::<W>` can move its typed
/// state vector out of the session, run without borrow entanglement, and
/// store it back for pooled reuse.
trait LaneSlot: Sized {
    /// Move the pooled state vector out of the slot when the slot is at
    /// this width (otherwise an empty vector — the caller rebuilds).
    fn take(lanes: &mut BatchLanes) -> Vec<Self>;
    /// Store the state vector back into the slot at this width.
    fn put(lanes: &mut BatchLanes, states: Vec<Self>);
}

macro_rules! impl_lane_slot {
    ($w:literal, $variant:ident) => {
        impl LaneSlot for MsBfsNodeState<$w> {
            fn take(lanes: &mut BatchLanes) -> Vec<Self> {
                match std::mem::replace(lanes, BatchLanes::empty()) {
                    BatchLanes::$variant(v) => v,
                    _ => Vec::new(),
                }
            }
            fn put(lanes: &mut BatchLanes, states: Vec<Self>) {
                *lanes = BatchLanes::$variant(states);
            }
        }
    };
}
impl_lane_slot!(1, W1);
impl_lane_slot!(2, W2);
impl_lane_slot!(4, W4);
impl_lane_slot!(8, W8);

/// The direction-optimizing α/β hysteresis machine — one implementation
/// drives both the single-root and the batched level loop, so the two
/// engine paths cannot drift apart. (The single-node oracle
/// [`ms_bfs_dir`](crate::bfs::msbfs::ms_bfs_dir) mirrors the policy
/// *independently* on purpose: it is the cross-check the equivalence
/// suite compares the engine against.)
struct DirOptState {
    bottom_up: bool,
    prev_frontier: u64,
    /// Edge mass not yet claimed by any traversal (lane-union for
    /// batches) — the denominator of the TD→BU threshold.
    m_unexplored: u64,
}

impl DirOptState {
    fn new(graph_edges: u64) -> Self {
        Self { bottom_up: false, prev_frontier: 0, m_unexplored: graph_edges }
    }

    /// Decide this level's direction from the level-start statistics.
    /// `m_frontier` (the frontier's distinct-vertex edge mass) is taken
    /// lazily: it is only needed for the TD→BU check, so latched
    /// bottom-up levels skip the O(frontier) degree sum entirely.
    fn step(
        &mut self,
        direction: DirectionMode,
        frontier: u64,
        num_vertices: u64,
        m_frontier: impl FnOnce() -> u64,
    ) -> bool {
        match direction {
            DirectionMode::TopDown => {}
            DirectionMode::BottomUp => self.bottom_up = true,
            DirectionMode::DirOpt { alpha, beta } => {
                let growing = frontier > self.prev_frontier;
                if !self.bottom_up
                    && alpha > 0
                    && growing
                    && m_frontier() > self.m_unexplored / alpha
                {
                    self.bottom_up = true;
                } else if self.bottom_up
                    && beta > 0
                    && !growing
                    && frontier < num_vertices / beta
                {
                    self.bottom_up = false;
                }
                self.prev_frontier = frontier;
            }
        }
        self.bottom_up
    }

    /// Post-level bookkeeping: claim the next frontier's edge mass out of
    /// the unexplored pool (lazy for the same reason as `step`).
    fn claim_next(&mut self, direction: DirectionMode, next_edges: impl FnOnce() -> u64) {
        if let DirectionMode::DirOpt { .. } = direction {
            self.m_unexplored = self.m_unexplored.saturating_sub(next_edges());
        }
    }
}

impl QuerySession {
    /// Session with the native CSR backend on every node
    /// ([`TraversalPlan::session`]).
    pub(crate) fn with_native_backends(plan: &TraversalPlan) -> Self {
        let backends: Vec<Box<dyn ComputeBackend>> = (0..plan.num_nodes())
            .map(|_| {
                Box::new(
                    NativeCsr::new(plan.config().use_lrb)
                        .with_kernel(plan.config().kernel),
                ) as Box<dyn ComputeBackend>
            })
            .collect();
        Self::from_parts(plan, backends)
    }

    /// Session with caller-supplied backends; the count was validated by
    /// [`TraversalPlan::session_with_backends`].
    pub(crate) fn from_parts(
        plan: &TraversalPlan,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Self {
        debug_assert_eq!(backends.len(), plan.num_nodes());
        let nodes: Vec<ComputeNode> = (0..plan.num_nodes())
            .map(|i| ComputeNode::from_shared(i as u32, plan.slab(i), plan.num_vertices()))
            .collect();
        let scratch = (0..plan.num_nodes()).map(|_| ExpandOutput::default()).collect();
        Self {
            config: plan.config().clone(),
            topology: plan.config().resolved_topology(),
            schedule: plan.schedule_arc(),
            fold_rounds: plan.fold_rounds(),
            num_vertices: plan.num_vertices(),
            graph_edges: plan.graph_edges(),
            nodes,
            backends,
            scratch,
            pool: None,
            batch_lanes: BatchLanes::empty(),
            batch_scratch: Vec::new(),
            pooled_buckets: None,
            batch_width: 0,
            merge_scratch: MergeScratch::default(),
            fault: None,
        }
    }

    /// Number of buffer-growth events (allocations) the session's pooled
    /// Phase-1/Phase-2 scratch has taken since construction. A repeated
    /// identical query adds **zero**: every per-level buffer — the
    /// batched bottom-up kernel state, dense merge snapshots, occupancy
    /// words, sparse prefix copies — is cleared in place and reused.
    pub fn scratch_alloc_events(&self) -> u64 {
        self.merge_scratch.alloc_events
    }

    /// Arm (or, with `None`, disarm) deterministic fault injection at the
    /// Phase-2 exchange seam. The injector is shared — pass clones of one
    /// `Arc` to correlate fire counts across sessions (serve retries,
    /// re-planned replays). While armed, every level's exchange is checked
    /// against the plan: tolerated faults add `retries` / `retry_bytes` /
    /// `recovery_time` to that level's [`LevelMetrics`] (distances are
    /// bit-identical to the fault-free run by construction), exhausted
    /// budgets surface [`QueryError::Unrecoverable`], and a killed rank
    /// surfaces [`QueryError::RankDead`] with a checkpoint stashed for
    /// [`Self::take_checkpoint`].
    pub fn arm_faults(&mut self, injector: Option<Arc<FaultInjector>>) {
        self.fault = injector.map(|injector| FaultArm { injector, checkpoint: None });
    }

    /// Take the level checkpoint stashed by the most recent
    /// [`QueryError::RankDead`] failure, if any. Feed it to
    /// [`Self::resume`] / [`Self::resume_batch`] on a session over a
    /// re-planned (degraded) plan to replay only the lost level.
    pub fn take_checkpoint(&mut self) -> Option<Checkpoint> {
        self.fault.as_mut().and_then(|f| f.checkpoint.take())
    }

    /// Apply the armed fault plan (if any) to one level's exchange.
    fn check_faults(
        &self,
        level: u32,
        payloads: &[Vec<u64>],
    ) -> Result<LevelRecovery, FaultFailure> {
        match &self.fault {
            Some(arm) => {
                arm.injector.apply_level(level, &self.schedule, payloads, &self.topology)
            }
            None => Ok(LevelRecovery::default()),
        }
    }

    /// Translate an exchange failure into the session-level error,
    /// stashing the level checkpoint when a rank died (so the caller can
    /// re-plan and resume).
    fn fault_failure(&mut self, fail: FaultFailure, ckpt: Option<Checkpoint>) -> QueryError {
        match fail.error {
            ExchangeError::RankDead { rank, level } => {
                if let Some(arm) = &mut self.fault {
                    arm.checkpoint = ckpt;
                }
                QueryError::RankDead { rank, level }
            }
            error => QueryError::Unrecoverable { error, attempts: fail.attempts },
        }
    }

    /// True when the armed plan could kill a rank — only then does the
    /// level loop pay the per-level checkpoint clone.
    fn capture_checkpoints(&self) -> bool {
        self.fault.as_ref().is_some_and(|f| f.injector.plan().has_kill())
    }

    /// Engine configuration (shared with the plan).
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The synchronization schedule this session executes per level.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Vertex count of the planned graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Clear all per-query state (single-root and batched) while keeping
    /// every buffer allocation — the pooled-reuse path for long-lived
    /// sessions. Calling [`Self::run`] / [`Self::run_batch`] resets
    /// implicitly, so an explicit `reset` is only needed to drop state
    /// early.
    pub fn reset(&mut self) {
        for n in &mut self.nodes {
            n.reset();
        }
        let bw = self.batch_width;
        for_lanes!(&mut self.batch_lanes, s => {
            for st in s.iter_mut() {
                st.reset(bw);
            }
        });
    }

    /// Spawn the persistent worker pool if this session wants one (either
    /// phase pooled) and it does not exist yet.
    fn ensure_pool(&mut self) {
        let wants = self.config.parallel_phase1 || self.config.parallel_phase2;
        if self.pool.is_none() && wants && self.config.num_nodes > 1 {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(self.config.num_nodes);
            self.pool = Some(ThreadPool::new(workers));
        }
    }

    /// Distinct active frontier vertices across the machine. In 1D each
    /// owned vertex is queued on exactly one node; in 2D every node of a
    /// processor row queues the row's vertices (each expands its own
    /// column block), so count one column representative per row.
    fn frontier_len(&self) -> u64 {
        match self.config.partition {
            PartitionMode::OneD | PartitionMode::Hierarchical { .. } => {
                self.nodes.iter().map(|n| n.q_local.len() as u64).sum()
            }
            PartitionMode::TwoD { cols, .. } => self
                .nodes
                .iter()
                .step_by(cols as usize)
                .map(|n| n.q_local.len() as u64)
                .sum(),
        }
    }

    /// Batched analog of [`Self::frontier_len`] (over the monomorphized
    /// lane states the caller holds).
    fn batch_frontier_len<const W: usize>(&self, states: &[MsBfsNodeState<W>]) -> u64 {
        match self.config.partition {
            PartitionMode::OneD | PartitionMode::Hierarchical { .. } => {
                states.iter().map(|s| s.q_local.len() as u64).sum()
            }
            PartitionMode::TwoD { cols, .. } => states
                .iter()
                .step_by(cols as usize)
                .map(|s| s.q_local.len() as u64)
                .sum(),
        }
    }

    /// The pooled Phase-2 merge plan (see [`RoundBuckets`]), computed on
    /// first use and handed out as a cheap `Arc` clone so the Phase-2
    /// loops hold no borrow of `self` while mutating receivers.
    fn pooled_buckets(&mut self) -> Arc<RoundBuckets> {
        if self.pooled_buckets.is_none() {
            let buckets = self
                .schedule
                .rounds
                .iter()
                .map(|round| {
                    let mut by_dst: Vec<Vec<usize>> =
                        vec![Vec::new(); self.config.num_nodes];
                    for t in round {
                        by_dst[t.dst as usize].push(t.src as usize);
                    }
                    by_dst
                        .into_iter()
                        .enumerate()
                        .filter(|(_, srcs)| !srcs.is_empty())
                        .collect()
                })
                .collect();
            self.pooled_buckets = Some(Arc::new(buckets));
        }
        Arc::clone(self.pooled_buckets.as_ref().expect("just filled"))
    }

    /// 2D mode: the (fold messages, fold bytes, expand messages, expand
    /// bytes) split of one level's payload matrix; `None` in 1D mode.
    fn phase_split(&self, payloads: &[Vec<u64>]) -> Option<(u64, u64, u64, u64)> {
        if !matches!(self.config.partition, PartitionMode::TwoD { .. }) {
            return None;
        }
        let (fold, expand) = payloads.split_at(self.fold_rounds.min(payloads.len()));
        let msgs = |rs: &[Vec<u64>]| rs.iter().map(|r| r.len() as u64).sum::<u64>();
        let bytes = |rs: &[Vec<u64>]| rs.iter().flatten().copied().sum::<u64>();
        Some((msgs(fold), bytes(fold), msgs(expand), bytes(expand)))
    }

    /// Run a full traversal from `root`. The returned [`TraversalResult`]
    /// owns its distances and metrics; the session's buffers are reused
    /// by the next query.
    pub fn run(&mut self, root: VertexId) -> Result<TraversalResult, QueryError> {
        let metrics = self.run_inner(root, None)?;
        Ok(TraversalResult {
            root,
            dist: self.nodes[0].d_local.clone(),
            metrics,
        })
    }

    /// Resume a single-root traversal from a level [`Checkpoint`]
    /// (captured by a fault-armed session when a rank died): seeds every
    /// node from the checkpoint's distance array and replays from the
    /// checkpointed level. The checkpoint's completed-level metrics are
    /// carried over, so the result's per-level trace covers the whole
    /// traversal. Typically called on a session over a *degraded* re-plan
    /// by [`FaultTolerantRunner`](crate::fault::FaultTolerantRunner).
    pub fn resume(&mut self, ck: &Checkpoint) -> Result<TraversalResult, QueryError> {
        if ck.lanes() != 0 {
            return Err(QueryError::CheckpointMismatch {
                what: "lanes",
                expected: 0,
                got: ck.lanes(),
            });
        }
        if ck.dist.len() != self.num_vertices {
            return Err(QueryError::CheckpointMismatch {
                what: "vertices",
                expected: self.num_vertices,
                got: ck.dist.len(),
            });
        }
        let root = ck.roots.first().copied().ok_or(QueryError::EmptyBatch)?;
        let metrics = self.run_inner(root, Some(ck))?;
        Ok(TraversalResult {
            root,
            dist: self.nodes[0].d_local.clone(),
            metrics,
        })
    }

    /// Metrics-only variant of [`Self::run`]: identical traversal, but
    /// skips materializing the owned distance array — the right call for
    /// harness/bench hot loops that only consume the simulated clock and
    /// counters (one `O(V)` copy per query saved).
    pub fn run_metrics_only(&mut self, root: VertexId) -> Result<RunMetrics, QueryError> {
        self.run_inner(root, None)
    }

    fn run_inner(
        &mut self,
        root: VertexId,
        resume: Option<&Checkpoint>,
    ) -> Result<RunMetrics, QueryError> {
        if root as usize >= self.num_vertices {
            return Err(QueryError::RootOutOfRange { root, num_vertices: self.num_vertices });
        }
        let t0 = std::time::Instant::now();
        self.ensure_pool();
        let mut metrics = RunMetrics {
            graph_edges: self.graph_edges,
            ..Default::default()
        };
        let mut level = 0u32;
        // Direction-optimizing state (global statistics — the leader
        // computes these from per-node counts each level).
        let mut dir_state = DirOptState::new(self.graph_edges);
        if let Some(ck) = resume {
            // Seed every node to the state it would hold entering level
            // `ck.level`: distances and visited bits for everything
            // reached, the full-frontier bitmap and (owner-side) local
            // queue for the checkpointed frontier `{v : dist[v] == level}`.
            // Queue order differs from the original run's discovery order,
            // but every downstream quantity (dedup via `visited`, degree
            // sums, payload lengths) is order-independent.
            for n in &mut self.nodes {
                n.reset();
                for (v, &d) in ck.dist.iter().enumerate() {
                    if d == INF {
                        continue;
                    }
                    let vid = v as VertexId;
                    n.d_local[v] = d;
                    n.visited.set(vid);
                    if d == ck.level {
                        n.frontier_full.set(vid);
                        if n.owns(vid) {
                            n.q_local.push(vid);
                        }
                    }
                }
            }
            level = ck.level;
            metrics.levels = ck.levels.clone();
            dir_state = DirOptState {
                bottom_up: ck.bottom_up,
                prev_frontier: ck.prev_frontier,
                m_unexplored: ck.m_unexplored,
            };
        } else {
            for n in &mut self.nodes {
                n.init_root(root);
            }
        }
        let capture = self.capture_checkpoints();
        let mut level_ckpt: Option<Checkpoint> = None;
        loop {
            let frontier = self.frontier_len();
            if frontier == 0 {
                break;
            }
            if capture {
                level_ckpt = Some(Checkpoint {
                    level,
                    roots: vec![root],
                    batch: false,
                    dist: self.nodes[0].d_local.clone(),
                    bottom_up: dir_state.bottom_up,
                    prev_frontier: dir_state.prev_frontier,
                    m_unexplored: dir_state.m_unexplored,
                    levels: metrics.levels.clone(),
                    sync_rounds: 0,
                });
            }
            // ---- Direction choice (contribution 3: independent of sync) ----
            let bottom_up = dir_state.step(
                self.config.direction,
                frontier,
                self.num_vertices as u64,
                || {
                    self.nodes
                        .iter()
                        .flat_map(|n| {
                            n.q_local.iter().map(|&v| n.slab.degree_global(v) as u64)
                        })
                        .sum()
                },
            );
            // ---- Phase 1: traversal ----
            self.phase1(level, bottom_up);
            let edges: u64 = self.nodes.iter().map(|n| n.edges_this_level).sum();
            let max_node_edges =
                self.nodes.iter().map(|n| n.edges_this_level).max().unwrap_or(0);
            let sim_compute = self.config.device.level_time_dir(max_node_edges, bottom_up);
            // Deterministic kernel-work counters: every node's Phase-1
            // sweep/probe work, then the Phase-2 word-wise merge traffic.
            let mut level_work = KernelWork::default();
            for out in &self.scratch {
                level_work.absorb(&out.work);
            }

            // ---- Phase 2: frontier synchronization ----
            let payloads = self.phase2(level, &mut level_work);
            let recovery = match self.check_faults(level, &payloads) {
                Ok(r) => r,
                Err(fail) => return Err(self.fault_failure(fail, level_ckpt.take())),
            };
            let comm = simulate_topology(&self.schedule, &self.topology, |r, t| {
                payloads[r][t]
            });

            // After full coverage, every node's global queue holds the
            // complete deduped set of this level's discoveries.
            let discovered = self.nodes[0].q_global.len() as u64;
            metrics.push_level(
                level,
                frontier,
                edges,
                max_node_edges,
                discovered,
                &comm,
                sim_compute,
                bottom_up,
            );
            if let Some((fm, fb, em, eb)) = self.phase_split(&payloads) {
                let l = metrics.levels.last_mut().expect("level just pushed");
                l.fold_messages = fm;
                l.fold_bytes = fb;
                l.expand_messages = em;
                l.expand_bytes = eb;
            }
            {
                let l = metrics.levels.last_mut().expect("level just pushed");
                l.retries = recovery.retries;
                l.retry_bytes = recovery.retry_bytes;
                l.recovery_time = recovery.recovery_time;
                l.words_touched = level_work.words_touched;
                l.words_skipped = level_work.words_skipped;
                l.dispatches = level_work.dispatches;
                l.dispatch_max_work = level_work.dispatch_max_work;
            }

            // Update the DO bookkeeping before queues rotate.
            dir_state.claim_next(self.config.direction, || {
                self.nodes
                    .iter()
                    .flat_map(|n| {
                        n.q_local_next.iter().map(|&v| n.slab.degree_global(v) as u64)
                    })
                    .sum()
            });
            for n in &mut self.nodes {
                n.swap_queues();
            }
            level += 1;
        }
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        metrics.reached = self.nodes[0]
            .d_local
            .iter()
            .filter(|&&d| d != INF)
            .count() as u64;
        Ok(metrics)
    }

    /// Phase 1: expand every node's owned frontier (top-down) or scan its
    /// owned unvisited vertices against the full frontier (bottom-up).
    /// Discoveries are routed into global/local queues (Alg. 2's inner
    /// loop). With the pool present, the (node, backend, scratch) triples
    /// step on persistent workers — they are disjoint, so pooled results
    /// are bit-identical to sequential stepping.
    fn phase1(&mut self, level: u32, bottom_up: bool) {
        let pool = if self.config.parallel_phase1 { self.pool.as_ref() } else { None };
        if let Some(pool) = pool {
            let count = self.nodes.len();
            let nodes = SendPtr(self.nodes.as_mut_ptr());
            let backends = SendPtr(self.backends.as_mut_ptr());
            let scratch = SendPtr(self.scratch.as_mut_ptr());
            pool.run_indexed(count, |i| {
                // SAFETY: `run_indexed` invokes each index exactly once
                // and blocks until every job finished, so each `&mut`
                // derived from index `i` aliases nothing and outlives no
                // borrow.
                let node = unsafe { &mut *nodes.at(i) };
                let backend = unsafe { &mut *backends.at(i) };
                let out = unsafe { &mut *scratch.at(i) };
                expand_node(node, backend.as_mut(), out, bottom_up);
            });
        } else {
            for ((node, backend), out) in self
                .nodes
                .iter_mut()
                .zip(self.backends.iter_mut())
                .zip(self.scratch.iter_mut())
            {
                expand_node(node, backend.as_mut(), out, bottom_up);
            }
        }
        // Route discoveries (cheap, sequential: O(discovered)).
        for (node, out) in self.nodes.iter_mut().zip(self.scratch.iter()) {
            node.edges_this_level = out.edges_examined;
            for &v in &out.discovered {
                // Backend already marked `visited`; record queues+distance.
                node.d_local[v as usize] = level + 1;
                node.q_global.push(v);
                node.q_global_bits.set(v);
                if node.owns(v) {
                    node.q_local_next.push(v);
                }
            }
        }
    }

    /// Phase 2: execute the synchronization schedule. Returns per-round
    /// per-transfer payload byte sizes for the interconnect simulator.
    ///
    /// With `parallel_phase2` set, each destination's merges run on its
    /// own worker: senders are frozen round-start snapshots, receivers are
    /// disjoint, and every receiver replays its transfers in schedule
    /// order — bit-identical to the sequential merge loop.
    fn phase2(&mut self, level: u32, work: &mut KernelWork) -> Vec<Vec<u64>> {
        // The schedule is plan-owned and immutable; clone the handle so
        // iterating rounds never borrows `self` (nodes mutate freely).
        let schedule = Arc::clone(&self.schedule);
        let encoding = self.config.payload;
        let nv = self.num_vertices;
        let words = nv.div_ceil(64);
        // Dense/sparse dispatch threshold (§Perf optimization 1): word-wise
        // bitmap merge costs O(V/64) per transfer; entry-wise costs
        // O(queue). Cross-over at queue ≈ V/16 entries (4 words of queue
        // per bitmap word, measured on the microbench).
        let dense_threshold = (nv / 16).max(64);
        let pooled =
            self.config.parallel_phase2 && self.pool.is_some() && self.nodes.len() > 1;
        let buckets = if pooled { Some(self.pooled_buckets()) } else { None };
        let mut payloads = Vec::with_capacity(schedule.rounds.len());
        // Hoisted scratch: moved out of the session for the duration of
        // the call (no field-borrow entanglement), moved back at the end.
        let mut scratch = std::mem::take(&mut self.merge_scratch);
        // `CopyFrontier` semantics: transfers in a round see round-start
        // state. Queues are frozen by snapshotting *lengths* (they only
        // grow); bitmaps by copying words into the flat scratch buffer.
        MergeScratch::will_grow(&mut scratch.alloc_events, &scratch.snap_len, self.nodes.len());
        // Pooled merging also freezes the sparse queue prefixes by copy
        // (a receiver appending to its queue may reallocate it under a
        // concurrent sender-side read; the sequential path is zero-copy).
        if pooled && scratch.sparse_snap.len() < self.nodes.len() {
            scratch.alloc_events += 1;
            scratch.sparse_snap.resize_with(self.nodes.len(), Vec::new);
        }
        for (ri, round) in schedule.rounds.iter().enumerate() {
            scratch.snap_len.clear();
            scratch.snap_len.extend(self.nodes.iter().map(|n| n.q_global.len()));
            let snap_len = &scratch.snap_len;
            let any_dense = snap_len.iter().any(|&l| l >= dense_threshold);
            if any_dense {
                MergeScratch::will_grow(
                    &mut scratch.alloc_events,
                    &scratch.bit_snap,
                    words * self.nodes.len(),
                );
                scratch.bit_snap.clear();
                for n in &self.nodes {
                    scratch.bit_snap.extend_from_slice(n.q_global_bits.words());
                }
            }
            let mut round_payloads = Vec::with_capacity(round.len());
            for t in round {
                let take = scratch.snap_len[t.src as usize];
                round_payloads.push(encoding.bytes(take as u64, nv));
                // Word-wise merge traffic: a dense transfer ORs the
                // sender's V-bit bitmap (⌈V/64⌉ words) into the receiver;
                // sparse transfers replay queue entries, not mask words.
                if take >= dense_threshold {
                    work.words_touched += words as u64;
                }
            }
            if let Some(buckets) = &buckets {
                for (k, n) in self.nodes.iter().enumerate() {
                    let take = scratch.snap_len[k];
                    let sp = &mut scratch.sparse_snap[k];
                    sp.clear();
                    if take < dense_threshold {
                        MergeScratch::will_grow(&mut scratch.alloc_events, sp, take);
                        let sp = &mut scratch.sparse_snap[k];
                        sp.extend_from_slice(&n.q_global[..take]);
                    }
                }
                let (snap_ref, bits_ref, sparse_ref) =
                    (&scratch.snap_len, &scratch.bit_snap, &scratch.sparse_snap);
                let nodes = SendPtr(self.nodes.as_mut_ptr());
                let pool = self.pool.as_ref().expect("pooled implies pool");
                merge_round_pooled(pool, &buckets[ri], &nodes, |receiver, _dst, src| {
                    let take = snap_ref[src];
                    if take >= dense_threshold {
                        receiver.merge_bits(
                            &bits_ref[src * words..(src + 1) * words],
                            level,
                        );
                    } else {
                        for &v in &sparse_ref[src][..take] {
                            receiver.discover(v, level);
                        }
                    }
                });
            } else {
                for t in round {
                    let src = t.src as usize;
                    let dst = t.dst as usize;
                    let take = snap_len[src];
                    if take >= dense_threshold {
                        // Dense path: 64-way duplicate rejection.
                        let src_words = &scratch.bit_snap[src * words..(src + 1) * words];
                        self.nodes[dst].merge_bits(src_words, level);
                    } else {
                        // Sparse path: entry-wise merge of the frozen
                        // prefix.
                        let (sender, receiver) = if src < dst {
                            let (lo, hi) = self.nodes.split_at_mut(dst);
                            (&lo[src], &mut hi[0])
                        } else {
                            let (lo, hi) = self.nodes.split_at_mut(src);
                            (&hi[0] as &ComputeNode, &mut lo[dst])
                        };
                        for &v in &sender.q_global[..take] {
                            receiver.discover(v, level);
                        }
                    }
                }
            }
            payloads.push(round_payloads);
        }
        self.merge_scratch = scratch;
        payloads
    }

    /// Run a batched multi-source BFS: up to [`MAX_LANES`] (512) roots
    /// advance in lock-step, one exchange per level serving the whole
    /// batch (the MS-BFS bit-parallel formulation — see
    /// [`crate::bfs::msbfs`]). The lane mask is a const-generic
    /// [`LaneMask`] of `W ∈ {1, 2, 4, 8}` words: the engine monomorphizes
    /// the whole level loop over the smallest width that fits the batch
    /// (never below the configured
    /// [`BatchWidth`](super::config::BatchWidth) floor), so a 64-root
    /// batch keeps the classic 12-byte wire entries while a 256-root
    /// batch runs four words per mask — one exchange per level either
    /// way. The plan's schedule, partition, and slabs are reused as-is;
    /// payloads are priced by the width-aware negotiated mask-delta
    /// encoding ([`crate::bfs::msbfs::mask_delta_bytes`]) regardless of
    /// the configured single-root encoding, because the exchange
    /// genuinely ships `(vertex, lane-mask)` deltas.
    ///
    /// The returned [`BatchResult`] owns every lane's distances;
    /// [`Self::assert_batch_agreement`] checks the cross-node correctness
    /// invariant. Duplicate roots are allowed (independent lanes).
    pub fn run_batch(&mut self, roots: &[VertexId]) -> Result<BatchResult, QueryError> {
        let metrics = self.run_batch_inner(roots, None)?;
        Ok(BatchResult {
            roots: roots.to_vec(),
            num_vertices: self.num_vertices,
            dist: self
                .batch_lanes
                .node0_dist()
                .expect("batch just ran")
                .to_vec(),
            metrics,
        })
    }

    /// Resume a batched traversal from a level [`Checkpoint`] — the
    /// batched analog of [`Self::resume`]: every node's lane state is
    /// seeded from the checkpoint's lane-major distances and the batch
    /// replays from the checkpointed level.
    pub fn resume_batch(&mut self, ck: &Checkpoint) -> Result<BatchResult, QueryError> {
        if ck.lanes() == 0 {
            return Err(QueryError::CheckpointMismatch { what: "lanes", expected: 1, got: 0 });
        }
        if ck.dist.len() != ck.lanes() * self.num_vertices {
            return Err(QueryError::CheckpointMismatch {
                what: "vertices",
                expected: ck.lanes() * self.num_vertices,
                got: ck.dist.len(),
            });
        }
        let roots = ck.roots.clone();
        let metrics = self.run_batch_inner(&roots, Some(ck))?;
        Ok(BatchResult {
            roots,
            num_vertices: self.num_vertices,
            dist: self
                .batch_lanes
                .node0_dist()
                .expect("batch just ran")
                .to_vec(),
            metrics,
        })
    }

    /// Metrics-only variant of [`Self::run_batch`]: identical traversal,
    /// but skips materializing the owned `b·V` lane-major distance copy.
    pub fn run_batch_metrics_only(
        &mut self,
        roots: &[VertexId],
    ) -> Result<BatchMetrics, QueryError> {
        self.run_batch_inner(roots, None)
    }

    /// Validate the batch and dispatch to the monomorphized level loop:
    /// the lane word count is the smallest of `{1, 2, 4, 8}` covering
    /// `roots.len()`, floored by the configured
    /// [`BatchWidth`](super::config::BatchWidth) (so experiments can pin
    /// the wire format across batch sizes).
    fn run_batch_inner(
        &mut self,
        roots: &[VertexId],
        resume: Option<&Checkpoint>,
    ) -> Result<BatchMetrics, QueryError> {
        if roots.is_empty() {
            return Err(QueryError::EmptyBatch);
        }
        if roots.len() > MAX_LANES {
            return Err(QueryError::WidthTooLarge { got: roots.len(), max: MAX_LANES });
        }
        for &r in roots {
            if r as usize >= self.num_vertices {
                return Err(QueryError::RootOutOfRange {
                    root: r,
                    num_vertices: self.num_vertices,
                });
            }
        }
        let words = self.config.batch_width.words().max(words_for_lanes(roots.len()));
        match words {
            1 => self.run_batch_w::<1>(roots, resume),
            2 => self.run_batch_w::<2>(roots, resume),
            4 => self.run_batch_w::<4>(roots, resume),
            _ => self.run_batch_w::<8>(roots, resume),
        }
    }

    /// The batched level loop, monomorphized over the lane word count
    /// `W`. The typed lane states move out of the width-erased
    /// [`BatchLanes`] slot for the duration of the run (reset in place
    /// when the previous batch used the same width) and move back in at
    /// the end — pooled reuse without borrow entanglement.
    fn run_batch_w<const W: usize>(
        &mut self,
        roots: &[VertexId],
        resume: Option<&Checkpoint>,
    ) -> Result<BatchMetrics, QueryError>
    where
        MsBfsNodeState<W>: LaneSlot,
    {
        let t0 = std::time::Instant::now();
        let nv = self.num_vertices;
        let b = roots.len();
        self.batch_width = b;
        // Pooled lane state: reset in place (allocations kept) when the
        // session has run a batch at this width before.
        let mut states: Vec<MsBfsNodeState<W>> = LaneSlot::take(&mut self.batch_lanes);
        if states.len() == self.config.num_nodes {
            for st in &mut states {
                st.reset(b);
            }
        } else {
            states = (0..self.config.num_nodes)
                .map(|_| MsBfsNodeState::<W>::new(nv, b))
                .collect();
        }
        // Batch expansion scratch: sized once per session (kept across
        // batches), with per-batch in-place reset — the settled bitmap and
        // candidate buffers must not leak across batches.
        if self.batch_scratch.len() != self.config.num_nodes {
            self.merge_scratch.alloc_events += 1;
            self.batch_scratch =
                (0..self.config.num_nodes).map(|_| BatchExpandOutput::default()).collect();
        }
        for out in &mut self.batch_scratch {
            out.reset_for_batch();
        }
        // Direction policy: bottom-up needs a batched wide-lane kernel on
        // *every* node's backend — native or the semiring formulation
        // (`masks_next = Aᵀ ⊗ masks_frontier` over (OR, AND-NOT-seen), the
        // matmul-shaped fallback backends without lane-mask support
        // provide). Only when a backend has *neither* does the whole batch
        // degrade to top-down, keeping results correct and the metrics
        // honestly tagged.
        let direction = if self
            .backends
            .iter()
            .all(|bk| bk.supports_bottom_up_batch() || bk.supports_bottom_up_batch_semiring())
        {
            self.config.direction
        } else {
            DirectionMode::TopDown
        };
        let track_full = !matches!(direction, DirectionMode::TopDown);
        let full: LaneMask<W> = full_lane_mask(b);
        if let Some(ck) = resume {
            // Seed every node's lane state to what it would hold entering
            // level `ck.level` (the batched analog of the single-root
            // resume seeding): `seen` bits and distances for every reached
            // `(vertex, lane)` pair, and the frontier masks
            // `{(v, lane) : dist == level}` into the full-frontier array
            // (every node) and the owner's visit mask + local queue.
            for (node, st) in self.nodes.iter().zip(states.iter_mut()) {
                st.set_full_tracking(track_full);
                for v in 0..nv {
                    let mut fmask: LaneMask<W> = [0u64; W];
                    let mut any_frontier = false;
                    for lane in 0..b {
                        let d = ck.dist[lane * nv + v];
                        if d == INF {
                            continue;
                        }
                        st.seen[v * W + lane / 64] |= 1u64 << (lane % 64);
                        st.dist[lane * nv + v] = d;
                        if d == ck.level {
                            fmask[lane / 64] |= 1u64 << (lane % 64);
                            any_frontier = true;
                        }
                    }
                    if any_frontier {
                        let vid = v as VertexId;
                        if track_full {
                            st.seed_full_frontier(vid, &fmask);
                        }
                        if node.owns(vid) {
                            st.q_local.push(vid);
                            for w in 0..W {
                                st.visit[v * W + w] |= fmask[w];
                            }
                        }
                    }
                }
            }
        } else {
            // Alg. 2 prologue, batched: every node marks every root's lane
            // ("All CN set their d"); only the owner enqueues it locally.
            // With a bottom-up-capable direction, every node also seeds the
            // level-0 full frontier (every node knows every root).
            for (node, st) in self.nodes.iter().zip(states.iter_mut()) {
                st.set_full_tracking(track_full);
                for (lane, &r) in roots.iter().enumerate() {
                    let bit: LaneMask<W> = lane_bit(lane);
                    let base = r as usize * W;
                    st.seen[base + lane / 64] |= 1u64 << (lane % 64);
                    st.dist[lane * nv + r as usize] = 0;
                    if track_full {
                        st.seed_full_frontier(r, &bit);
                    }
                    if node.owns(r) {
                        if st.visit[base..base + W].iter().all(|&x| x == 0) {
                            st.q_local.push(r);
                        }
                        st.visit[base + lane / 64] |= 1u64 << (lane % 64);
                    }
                }
            }
        }
        let mut metrics = BatchMetrics {
            num_roots: b,
            lane_words: W,
            graph_edges: self.graph_edges,
            ..Default::default()
        };
        self.ensure_pool();
        let mut level = 0u32;
        if let Some(ck) = resume {
            level = ck.level;
            metrics.levels = ck.levels.clone();
            metrics.sync_rounds = ck.sync_rounds;
        }
        let capture = self.capture_checkpoints();
        let mut level_ckpt: Option<Checkpoint> = None;
        // Direction-optimizing state — the same growing/shrinking machine
        // the single-root `run` drives (shared `DirOptState`), on
        // *union-frontier* statistics: a vertex active in many lanes still
        // costs one adjacency read, so the edge masses are over distinct
        // frontier vertices (in 2D, row-mates' block degrees sum to each
        // vertex's full degree).
        let mut dir_state = DirOptState::new(self.graph_edges);
        if let Some(ck) = resume {
            dir_state = DirOptState {
                bottom_up: ck.bottom_up,
                prev_frontier: ck.prev_frontier,
                m_unexplored: ck.m_unexplored,
            };
        }
        loop {
            let frontier = self.batch_frontier_len(&states);
            if frontier == 0 {
                break;
            }
            if capture {
                level_ckpt = Some(Checkpoint {
                    level,
                    roots: roots.to_vec(),
                    batch: true,
                    dist: states[0].dist.clone(),
                    bottom_up: dir_state.bottom_up,
                    prev_frontier: dir_state.prev_frontier,
                    m_unexplored: dir_state.m_unexplored,
                    levels: metrics.levels.clone(),
                    sync_rounds: metrics.sync_rounds,
                });
            }
            // ---- Direction choice (independent of the sync pattern) ----
            let bottom_up = dir_state.step(
                direction,
                frontier,
                self.num_vertices as u64,
                || {
                    self.nodes
                        .iter()
                        .zip(states.iter())
                        .flat_map(|(n, s)| {
                            s.q_local.iter().map(|&v| n.slab.degree_global(v) as u64)
                        })
                        .sum()
                },
            );
            // ---- Phase 1 dispatch: top-down expands the owned masked
            // frontier (one adjacency read serves every active lane of the
            // vertex); bottom-up scans owned not-fully-seen vertices
            // against the full frontier masks through the backend kernel.
            // Either way the per-node state is disjoint, so the pool can
            // step nodes bulk-synchronously with bit-identical results.
            if bottom_up {
                self.batch_phase1_bottom_up(&mut states, level, &full);
            } else if let Some(pool) =
                (if self.config.parallel_phase1 { self.pool.as_ref() } else { None })
            {
                let nodes = &self.nodes;
                let count = states.len();
                let states_ptr = SendPtr(states.as_mut_ptr());
                pool.run_indexed(count, |i| {
                    // SAFETY: `run_indexed` invokes each index exactly
                    // once and blocks until every job finished, so the
                    // `&mut` derived from index `i` aliases nothing and
                    // outlives no borrow.
                    let st = unsafe { &mut *states_ptr.at(i) };
                    batch_expand_node(&nodes[i], st, level);
                });
            } else {
                for (node, st) in self.nodes.iter().zip(states.iter_mut()) {
                    batch_expand_node(node, st, level);
                }
            }
            let edges: u64 = states.iter().map(|s| s.edges_this_level).sum();
            let max_node_edges = states.iter().map(|s| s.edges_this_level).max().unwrap_or(0);
            let sim_compute = self.config.device.level_time_dir(max_node_edges, bottom_up);

            // ---- Kernel work accounting for this level's Phase 1.
            // Bottom-up: the backends tallied word traffic into the batch
            // scratch. Top-down: each nonempty node reads W mask words per
            // frontier vertex and issues one dispatch covering its
            // adjacency work (LRB does not reorder the top-down walk).
            let mut level_work = KernelWork::default();
            if bottom_up {
                for out in &self.batch_scratch {
                    level_work.absorb(&out.work);
                }
            } else {
                for st in states.iter() {
                    if !st.q_local.is_empty() {
                        level_work.words_touched += (W * st.q_local.len()) as u64;
                        level_work.record_dispatch(st.edges_this_level);
                    }
                }
            }

            // ---- Phase 2: one exchange for the whole batch.
            let payloads = self.batch_phase2(&mut states, level, bottom_up, &mut level_work);
            let recovery = match self.check_faults(level, &payloads) {
                Ok(r) => r,
                Err(fail) => {
                    LaneSlot::put(&mut self.batch_lanes, states);
                    return Err(self.fault_failure(fail, level_ckpt.take()));
                }
            };
            let comm = simulate_topology(&self.schedule, &self.topology, |r, t| {
                payloads[r][t]
            });

            // After full coverage every node's delta list holds the
            // complete set of this level's (vertex, lane) discoveries.
            let discovered: u64 = states[0]
                .delta
                .entries()
                .iter()
                .map(|&(_, m)| lane_mask_count(&m) as u64)
                .sum();
            let (fm, fb, em, eb) = self.phase_split(&payloads).unwrap_or_default();
            metrics.levels.push(LevelMetrics {
                level,
                frontier,
                edges_examined: edges,
                max_node_edges,
                discovered,
                messages: comm.total_messages,
                bytes: comm.total_bytes,
                fold_messages: fm,
                fold_bytes: fb,
                expand_messages: em,
                expand_bytes: eb,
                intra_messages: comm.intra_messages,
                intra_bytes: comm.intra_bytes,
                inter_messages: comm.inter_messages,
                inter_bytes: comm.inter_bytes,
                sim_compute,
                sim_comm: comm.total(),
                bottom_up,
                retries: recovery.retries,
                retry_bytes: recovery.retry_bytes,
                recovery_time: recovery.recovery_time,
                words_touched: level_work.words_touched,
                words_skipped: level_work.words_skipped,
                dispatches: level_work.dispatches,
                dispatch_max_work: level_work.dispatch_max_work,
            });
            metrics.sync_rounds += self.schedule.depth() as u64;

            // Direction bookkeeping before queues rotate: claim the next
            // frontier's edge mass out of the unexplored pool.
            dir_state.claim_next(direction, || {
                self.nodes
                    .iter()
                    .zip(states.iter())
                    .flat_map(|(n, s)| {
                        s.q_local_next.iter().map(|&v| n.slab.degree_global(v) as u64)
                    })
                    .sum()
            });
            for st in &mut states {
                st.swap_level();
            }
            level += 1;
        }
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        metrics.reached_pairs = states[0].dist.iter().filter(|&&d| d != INF).count() as u64;
        LaneSlot::put(&mut self.batch_lanes, states);
        Ok(metrics)
    }

    /// Phase 1 of a batched *bottom-up* level: every node's backend scans
    /// its owned not-fully-seen vertices against the complete previous-
    /// level frontier masks (`visit_full`, flat `W`-word-per-vertex, held
    /// by every node after the exchange), then the session routes the
    /// `(vertex, new-lanes)` discoveries through
    /// [`MsBfsNodeState::discover`] in node/scan order — the same
    /// deterministic order pooled and sequential stepping produce, so the
    /// two are bit-identical.
    fn batch_phase1_bottom_up<const W: usize>(
        &mut self,
        states: &mut [MsBfsNodeState<W>],
        level: u32,
        full: &LaneMask<W>,
    ) {
        if self.batch_scratch.len() != self.nodes.len() {
            self.batch_scratch =
                (0..self.nodes.len()).map(|_| BatchExpandOutput::default()).collect();
        }
        let pool = if self.config.parallel_phase1 { self.pool.as_ref() } else { None };
        if let Some(pool) = pool {
            let nodes = &self.nodes;
            let states_ref: &[MsBfsNodeState<W>] = states;
            let count = self.nodes.len();
            let backends = SendPtr(self.backends.as_mut_ptr());
            let scratch = SendPtr(self.batch_scratch.as_mut_ptr());
            pool.run_indexed(count, |i| {
                // SAFETY: `run_indexed` invokes each index exactly once and
                // blocks until every job finished, so each `&mut` derived
                // from index `i` aliases nothing and outlives no borrow.
                let backend = unsafe { &mut *backends.at(i) };
                let out = unsafe { &mut *scratch.at(i) };
                if backend.supports_bottom_up_batch() {
                    backend.expand_bottom_up_batch(
                        &nodes[i].slab,
                        states_ref[i].full_frontier(),
                        &states_ref[i].seen,
                        full,
                        out,
                    );
                } else {
                    backend.expand_bottom_up_batch_semiring(
                        &nodes[i].slab,
                        states_ref[i].full_frontier(),
                        &states_ref[i].seen,
                        full,
                        out,
                    );
                }
            });
        } else {
            for ((node, st), (backend, out)) in self
                .nodes
                .iter()
                .zip(states.iter())
                .zip(self.backends.iter_mut().zip(self.batch_scratch.iter_mut()))
            {
                if backend.supports_bottom_up_batch() {
                    backend.expand_bottom_up_batch(
                        &node.slab,
                        st.full_frontier(),
                        &st.seen,
                        full,
                        out,
                    );
                } else {
                    backend.expand_bottom_up_batch_semiring(
                        &node.slab,
                        st.full_frontier(),
                        &st.seen,
                        full,
                        out,
                    );
                }
            }
        }
        // Route discoveries (cheap, sequential: O(discovered·W)). Bottom-
        // up discoveries are always owned vertices of the scanning node.
        for (st, out) in states.iter_mut().zip(self.batch_scratch.iter()) {
            st.edges_this_level = out.edges_examined;
            for (i, &v) in out.discovered.iter().enumerate() {
                let d: &LaneMask<W> =
                    out.masks[i * W..(i + 1) * W].try_into().expect("W mask words");
                st.discover(v, d, level, true);
            }
        }
    }

    /// Phase 2 of a batched level: execute the synchronization schedule on
    /// the nodes' `(vertex, mask)` delta lists with `CopyFrontier`
    /// semantics (transfers in a round see round-start state, frozen by
    /// snapshotting list lengths — they only grow). Returns per-round
    /// per-transfer payload byte sizes for the interconnect simulator.
    ///
    /// Mirrors [`Self::phase2`]'s dense/sparse dispatch: once a sender's
    /// frozen prefix passes the `8·W·V`-byte accounting switchover (where
    /// the negotiated encoding caps the sparse `(4 + 8W)·entries` at the
    /// dense per-vertex `W`-word mask array — for `W = 1`, exactly
    /// [`PayloadEncoding::MaskDelta`](super::config::PayloadEncoding)'s
    /// `⌈8V/12⌉` crossover), the merge follows the wire format — a
    /// word-wise OR over the snapshotted masks — instead of replaying
    /// entries one by one.
    ///
    /// Bottom-up levels ship the dense presence-bitmap wire format (the
    /// scan produces discoveries as a dense sweep, not a sorted queue):
    /// every nonempty sender is *priced* by the per-lane-bitmap/presence
    /// arms of the negotiated encoding
    /// ([`MsBfsNodeState::delta_payload_bytes_dense`]). The merge
    /// dispatch stays on the entry-count threshold regardless of
    /// direction — replaying sparse entries is idempotent and
    /// bit-identical to the word-wise OR, so a sparse bottom-up level
    /// (deep-graph tail under `DirectionMode::BottomUp`) merges in
    /// O(entries) instead of O(V) per transfer.
    ///
    /// Under the chunked [`KernelVariant`](super::KernelVariant) the dense
    /// merge additionally carries a per-sender V-bit *occupancy bitmap*
    /// (maintained incrementally alongside the mask snapshot), and
    /// receivers walk occupied vertices in ascending order instead of
    /// scanning all `V` mask slots — bit-identical discoveries, strictly
    /// fewer words read whenever the snapshot has empty slots. Word
    /// traffic is tallied into `work` per transfer (outside the merge
    /// closures, so pooled and sequential runs report identically).
    fn batch_phase2<const W: usize>(
        &mut self,
        states: &mut [MsBfsNodeState<W>],
        level: u32,
        bottom_up: bool,
        work: &mut KernelWork,
    ) -> Vec<Vec<u64>> {
        let schedule = Arc::clone(&self.schedule);
        let nv = self.num_vertices;
        let chunked = self.config.kernel.is_chunked();
        let occ_words = nv.div_ceil(64);
        // Entries at which `(4 + 8W)·entries >= 8·W·V`: the dense mask
        // array is now the (no larger) negotiated form, so merge it
        // word-wise. For W = 1 this is the classic `⌈8V/12⌉` switchover.
        let dense_threshold = ((nv as u64 * 8 * W as u64)
            .div_ceil(MaskFrontier::<W>::ENTRY_BYTES) as usize)
            .max(1);
        let pooled = self.config.parallel_phase2 && self.pool.is_some() && states.len() > 1;
        let buckets = if pooled { Some(self.pooled_buckets()) } else { None };
        let mut payloads = Vec::with_capacity(schedule.rounds.len());
        // Hoisted scratch: moved out of the session for the duration of
        // the call, moved back at the end. The width-monomorphized sparse
        // entry snapshots live in width-erased parallel arrays
        // (`sparse_snap_v` vertices + `sparse_snap_m` flat `W`-word
        // masks) so one set of buffers serves every lane width.
        let mut scratch = std::mem::take(&mut self.merge_scratch);
        // Round-start dense snapshots (one V·W-word lane-mask array per
        // dense sender), flat like `phase2`'s `bit_snap` — but built
        // *incrementally*: deltas only grow within a level and the merge
        // is an idempotent OR, so each round folds in only the entries
        // appended since the previous round (`mask_done` tracks the
        // per-node accumulated prefix) instead of replaying from zero.
        // The dense snapshot is lazily zeroed once per call; under the
        // chunked kernel each sender also maintains a V-bit occupancy
        // bitmap (`mask_occ`) so receivers walk only occupied vertices.
        let mut mask_ready = false;
        MergeScratch::will_grow(&mut scratch.alloc_events, &scratch.mask_done, states.len());
        scratch.mask_done.clear();
        scratch.mask_done.resize(states.len(), 0);
        // Pooled merging freezes the sparse sender prefixes by copy: a
        // node can be sender and receiver in the same round, and a
        // receiver appending to its delta list may reallocate it under a
        // concurrent reader. (The sequential path reads senders zero-copy.)
        if pooled && scratch.sparse_snap_v.len() < states.len() {
            scratch.alloc_events += 1;
            scratch.sparse_snap_v.resize_with(states.len(), Vec::new);
            scratch.sparse_snap_m.resize_with(states.len(), Vec::new);
        }
        for (ri, round) in schedule.rounds.iter().enumerate() {
            // Snapshot (prefix length, priced bytes) together: the
            // coalescing statistics are monotone within the level, so
            // pricing at snapshot time is exact for the frozen prefix.
            MergeScratch::will_grow(&mut scratch.alloc_events, &scratch.snap, states.len());
            scratch.snap.clear();
            scratch.snap.extend(states.iter().map(|s| {
                let len = s.delta.len();
                let priced = if bottom_up {
                    s.delta_payload_bytes_dense(len)
                } else {
                    s.delta_payload_bytes(len)
                };
                (len, priced)
            }));
            let any_dense = scratch.snap.iter().any(|&(l, _)| l >= dense_threshold);
            if any_dense {
                if !mask_ready {
                    MergeScratch::will_grow(
                        &mut scratch.alloc_events,
                        &scratch.mask_snap,
                        nv * W * states.len(),
                    );
                    scratch.mask_snap.clear();
                    scratch.mask_snap.resize(nv * W * states.len(), 0);
                    if chunked {
                        MergeScratch::will_grow(
                            &mut scratch.alloc_events,
                            &scratch.mask_occ,
                            occ_words * states.len(),
                        );
                        scratch.mask_occ.clear();
                        scratch.mask_occ.resize(occ_words * states.len(), 0);
                    }
                    mask_ready = true;
                }
                for (k, s) in states.iter().enumerate() {
                    let take_k = scratch.snap[k].0;
                    if take_k >= dense_threshold {
                        if chunked {
                            s.delta.accumulate_range_occ(
                                scratch.mask_done[k],
                                take_k,
                                &mut scratch.mask_snap[k * nv * W..(k + 1) * nv * W],
                                &mut scratch.mask_occ
                                    [k * occ_words..(k + 1) * occ_words],
                            );
                        } else {
                            s.delta.accumulate_range(
                                scratch.mask_done[k],
                                take_k,
                                &mut scratch.mask_snap[k * nv * W..(k + 1) * nv * W],
                            );
                        }
                        scratch.mask_done[k] = take_k;
                    }
                }
            }
            // Per-transfer payload pricing and merge-side word-traffic
            // accounting (computed here, outside the merge closures, so
            // pooled and sequential merging tally identically): a scalar
            // dense merge reads all `W·V` snapshot words; a chunked dense
            // merge reads the `⌈V/64⌉`-word occupancy bitmap plus `W`
            // words per occupied vertex, skipping the rest; a sparse
            // merge reads `W` words per replayed entry.
            let mut round_payloads = Vec::with_capacity(round.len());
            for t in round {
                let (take, priced) = scratch.snap[t.src as usize];
                round_payloads.push(priced);
                if take >= dense_threshold {
                    if chunked {
                        let src = t.src as usize;
                        let occ =
                            &scratch.mask_occ[src * occ_words..(src + 1) * occ_words];
                        let occupied: u64 =
                            occ.iter().map(|w| w.count_ones() as u64).sum();
                        work.words_touched += occ_words as u64 + W as u64 * occupied;
                        work.words_skipped += W as u64 * (nv as u64 - occupied);
                    } else {
                        work.words_touched += (W * nv) as u64;
                    }
                } else {
                    work.words_touched += (W * take) as u64;
                }
            }
            if let Some(buckets) = &buckets {
                for (k, s) in states.iter().enumerate() {
                    let take_k = scratch.snap[k].0;
                    scratch.sparse_snap_v[k].clear();
                    scratch.sparse_snap_m[k].clear();
                    if take_k < dense_threshold {
                        MergeScratch::will_grow(
                            &mut scratch.alloc_events,
                            &scratch.sparse_snap_v[k],
                            take_k,
                        );
                        MergeScratch::will_grow(
                            &mut scratch.alloc_events,
                            &scratch.sparse_snap_m[k],
                            take_k * W,
                        );
                        for &(v, ref m) in &s.delta.entries()[..take_k] {
                            scratch.sparse_snap_v[k].push(v);
                            scratch.sparse_snap_m[k].extend_from_slice(m);
                        }
                    }
                }
                let nodes = &self.nodes;
                let (snap_ref, mask_ref, occ_ref) =
                    (&scratch.snap, &scratch.mask_snap, &scratch.mask_occ);
                let (sparse_v_ref, sparse_m_ref) =
                    (&scratch.sparse_snap_v, &scratch.sparse_snap_m);
                let states_ptr = SendPtr(states.as_mut_ptr());
                let pool = self.pool.as_ref().expect("pooled implies pool");
                merge_round_pooled(pool, &buckets[ri], &states_ptr, |receiver, dst, src| {
                    let take = snap_ref[src].0;
                    let dst_node = &nodes[dst];
                    if take >= dense_threshold {
                        let masks = &mask_ref[src * nv * W..(src + 1) * nv * W];
                        if chunked {
                            let occ = &occ_ref[src * occ_words..(src + 1) * occ_words];
                            merge_dense_chunked(receiver, dst_node, masks, occ, nv, level);
                        } else {
                            merge_dense_scalar(receiver, dst_node, masks, nv, level);
                        }
                    } else {
                        let sm = &sparse_m_ref[src];
                        for (i, &v) in sparse_v_ref[src][..take].iter().enumerate() {
                            let m: &LaneMask<W> =
                                sm[i * W..(i + 1) * W].try_into().expect("W words");
                            receiver.discover(v, m, level, dst_node.owns(v));
                        }
                    }
                });
            } else {
                for t in round {
                    let src = t.src as usize;
                    let dst = t.dst as usize;
                    let take = scratch.snap[src].0;
                    let dst_node = &self.nodes[dst];
                    if take >= dense_threshold {
                        // Dense path: the frozen prefix as per-vertex masks.
                        let masks = &scratch.mask_snap[src * nv * W..(src + 1) * nv * W];
                        let receiver = &mut states[dst];
                        if chunked {
                            let occ =
                                &scratch.mask_occ[src * occ_words..(src + 1) * occ_words];
                            merge_dense_chunked(receiver, dst_node, masks, occ, nv, level);
                        } else {
                            merge_dense_scalar(receiver, dst_node, masks, nv, level);
                        }
                    } else {
                        // Sparse path: entry-wise replay of the frozen
                        // prefix.
                        let (sender, receiver) = if src < dst {
                            let (lo, hi) = states.split_at_mut(dst);
                            (&lo[src], &mut hi[0])
                        } else {
                            let (lo, hi) = states.split_at_mut(src);
                            (&hi[0] as &MsBfsNodeState<W>, &mut lo[dst])
                        };
                        for &(v, ref m) in &sender.delta.entries()[..take] {
                            receiver.discover(v, m, level, dst_node.owns(v));
                        }
                    }
                }
            }
            payloads.push(round_payloads);
        }
        self.merge_scratch = scratch;
        payloads
    }

    /// Run each root one at a time through [`Self::run`] and accumulate
    /// the synchronization totals — the baseline [`Self::run_batch`] is
    /// compared against (used by the CLI `batch --compare`, the
    /// `msbfs_amortization` bench, the amortization tests, and the
    /// closeness-centrality example). Fails fast on the first invalid
    /// root; totals from roots already run are discarded.
    pub fn sequential_baseline(
        &mut self,
        roots: &[VertexId],
    ) -> Result<SequentialBaseline, QueryError> {
        let sched_depth = self.schedule.depth() as u64;
        let mut b = SequentialBaseline::default();
        for &r in roots {
            let m = self.run_metrics_only(r)?;
            b.bytes += m.bytes();
            b.messages += m.messages();
            b.sync_rounds += m.depth() as u64 * sched_depth;
            b.sim_seconds += m.sim_seconds();
        }
        Ok(b)
    }

    /// Node 0's *live* distance array — legacy shim support: the old
    /// engine exposed this view via `dist()` (INF-filled before the
    /// first run, reflecting whatever query ran last).
    pub(crate) fn node0_dist(&self) -> &[u32] {
        &self.nodes[0].d_local
    }

    /// Node 0's live lane-major batch distances — legacy shim support
    /// with the old engine's panic messages.
    pub(crate) fn node0_batch_dist(&self, lane: usize) -> &[u32] {
        let dist = self
            .batch_lanes
            .node0_dist()
            .expect("run_batch has not been called");
        assert!(lane < self.batch_width, "lane {lane} out of range");
        let nv = self.num_vertices;
        &dist[lane * nv..(lane + 1) * nv]
    }

    /// Lane count of the most recent batch (legacy shim support).
    pub(crate) fn batch_width(&self) -> usize {
        self.batch_width
    }

    /// Check that every node ended the last single-root query with an
    /// identical distance array — the correctness invariant of the
    /// synchronization pattern.
    pub fn assert_agreement(&self) -> Result<(), String> {
        let d0 = &self.nodes[0].d_local;
        for n in &self.nodes[1..] {
            if &n.d_local != d0 {
                let bad = d0
                    .iter()
                    .zip(&n.d_local)
                    .position(|(a, b)| a != b)
                    .unwrap();
                return Err(format!(
                    "node {} disagrees with node 0 at vertex {bad}: {} vs {}",
                    n.id, n.d_local[bad], d0[bad]
                ));
            }
        }
        Ok(())
    }

    /// Check that every node ended the last batch with identical per-lane
    /// distance arrays — the batched analog of [`Self::assert_agreement`].
    pub fn assert_batch_agreement(&self) -> Result<(), String> {
        let nv = self.num_vertices;
        for_lanes!(&self.batch_lanes, s => {
            let Some(first) = s.first() else {
                return Err("run_batch has not been called".to_string());
            };
            for (i, st) in s.iter().enumerate().skip(1) {
                if st.dist != first.dist {
                    let bad = first
                        .dist
                        .iter()
                        .zip(&st.dist)
                        .position(|(a, c)| a != c)
                        .unwrap();
                    return Err(format!(
                        "node {i} disagrees with node 0 at lane {} vertex {}: {} vs {}",
                        bad / nv,
                        bad % nv,
                        st.dist[bad],
                        first.dist[bad]
                    ));
                }
            }
            Ok(())
        })
    }
}

/// Execute one synchronization round's pooled merges: one worker per
/// destination in `bucket`, each replaying its transfers in schedule
/// order via `merge(receiver, dst, src)` — so every receiver sees exactly
/// the subsequence of merges the sequential loop would apply to it, and
/// pooled merging is bit-identical by construction. Shared by the
/// single-root and batched Phase 2 so the snapshot/aliasing discipline
/// lives in one place.
///
/// Contract: sender data must already be frozen (round-start snapshots —
/// a node can be sender and receiver in the same round), and `receivers`
/// must point at live elements nothing else touches during the call;
/// destinations are distinct across bucket entries, so each element gets
/// at most one `&mut`.
/// Scalar dense-merge kernel: scan every vertex's `W`-word snapshot mask
/// and discover the non-empty ones. `O(W·V)` words read per transfer.
fn merge_dense_scalar<const W: usize>(
    receiver: &mut MsBfsNodeState<W>,
    dst_node: &ComputeNode,
    masks: &[u64],
    nv: usize,
    level: u32,
) {
    for v in 0..nv {
        let m: &LaneMask<W> = masks[v * W..(v + 1) * W].try_into().expect("W words");
        if !lane_mask_is_zero(m) {
            receiver.discover(v as VertexId, m, level, dst_node.owns(v as VertexId));
        }
    }
}

/// Chunked dense-merge kernel: walk the sender's occupancy bitmap and
/// visit only occupied vertices (ascending — bit-identical discovery
/// order to the scalar scan, which skips empty masks anyway).
/// `O(⌈V/64⌉ + W·occupied)` words read per transfer.
fn merge_dense_chunked<const W: usize>(
    receiver: &mut MsBfsNodeState<W>,
    dst_node: &ComputeNode,
    masks: &[u64],
    occ: &[u64],
    nv: usize,
    level: u32,
) {
    for (wi, &word) in occ.iter().enumerate() {
        let mut bits = word;
        while bits != 0 {
            let v = wi * 64 + bits.trailing_zeros() as usize;
            bits &= bits - 1;
            if v >= nv {
                break;
            }
            let m: &LaneMask<W> = masks[v * W..(v + 1) * W].try_into().expect("W words");
            if !lane_mask_is_zero(m) {
                receiver.discover(v as VertexId, m, level, dst_node.owns(v as VertexId));
            }
        }
    }
}

fn merge_round_pooled<R, F>(
    pool: &ThreadPool,
    bucket: &[(usize, Vec<usize>)],
    receivers: &SendPtr<R>,
    merge: F,
) where
    F: Fn(&mut R, usize, usize) + Sync + Send,
{
    pool.run_indexed(bucket.len(), |k| {
        let (dst, srcs) = &bucket[k];
        // SAFETY: destinations are distinct across bucket entries and
        // `run_indexed` blocks until every job finished, so this `&mut`
        // aliases nothing (see the contract above).
        let receiver = unsafe { &mut *receivers.at(*dst) };
        for &src in srcs {
            merge(receiver, *dst, src);
        }
    });
}

/// Raw-pointer transport for handing the pool disjoint `&mut` slots of
/// parallel vectors (each `run_indexed` index touches exactly one element
/// of each).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Pointer to slot `i`. A method (not a field access) so that
    /// edition-2021 precise closure capture grabs the `Sync` wrapper
    /// itself rather than its raw-pointer field (which is neither `Send`
    /// nor `Sync`, and would poison the pool closure).
    fn at(&self, i: usize) -> *mut T {
        // SAFETY of the arithmetic: callers index within the vector the
        // pointer was taken from (`i < count`).
        unsafe { self.0.add(i) }
    }
}

/// One node's Phase-1 step of a batched level — shared by the pooled and
/// sequential paths, so the two are bit-identical by construction. One
/// adjacency read serves every active lane of the vertex regardless of
/// the lane width `W`.
fn batch_expand_node<const W: usize>(
    node: &ComputeNode,
    st: &mut MsBfsNodeState<W>,
    level: u32,
) {
    let q = std::mem::take(&mut st.q_local);
    for &v in &q {
        let base = v as usize * W;
        let mut mv = [0u64; W];
        for w in 0..W {
            mv[w] = st.visit[base + w];
            st.visit[base + w] = 0;
        }
        debug_assert!(!lane_mask_is_zero(&mv), "frontier vertex {v} with empty mask");
        st.edges_this_level += node.slab.degree_global(v) as u64;
        for &u in node.slab.neighbors_global(v) {
            st.discover(u, &mv, level, node.owns(u));
        }
    }
    st.q_local = q; // keep the allocation; cleared at swap
}

/// One node's Phase-1 step of a single-root level — shared by the pooled
/// and sequential paths, so the two are bit-identical by construction.
fn expand_node(
    node: &mut ComputeNode,
    backend: &mut dyn ComputeBackend,
    out: &mut ExpandOutput,
    bottom_up: bool,
) {
    if bottom_up {
        // The full-frontier bitmap is moved out so the backend can borrow
        // it alongside the mutable visited bitmap.
        let frontier_full = std::mem::replace(
            &mut node.frontier_full,
            crate::bfs::frontier::Bitmap::new(0),
        );
        backend.expand_bottom_up(&node.slab, &frontier_full, &mut node.visited, out);
        node.frontier_full = frontier_full;
    } else {
        // The frontier is moved out so backend gets plain slices.
        let frontier = std::mem::take(&mut node.q_local);
        backend.expand(&node.slab, &frontier, &mut node.visited, out);
        node.q_local = frontier; // restored for metrics/debug; cleared at swap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::coordinator::config::{PatternKind, PayloadEncoding};
    use crate::graph::csr::Csr;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    fn session_for(g: &Csr, cfg: EngineConfig) -> QuerySession {
        TraversalPlan::build(g, cfg).expect("valid plan").session()
    }

    fn check_against_serial(g: &Csr, cfg: EngineConfig, root: VertexId) {
        let mut session = session_for(g, cfg);
        let r = session.run(root).unwrap();
        session.assert_agreement().unwrap();
        let want = serial_bfs(g, root);
        assert_eq!(r.dist(), &want[..], "distances match serial");
        let reached = want.iter().filter(|&&d| d != INF).count() as u64;
        assert_eq!(r.reached(), reached);
        assert_eq!(r.root(), root);
    }

    /// The integer (deterministic) slice of one level's metrics.
    fn level_key(l: &LevelMetrics) -> (u64, u64, u64, u64, u64, u64, u64) {
        (
            l.frontier,
            l.edges_examined,
            l.max_node_edges,
            l.discovered,
            l.messages,
            l.bytes,
            l.fold_bytes + l.expand_bytes,
        )
    }

    #[test]
    fn matches_serial_16_nodes_fanout1_and_4() {
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 31);
        for fanout in [1, 4] {
            check_against_serial(&g, EngineConfig::dgx2(16, fanout), 0);
        }
    }

    #[test]
    fn matches_serial_all_patterns() {
        let (g, _) = uniform_random(900, 8, 77);
        for pattern in [
            PatternKind::Butterfly { fanout: 1 },
            PatternKind::Butterfly { fanout: 2 },
            PatternKind::Butterfly { fanout: 4 },
            PatternKind::AllToAllConcurrent,
            PatternKind::AllToAllIterative,
        ] {
            let cfg = EngineConfig {
                pattern,
                ..EngineConfig::dgx2(8, 1)
            };
            check_against_serial(&g, cfg, 13);
        }
    }

    #[test]
    fn matches_serial_non_power_of_two_nodes() {
        let (g, _) = uniform_random(1100, 8, 5);
        for nodes in [3, 5, 9, 13] {
            check_against_serial(&g, EngineConfig::dgx2(nodes, 1), 1);
            check_against_serial(&g, EngineConfig::dgx2(nodes, 4), 1);
        }
    }

    #[test]
    fn structured_graphs_all_roots() {
        let graphs = vec![path(40), star(50), grid2d(6, 8)];
        for g in &graphs {
            for root in [0u32, (g.num_vertices() - 1) as u32] {
                check_against_serial(g, EngineConfig::dgx2(4, 1), root);
            }
        }
    }

    #[test]
    fn disconnected_graph_unreached_stay_inf() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(40);
        for v in 1..20u32 {
            b.add_edge(0, v);
        }
        b.add_edge(30, 31); // island
        let (g, _) = b.build_undirected();
        let mut session = session_for(&g, EngineConfig::dgx2(4, 2));
        let r = session.run(0).unwrap();
        assert_eq!(r.reached(), 20);
        assert_eq!(r.dist()[30], INF);
        session.assert_agreement().unwrap();
    }

    #[test]
    fn single_node_degenerates_to_local_bfs() {
        let (g, _) = uniform_random(400, 8, 3);
        let mut session = session_for(&g, EngineConfig::dgx2(1, 1));
        let r = session.run(0).unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
        assert_eq!(r.metrics().messages(), 0, "one node never communicates");
    }

    #[test]
    fn parallel_phase1_matches_sequential() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 4);
        let mut seq = session_for(&g, EngineConfig::dgx2(8, 4));
        let mut par = session_for(
            &g,
            EngineConfig {
                parallel_phase1: true,
                ..EngineConfig::dgx2(8, 4)
            },
        );
        let rs = seq.run(9).unwrap();
        let rp = par.run(9).unwrap();
        assert_eq!(rs.dist(), rp.dist());
        assert_eq!(rs.metrics().edges_examined(), rp.metrics().edges_examined());
        assert_eq!(rs.depth(), rp.depth());
        for (a, b) in rs.metrics().levels.iter().zip(&rp.metrics().levels) {
            assert_eq!(level_key(a), level_key(b), "level {}", a.level);
        }
    }

    #[test]
    fn pooled_run_bit_identical_to_sequential() {
        // Satellite acceptance: single-root Phase 1 now steps on the
        // persistent pool under `parallel_phase1`, and pooled stepping
        // must reproduce sequential stepping bit for bit — distances and
        // per-level accounting — across seeded configs in both partition
        // modes and all direction policies.
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(30), "pooled run == sequential", |rng| {
            let n = gen::usize_in(rng, 10, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let base = if rng.next_below(2) == 0 {
                let nodes = gen::usize_in(rng, 2, 8.min(n));
                EngineConfig::dgx2(nodes, gen::usize_in(rng, 1, 4) as u32)
            } else {
                let rows = gen::usize_in(rng, 1, 4.min(n)) as u32;
                let cols = gen::usize_in(rng, 1, 4.min(n)) as u32;
                EngineConfig::dgx2_2d(rows, cols)
            };
            let direction = match rng.next_below(3) {
                0 => DirectionMode::TopDown,
                1 => DirectionMode::BottomUp,
                _ => DirectionMode::diropt(),
            };
            let cfg = EngineConfig { direction, ..base };
            let mut seq = session_for(&g, cfg.clone());
            let mut par =
                session_for(&g, EngineConfig { parallel_phase1: true, ..cfg });
            let rs = seq.run(root).unwrap();
            let rp = par.run(root).unwrap();
            let mut ok = par.assert_agreement().is_ok()
                && rs.dist() == rp.dist()
                && rs.depth() == rp.depth()
                && rs.reached() == rp.reached();
            for (a, b) in rs.metrics().levels.iter().zip(&rp.metrics().levels) {
                ok &= level_key(a) == level_key(b);
            }
            (ok, format!("n={n} ef={ef} root={root} {direction:?}"))
        });
    }

    #[test]
    fn metrics_level_structure() {
        let g = path(12);
        let mut session = session_for(&g, EngineConfig::dgx2(2, 1));
        let r = session.run(0).unwrap();
        let m = r.metrics();
        // Path of 12 vertices from one end: 11 expansion levels with
        // nonempty frontiers.
        assert_eq!(m.depth(), 12);
        assert!(m.levels.iter().all(|l| l.frontier >= 1));
        // Graph500 vs honest GTEPS both finite.
        assert!(m.sim_gteps() > 0.0);
        assert!(m.sim_seconds() > 0.0);
    }

    #[test]
    fn message_count_per_level_matches_schedule() {
        let (g, _) = uniform_random(600, 8, 8);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(16, 1)).unwrap();
        let sched_msgs = plan.schedule().total_messages();
        let mut session = plan.session();
        let r = session.run(0).unwrap();
        for l in &r.metrics().levels {
            assert_eq!(l.messages, sched_msgs, "level {}", l.level);
        }
    }

    #[test]
    fn bitmap_payload_is_level_invariant() {
        let (g, _) = uniform_random(640, 8, 2);
        let cfg = EngineConfig {
            payload: PayloadEncoding::Bitmap,
            ..EngineConfig::dgx2(4, 1)
        };
        let mut session = session_for(&g, cfg);
        let r = session.run(0).unwrap();
        // Bitmap encoding: every level ships the same number of bytes —
        // the paper's tight bound (contribution 4).
        let per_level: Vec<u64> = r.metrics().levels.iter().map(|l| l.bytes).collect();
        assert!(per_level.windows(2).all(|w| w[0] == w[1]), "{per_level:?}");
    }

    #[test]
    fn session_is_reusable_across_roots() {
        let (g, _) = uniform_random(500, 8, 6);
        let mut session = session_for(&g, EngineConfig::dgx2(4, 4));
        let d1 = session.run(3).unwrap().into_dist();
        let r2 = session.run(10).unwrap();
        let want = serial_bfs(&g, 10);
        assert_eq!(r2.dist(), &want[..]);
        assert_ne!(d1, want, "different roots differ");
        // An explicit reset is also allowed between queries.
        session.reset();
        let r3 = session.run(3).unwrap();
        assert_eq!(r3.dist(), &d1[..]);
    }

    #[test]
    fn bottom_up_mode_matches_serial() {
        let (g, _) = uniform_random(800, 8, 12);
        let cfg = EngineConfig {
            direction: DirectionMode::BottomUp,
            ..EngineConfig::dgx2(8, 4)
        };
        let mut session = session_for(&g, cfg);
        let r = session.run(0).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
    }

    #[test]
    fn diropt_mode_matches_serial_and_saves_edges() {
        let (g, _) = uniform_random(4000, 16, 6);
        let mut td = session_for(&g, EngineConfig::dgx2(8, 4));
        let cfg = EngineConfig {
            direction: DirectionMode::diropt(),
            ..EngineConfig::dgx2(8, 4)
        };
        let mut dopt = session_for(&g, cfg);
        let rtd = td.run(0).unwrap();
        let rdo = dopt.run(0).unwrap();
        dopt.assert_agreement().unwrap();
        assert_eq!(rdo.dist(), rtd.dist());
        assert_eq!(rdo.dist(), &serial_bfs(&g, 0)[..]);
        // Small-world graph: DO must examine fewer edges (the paper's
        // "promising optimization").
        assert!(
            rdo.metrics().edges_examined() < rtd.metrics().edges_examined(),
            "DO {} vs TD {}",
            rdo.metrics().edges_examined(),
            rtd.metrics().edges_examined()
        );
    }

    #[test]
    fn diropt_mode_many_node_counts() {
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 5);
        for nodes in [1usize, 3, 9, 16] {
            let cfg = EngineConfig {
                direction: DirectionMode::diropt(),
                ..EngineConfig::dgx2(nodes, 1)
            };
            let mut session = session_for(&g, cfg);
            let r = session.run(2).unwrap();
            session.assert_agreement().unwrap();
            assert_eq!(r.dist(), &serial_bfs(&g, 2)[..], "nodes={nodes}");
        }
    }

    #[test]
    fn run_batch_matches_serial_per_lane() {
        let (g, _) = uniform_random(700, 8, 19);
        let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 11) % 700).collect();
        for (nodes, fanout) in [(1usize, 1u32), (4, 1), (16, 4), (9, 2)] {
            let mut session = session_for(&g, EngineConfig::dgx2(nodes, fanout));
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            assert_eq!(b.num_roots(), 64);
            assert_eq!(b.roots(), &roots[..]);
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(
                    b.dist(lane),
                    &serial_bfs(&g, r)[..],
                    "nodes={nodes} f={fanout} lane={lane}"
                );
            }
        }
    }

    #[test]
    fn run_batch_small_and_duplicate_batches() {
        let (g, _) = uniform_random(400, 6, 2);
        let mut session = session_for(&g, EngineConfig::dgx2(8, 4));
        for roots in [vec![5u32], vec![1, 1, 1], vec![0, 399, 7, 7, 200]] {
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            assert_eq!(b.num_roots(), roots.len());
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(b.dist(lane), &serial_bfs(&g, r)[..]);
            }
        }
    }

    #[test]
    fn run_batch_matches_bit_parallel_oracle() {
        use crate::bfs::msbfs::ms_bfs;
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 77);
        let roots: Vec<VertexId> = (0..32u32).map(|i| i * 3).collect();
        let mut session = session_for(&g, EngineConfig::dgx2(16, 1));
        let b = session.run_batch(&roots).unwrap();
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(b.dist(lane), want.dist(lane), "lane {lane}");
        }
        assert_eq!(b.reached_pairs(), want.reached_pairs());
    }

    #[test]
    fn run_batch_amortizes_bytes_and_rounds() {
        // The acceptance criterion: one 64-root batch must ship measurably
        // fewer synchronization bytes and execute fewer schedule rounds
        // than 64 sequential runs of the same roots.
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 13);
        let roots: Vec<VertexId> =
            crate::bfs::msbfs::sample_batch_roots(&g, 64, 0xBEEF);
        let mut session = session_for(&g, EngineConfig::dgx2(16, 4));
        let bm = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        let seq = session.sequential_baseline(&roots).unwrap();
        // Bytes: strictly fewer. (The dense mask forms are information-
        // equivalent to 64 bitmaps, so hot levels roughly tie; the win
        // comes from the mask-grouped encoding collapsing lanes that
        // travel together.)
        assert!(
            bm.metrics().bytes() < seq.bytes,
            "batch bytes {} vs sequential {}",
            bm.metrics().bytes(),
            seq.bytes
        );
        // Rounds: the headline amortization — one schedule execution per
        // level serves all 64 roots, so the reduction is ~batch-width ×
        // (sum of depths / max depth) and far exceeds 8×.
        assert!(
            bm.metrics().sync_rounds * 8 < seq.sync_rounds,
            "batch rounds {} vs sequential {}",
            bm.metrics().sync_rounds,
            seq.sync_rounds
        );
    }

    #[test]
    fn run_batch_duplicate_roots_amortize_sharply() {
        // 64 identical roots: the batch's mask-grouped encoding collapses
        // the whole batch to near one traversal's bytes, while the
        // sequential path pays 64 full runs — a many-fold reduction.
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 3);
        let roots = vec![5u32; 64];
        let mut session = session_for(&g, EngineConfig::dgx2(16, 4));
        let bm = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        let seq = session.sequential_baseline(&roots).unwrap();
        assert!(
            bm.metrics().bytes() * 4 < seq.bytes,
            "batch bytes {} vs sequential {}",
            bm.metrics().bytes(),
            seq.bytes
        );
        assert_eq!(bm.dist(0), bm.dist(63));
    }

    #[test]
    fn batch_results_outlive_later_queries() {
        // Results own their distances, so a batch result is immune to the
        // session moving on to other queries (the old engine required
        // scraping `batch_dist` before the next `run_batch`).
        let (g, _) = uniform_random(300, 6, 4);
        let mut session = session_for(&g, EngineConfig::dgx2(4, 2));
        let b1 = session.run_batch(&[3, 9]).unwrap();
        let r = session.run(5).unwrap();
        let b2 = session.run_batch(&[8]).unwrap();
        assert_eq!(b1.dist(1), &serial_bfs(&g, 9)[..]);
        assert_eq!(r.dist(), &serial_bfs(&g, 5)[..]);
        assert_eq!(b2.dist(0), &serial_bfs(&g, 8)[..]);
        assert_eq!(b2.num_roots(), 1);
        assert!(b2.lane_dist(1).is_none());
    }

    #[test]
    fn batch_agreement_errors_before_any_batch() {
        let (g, _) = uniform_random(50, 4, 1);
        let session = session_for(&g, EngineConfig::dgx2(2, 1));
        assert!(session.assert_batch_agreement().is_err());
    }

    #[test]
    fn query_errors_are_typed_and_session_stays_usable() {
        let (g, _) = uniform_random(50, 4, 9);
        let mut session = session_for(&g, EngineConfig::dgx2(4, 2));
        assert_eq!(
            session.run(50).unwrap_err(),
            QueryError::RootOutOfRange { root: 50, num_vertices: 50 }
        );
        assert_eq!(session.run_batch(&[]).unwrap_err(), QueryError::EmptyBatch);
        // 65 roots used to be an error at the single-word width; the
        // engine now auto-widens the lane mask, and the hard cap sits at
        // MAX_LANES = 512.
        let wide65: Vec<VertexId> = (0..65).map(|i| i % 50).collect();
        let b65 = session.run_batch(&wide65).unwrap();
        assert_eq!(b65.num_roots(), 65);
        assert_eq!(b65.metrics().lane_words, 2);
        let too_wide: Vec<VertexId> = (0..513).map(|i| i % 50).collect();
        assert_eq!(
            session.run_batch(&too_wide).unwrap_err(),
            QueryError::WidthTooLarge { got: 513, max: MAX_LANES }
        );
        assert_eq!(
            session.run_batch(&[0, 99]).unwrap_err(),
            QueryError::RootOutOfRange { root: 99, num_vertices: 50 }
        );
        assert_eq!(
            session.sequential_baseline(&[0, 99]).unwrap_err(),
            QueryError::RootOutOfRange { root: 99, num_vertices: 50 }
        );
        // A failed query leaves the session fully usable.
        let r = session.run(7).unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 7)[..]);
    }

    #[test]
    fn property_run_batch_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(12), "run_batch == serial per lane", |rng| {
            let n = gen::usize_in(rng, 10, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let nodes = gen::usize_in(rng, 1, 8.min(n));
            let fanout = gen::usize_in(rng, 1, 4) as u32;
            let b = gen::usize_in(rng, 1, 16);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let mut session = session_for(&g, EngineConfig::dgx2(nodes, fanout));
            let batch = session.run_batch(&roots).unwrap();
            let ok = session.assert_batch_agreement().is_ok()
                && roots.iter().enumerate().all(|(lane, &r)| {
                    batch.dist(lane) == &serial_bfs(&g, r)[..]
                });
            (ok, format!("n={n} ef={ef} nodes={nodes} f={fanout} b={b}"))
        });
    }

    /// Run a 2D-mode traversal, check distances against serial BFS and
    /// the measured message count against the analytical
    /// `Partition2D::message_volume` model, and check the fold/expand
    /// splits tile the totals.
    fn check_two_d(g: &Csr, rows: u32, cols: u32, root: VertexId) {
        let plan = TraversalPlan::build(g, EngineConfig::dgx2_2d(rows, cols)).unwrap();
        let mut session = plan.session();
        let r = session.run(root).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(
            r.dist(),
            &serial_bfs(g, root)[..],
            "grid {rows}x{cols} root {root}"
        );
        let p2 = plan.partition().as_two_d().expect("2D mode");
        let m = r.metrics();
        assert_eq!(
            m.messages(),
            p2.message_volume(m.depth() as u64),
            "grid {rows}x{cols}: measured vs model"
        );
        for l in &m.levels {
            assert_eq!(l.fold_messages + l.expand_messages, l.messages);
            assert_eq!(l.fold_bytes + l.expand_bytes, l.bytes);
        }
    }

    #[test]
    fn two_d_matches_serial_square_and_ragged_grids() {
        let (g, _) = uniform_random(900, 8, 77);
        for (rows, cols) in [(4u32, 4u32), (2, 8), (8, 2), (1, 4), (4, 1), (3, 5)] {
            check_two_d(&g, rows, cols, 13);
        }
    }

    #[test]
    fn two_d_single_processor_degenerates_to_local_bfs() {
        let (g, _) = uniform_random(400, 8, 3);
        let mut session = session_for(&g, EngineConfig::dgx2_2d(1, 1));
        let r = session.run(0).unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
        assert_eq!(r.metrics().messages(), 0, "one processor never communicates");
    }

    #[test]
    fn two_d_direction_modes_match_serial() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 9);
        for direction in [DirectionMode::BottomUp, DirectionMode::diropt()] {
            let cfg = EngineConfig { direction, ..EngineConfig::dgx2_2d(4, 4) };
            let mut session = session_for(&g, cfg);
            let r = session.run(2).unwrap();
            session.assert_agreement().unwrap();
            assert_eq!(r.dist(), &serial_bfs(&g, 2)[..], "{direction:?}");
        }
    }

    #[test]
    fn two_d_run_batch_matches_serial_per_lane() {
        let (g, _) = uniform_random(500, 8, 19);
        let roots: Vec<VertexId> = (0..32u32).map(|i| (i * 13) % 500).collect();
        for (rows, cols) in [(4u32, 4u32), (2, 3), (1, 5)] {
            let plan =
                TraversalPlan::build(&g, EngineConfig::dgx2_2d(rows, cols)).unwrap();
            let mut session = plan.session();
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            let p2 = plan.partition().as_two_d().unwrap();
            let m = b.metrics();
            assert_eq!(m.messages(), p2.message_volume(m.depth() as u64));
            assert_eq!(m.fold_messages() + m.expand_messages(), m.messages());
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(
                    b.dist(lane),
                    &serial_bfs(&g, r)[..],
                    "grid {rows}x{cols} lane {lane}"
                );
            }
        }
    }

    #[test]
    fn property_two_d_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "2d fold/expand == serial", |rng| {
            let n = gen::usize_in(rng, 8, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let rows = gen::usize_in(rng, 1, 6.min(n)) as u32;
            let cols = gen::usize_in(rng, 1, 6.min(n)) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let plan =
                TraversalPlan::build(&g, EngineConfig::dgx2_2d(rows, cols)).unwrap();
            let mut session = plan.session();
            let r = session.run(root).unwrap();
            let p2 = plan.partition().as_two_d().unwrap();
            let ok = session.assert_agreement().is_ok()
                && r.dist() == &serial_bfs(&g, root)[..]
                && r.metrics().messages() == p2.message_volume(r.depth() as u64);
            (ok, format!("n={n} ef={ef} grid={rows}x{cols} root={root}"))
        });
    }

    #[test]
    fn pooled_batch_stepping_bit_identical_to_sequential() {
        // The threadpool determinism acceptance: pooled per-node stepping
        // must reproduce sequential stepping bit for bit — distances,
        // per-level byte/message accounting, everything — across 50
        // seeded configs in both partition modes.
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(50), "pooled run_batch == sequential", |rng| {
            let n = gen::usize_in(rng, 10, 250);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let b = gen::usize_in(rng, 1, 24);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let cfg = if rng.next_below(2) == 0 {
                let nodes = gen::usize_in(rng, 2, 8.min(n));
                EngineConfig::dgx2(nodes, gen::usize_in(rng, 1, 4) as u32)
            } else {
                let rows = gen::usize_in(rng, 1, 4.min(n)) as u32;
                let cols = gen::usize_in(rng, 1, 4.min(n)) as u32;
                EngineConfig::dgx2_2d(rows, cols)
            };
            let mut seq = session_for(&g, cfg.clone());
            let mut par =
                session_for(&g, EngineConfig { parallel_phase1: true, ..cfg });
            let bs = seq.run_batch(&roots).unwrap();
            let bp = par.run_batch(&roots).unwrap();
            let mut ok = par.assert_batch_agreement().is_ok();
            for lane in 0..roots.len() {
                ok &= bs.dist(lane) == bp.dist(lane);
            }
            ok &= bs.depth() == bp.depth();
            for (a, c) in bs.metrics().levels.iter().zip(&bp.metrics().levels) {
                ok &= a.frontier == c.frontier
                    && a.edges_examined == c.edges_examined
                    && a.discovered == c.discovered
                    && a.messages == c.messages
                    && a.bytes == c.bytes;
            }
            (ok, format!("n={n} ef={ef} b={b}"))
        });
    }

    #[test]
    fn wide_batch_matches_oracle_and_serial() {
        // The tentpole's core equivalence: a 256-root batch (4 mask
        // words) through one exchange per level is bit-identical to the
        // bit-parallel oracle and the serial per-root BFS, in 1D and 2D.
        use crate::bfs::msbfs::ms_bfs;
        let (g, _) = uniform_random(400, 6, 29);
        let roots: Vec<VertexId> = (0..256u32).map(|i| (i * 3 + 1) % 400).collect();
        let want = ms_bfs(&g, &roots);
        for cfg in [EngineConfig::dgx2(8, 2), EngineConfig::dgx2_2d(2, 3)] {
            let mut session = session_for(&g, cfg.clone());
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            assert_eq!(b.metrics().lane_words, 4);
            assert_eq!(b.metrics().lanes_per_exchange(), 256);
            for lane in 0..roots.len() {
                assert_eq!(b.dist(lane), want.dist(lane), "{cfg:?} lane {lane}");
            }
            assert_eq!(b.dist(17), &serial_bfs(&g, roots[17])[..]);
        }
    }

    #[test]
    fn configured_width_floor_pins_the_wire_format() {
        // A 10-root batch under a W256 floor runs four-word lanes: same
        // distances, lane_words == 4, and priced bytes at least the
        // single-word pricing (wider entries can only cost more; the
        // presence-bitmap arm is width-invariant).
        use crate::coordinator::config::BatchWidth;
        let (g, _) = uniform_random(300, 6, 8);
        let roots: Vec<VertexId> = (0..10u32).map(|i| i * 7).collect();
        let mut narrow = session_for(&g, EngineConfig::dgx2(4, 2));
        let mut wide = session_for(
            &g,
            EngineConfig {
                batch_width: BatchWidth::W256,
                ..EngineConfig::dgx2(4, 2)
            },
        );
        let bn = narrow.run_batch(&roots).unwrap();
        let bw = wide.run_batch(&roots).unwrap();
        assert_eq!(bn.metrics().lane_words, 1);
        assert_eq!(bw.metrics().lane_words, 4);
        for lane in 0..roots.len() {
            assert_eq!(bn.dist(lane), bw.dist(lane), "lane {lane}");
        }
        assert_eq!(
            bn.metrics().edges_examined(),
            bw.metrics().edges_examined(),
            "width changes pricing, never traversal work"
        );
        assert!(bw.metrics().bytes() >= bn.metrics().bytes());
    }

    #[test]
    fn width_change_reuses_session_bit_identically() {
        // Crossing every word width in one session (pooled lane state is
        // rebuilt on width change, reset in place otherwise) matches
        // fresh sessions bit for bit.
        let (g, _) = uniform_random(350, 6, 40);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap();
        let mut reused = plan.session();
        for width in [48usize, 130, 3, 256, 65, 512] {
            let roots: Vec<VertexId> =
                (0..width).map(|i| ((i * 11 + 2) % 350) as VertexId).collect();
            let b = reused.run_batch(&roots).unwrap();
            reused.assert_batch_agreement().unwrap();
            let fresh = plan.session().run_batch(&roots).unwrap();
            assert_eq!(b.metrics().lane_words, fresh.metrics().lane_words);
            assert_eq!(b.metrics().bytes(), fresh.metrics().bytes());
            for lane in 0..width {
                assert_eq!(b.dist(lane), fresh.dist(lane), "w={width} lane={lane}");
            }
        }
    }

    #[test]
    fn batch_dense_merge_fallback_matches_oracle() {
        // A star forces a level whose delta list (≈ V entries) crosses the
        // 8·V-byte switchover, so the dense word-wise OR path runs; the
        // result must match the bit-parallel oracle exactly.
        use crate::bfs::msbfs::ms_bfs;
        let g = star(600);
        let roots: Vec<VertexId> = (0..64u32).map(|i| i % 2).collect();
        let mut session = session_for(&g, EngineConfig::dgx2(8, 2));
        let b = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(b.dist(lane), want.dist(lane), "lane {lane}");
        }
    }

    /// A hierarchical cluster preset: butterfly inside each island, a
    /// representative butterfly across islands, priced by the 10:1
    /// dgx2-cluster topology model.
    fn hier_cfg(islands: u32, per_island: u32, fanout: u32) -> EngineConfig {
        EngineConfig::dgx2_cluster_hier(islands, per_island, fanout)
    }

    #[test]
    fn hierarchical_matches_serial_and_flat_1d() {
        let (g, _) = uniform_random(900, 8, 77);
        for (islands, per_island, fanout) in [(2u32, 4u32, 1u32), (4, 2, 2), (2, 2, 4), (3, 3, 1)]
        {
            let mut hier = session_for(&g, hier_cfg(islands, per_island, fanout));
            let r = hier.run(13).unwrap();
            hier.assert_agreement().unwrap();
            assert_eq!(
                r.dist(),
                &serial_bfs(&g, 13)[..],
                "grid {islands}x{per_island} f={fanout}"
            );
            let mut flat =
                session_for(&g, EngineConfig::dgx2((islands * per_island) as usize, fanout));
            assert_eq!(r.dist(), flat.run(13).unwrap().dist());
            // Per-class accounting tiles the totals, and a true grid
            // actually crosses island boundaries.
            let m = r.metrics();
            assert_eq!(m.intra_messages() + m.inter_messages(), m.messages());
            assert_eq!(m.intra_bytes() + m.inter_bytes(), m.bytes());
            assert!(m.inter_messages() > 0, "grid {islands}x{per_island}");
            assert!(m.intra_messages() > 0, "grid {islands}x{per_island}");
        }
    }

    #[test]
    fn hierarchical_direction_modes_match_serial() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 9);
        for direction in
            [DirectionMode::TopDown, DirectionMode::BottomUp, DirectionMode::diropt()]
        {
            let cfg = EngineConfig { direction, ..hier_cfg(2, 4, 2) };
            let mut session = session_for(&g, cfg);
            let r = session.run(2).unwrap();
            session.assert_agreement().unwrap();
            assert_eq!(r.dist(), &serial_bfs(&g, 2)[..], "{direction:?}");
        }
    }

    #[test]
    fn hierarchical_wide_batches_match_oracle() {
        use crate::bfs::msbfs::ms_bfs;
        let (g, _) = uniform_random(400, 6, 29);
        for width in [1usize, 64, 256, 512] {
            let roots: Vec<VertexId> =
                (0..width).map(|i| ((i * 3 + 1) % 400) as VertexId).collect();
            let mut session = session_for(&g, hier_cfg(2, 4, 2));
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            let want = ms_bfs(&g, &roots);
            for lane in 0..width {
                assert_eq!(b.dist(lane), want.dist(lane), "width {width} lane {lane}");
            }
            let m = b.metrics();
            assert_eq!(m.intra_messages() + m.inter_messages(), m.messages());
            assert_eq!(m.intra_bytes() + m.inter_bytes(), m.bytes());
            assert!(m.inter_messages() > 0);
        }
    }

    #[test]
    fn hierarchical_degenerate_grids_match_flat_butterfly() {
        // 1×p and p×1 grids collapse to the flat butterfly schedule;
        // without an explicit cluster topology the classified pricing is
        // numerically identical to flat pricing, so the whole metrics
        // stream — bytes, messages, simulated clock — matches exactly.
        let (g, _) = uniform_random(600, 8, 8);
        let mut flat = session_for(&g, EngineConfig::dgx2(6, 1));
        let rf = flat.run(0).unwrap();
        for (islands, per_island) in [(1u32, 6u32), (6, 1)] {
            let cfg = EngineConfig { topology: None, ..hier_cfg(islands, per_island, 1) };
            let mut hier = session_for(&g, cfg);
            let rh = hier.run(0).unwrap();
            assert_eq!(rh.dist(), rf.dist(), "grid {islands}x{per_island}");
            let (mh, mf) = (rh.metrics(), rf.metrics());
            assert_eq!(mh.messages(), mf.messages(), "grid {islands}x{per_island}");
            assert_eq!(mh.bytes(), mf.bytes(), "grid {islands}x{per_island}");
            assert_eq!(
                mh.sim_seconds(),
                mf.sim_seconds(),
                "grid {islands}x{per_island}: degenerate pricing must be exact"
            );
        }
    }

    #[test]
    fn property_hierarchical_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "grid-of-islands == serial", |rng| {
            let n = gen::usize_in(rng, 64, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let islands = gen::usize_in(rng, 1, 8) as u32;
            let per_island = gen::usize_in(rng, 1, 8) as u32;
            let fanout = gen::usize_in(rng, 1, 4) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let mut session = session_for(&g, hier_cfg(islands, per_island, fanout));
            let r = session.run(root).unwrap();
            let ok = session.assert_agreement().is_ok()
                && r.dist() == &serial_bfs(&g, root)[..];
            (ok, format!("n={n} grid={islands}x{per_island} f={fanout} root={root}"))
        });
    }

    #[test]
    fn property_distributed_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(25), "butterfly bfs == serial bfs", |rng| {
            let n = gen::usize_in(rng, 10, 500);
            let ef = gen::usize_in(rng, 1, 8) as u32;
            let nodes = gen::usize_in(rng, 1, 10.min(n));
            let fanout = gen::usize_in(rng, 1, 5) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let mut session = session_for(&g, EngineConfig::dgx2(nodes, fanout));
            let r = session.run(root).unwrap();
            let ok = session.assert_agreement().is_ok()
                && r.dist() == &serial_bfs(&g, root)[..];
            (ok, format!("n={n} ef={ef} nodes={nodes} f={fanout} root={root}"))
        });
    }

    /// Satellite: buffer reuse across levels *and* across queries. The
    /// first run of a batch (and of a single-root query) is allowed to
    /// grow the session's hoisted merge/expand scratch; re-running the
    /// identical workload must be allocation-free — every capacity-growth
    /// event is counted, so the second run's delta must be exactly zero.
    #[test]
    fn repeated_queries_reuse_scratch_without_allocating() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 7);
        let roots =
            crate::bfs::msbfs::sample_batch_roots(&g, 64, 0x5CA7C4);
        for cfg in [
            EngineConfig::dgx2(8, 4),
            EngineConfig {
                direction: DirectionMode::diropt(),
                ..EngineConfig::dgx2(8, 4)
            },
        ] {
            let mut session = session_for(&g, cfg);
            session.run(roots[0]).unwrap();
            session.run_batch_metrics_only(&roots).unwrap();
            let warm = session.scratch_alloc_events();
            session.run(roots[0]).unwrap();
            session.run_batch_metrics_only(&roots).unwrap();
            assert_eq!(
                session.scratch_alloc_events(),
                warm,
                "second identical run must not grow any scratch buffer"
            );
        }
    }
}
