//! The distributed multi-pattern BFS engine — Alg. 2 of the paper, over
//! either partition layout.
//!
//! Each level runs two strictly separated phases:
//!
//! 1. **Traversal** — every compute node expands its owned frontier over
//!    its adjacency slab (via its [`ComputeBackend`]), discovering vertices
//!    into its global queue and distance array.
//! 2. **Synchronization** — the schedule's rounds execute with allgather
//!    semantics: each transfer ships the sender's accumulated global queue
//!    (snapshotted at round start, the paper's `CopyFrontier`); receivers
//!    dedup against their distance array, extend their own global queue
//!    (so later rounds relay), and route owned vertices into their next
//!    local queue.
//!
//! The [`PartitionMode`] picks the (layout, schedule) pair — the seam
//! every exchange pattern plugs into:
//!
//! * **1D** (the paper's mode): contiguous edge-balanced row slabs,
//!   synchronized by the configured
//!   [`PatternKind`](crate::coordinator::config::PatternKind) — butterfly
//!   or all-to-all.
//! * **2D** (the Buluç & Madduri comparator): checkerboard edge blocks of
//!   a `rows × cols` grid, synchronized by the fold-along-rows /
//!   expand-along-columns exchange ([`crate::comm::FoldExpand`]). Every
//!   node of a processor row owns the same source range (each expands its
//!   own column block), and per-phase fold/expand byte/message accounting
//!   flows into the level metrics.
//!
//! The engine also keeps the simulated clock: Phase-1 compute is priced by
//! the [`DeviceModel`](crate::net::model::DeviceModel) (slowest node wins —
//! the bulk-synchronous barrier), Phase-2 by the interconnect simulator
//! with the *actual measured payloads* of every message.
//!
//! Besides the single-root [`ButterflyBfs::run`], the engine offers the
//! batched multi-source [`ButterflyBfs::run_batch`]: up to 64 roots
//! advance bit-parallel through the *same* schedule, one exchange per
//! level serving the whole batch (see [`crate::bfs::msbfs`]). With
//! `parallel_phase1` set, the batched per-node stepping runs on the
//! [`ThreadPool`] (the per-(node, batch-state) slices are disjoint).

use super::backend::{ComputeBackend, ExpandOutput, NativeCsr};
use super::config::{DirectionMode, EngineConfig, PartitionMode};
use super::metrics::{BatchMetrics, LevelMetrics, RunMetrics, SequentialBaseline};
use super::node::ComputeNode;
use crate::bfs::frontier::MaskFrontier;
use crate::bfs::msbfs::{MsBfsNodeState, MAX_BATCH};
use crate::bfs::serial::INF;
use crate::comm::fold_expand::FoldExpand;
use crate::comm::pattern::{CommPattern, Schedule};
use crate::graph::csr::{Csr, VertexId};
use crate::net::sim::simulate_schedule;
use crate::partition::one_d::partition_1d;
use crate::partition::{Partition2D, PartitionSpec};
use crate::util::threadpool::ThreadPool;

/// The multi-node BFS engine.
pub struct ButterflyBfs {
    config: EngineConfig,
    partition: PartitionSpec,
    nodes: Vec<ComputeNode>,
    backends: Vec<Box<dyn ComputeBackend>>,
    schedule: Schedule,
    /// Leading schedule rounds that are the 2D fold phase (0 in 1D mode;
    /// the remaining rounds are the expand phase).
    fold_rounds: usize,
    num_vertices: usize,
    graph_edges: u64,
    scratch: Vec<ExpandOutput>,
    /// Worker pool for batched per-node stepping — created lazily on the
    /// first [`Self::run_batch`] that wants it (`parallel_phase1` set,
    /// more than one node), so single-root-only engines never spawn it.
    pool: Option<ThreadPool>,
    /// Per-node MS-BFS state of the most recent [`Self::run_batch`] (empty
    /// until the first batch).
    batch_states: Vec<MsBfsNodeState>,
    /// Lane count of the most recent batch.
    batch_width: usize,
}

impl ButterflyBfs {
    /// Build an engine over `g` with the native CSR backend on every node.
    pub fn new(g: &Csr, config: EngineConfig) -> Self {
        let backends: Vec<Box<dyn ComputeBackend>> = (0..config.num_nodes)
            .map(|_| Box::new(NativeCsr::new(config.use_lrb)) as Box<dyn ComputeBackend>)
            .collect();
        Self::with_backends(g, config, backends)
    }

    /// Build an engine with caller-supplied per-node backends (e.g. the
    /// XLA/PJRT backend from `runtime::`).
    pub fn with_backends(
        g: &Csr,
        config: EngineConfig,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Self {
        assert_eq!(backends.len(), config.num_nodes, "one backend per node");
        assert!(config.num_nodes >= 1);
        // The multi-pattern seam: each mode yields its (layout, schedule)
        // pair; everything downstream is mode-agnostic.
        let (partition, slabs, schedule, fold_rounds) = match config.partition {
            PartitionMode::OneD => {
                let p = partition_1d(g, config.num_nodes);
                let slabs = p.slabs(g);
                let schedule = config.pattern.build().schedule(config.num_nodes as u32);
                (PartitionSpec::OneD(p), slabs, schedule, 0)
            }
            PartitionMode::TwoD { rows, cols } => {
                assert_eq!(
                    config.num_nodes,
                    rows as usize * cols as usize,
                    "2D mode needs num_nodes == rows*cols (grid {rows}x{cols})"
                );
                let p = Partition2D::new(g, rows, cols);
                let slabs = p.block_slabs(g);
                let fe = FoldExpand::new(rows, cols);
                let schedule = fe.schedule(config.num_nodes as u32);
                (PartitionSpec::TwoD(p), slabs, schedule, fe.fold_rounds())
            }
        };
        schedule.validate().expect("generated schedule invalid");
        let nodes: Vec<ComputeNode> = slabs
            .into_iter()
            .enumerate()
            .map(|(i, slab)| ComputeNode::new(i as u32, slab, g.num_vertices()))
            .collect();
        let scratch = (0..config.num_nodes).map(|_| ExpandOutput::default()).collect();
        Self {
            config,
            partition,
            nodes,
            backends,
            schedule,
            fold_rounds,
            num_vertices: g.num_vertices(),
            graph_edges: g.num_edges(),
            scratch,
            pool: None,
            batch_states: Vec::new(),
            batch_width: 0,
        }
    }

    /// The partition in use (1D row slabs or the 2D grid).
    pub fn partition(&self) -> &PartitionSpec {
        &self.partition
    }

    /// Distinct active frontier vertices across the machine. In 1D each
    /// owned vertex is queued on exactly one node; in 2D every node of a
    /// processor row queues the row's vertices (each expands its own
    /// column block), so count one column representative per row.
    fn frontier_len(&self) -> u64 {
        match self.config.partition {
            PartitionMode::OneD => {
                self.nodes.iter().map(|n| n.q_local.len() as u64).sum()
            }
            PartitionMode::TwoD { cols, .. } => self
                .nodes
                .iter()
                .step_by(cols as usize)
                .map(|n| n.q_local.len() as u64)
                .sum(),
        }
    }

    /// Batched analog of [`Self::frontier_len`].
    fn batch_frontier_len(&self) -> u64 {
        match self.config.partition {
            PartitionMode::OneD => self
                .batch_states
                .iter()
                .map(|s| s.q_local.len() as u64)
                .sum(),
            PartitionMode::TwoD { cols, .. } => self
                .batch_states
                .iter()
                .step_by(cols as usize)
                .map(|s| s.q_local.len() as u64)
                .sum(),
        }
    }

    /// 2D mode: the (fold messages, fold bytes, expand messages, expand
    /// bytes) split of one level's payload matrix; `None` in 1D mode.
    fn phase_split(&self, payloads: &[Vec<u64>]) -> Option<(u64, u64, u64, u64)> {
        if !matches!(self.config.partition, PartitionMode::TwoD { .. }) {
            return None;
        }
        let (fold, expand) = payloads.split_at(self.fold_rounds.min(payloads.len()));
        let msgs = |rs: &[Vec<u64>]| rs.iter().map(|r| r.len() as u64).sum::<u64>();
        let bytes = |rs: &[Vec<u64>]| rs.iter().flatten().copied().sum::<u64>();
        Some((msgs(fold), bytes(fold), msgs(expand), bytes(expand)))
    }

    /// The synchronization schedule in use.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Engine configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Run a full traversal from `root`; returns metrics. Distances are
    /// afterwards available via [`Self::dist`].
    pub fn run(&mut self, root: VertexId) -> RunMetrics {
        assert!((root as usize) < self.num_vertices, "root out of range");
        let t0 = std::time::Instant::now();
        for n in &mut self.nodes {
            n.init_root(root);
        }
        let mut metrics = RunMetrics {
            graph_edges: self.graph_edges,
            ..Default::default()
        };
        let mut level = 0u32;
        // Direction-optimizing state (global statistics — the leader
        // computes these from per-node counts each level).
        let mut bottom_up = false;
        let mut prev_frontier = 0u64;
        let mut m_unexplored = self.graph_edges;
        loop {
            let frontier = self.frontier_len();
            if frontier == 0 {
                break;
            }
            // ---- Direction choice (contribution 3: independent of sync) ----
            match self.config.direction {
                DirectionMode::TopDown => {}
                DirectionMode::BottomUp => bottom_up = true,
                DirectionMode::DirOpt { alpha, beta } => {
                    let m_frontier: u64 = self
                        .nodes
                        .iter()
                        .flat_map(|n| n.q_local.iter().map(|&v| n.slab.degree_global(v) as u64))
                        .sum();
                    let growing = frontier > prev_frontier;
                    if !bottom_up && alpha > 0 && growing && m_frontier > m_unexplored / alpha {
                        bottom_up = true;
                    } else if bottom_up
                        && beta > 0
                        && !growing
                        && frontier < (self.num_vertices as u64) / beta
                    {
                        bottom_up = false;
                    }
                    prev_frontier = frontier;
                }
            }
            // ---- Phase 1: traversal ----
            self.phase1(level, bottom_up);
            let edges: u64 = self.nodes.iter().map(|n| n.edges_this_level).sum();
            let max_node_edges =
                self.nodes.iter().map(|n| n.edges_this_level).max().unwrap_or(0);
            let sim_compute = self.config.device.level_time_dir(max_node_edges, bottom_up);

            // ---- Phase 2: frontier synchronization ----
            let payloads = self.phase2(level);
            let comm = simulate_schedule(&self.schedule, &self.config.net, |r, t| {
                payloads[r][t]
            });

            // After full coverage, every node's global queue holds the
            // complete deduped set of this level's discoveries.
            let discovered = self.nodes[0].q_global.len() as u64;
            metrics.push_level(
                level,
                frontier,
                edges,
                max_node_edges,
                discovered,
                &comm,
                sim_compute,
            );
            if let Some((fm, fb, em, eb)) = self.phase_split(&payloads) {
                let l = metrics.levels.last_mut().expect("level just pushed");
                l.fold_messages = fm;
                l.fold_bytes = fb;
                l.expand_messages = em;
                l.expand_bytes = eb;
            }

            // Update the DO bookkeeping before queues rotate.
            if let DirectionMode::DirOpt { .. } = self.config.direction {
                let next_edges: u64 = self
                    .nodes
                    .iter()
                    .flat_map(|n| {
                        n.q_local_next.iter().map(|&v| n.slab.degree_global(v) as u64)
                    })
                    .sum();
                m_unexplored = m_unexplored.saturating_sub(next_edges);
            }
            for n in &mut self.nodes {
                n.swap_queues();
            }
            level += 1;
        }
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        metrics.reached = self.nodes[0]
            .d_local
            .iter()
            .filter(|&&d| d != INF)
            .count() as u64;
        metrics
    }

    /// Phase 1: expand every node's owned frontier (top-down) or scan its
    /// owned unvisited vertices against the full frontier (bottom-up).
    /// Discoveries are routed into global/local queues (Alg. 2's inner
    /// loop).
    fn phase1(&mut self, level: u32, bottom_up: bool) {
        if self.config.parallel_phase1 {
            // Each (node, backend, scratch) triple is disjoint: scoped
            // threads give safe parallelism without locks.
            std::thread::scope(|s| {
                for ((node, backend), out) in self
                    .nodes
                    .iter_mut()
                    .zip(self.backends.iter_mut())
                    .zip(self.scratch.iter_mut())
                {
                    s.spawn(move || {
                        expand_node(node, backend.as_mut(), out, bottom_up);
                    });
                }
            });
        } else {
            for ((node, backend), out) in self
                .nodes
                .iter_mut()
                .zip(self.backends.iter_mut())
                .zip(self.scratch.iter_mut())
            {
                expand_node(node, backend.as_mut(), out, bottom_up);
            }
        }
        // Route discoveries (cheap, sequential: O(discovered)).
        for (node, out) in self.nodes.iter_mut().zip(self.scratch.iter()) {
            node.edges_this_level = out.edges_examined;
            for &v in &out.discovered {
                // Backend already marked `visited`; record queues+distance.
                node.d_local[v as usize] = level + 1;
                node.q_global.push(v);
                node.q_global_bits.set(v);
                if node.owns(v) {
                    node.q_local_next.push(v);
                }
            }
        }
    }

    /// Phase 2: execute the synchronization schedule. Returns per-round
    /// per-transfer payload byte sizes for the interconnect simulator.
    fn phase2(&mut self, level: u32) -> Vec<Vec<u64>> {
        let encoding = self.config.payload;
        let nv = self.num_vertices;
        let words = nv.div_ceil(64);
        // Dense/sparse dispatch threshold (§Perf optimization 1): word-wise
        // bitmap merge costs O(V/64) per transfer; entry-wise costs
        // O(queue). Cross-over at queue ≈ V/16 entries (4 words of queue
        // per bitmap word, measured on the microbench).
        let dense_threshold = (nv / 16).max(64);
        let mut payloads = Vec::with_capacity(self.schedule.rounds.len());
        // `CopyFrontier` semantics: transfers in a round see round-start
        // state. Queues are frozen by snapshotting *lengths* (they only
        // grow); bitmaps by copying words into a flat scratch buffer.
        let mut bit_snap: Vec<u64> = Vec::new();
        for round in 0..self.schedule.rounds.len() {
            let snap_len: Vec<usize> =
                self.nodes.iter().map(|n| n.q_global.len()).collect();
            let any_dense = snap_len.iter().any(|&l| l >= dense_threshold);
            if any_dense {
                bit_snap.clear();
                bit_snap.reserve(words * self.nodes.len());
                for n in &self.nodes {
                    bit_snap.extend_from_slice(n.q_global_bits.words());
                }
            }
            let transfers = std::mem::take(&mut self.schedule.rounds[round]);
            let mut round_payloads = Vec::with_capacity(transfers.len());
            for t in &transfers {
                let src = t.src as usize;
                let dst = t.dst as usize;
                let take = snap_len[src];
                round_payloads.push(encoding.bytes(take as u64, nv));
                if take >= dense_threshold {
                    // Dense path: 64-way duplicate rejection.
                    let src_words = &bit_snap[src * words..(src + 1) * words];
                    self.nodes[dst].merge_bits(src_words, level);
                } else {
                    // Sparse path: entry-wise merge of the frozen prefix.
                    let (sender, receiver) = if src < dst {
                        let (lo, hi) = self.nodes.split_at_mut(dst);
                        (&lo[src], &mut hi[0])
                    } else {
                        let (lo, hi) = self.nodes.split_at_mut(src);
                        (&hi[0] as &ComputeNode, &mut lo[dst])
                    };
                    for i in 0..take {
                        let v = sender.q_global[i];
                        receiver.discover(v, level);
                    }
                }
            }
            self.schedule.rounds[round] = transfers;
            payloads.push(round_payloads);
        }
        payloads
    }

    /// Run a batched multi-source BFS: up to [`MAX_BATCH`] roots advance
    /// in lock-step, one butterfly exchange per level serving the whole
    /// batch (the MS-BFS bit-parallel formulation — see
    /// [`crate::bfs::msbfs`]). The engine's schedule, partition, and node
    /// slabs are reused as-is; payloads are priced by the negotiated
    /// mask-delta encoding ([`crate::bfs::msbfs::mask_delta_bytes`])
    /// regardless of the configured single-root encoding, because the
    /// exchange genuinely ships `(vertex, lane-mask)` deltas.
    ///
    /// Per-lane distances are afterwards available via
    /// [`Self::batch_dist`]; [`Self::assert_batch_agreement`] checks the
    /// cross-node correctness invariant.
    pub fn run_batch(&mut self, roots: &[VertexId]) -> BatchMetrics {
        assert!(
            !roots.is_empty() && roots.len() <= MAX_BATCH,
            "batch width must be 1..=64 (got {})",
            roots.len()
        );
        for &r in roots {
            assert!((r as usize) < self.num_vertices, "root {r} out of range");
        }
        let t0 = std::time::Instant::now();
        let nv = self.num_vertices;
        let b = roots.len();
        self.batch_width = b;
        self.batch_states = (0..self.config.num_nodes)
            .map(|_| MsBfsNodeState::new(nv, b))
            .collect();
        // Alg. 2 prologue, batched: every node marks every root's lane
        // ("All CN set their d"); only the owner enqueues it locally.
        for (node, st) in self.nodes.iter().zip(self.batch_states.iter_mut()) {
            for (lane, &r) in roots.iter().enumerate() {
                let bit = 1u64 << lane;
                st.seen[r as usize] |= bit;
                st.dist[lane * nv + r as usize] = 0;
                if node.owns(r) {
                    if st.visit[r as usize] == 0 {
                        st.q_local.push(r);
                    }
                    st.visit[r as usize] |= bit;
                }
            }
        }
        let mut metrics = BatchMetrics {
            num_roots: b,
            graph_edges: self.graph_edges,
            ..Default::default()
        };
        if self.pool.is_none() && self.config.parallel_phase1 && self.config.num_nodes > 1
        {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(self.config.num_nodes);
            self.pool = Some(ThreadPool::new(workers));
        }
        let mut level = 0u32;
        loop {
            let frontier = self.batch_frontier_len();
            if frontier == 0 {
                break;
            }
            // ---- Phase 1: every node expands its owned masked frontier;
            // one adjacency read serves every active lane of the vertex.
            // The (node, batch-state) pairs are disjoint, so the pool can
            // step them bulk-synchronously; the per-node work is identical
            // either way, so pooled results are bit-identical to
            // sequential stepping.
            if let Some(pool) = &self.pool {
                let nodes = &self.nodes;
                let count = self.batch_states.len();
                let states = SendPtr(self.batch_states.as_mut_ptr());
                pool.run_indexed(count, |i| {
                    // SAFETY: `run_indexed` invokes each index exactly
                    // once and blocks until every job finished, so the
                    // `&mut` derived from index `i` aliases nothing and
                    // outlives no borrow.
                    let st = unsafe { &mut *states.0.add(i) };
                    batch_expand_node(&nodes[i], st, level);
                });
            } else {
                for (node, st) in self.nodes.iter().zip(self.batch_states.iter_mut()) {
                    batch_expand_node(node, st, level);
                }
            }
            let edges: u64 = self.batch_states.iter().map(|s| s.edges_this_level).sum();
            let max_node_edges = self
                .batch_states
                .iter()
                .map(|s| s.edges_this_level)
                .max()
                .unwrap_or(0);
            let sim_compute = self.config.device.level_time_dir(max_node_edges, false);

            // ---- Phase 2: one butterfly exchange for the whole batch.
            let payloads = self.batch_phase2(level);
            let comm = simulate_schedule(&self.schedule, &self.config.net, |r, t| {
                payloads[r][t]
            });

            // After full coverage every node's delta list holds the
            // complete set of this level's (vertex, lane) discoveries.
            let discovered: u64 = self.batch_states[0]
                .delta
                .entries()
                .iter()
                .map(|&(_, m)| m.count_ones() as u64)
                .sum();
            let (fm, fb, em, eb) = self.phase_split(&payloads).unwrap_or_default();
            metrics.levels.push(LevelMetrics {
                level,
                frontier,
                edges_examined: edges,
                max_node_edges,
                discovered,
                messages: comm.total_messages,
                bytes: comm.total_bytes,
                fold_messages: fm,
                fold_bytes: fb,
                expand_messages: em,
                expand_bytes: eb,
                sim_compute,
                sim_comm: comm.total(),
            });
            metrics.sync_rounds += self.schedule.depth() as u64;

            for st in &mut self.batch_states {
                st.swap_level();
            }
            level += 1;
        }
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        metrics.reached_pairs = self.batch_states[0]
            .dist
            .iter()
            .filter(|&&d| d != INF)
            .count() as u64;
        metrics
    }

    /// Phase 2 of a batched level: execute the synchronization schedule on
    /// the nodes' `(vertex, mask)` delta lists with `CopyFrontier`
    /// semantics (transfers in a round see round-start state, frozen by
    /// snapshotting list lengths — they only grow). Returns per-round
    /// per-transfer payload byte sizes for the interconnect simulator.
    ///
    /// Mirrors [`Self::phase2`]'s dense/sparse dispatch: once a sender's
    /// frozen prefix passes the `8·V`-byte accounting switchover (where
    /// [`PayloadEncoding::MaskDelta`](super::config::PayloadEncoding) caps
    /// the sparse `12·entries` at the dense per-vertex mask array), the
    /// merge follows the wire format — a word-wise OR over the snapshotted
    /// masks — instead of replaying entries one by one.
    fn batch_phase2(&mut self, level: u32) -> Vec<Vec<u64>> {
        let nv = self.num_vertices;
        // Entries at which `12·entries >= 8·V`: the dense mask array is
        // now the (no larger) negotiated form, so merge it word-wise.
        let dense_threshold =
            ((nv as u64 * 8).div_ceil(MaskFrontier::ENTRY_BYTES) as usize).max(1);
        let mut payloads = Vec::with_capacity(self.schedule.rounds.len());
        // Round-start dense snapshots (one V-word lane-mask array per
        // dense sender), flat like `phase2`'s `bit_snap` — but built
        // *incrementally*: deltas only grow within a level and the merge
        // is an idempotent OR, so each round folds in only the entries
        // appended since the previous round (`mask_done` tracks the
        // per-node accumulated prefix) instead of replaying from zero.
        let mut mask_snap: Vec<u64> = Vec::new();
        let mut mask_done: Vec<usize> = vec![0; self.batch_states.len()];
        for round in 0..self.schedule.rounds.len() {
            // Snapshot (prefix length, priced bytes) together: the
            // coalescing statistics are monotone within the level, so
            // pricing at snapshot time is exact for the frozen prefix.
            let snap: Vec<(usize, u64)> = self
                .batch_states
                .iter()
                .map(|s| (s.delta.len(), s.delta_payload_bytes(s.delta.len())))
                .collect();
            let any_dense = snap.iter().any(|&(l, _)| l >= dense_threshold);
            if any_dense {
                if mask_snap.is_empty() {
                    mask_snap.resize(nv * self.batch_states.len(), 0);
                }
                for (k, s) in self.batch_states.iter().enumerate() {
                    if snap[k].0 >= dense_threshold {
                        s.delta.accumulate_range(
                            mask_done[k],
                            snap[k].0,
                            &mut mask_snap[k * nv..(k + 1) * nv],
                        );
                        mask_done[k] = snap[k].0;
                    }
                }
            }
            let transfers = std::mem::take(&mut self.schedule.rounds[round]);
            let mut round_payloads = Vec::with_capacity(transfers.len());
            for t in &transfers {
                let src = t.src as usize;
                let dst = t.dst as usize;
                let (take, priced) = snap[src];
                round_payloads.push(priced);
                let dst_node = &self.nodes[dst];
                if take >= dense_threshold {
                    // Dense path: the frozen prefix as per-vertex masks.
                    let masks = &mask_snap[src * nv..(src + 1) * nv];
                    let receiver = &mut self.batch_states[dst];
                    for (v, &m) in masks.iter().enumerate() {
                        if m != 0 {
                            receiver.discover(
                                v as VertexId,
                                m,
                                level,
                                dst_node.owns(v as VertexId),
                            );
                        }
                    }
                } else {
                    // Sparse path: entry-wise replay of the frozen prefix.
                    let (sender, receiver) = if src < dst {
                        let (lo, hi) = self.batch_states.split_at_mut(dst);
                        (&lo[src], &mut hi[0])
                    } else {
                        let (lo, hi) = self.batch_states.split_at_mut(src);
                        (&hi[0] as &MsBfsNodeState, &mut lo[dst])
                    };
                    for i in 0..take {
                        let (v, m) = sender.delta.entries()[i];
                        receiver.discover(v, m, level, dst_node.owns(v));
                    }
                }
            }
            self.schedule.rounds[round] = transfers;
            payloads.push(round_payloads);
        }
        payloads
    }

    /// Run each root one at a time through [`Self::run`] and accumulate
    /// the synchronization totals — the baseline [`Self::run_batch`] is
    /// compared against (used by the CLI `batch --compare`, the
    /// `msbfs_amortization` bench, the amortization tests, and the
    /// closeness-centrality example).
    pub fn sequential_baseline(&mut self, roots: &[VertexId]) -> SequentialBaseline {
        let sched_depth = self.schedule.depth() as u64;
        let mut b = SequentialBaseline::default();
        for &r in roots {
            let m = self.run(r);
            b.bytes += m.bytes();
            b.messages += m.messages();
            b.sync_rounds += m.depth() as u64 * sched_depth;
            b.sim_seconds += m.sim_seconds();
        }
        b
    }

    /// Lane count of the most recent [`Self::run_batch`] (0 before any).
    pub fn batch_width(&self) -> usize {
        self.batch_width
    }

    /// Distance array of batch lane `lane` after [`Self::run_batch`]
    /// (node 0's view; [`Self::assert_batch_agreement`] verifies all
    /// views coincide).
    pub fn batch_dist(&self, lane: usize) -> &[u32] {
        assert!(
            !self.batch_states.is_empty(),
            "run_batch has not been called"
        );
        assert!(lane < self.batch_width, "lane {lane} out of range");
        let nv = self.num_vertices;
        &self.batch_states[0].dist[lane * nv..(lane + 1) * nv]
    }

    /// Check that every node ended the batch with identical per-lane
    /// distance arrays — the batched analog of [`Self::assert_agreement`].
    pub fn assert_batch_agreement(&self) -> Result<(), String> {
        let Some(first) = self.batch_states.first() else {
            return Err("run_batch has not been called".to_string());
        };
        let nv = self.num_vertices;
        for (i, st) in self.batch_states.iter().enumerate().skip(1) {
            if st.dist != first.dist {
                let bad = first
                    .dist
                    .iter()
                    .zip(&st.dist)
                    .position(|(a, c)| a != c)
                    .unwrap();
                return Err(format!(
                    "node {i} disagrees with node 0 at lane {} vertex {}: {} vs {}",
                    bad / nv,
                    bad % nv,
                    st.dist[bad],
                    first.dist[bad]
                ));
            }
        }
        Ok(())
    }

    /// Distance array after a run (node 0's view; `assert_agreement`
    /// verifies all views coincide).
    pub fn dist(&self) -> &[u32] {
        &self.nodes[0].d_local
    }

    /// Check that every node ended with an identical distance array — the
    /// correctness invariant of the synchronization pattern.
    pub fn assert_agreement(&self) -> Result<(), String> {
        let d0 = &self.nodes[0].d_local;
        for n in &self.nodes[1..] {
            if &n.d_local != d0 {
                let bad = d0
                    .iter()
                    .zip(&n.d_local)
                    .position(|(a, b)| a != b)
                    .unwrap();
                return Err(format!(
                    "node {} disagrees with node 0 at vertex {bad}: {} vs {}",
                    n.id, n.d_local[bad], d0[bad]
                ));
            }
        }
        Ok(())
    }
}

/// Raw-pointer transport for handing the pool disjoint `&mut` slots of one
/// slice (each `run_indexed` index touches exactly one element).
struct SendPtr(*mut MsBfsNodeState);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// One node's Phase-1 step of a batched level — shared by the pooled and
/// sequential paths, so the two are bit-identical by construction.
fn batch_expand_node(node: &ComputeNode, st: &mut MsBfsNodeState, level: u32) {
    let q = std::mem::take(&mut st.q_local);
    for &v in &q {
        let mv = st.visit[v as usize];
        st.visit[v as usize] = 0;
        debug_assert!(mv != 0, "frontier vertex {v} with empty mask");
        st.edges_this_level += node.slab.degree_global(v) as u64;
        for &u in node.slab.neighbors_global(v) {
            st.discover(u, mv, level, node.owns(u));
        }
    }
    st.q_local = q; // keep the allocation; cleared at swap
}

fn expand_node(
    node: &mut ComputeNode,
    backend: &mut dyn ComputeBackend,
    out: &mut ExpandOutput,
    bottom_up: bool,
) {
    if bottom_up {
        // The full-frontier bitmap is moved out so the backend can borrow
        // it alongside the mutable visited bitmap.
        let frontier_full = std::mem::replace(
            &mut node.frontier_full,
            crate::bfs::frontier::Bitmap::new(0),
        );
        backend.expand_bottom_up(&node.slab, &frontier_full, &mut node.visited, out);
        node.frontier_full = frontier_full;
    } else {
        // The frontier is moved out so backend gets plain slices.
        let frontier = std::mem::take(&mut node.q_local);
        backend.expand(&node.slab, &frontier, &mut node.visited, out);
        node.q_local = frontier; // restored for metrics/debug; cleared at swap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::coordinator::config::{PatternKind, PayloadEncoding};
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};
    use crate::graph::gen::structured::{grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    fn check_against_serial(g: &Csr, cfg: EngineConfig, root: VertexId) {
        let mut engine = ButterflyBfs::new(g, cfg);
        let metrics = engine.run(root);
        engine.assert_agreement().unwrap();
        let want = serial_bfs(g, root);
        assert_eq!(engine.dist(), &want[..], "distances match serial");
        let reached = want.iter().filter(|&&d| d != INF).count() as u64;
        assert_eq!(metrics.reached, reached);
    }

    #[test]
    fn matches_serial_16_nodes_fanout1_and_4() {
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 31);
        for fanout in [1, 4] {
            check_against_serial(&g, EngineConfig::dgx2(16, fanout), 0);
        }
    }

    #[test]
    fn matches_serial_all_patterns() {
        let (g, _) = uniform_random(900, 8, 77);
        for pattern in [
            PatternKind::Butterfly { fanout: 1 },
            PatternKind::Butterfly { fanout: 2 },
            PatternKind::Butterfly { fanout: 4 },
            PatternKind::AllToAllConcurrent,
            PatternKind::AllToAllIterative,
        ] {
            let cfg = EngineConfig {
                pattern,
                ..EngineConfig::dgx2(8, 1)
            };
            check_against_serial(&g, cfg, 13);
        }
    }

    #[test]
    fn matches_serial_non_power_of_two_nodes() {
        let (g, _) = uniform_random(1100, 8, 5);
        for nodes in [3, 5, 9, 13] {
            check_against_serial(&g, EngineConfig::dgx2(nodes, 1), 1);
            check_against_serial(&g, EngineConfig::dgx2(nodes, 4), 1);
        }
    }

    #[test]
    fn structured_graphs_all_roots() {
        let graphs = vec![path(40), star(50), grid2d(6, 8)];
        for g in &graphs {
            for root in [0u32, (g.num_vertices() - 1) as u32] {
                check_against_serial(g, EngineConfig::dgx2(4, 1), root);
            }
        }
    }

    #[test]
    fn disconnected_graph_unreached_stay_inf() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(40);
        for v in 1..20u32 {
            b.add_edge(0, v);
        }
        b.add_edge(30, 31); // island
        let (g, _) = b.build_undirected();
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 2));
        let m = engine.run(0);
        assert_eq!(m.reached, 20);
        assert_eq!(engine.dist()[30], INF);
        engine.assert_agreement().unwrap();
    }

    #[test]
    fn single_node_degenerates_to_local_bfs() {
        let (g, _) = uniform_random(400, 8, 3);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(1, 1));
        let m = engine.run(0);
        assert_eq!(engine.dist(), &serial_bfs(&g, 0)[..]);
        assert_eq!(m.messages(), 0, "one node never communicates");
    }

    #[test]
    fn parallel_phase1_matches_sequential() {
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 4);
        let mut seq = ButterflyBfs::new(&g, EngineConfig::dgx2(8, 4));
        let mut par = ButterflyBfs::new(
            &g,
            EngineConfig {
                parallel_phase1: true,
                ..EngineConfig::dgx2(8, 4)
            },
        );
        let ms = seq.run(9);
        let mp = par.run(9);
        assert_eq!(seq.dist(), par.dist());
        assert_eq!(ms.edges_examined(), mp.edges_examined());
    }

    #[test]
    fn metrics_level_structure() {
        let g = path(12);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(2, 1));
        let m = engine.run(0);
        // Path of 12 vertices from one end: 11 expansion levels with
        // nonempty frontiers.
        assert_eq!(m.depth(), 12);
        assert!(m.levels.iter().all(|l| l.frontier >= 1));
        // Graph500 vs honest GTEPS both finite.
        assert!(m.sim_gteps() > 0.0);
        assert!(m.sim_seconds() > 0.0);
    }

    #[test]
    fn message_count_per_level_matches_schedule() {
        let (g, _) = uniform_random(600, 8, 8);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 1));
        let sched_msgs = engine.schedule().total_messages();
        let m = engine.run(0);
        for l in &m.levels {
            assert_eq!(l.messages, sched_msgs, "level {}", l.level);
        }
    }

    #[test]
    fn bitmap_payload_is_level_invariant() {
        let (g, _) = uniform_random(640, 8, 2);
        let cfg = EngineConfig {
            payload: PayloadEncoding::Bitmap,
            ..EngineConfig::dgx2(4, 1)
        };
        let mut engine = ButterflyBfs::new(&g, cfg);
        let m = engine.run(0);
        // Bitmap encoding: every level ships the same number of bytes —
        // the paper's tight bound (contribution 4).
        let per_level: Vec<u64> = m.levels.iter().map(|l| l.bytes).collect();
        assert!(per_level.windows(2).all(|w| w[0] == w[1]), "{per_level:?}");
    }

    #[test]
    fn rerunning_engine_is_reusable() {
        let (g, _) = uniform_random(500, 8, 6);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 4));
        let d1 = {
            engine.run(3);
            engine.dist().to_vec()
        };
        engine.run(10);
        let want = serial_bfs(&g, 10);
        assert_eq!(engine.dist(), &want[..]);
        assert_ne!(d1, want, "different roots differ");
    }

    #[test]
    fn bottom_up_mode_matches_serial() {
        use crate::coordinator::config::DirectionMode;
        let (g, _) = uniform_random(800, 8, 12);
        let cfg = EngineConfig {
            direction: DirectionMode::BottomUp,
            ..EngineConfig::dgx2(8, 4)
        };
        let mut engine = ButterflyBfs::new(&g, cfg);
        engine.run(0);
        engine.assert_agreement().unwrap();
        assert_eq!(engine.dist(), &serial_bfs(&g, 0)[..]);
    }

    #[test]
    fn diropt_mode_matches_serial_and_saves_edges() {
        use crate::coordinator::config::DirectionMode;
        let (g, _) = uniform_random(4000, 16, 6);
        let mut td = ButterflyBfs::new(&g, EngineConfig::dgx2(8, 4));
        let cfg = EngineConfig {
            direction: DirectionMode::diropt(),
            ..EngineConfig::dgx2(8, 4)
        };
        let mut dopt = ButterflyBfs::new(&g, cfg);
        let mtd = td.run(0);
        let mdo = dopt.run(0);
        dopt.assert_agreement().unwrap();
        assert_eq!(dopt.dist(), td.dist());
        assert_eq!(dopt.dist(), &serial_bfs(&g, 0)[..]);
        // Small-world graph: DO must examine fewer edges (the paper's
        // "promising optimization").
        assert!(
            mdo.edges_examined() < mtd.edges_examined(),
            "DO {} vs TD {}",
            mdo.edges_examined(),
            mtd.edges_examined()
        );
    }

    #[test]
    fn diropt_mode_many_node_counts() {
        use crate::coordinator::config::DirectionMode;
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 5);
        for nodes in [1usize, 3, 9, 16] {
            let cfg = EngineConfig {
                direction: DirectionMode::diropt(),
                ..EngineConfig::dgx2(nodes, 1)
            };
            let mut engine = ButterflyBfs::new(&g, cfg);
            engine.run(2);
            engine.assert_agreement().unwrap();
            assert_eq!(engine.dist(), &serial_bfs(&g, 2)[..], "nodes={nodes}");
        }
    }

    #[test]
    fn run_batch_matches_serial_per_lane() {
        let (g, _) = uniform_random(700, 8, 19);
        let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 11) % 700).collect();
        for (nodes, fanout) in [(1usize, 1u32), (4, 1), (16, 4), (9, 2)] {
            let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(nodes, fanout));
            let m = engine.run_batch(&roots);
            engine.assert_batch_agreement().unwrap();
            assert_eq!(m.num_roots, 64);
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(
                    engine.batch_dist(lane),
                    &serial_bfs(&g, r)[..],
                    "nodes={nodes} f={fanout} lane={lane}"
                );
            }
        }
    }

    #[test]
    fn run_batch_small_and_duplicate_batches() {
        let (g, _) = uniform_random(400, 6, 2);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(8, 4));
        for roots in [vec![5u32], vec![1, 1, 1], vec![0, 399, 7, 7, 200]] {
            let m = engine.run_batch(&roots);
            engine.assert_batch_agreement().unwrap();
            assert_eq!(m.num_roots, roots.len());
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(engine.batch_dist(lane), &serial_bfs(&g, r)[..]);
            }
        }
    }

    #[test]
    fn run_batch_matches_bit_parallel_oracle() {
        use crate::bfs::msbfs::ms_bfs;
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 77);
        let roots: Vec<VertexId> = (0..32u32).map(|i| i * 3).collect();
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 1));
        let m = engine.run_batch(&roots);
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(engine.batch_dist(lane), want.dist(lane), "lane {lane}");
        }
        assert_eq!(m.reached_pairs, want.reached_pairs());
    }

    #[test]
    fn run_batch_amortizes_bytes_and_rounds() {
        // The acceptance criterion: one 64-root batch must ship measurably
        // fewer synchronization bytes and execute fewer schedule rounds
        // than 64 sequential runs of the same roots.
        let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 13);
        let roots: Vec<VertexId> =
            crate::bfs::msbfs::sample_batch_roots(&g, 64, 0xBEEF);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 4));
        let bm = engine.run_batch(&roots);
        engine.assert_batch_agreement().unwrap();
        let seq = engine.sequential_baseline(&roots);
        // Bytes: strictly fewer. (The dense mask forms are information-
        // equivalent to 64 bitmaps, so hot levels roughly tie; the win
        // comes from the mask-grouped encoding collapsing lanes that
        // travel together.)
        assert!(
            bm.bytes() < seq.bytes,
            "batch bytes {} vs sequential {}",
            bm.bytes(),
            seq.bytes
        );
        // Rounds: the headline amortization — one schedule execution per
        // level serves all 64 roots, so the reduction is ~batch-width ×
        // (sum of depths / max depth) and far exceeds 8×.
        assert!(
            bm.sync_rounds * 8 < seq.sync_rounds,
            "batch rounds {} vs sequential {}",
            bm.sync_rounds,
            seq.sync_rounds
        );
    }

    #[test]
    fn run_batch_duplicate_roots_amortize_sharply() {
        // 64 identical roots: the batch's mask-grouped encoding collapses
        // the whole batch to near one traversal's bytes, while the
        // sequential path pays 64 full runs — a many-fold reduction.
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 3);
        let roots = vec![5u32; 64];
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 4));
        let bm = engine.run_batch(&roots);
        engine.assert_batch_agreement().unwrap();
        let seq = engine.sequential_baseline(&roots);
        assert!(
            bm.bytes() * 4 < seq.bytes,
            "batch bytes {} vs sequential {}",
            bm.bytes(),
            seq.bytes
        );
        assert_eq!(engine.batch_dist(0), engine.batch_dist(63));
    }

    #[test]
    fn run_batch_engine_reusable_and_interleaves_with_run() {
        let (g, _) = uniform_random(300, 6, 4);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 2));
        engine.run_batch(&[3, 9]);
        let d1 = engine.batch_dist(1).to_vec();
        engine.run(5); // single-root state is independent of batch state
        assert_eq!(engine.dist(), &serial_bfs(&g, 5)[..]);
        assert_eq!(d1, serial_bfs(&g, 9));
        engine.run_batch(&[8]);
        assert_eq!(engine.batch_dist(0), &serial_bfs(&g, 8)[..]);
        assert_eq!(engine.batch_width(), 1);
    }

    #[test]
    fn batch_agreement_errors_before_any_batch() {
        let (g, _) = uniform_random(50, 4, 1);
        let engine = ButterflyBfs::new(&g, EngineConfig::dgx2(2, 1));
        assert!(engine.assert_batch_agreement().is_err());
    }

    #[test]
    fn property_run_batch_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(12), "run_batch == serial per lane", |rng| {
            let n = gen::usize_in(rng, 10, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let nodes = gen::usize_in(rng, 1, 8.min(n));
            let fanout = gen::usize_in(rng, 1, 4) as u32;
            let b = gen::usize_in(rng, 1, 16);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(nodes, fanout));
            engine.run_batch(&roots);
            let ok = engine.assert_batch_agreement().is_ok()
                && roots.iter().enumerate().all(|(lane, &r)| {
                    engine.batch_dist(lane) == &serial_bfs(&g, r)[..]
                });
            (ok, format!("n={n} ef={ef} nodes={nodes} f={fanout} b={b}"))
        });
    }

    /// Run a 2D-mode traversal, check distances against serial BFS and
    /// the measured message count against the analytical
    /// `Partition2D::message_volume` model, and check the fold/expand
    /// splits tile the totals.
    fn check_two_d(g: &Csr, rows: u32, cols: u32, root: VertexId) {
        let mut engine = ButterflyBfs::new(g, EngineConfig::dgx2_2d(rows, cols));
        let m = engine.run(root);
        engine.assert_agreement().unwrap();
        assert_eq!(
            engine.dist(),
            &serial_bfs(g, root)[..],
            "grid {rows}x{cols} root {root}"
        );
        let p2 = engine.partition().as_two_d().expect("2D mode");
        assert_eq!(
            m.messages(),
            p2.message_volume(m.depth() as u64),
            "grid {rows}x{cols}: measured vs model"
        );
        for l in &m.levels {
            assert_eq!(l.fold_messages + l.expand_messages, l.messages);
            assert_eq!(l.fold_bytes + l.expand_bytes, l.bytes);
        }
    }

    #[test]
    fn two_d_matches_serial_square_and_ragged_grids() {
        let (g, _) = uniform_random(900, 8, 77);
        for (rows, cols) in [(4u32, 4u32), (2, 8), (8, 2), (1, 4), (4, 1), (3, 5)] {
            check_two_d(&g, rows, cols, 13);
        }
    }

    #[test]
    fn two_d_single_processor_degenerates_to_local_bfs() {
        let (g, _) = uniform_random(400, 8, 3);
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2_2d(1, 1));
        let m = engine.run(0);
        assert_eq!(engine.dist(), &serial_bfs(&g, 0)[..]);
        assert_eq!(m.messages(), 0, "one processor never communicates");
    }

    #[test]
    fn two_d_direction_modes_match_serial() {
        use crate::coordinator::config::DirectionMode;
        let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 9);
        for direction in [DirectionMode::BottomUp, DirectionMode::diropt()] {
            let cfg = EngineConfig { direction, ..EngineConfig::dgx2_2d(4, 4) };
            let mut engine = ButterflyBfs::new(&g, cfg);
            engine.run(2);
            engine.assert_agreement().unwrap();
            assert_eq!(engine.dist(), &serial_bfs(&g, 2)[..], "{direction:?}");
        }
    }

    #[test]
    fn two_d_run_batch_matches_serial_per_lane() {
        let (g, _) = uniform_random(500, 8, 19);
        let roots: Vec<VertexId> = (0..32u32).map(|i| (i * 13) % 500).collect();
        for (rows, cols) in [(4u32, 4u32), (2, 3), (1, 5)] {
            let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2_2d(rows, cols));
            let m = engine.run_batch(&roots);
            engine.assert_batch_agreement().unwrap();
            let p2 = engine.partition().as_two_d().unwrap();
            assert_eq!(m.messages(), p2.message_volume(m.depth() as u64));
            assert_eq!(m.fold_messages() + m.expand_messages(), m.messages());
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(
                    engine.batch_dist(lane),
                    &serial_bfs(&g, r)[..],
                    "grid {rows}x{cols} lane {lane}"
                );
            }
        }
    }

    #[test]
    fn property_two_d_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(20), "2d fold/expand == serial", |rng| {
            let n = gen::usize_in(rng, 8, 300);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let rows = gen::usize_in(rng, 1, 6.min(n)) as u32;
            let cols = gen::usize_in(rng, 1, 6.min(n)) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2_2d(rows, cols));
            let m = engine.run(root);
            let p2 = engine.partition().as_two_d().unwrap();
            let ok = engine.assert_agreement().is_ok()
                && engine.dist() == &serial_bfs(&g, root)[..]
                && m.messages() == p2.message_volume(m.depth() as u64);
            (ok, format!("n={n} ef={ef} grid={rows}x{cols} root={root}"))
        });
    }

    #[test]
    fn pooled_batch_stepping_bit_identical_to_sequential() {
        // The threadpool determinism acceptance: pooled per-node stepping
        // must reproduce sequential stepping bit for bit — distances,
        // per-level byte/message accounting, everything — across 50
        // seeded configs in both partition modes.
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(50), "pooled run_batch == sequential", |rng| {
            let n = gen::usize_in(rng, 10, 250);
            let ef = gen::usize_in(rng, 1, 6) as u32;
            let b = gen::usize_in(rng, 1, 24);
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let roots: Vec<VertexId> =
                (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
            let cfg = if rng.next_below(2) == 0 {
                let nodes = gen::usize_in(rng, 2, 8.min(n));
                EngineConfig::dgx2(nodes, gen::usize_in(rng, 1, 4) as u32)
            } else {
                let rows = gen::usize_in(rng, 1, 4.min(n)) as u32;
                let cols = gen::usize_in(rng, 1, 4.min(n)) as u32;
                EngineConfig::dgx2_2d(rows, cols)
            };
            let mut seq = ButterflyBfs::new(&g, cfg.clone());
            let mut par = ButterflyBfs::new(
                &g,
                EngineConfig { parallel_phase1: true, ..cfg },
            );
            let ms = seq.run_batch(&roots);
            let mp = par.run_batch(&roots);
            let mut ok = par.assert_batch_agreement().is_ok();
            for lane in 0..roots.len() {
                ok &= seq.batch_dist(lane) == par.batch_dist(lane);
            }
            ok &= ms.depth() == mp.depth();
            for (a, c) in ms.levels.iter().zip(&mp.levels) {
                ok &= a.frontier == c.frontier
                    && a.edges_examined == c.edges_examined
                    && a.discovered == c.discovered
                    && a.messages == c.messages
                    && a.bytes == c.bytes;
            }
            (ok, format!("n={n} ef={ef} b={b}"))
        });
    }

    #[test]
    fn batch_dense_merge_fallback_matches_oracle() {
        // A star forces a level whose delta list (≈ V entries) crosses the
        // 8·V-byte switchover, so the dense word-wise OR path runs; the
        // result must match the bit-parallel oracle exactly.
        use crate::bfs::msbfs::ms_bfs;
        let g = star(600);
        let roots: Vec<VertexId> = (0..64u32).map(|i| i % 2).collect();
        let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(8, 2));
        engine.run_batch(&roots);
        engine.assert_batch_agreement().unwrap();
        let want = ms_bfs(&g, &roots);
        for lane in 0..roots.len() {
            assert_eq!(engine.batch_dist(lane), want.dist(lane), "lane {lane}");
        }
    }

    #[test]
    fn property_distributed_equals_serial() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(25), "butterfly bfs == serial bfs", |rng| {
            let n = gen::usize_in(rng, 10, 500);
            let ef = gen::usize_in(rng, 1, 8) as u32;
            let nodes = gen::usize_in(rng, 1, 10.min(n));
            let fanout = gen::usize_in(rng, 1, 5) as u32;
            let (g, _) = uniform_random(n, ef, rng.next_u64());
            let root = rng.next_usize(n) as u32;
            let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(nodes, fanout));
            engine.run(root);
            let ok = engine.assert_agreement().is_ok()
                && engine.dist() == &serial_bfs(&g, root)[..];
            (ok, format!("n={n} ef={ef} nodes={nodes} f={fanout} root={root}"))
        });
    }
}
