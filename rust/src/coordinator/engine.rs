//! Deprecated single-object engine façade.
//!
//! [`ButterflyBfs`] predates the plan/session split: it conflated the
//! expensive, reusable artifacts (CSR slabs, partition, schedule) with
//! per-query mutable state, so two queries could never run concurrently,
//! results had to be scraped out via `dist()`/`batch_dist()` after the
//! fact, and invalid input panicked. It survives as a thin compatibility
//! shim over [`TraversalPlan`] + [`QuerySession`] — same construction
//! signatures, same panicking behavior on invalid input, same accessors —
//! so downstream code keeps compiling while it migrates:
//!
//! | old (`ButterflyBfs`)                | new (plan/session)                          |
//! |-------------------------------------|---------------------------------------------|
//! | `ButterflyBfs::new(&g, cfg)`        | `TraversalPlan::build(&g, cfg)?` + `.session()` |
//! | `engine.run(root)` then `.dist()`   | `session.run(root)? -> TraversalResult`     |
//! | `engine.run_batch(&roots)` then `.batch_dist(lane)` | `session.run_batch(&roots)? -> BatchResult` |
//! | panic on bad root/grid/batch        | typed [`PlanError`] / [`QueryError`]        |
//! | one engine = one traversal at a time | N sessions share one `Arc<TraversalPlan>`  |
//!
//! [`PlanError`]: super::plan::PlanError
//! [`QueryError`]: super::session::QueryError

use super::backend::ComputeBackend;
use super::config::EngineConfig;
use super::metrics::{BatchMetrics, RunMetrics, SequentialBaseline};
use super::plan::TraversalPlan;
use super::session::QuerySession;
use crate::comm::pattern::Schedule;
use crate::graph::csr::{Csr, VertexId};
use crate::partition::PartitionSpec;

/// The legacy multi-node BFS engine: a deprecated shim over
/// [`TraversalPlan`] + [`QuerySession`]. Prefer the split API — it shares
/// one plan across concurrent sessions and returns typed results and
/// errors instead of panicking and scraping.
#[deprecated(
    since = "0.1.0",
    note = "use TraversalPlan::build(..) + plan.session(); run()/run_batch() \
            return typed results and errors there"
)]
pub struct ButterflyBfs {
    plan: TraversalPlan,
    session: QuerySession,
}

#[allow(deprecated)]
impl ButterflyBfs {
    /// Build an engine over `g` with the native CSR backend on every node.
    ///
    /// # Panics
    ///
    /// On any invalid layout (the legacy behavior). Use
    /// [`TraversalPlan::build`] for a typed error instead.
    pub fn new(g: &Csr, config: EngineConfig) -> Self {
        let plan = TraversalPlan::build(g, config).expect("invalid engine configuration");
        let session = plan.session();
        Self { plan, session }
    }

    /// Build an engine with caller-supplied per-node backends (e.g. the
    /// XLA/PJRT backend from `runtime::`).
    ///
    /// # Panics
    ///
    /// On any invalid layout or backend count (the legacy behavior). Use
    /// [`TraversalPlan::session_with_backends`] for a typed error.
    pub fn with_backends(
        g: &Csr,
        config: EngineConfig,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Self {
        let plan = TraversalPlan::build(g, config).expect("invalid engine configuration");
        let session = plan
            .session_with_backends(backends)
            .expect("one backend per node");
        Self { plan, session }
    }

    /// The partition in use (1D row slabs or the 2D grid).
    pub fn partition(&self) -> &PartitionSpec {
        self.plan.partition()
    }

    /// The synchronization schedule in use.
    pub fn schedule(&self) -> &Schedule {
        self.plan.schedule()
    }

    /// Engine configuration.
    pub fn config(&self) -> &EngineConfig {
        self.plan.config()
    }

    /// Run a full traversal from `root`; returns metrics. Distances are
    /// afterwards available via [`Self::dist`].
    ///
    /// # Panics
    ///
    /// When `root` is out of range (the legacy behavior);
    /// [`QuerySession::run`] returns a typed error instead.
    pub fn run(&mut self, root: VertexId) -> RunMetrics {
        self.session
            .run_metrics_only(root)
            .expect("root out of range")
    }

    /// Run a batched multi-source BFS (up to 512 roots); returns metrics.
    /// Per-lane distances are afterwards available via
    /// [`Self::batch_dist`].
    ///
    /// # Panics
    ///
    /// On an empty/oversized batch or out-of-range root (the legacy
    /// behavior); [`QuerySession::run_batch`] returns a typed error.
    pub fn run_batch(&mut self, roots: &[VertexId]) -> BatchMetrics {
        self.session
            .run_batch_metrics_only(roots)
            .expect("invalid batch")
    }

    /// Run each root one at a time through [`Self::run`] and accumulate
    /// the synchronization totals.
    pub fn sequential_baseline(&mut self, roots: &[VertexId]) -> SequentialBaseline {
        self.session
            .sequential_baseline(roots)
            .expect("root out of range")
    }

    /// Distance array after a run (node 0's live view, exactly as the
    /// pre-split engine exposed it: INF-filled before the first run,
    /// reflecting whatever single-root query — including
    /// [`Self::sequential_baseline`]'s last root — ran most recently).
    pub fn dist(&self) -> &[u32] {
        self.session.node0_dist()
    }

    /// Lane count of the most recent [`Self::run_batch`] (0 before any).
    pub fn batch_width(&self) -> usize {
        self.session.batch_width()
    }

    /// Distance array of batch lane `lane` after [`Self::run_batch`]
    /// (node 0's live view).
    ///
    /// # Panics
    ///
    /// When no batch has run yet or `lane` is out of range (the legacy
    /// behavior).
    pub fn batch_dist(&self, lane: usize) -> &[u32] {
        self.session.node0_batch_dist(lane)
    }

    /// Check that every node ended with an identical distance array — the
    /// correctness invariant of the synchronization pattern.
    pub fn assert_agreement(&self) -> Result<(), String> {
        self.session.assert_agreement()
    }

    /// Check that every node ended the batch with identical per-lane
    /// distance arrays — the batched analog of [`Self::assert_agreement`].
    pub fn assert_batch_agreement(&self) -> Result<(), String> {
        self.session.assert_batch_agreement()
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn shim_matches_plan_session_results() {
        let (g, _) = uniform_random(400, 6, 11);
        let mut shim = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 2));
        let sm = shim.run(3);
        shim.assert_agreement().unwrap();
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap();
        let mut session = plan.session();
        let r = session.run(3).unwrap();
        assert_eq!(shim.dist(), r.dist());
        assert_eq!(shim.dist(), &serial_bfs(&g, 3)[..]);
        // Shim metrics are the session metrics, field for field (modulo
        // wallclock, which is measured per run).
        let mut a = sm.clone();
        let mut b = r.metrics().clone();
        a.wall_seconds = 0.0;
        b.wall_seconds = 0.0;
        assert_eq!(a.to_json().render(), b.to_json().render());
    }

    #[test]
    fn shim_dist_is_a_live_view_like_the_old_engine() {
        use crate::bfs::serial::INF;
        let (g, _) = uniform_random(200, 5, 2);
        let mut shim = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 1));
        // Before the first run: the INF-initialized array, not a panic.
        assert!(shim.dist().iter().all(|&d| d == INF));
        // After sequential_baseline: the last baseline root's distances.
        shim.sequential_baseline(&[3, 9]);
        assert_eq!(shim.dist(), &serial_bfs(&g, 9)[..]);
    }

    #[test]
    fn shim_batch_accessors_delegate() {
        let (g, _) = uniform_random(300, 6, 4);
        let mut shim = ButterflyBfs::new(&g, EngineConfig::dgx2(4, 2));
        assert_eq!(shim.batch_width(), 0);
        assert!(shim.assert_batch_agreement().is_err());
        let bm = shim.run_batch(&[3, 9, 9]);
        shim.assert_batch_agreement().unwrap();
        assert_eq!(bm.num_roots, 3);
        assert_eq!(shim.batch_width(), 3);
        assert_eq!(shim.batch_dist(1), &serial_bfs(&g, 9)[..]);
        assert_eq!(shim.batch_dist(1), shim.batch_dist(2));
        // Single-root runs do not disturb the stored batch result.
        shim.run(5);
        assert_eq!(shim.dist(), &serial_bfs(&g, 5)[..]);
        assert_eq!(shim.batch_dist(0), &serial_bfs(&g, 3)[..]);
        let seq = shim.sequential_baseline(&[3, 9]);
        assert!(seq.bytes > 0 && seq.sync_rounds > 0);
    }

    #[test]
    fn shim_exposes_plan_artifacts() {
        let (g, _) = uniform_random(200, 4, 7);
        let shim = ButterflyBfs::new(&g, EngineConfig::dgx2_2d(2, 3));
        assert!(shim.partition().as_two_d().is_some());
        assert_eq!(shim.config().num_nodes, 6);
        assert!(shim.schedule().depth() >= 1);
    }
}
