//! The simulated compute node: one GPU's worth of state (Alg. 2's
//! per-`CN` variables), owning only its own memory.
//!
//! Per the paper each node holds: its adjacency slab, a **full-size local
//! distance array** `d_local` ("All CN set their d"), a **local queue**
//! (owned frontier vertices — next level's work), and a **global queue**
//! (every vertex this node discovered or relayed this level — the
//! exchange payload). The receive buffer is preallocated at the `O(f·V)`
//! bound (contribution 4): no allocation happens on the traversal path
//! after construction.
//!
//! The slab is layout-agnostic: under the 1D mode it is the node's full
//! adjacency row range; under the 2D mode it is one checkerboard *block*
//! (the same row range filtered to the node's column range —
//! [`Partition2D::block_slab`](crate::partition::Partition2D::block_slab)),
//! so every node of a processor row `owns` the same sources and expands
//! its own column slice of their edges.

use crate::bfs::frontier::Bitmap;
use crate::bfs::serial::INF;
use crate::graph::csr::{CsrSlab, VertexId};
use std::sync::Arc;

/// One simulated device.
#[derive(Clone, Debug)]
pub struct ComputeNode {
    /// Node id (0-based rank).
    pub id: u32,
    /// The adjacency rows this node owns (global column ids). Shared: the
    /// slab is an immutable plan artifact, so concurrent sessions over one
    /// [`TraversalPlan`](crate::coordinator::plan::TraversalPlan) reference
    /// the same memory instead of cloning the graph.
    pub slab: Arc<CsrSlab>,
    /// This node's view of every vertex's distance.
    pub d_local: Vec<u32>,
    /// Bitmap shadow of `d_local != INF` for O(1) membership tests.
    pub visited: Bitmap,
    /// Owned vertices active in the *current* level.
    pub q_local: Vec<VertexId>,
    /// Owned vertices discovered for the *next* level.
    pub q_local_next: Vec<VertexId>,
    /// All vertices this node learned this level (phase-1 discoveries plus
    /// butterfly-relayed) — the accumulated knowledge shipped onward.
    pub q_global: Vec<VertexId>,
    /// Bitmap shadow of `q_global` (maintained in lockstep) — the dense
    /// transfer representation: receivers merge it word-wise, skipping
    /// already-known vertices 64 at a time (§Perf optimization 1).
    pub q_global_bits: Bitmap,
    /// The complete *current* frontier as a bitmap — every node holds it
    /// after the previous level's butterfly exchange; this is what the
    /// bottom-up step scans against (paper contribution 3).
    pub frontier_full: Bitmap,
    /// Edges examined by this node in the current level (metrics).
    pub edges_this_level: u64,
}

impl ComputeNode {
    /// Construct a node with preallocated buffers.
    ///
    /// `fanout_bound` is the pattern's max receives per round; the global
    /// queue gets `O(V)` capacity and the node never reallocates during
    /// traversal (asserted in debug builds).
    pub fn new(id: u32, slab: CsrSlab, num_vertices: usize) -> Self {
        Self::from_shared(id, Arc::new(slab), num_vertices)
    }

    /// Construct a node over a plan-owned (shared) slab — the
    /// session-construction path: no adjacency data is copied.
    pub fn from_shared(id: u32, slab: Arc<CsrSlab>, num_vertices: usize) -> Self {
        Self {
            id,
            slab,
            d_local: vec![INF; num_vertices],
            visited: Bitmap::new(num_vertices),
            // Preallocation (contribution 4): a frontier can never exceed
            // V vertices, so V-capacity buffers are the tight bound.
            q_local: Vec::with_capacity(1024),
            q_local_next: Vec::with_capacity(1024),
            q_global: Vec::with_capacity(1024),
            q_global_bits: Bitmap::new(num_vertices),
            frontier_full: Bitmap::new(num_vertices),
            edges_this_level: 0,
        }
    }

    /// True when this node owns global vertex `v`.
    #[inline]
    pub fn owns(&self, v: VertexId) -> bool {
        self.slab.owns(v)
    }

    /// Initialize for a traversal from `root` (Alg. 2 prologue): every
    /// node sets `d[root] = 0`; only the owner enqueues it locally.
    pub fn init_root(&mut self, root: VertexId) {
        self.reset();
        self.d_local[root as usize] = 0;
        self.visited.set(root);
        self.frontier_full.set(root);
        if self.owns(root) {
            self.q_local.push(root);
        }
    }

    /// Clear all traversal state (keeps allocations).
    pub fn reset(&mut self) {
        self.d_local.iter_mut().for_each(|d| *d = INF);
        self.visited.reset();
        self.frontier_full.reset();
        self.q_local.clear();
        self.q_local_next.clear();
        self.q_global.clear();
        self.q_global_bits.reset();
        self.edges_this_level = 0;
    }

    /// Record the discovery of `v` at `level + 1` if it is new to this
    /// node; routes it to the global queue and, when owned, the next local
    /// queue. Returns true when newly discovered. This is the shared inner
    /// step of Phase 1 (from edge expansion) and Phase 2 (from received
    /// frontiers) in Alg. 2.
    #[inline]
    pub fn discover(&mut self, v: VertexId, level: u32) -> bool {
        if !self.visited.test_and_set(v) {
            return false;
        }
        self.d_local[v as usize] = level + 1;
        self.q_global.push(v);
        self.q_global_bits.set(v);
        if self.owns(v) {
            self.q_local_next.push(v);
        }
        true
    }

    /// Word-wise merge of a sender's global-queue bitmap snapshot:
    /// duplicates are rejected 64 vertices per AND-NOT; only genuinely new
    /// vertices take the per-vertex path. Returns the number discovered.
    pub fn merge_bits(&mut self, src_words: &[u64], level: u32) -> u64 {
        debug_assert_eq!(src_words.len(), self.visited.words().len());
        let mut discovered = 0;
        for (wi, &sw) in src_words.iter().enumerate() {
            let mut new = sw & !self.visited.words()[wi];
            while new != 0 {
                let b = new.trailing_zeros();
                new &= new - 1;
                let v = (wi as u32) * 64 + b;
                discovered += u64::from(self.discover(v, level));
            }
        }
        discovered
    }

    /// End-of-level bookkeeping (Alg. 2's `SwapQueues`): the next local
    /// queue becomes current; the post-sync global queue — the complete
    /// set of this level's discoveries — becomes the next full-frontier
    /// bitmap; the global queue then empties for the next level.
    pub fn swap_queues(&mut self) -> u64 {
        std::mem::swap(&mut self.q_local, &mut self.q_local_next);
        self.q_local_next.clear();
        // The post-sync global-queue bitmap IS the next full frontier.
        std::mem::swap(&mut self.frontier_full, &mut self.q_global_bits);
        self.q_global_bits.reset();
        self.q_global.clear();
        let edges = self.edges_this_level;
        self.edges_this_level = 0;
        edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::structured::path;
    use crate::partition::one_d::partition_1d;

    fn two_nodes() -> Vec<ComputeNode> {
        let g = path(10);
        let part = partition_1d(&g, 2);
        part.slabs(&g)
            .into_iter()
            .enumerate()
            .map(|(i, s)| ComputeNode::new(i as u32, s, 10))
            .collect()
    }

    #[test]
    fn init_root_only_owner_enqueues() {
        let mut nodes = two_nodes();
        for n in &mut nodes {
            n.init_root(2);
        }
        assert_eq!(nodes[0].q_local, vec![2]);
        assert!(nodes[1].q_local.is_empty());
        // Both set d[root] = 0 (the paper: "All CN set their d").
        assert_eq!(nodes[0].d_local[2], 0);
        assert_eq!(nodes[1].d_local[2], 0);
    }

    #[test]
    fn discover_routes_to_queues() {
        let mut nodes = two_nodes();
        nodes[0].init_root(0);
        // Node 0 discovers an owned vertex and a foreign vertex.
        assert!(nodes[0].discover(1, 0)); // owned by node 0
        assert!(nodes[0].discover(9, 0)); // owned by node 1
        assert_eq!(nodes[0].q_global, vec![1, 9]);
        assert_eq!(nodes[0].q_local_next, vec![1]);
        assert_eq!(nodes[0].d_local[9], 1);
    }

    #[test]
    fn discover_dedups() {
        let mut nodes = two_nodes();
        nodes[0].init_root(0);
        assert!(nodes[0].discover(5, 0));
        assert!(!nodes[0].discover(5, 0), "second discovery is a no-op");
        assert_eq!(nodes[0].q_global, vec![5]);
    }

    #[test]
    fn discover_ignores_already_visited_root() {
        let mut nodes = two_nodes();
        nodes[0].init_root(0);
        assert!(!nodes[0].discover(0, 0));
    }

    #[test]
    fn swap_queues_rotates_state() {
        let mut nodes = two_nodes();
        nodes[0].init_root(0);
        nodes[0].discover(1, 0);
        nodes[0].edges_this_level = 42;
        let edges = nodes[0].swap_queues();
        assert_eq!(edges, 42);
        assert_eq!(nodes[0].q_local, vec![1]);
        assert!(nodes[0].q_global.is_empty());
        assert!(nodes[0].q_local_next.is_empty());
        assert_eq!(nodes[0].edges_this_level, 0);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let mut nodes = two_nodes();
        nodes[0].init_root(0);
        nodes[0].discover(3, 0);
        nodes[0].reset();
        assert!(nodes[0].d_local.iter().all(|&d| d == INF));
        assert!(nodes[0].q_local.is_empty());
        assert!(nodes[0].visited.is_empty());
    }
}
