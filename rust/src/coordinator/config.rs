//! Engine configuration: fanout, pattern choice, payload encoding,
//! backend, and the simulated hardware models.

use crate::bfs::kernels::KernelVariant;
use crate::net::model::{DeviceModel, NetModel, TopologyModel};

/// Which synchronization pattern Phase 2 uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PatternKind {
    /// The paper's butterfly network with the given fanout.
    Butterfly {
        /// Fanout `f` (1 = classic radix-2 butterfly).
        fanout: u32,
    },
    /// Single-round bulk all-to-all (naive baseline 1).
    AllToAllConcurrent,
    /// `CN−1` ring rounds (naive baseline 2).
    AllToAllIterative,
}

impl PatternKind {
    /// Build the pattern object.
    pub fn build(&self) -> Box<dyn crate::comm::CommPattern + Send + Sync> {
        match *self {
            PatternKind::Butterfly { fanout } => {
                Box::new(crate::comm::Butterfly::new(fanout))
            }
            PatternKind::AllToAllConcurrent => Box::new(crate::comm::ConcurrentAllToAll),
            PatternKind::AllToAllIterative => Box::new(crate::comm::IterativeAllToAll),
        }
    }

    /// Display name.
    pub fn name(&self) -> String {
        match *self {
            PatternKind::Butterfly { fanout } => format!("butterfly-f{fanout}"),
            PatternKind::AllToAllConcurrent => "alltoall-concurrent".to_string(),
            PatternKind::AllToAllIterative => "alltoall-iterative".to_string(),
        }
    }
}

/// Provisioned lane width of batched (MS-BFS) queries — how many 64-bit
/// mask words the engine monomorphizes
/// [`run_batch`](crate::coordinator::session::QuerySession::run_batch)
/// over, and therefore how many roots one butterfly exchange serves.
///
/// The width is a *floor*: a batch wider than the provisioned lanes
/// automatically widens to the smallest supported width that fits (up to
/// [`MAX_LANES`](crate::bfs::msbfs::MAX_LANES) = 512 roots), so the knob
/// matters for (a) pre-sizing pooled lane state and (b) pinning the wire
/// format — an experiment comparing chunked 64-root batches against one
/// wide batch can price both at the same per-entry cost by fixing the
/// width. Default [`BatchWidth::W64`] keeps the classic single-word
/// MS-BFS wire format (12-byte entries) for every batch of at most 64
/// roots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchWidth {
    /// One mask word: up to 64 lanes, 12-byte delta entries.
    #[default]
    W64,
    /// Two mask words: up to 128 lanes, 20-byte delta entries.
    W128,
    /// Four mask words: up to 256 lanes, 36-byte delta entries.
    W256,
    /// Eight mask words: up to 512 lanes, 68-byte delta entries.
    W512,
}

impl BatchWidth {
    /// Mask words this width provisions (1, 2, 4 or 8).
    pub fn words(&self) -> usize {
        match self {
            BatchWidth::W64 => 1,
            BatchWidth::W128 => 2,
            BatchWidth::W256 => 4,
            BatchWidth::W512 => 8,
        }
    }

    /// Lanes this width provisions (`64 · words`).
    pub fn lanes(&self) -> usize {
        self.words() * 64
    }

    /// Wire cost of one `(vertex, mask)` delta entry at this width
    /// (`4 + 8 · words` bytes).
    pub fn entry_bytes(&self) -> u64 {
        4 + 8 * self.words() as u64
    }

    /// Smallest width whose lane capacity covers `lanes` roots, or
    /// `None` when no supported width does (`lanes == 0`, or `lanes`
    /// exceeds [`MAX_LANES`](crate::bfs::msbfs::MAX_LANES) = 512).
    ///
    /// This is *checked on purpose*: the pre-PR-6 version mapped any
    /// over-wide request to [`BatchWidth::W512`] through a `_ =>` arm, so
    /// a library caller asking for 1024 lanes silently got a 512-lane
    /// engine and a confusing
    /// [`WidthTooLarge`](super::session::QueryError::WidthTooLarge) only
    /// once a too-wide batch actually ran. Over-wide configurations now fail at config
    /// time, with the request echoed back by the caller (the CLI and the
    /// serve admission path both route through here).
    pub fn for_lanes(lanes: usize) -> Option<Self> {
        if lanes == 0 || lanes > crate::bfs::msbfs::MAX_LANES {
            return None;
        }
        Some(match crate::bfs::msbfs::words_for_lanes(lanes) {
            1 => BatchWidth::W64,
            2 => BatchWidth::W128,
            4 => BatchWidth::W256,
            _ => BatchWidth::W512,
        })
    }

    /// Display name (`"64"` / `"128"` / `"256"` / `"512"`).
    pub fn name(&self) -> &'static str {
        match self {
            BatchWidth::W64 => "64",
            BatchWidth::W128 => "128",
            BatchWidth::W256 => "256",
            BatchWidth::W512 => "512",
        }
    }
}

/// How frontier payloads are encoded on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadEncoding {
    /// Explicit vertex list: `4·|queue|` bytes — cheap for sparse
    /// frontiers, unbounded worst case.
    Queue,
    /// Dense bitmap: `ceil(V/64)·8` bytes — the paper's tight bound,
    /// independent of frontier size.
    Bitmap,
    /// Per-message minimum of the two (what a production system would
    /// negotiate); still bounded by the bitmap size.
    Auto,
    /// Batched MS-BFS deltas at the single-word width: sparse
    /// `(vertex, 64-bit lane mask)` pairs at `12·|entries|` bytes
    /// (`MaskFrontier::<1>::ENTRY_BYTES`), bounded by the dense
    /// per-vertex mask array `8·V` (the negotiated fallback when the
    /// delta list outgrows it). One message serves up to 64 concurrent
    /// traversals; wider batches are priced by the width-aware negotiated
    /// encoding ([`mask_delta_bytes`](crate::bfs::msbfs::mask_delta_bytes))
    /// inside `run_batch` regardless of this setting.
    MaskDelta,
}

impl PayloadEncoding {
    /// Bytes on the wire for a message carrying `queue_len` entries
    /// (frontier vertices, or `(vertex, mask)` deltas for
    /// [`PayloadEncoding::MaskDelta`]) of a `num_vertices`-vertex graph.
    pub fn bytes(&self, queue_len: u64, num_vertices: usize) -> u64 {
        let q = queue_len * 4;
        let b = (num_vertices as u64).div_ceil(64) * 8;
        match self {
            PayloadEncoding::Queue => q,
            PayloadEncoding::Bitmap => b,
            PayloadEncoding::Auto => q.min(b),
            PayloadEncoding::MaskDelta => {
                (queue_len * crate::bfs::frontier::MaskFrontier::<1>::ENTRY_BYTES)
                    .min(num_vertices as u64 * 8)
            }
        }
    }
}

/// How the graph is laid out across compute nodes — the engine's
/// multi-pattern seam: each mode pairs a partition (who owns which
/// edges) with the synchronization schedule that matches it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionMode {
    /// The paper's 1D layout: contiguous edge-balanced vertex ranges,
    /// synchronized by the configured [`PatternKind`] (butterfly /
    /// all-to-all).
    OneD,
    /// Checkerboard 2D layout (Buluç & Madduri): a `rows × cols`
    /// processor grid over the adjacency matrix, synchronized by the
    /// fold-along-rows / expand-along-columns exchange
    /// ([`crate::comm::FoldExpand`]); [`PatternKind`] is ignored.
    /// Requires `num_nodes == rows·cols`.
    TwoD {
        /// Processor-grid rows (source-axis split).
        rows: u32,
        /// Processor-grid columns (target-axis split).
        cols: u32,
    },
    /// Hierarchical grid-of-islands layout
    /// ([`crate::comm::GridOfIslands`]): vertex ownership is the same
    /// contiguous edge-balanced 1D slab layout, assigned island-major
    /// (`rank = island·per_island + local`), but synchronization runs
    /// butterfly-within-island + representative exchange across islands.
    /// The butterfly fanout comes from [`PatternKind::Butterfly`] (other
    /// patterns fall back to fanout 1). Requires
    /// `num_nodes == islands·per_island`.
    Hierarchical {
        /// Number of islands (the slow axis).
        islands: u32,
        /// Compute nodes per island (the fast axis).
        per_island: u32,
    },
}

impl PartitionMode {
    /// Display name (`"1d"` / `"2d-RxC"` / `"hier-AxB"`).
    pub fn name(&self) -> String {
        match *self {
            PartitionMode::OneD => "1d".to_string(),
            PartitionMode::TwoD { rows, cols } => format!("2d-{rows}x{cols}"),
            PartitionMode::Hierarchical { islands, per_island } => {
                format!("hier-{islands}x{per_island}")
            }
        }
    }
}

/// Traversal direction policy for Phase 1 (the paper's contribution 3:
/// the butterfly sync composes with either formulation unchanged).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DirectionMode {
    /// Classic top-down only (the paper's evaluated configuration).
    TopDown,
    /// Bottom-up only (test/ablation vehicle).
    BottomUp,
    /// Direction-optimizing with GapBS-style α/β switching on *global*
    /// frontier statistics (the paper's "promising optimization").
    DirOpt {
        /// TD→BU switch divisor (GapBS default 15).
        alpha: u64,
        /// BU→TD switch divisor (GapBS default 18).
        beta: u64,
    },
}

impl DirectionMode {
    /// Direction-optimizing with GapBS defaults.
    pub fn diropt() -> Self {
        DirectionMode::DirOpt { alpha: 15, beta: 18 }
    }
}

/// Full engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of simulated compute nodes (GPUs).
    pub num_nodes: usize,
    /// Graph layout + exchange family (1D/butterfly or 2D/fold-expand).
    pub partition: PartitionMode,
    /// Synchronization pattern (1D mode; ignored by the 2D mode, whose
    /// schedule is fixed by the grid).
    pub pattern: PatternKind,
    /// Payload encoding.
    pub payload: PayloadEncoding,
    /// Provisioned lane width of batched queries (see [`BatchWidth`]).
    pub batch_width: BatchWidth,
    /// Use LRB binning in Phase 1.
    pub use_lrb: bool,
    /// Mask-kernel shape for the wide-lane hot loops (the `--kernel`
    /// CLI knob): scalar per-vertex sweeps, or chunked sweeps that skip
    /// settled 64-vertex chunks via summary words. Bit-identical
    /// results either way; only the deterministic work counters (and
    /// wallclock) differ.
    pub kernel: KernelVariant,
    /// Phase-1 direction policy.
    pub direction: DirectionMode,
    /// Run Phase 1 across worker threads (native backend only).
    pub parallel_phase1: bool,
    /// Run the Phase-2 merges across worker threads: each destination
    /// node's received transfers are replayed on its own worker (senders
    /// are frozen round-start snapshots, receivers are disjoint, and each
    /// receiver sees its transfers in schedule order — so pooled merging
    /// is bit-identical to sequential merging).
    pub parallel_phase2: bool,
    /// Interconnect model for simulated communication time (the uniform
    /// fallback when no [`topology`](Self::topology) is set).
    pub net: NetModel,
    /// Two-class interconnect topology, when the simulated cluster is not
    /// flat: `Some` prices every transfer per link class
    /// ([`crate::net::simulate_topology`]); `None` falls back to uniform
    /// pricing under [`net`](Self::net) — except in hierarchical mode,
    /// where transfers are still *classified* by island (so intra/inter
    /// counters stay meaningful) while both classes price as `net`.
    pub topology: Option<TopologyModel>,
    /// Device model for simulated compute time.
    pub device: DeviceModel,
}

impl EngineConfig {
    /// The paper's headline configuration: 16 nodes, fanout 4, DGX-2.
    pub fn dgx2(num_nodes: usize, fanout: u32) -> Self {
        Self {
            num_nodes,
            partition: PartitionMode::OneD,
            pattern: PatternKind::Butterfly { fanout },
            payload: PayloadEncoding::Auto,
            batch_width: BatchWidth::W64,
            use_lrb: true,
            kernel: KernelVariant::Auto,
            direction: DirectionMode::TopDown,
            parallel_phase1: false,
            parallel_phase2: false,
            net: NetModel::dgx2(),
            topology: None,
            device: DeviceModel::v100(),
        }
    }

    /// The 2D comparator on the same hardware models: a `rows × cols`
    /// fold/expand grid (`num_nodes = rows·cols`).
    pub fn dgx2_2d(rows: u32, cols: u32) -> Self {
        Self {
            partition: PartitionMode::TwoD { rows, cols },
            ..Self::dgx2((rows * cols) as usize, 1)
        }
    }

    /// A clustered hierarchical configuration: `islands × per_island`
    /// nodes in grid-of-islands mode, priced under the 10:1
    /// [`TopologyModel::dgx2_cluster`] topology.
    pub fn dgx2_cluster_hier(islands: u32, per_island: u32, fanout: u32) -> Self {
        Self {
            partition: PartitionMode::Hierarchical { islands, per_island },
            topology: Some(TopologyModel::dgx2_cluster(per_island)),
            ..Self::dgx2((islands * per_island) as usize, fanout)
        }
    }

    /// The topology every session prices its schedule under: the
    /// explicitly configured one, an island-classified uniform topology
    /// in hierarchical mode, or the flat uniform wrap of
    /// [`net`](Self::net).
    pub fn resolved_topology(&self) -> TopologyModel {
        if let Some(t) = self.topology {
            return t;
        }
        match self.partition {
            PartitionMode::Hierarchical { per_island, .. } => {
                TopologyModel::classified(self.net, per_island)
            }
            _ => TopologyModel::uniform(self.net),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_encoding_bytes() {
        // 100 vertices => bitmap = ceil(100/64)*8 = 16 bytes.
        assert_eq!(PayloadEncoding::Bitmap.bytes(50, 100), 16);
        assert_eq!(PayloadEncoding::Queue.bytes(50, 100), 200);
        assert_eq!(PayloadEncoding::Auto.bytes(50, 100), 16);
        assert_eq!(PayloadEncoding::Auto.bytes(2, 100), 8);
        // MaskDelta: 12 bytes/entry, capped at the dense 8·V mask array.
        assert_eq!(PayloadEncoding::MaskDelta.bytes(10, 100), 120);
        assert_eq!(PayloadEncoding::MaskDelta.bytes(90, 100), 800);
    }

    #[test]
    fn pattern_names() {
        assert_eq!(PatternKind::Butterfly { fanout: 4 }.name(), "butterfly-f4");
        assert_eq!(PatternKind::AllToAllConcurrent.name(), "alltoall-concurrent");
    }

    #[test]
    fn batch_width_knob() {
        assert_eq!(BatchWidth::default(), BatchWidth::W64);
        for (w, words, lanes, entry) in [
            (BatchWidth::W64, 1usize, 64usize, 12u64),
            (BatchWidth::W128, 2, 128, 20),
            (BatchWidth::W256, 4, 256, 36),
            (BatchWidth::W512, 8, 512, 68),
        ] {
            assert_eq!(w.words(), words);
            assert_eq!(w.lanes(), lanes);
            assert_eq!(w.entry_bytes(), entry);
            assert_eq!(BatchWidth::for_lanes(lanes), Some(w));
        }
        assert_eq!(BatchWidth::for_lanes(1), Some(BatchWidth::W64));
        assert_eq!(BatchWidth::for_lanes(65), Some(BatchWidth::W128));
        assert_eq!(BatchWidth::for_lanes(129), Some(BatchWidth::W256));
        assert_eq!(BatchWidth::for_lanes(257), Some(BatchWidth::W512));
        assert_eq!(BatchWidth::W256.name(), "256");
    }

    #[test]
    fn for_lanes_rejects_out_of_range_instead_of_clamping() {
        // The PR-6 bugfix regression: 513+ lanes used to silently clamp
        // to W512 (and 0 panicked inside words_for_lanes); both are now
        // config-time `None`s the caller can echo back.
        assert_eq!(BatchWidth::for_lanes(512), Some(BatchWidth::W512));
        assert_eq!(BatchWidth::for_lanes(0), None);
        assert_eq!(BatchWidth::for_lanes(513), None);
        assert_eq!(BatchWidth::for_lanes(1024), None);
        assert_eq!(BatchWidth::for_lanes(usize::MAX), None);
    }

    #[test]
    fn dgx2_preset() {
        let c = EngineConfig::dgx2(16, 4);
        assert_eq!(c.num_nodes, 16);
        assert_eq!(c.batch_width, BatchWidth::W64);
        assert_eq!(c.kernel, KernelVariant::Auto);
        assert_eq!(c.partition, PartitionMode::OneD);
        assert!(matches!(c.pattern, PatternKind::Butterfly { fanout: 4 }));
        assert_eq!(c.net.name, "dgx2-nvswitch");
    }

    #[test]
    fn dgx2_2d_preset_and_mode_names() {
        let c = EngineConfig::dgx2_2d(4, 8);
        assert_eq!(c.num_nodes, 32);
        assert_eq!(c.partition, PartitionMode::TwoD { rows: 4, cols: 8 });
        assert_eq!(c.partition.name(), "2d-4x8");
        assert_eq!(PartitionMode::OneD.name(), "1d");
        assert_eq!(
            PartitionMode::Hierarchical { islands: 8, per_island: 8 }.name(),
            "hier-8x8"
        );
    }

    #[test]
    fn cluster_hier_preset_and_topology_resolution() {
        let c = EngineConfig::dgx2_cluster_hier(8, 8, 4);
        assert_eq!(c.num_nodes, 64);
        assert_eq!(c.partition, PartitionMode::Hierarchical { islands: 8, per_island: 8 });
        assert!(matches!(c.pattern, PatternKind::Butterfly { fanout: 4 }));
        let topo = c.resolved_topology();
        assert_eq!(topo.name, "dgx2-cluster");
        assert_eq!(topo.per_island, 8);
        assert!((topo.speed_ratio() - 10.0).abs() < 1e-12);
        // Flat configs resolve to a uniform (single-island) topology...
        let flat = EngineConfig::dgx2(16, 4);
        assert_eq!(flat.resolved_topology().num_islands(16), 1);
        // ... while hierarchical mode under a flat net still classifies.
        let hier_flat = EngineConfig {
            partition: PartitionMode::Hierarchical { islands: 4, per_island: 4 },
            ..EngineConfig::dgx2(16, 4)
        };
        let t = hier_flat.resolved_topology();
        assert_eq!(t.num_islands(16), 4);
        assert!((t.speed_ratio() - 1.0).abs() < 1e-12);
    }
}
