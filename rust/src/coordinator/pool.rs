//! A reusable pool of [`QuerySession`]s over one shared
//! [`TraversalPlan`] — the service-facing follow-up to the plan/session
//! split: a request queue draws sessions from the pool instead of
//! constructing one per thread (or worse, per request), so the per-query
//! cost is a buffer reset, never an allocation of the per-vertex arrays.
//!
//! [`SessionPool::acquire`] pops an idle session (or builds one when the
//! pool is empty) behind a mutex; the returned [`PooledSession`] derefs
//! to [`QuerySession`] and hands the session back on drop. Sessions
//! circulate *dirty*: both checkout and return are a lock-push-pop, with
//! no O(V) buffer sweep on either path, because every query entry point
//! ([`run`](QuerySession::run) via `init_root`,
//! [`run_batch`](QuerySession::run_batch) via the lane-state
//! reset/rebuild) already clears exactly the state it uses. A dirty
//! session still exposes its previous query's results through the
//! live-view accessors (`assert_batch_agreement`, the legacy shims) —
//! call [`reset`](QuerySession::reset) explicitly if results must be
//! dropped before the next query runs.
//!
//! Pooled sessions are bit-identical to fresh ones (the pooled-reuse
//! invariant `tests` below pin across 4 threads × 8 queries): a session
//! holds no query state a reset does not clear.
//!
//! The pool is **panic-hardened** for service use: a query thread that
//! panics while holding a [`PooledSession`] (or even while inside the
//! pool's own lock) neither poisons the pool for every later caller nor
//! returns its possibly-torn session. Lock acquisition recovers from
//! poisoning (`PoisonError::into_inner` — the guarded `Vec` cannot be
//! left torn by a push/pop), and `PooledSession::drop` *discards* the
//! session when the thread is unwinding (`std::thread::panicking()`),
//! because a mid-query unwind can leave lane state that violates the
//! "the next query's reset clears everything" invariant. Other threads
//! keep acquiring and keep getting bit-identical results — the
//! regression tests below inject both failure modes.

use super::plan::TraversalPlan;
use super::session::QuerySession;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex, MutexGuard};

/// A mutex-guarded stack of idle [`QuerySession`]s over one plan.
///
/// ```
/// use butterfly_bfs::coordinator::{EngineConfig, SessionPool, TraversalPlan};
/// use butterfly_bfs::graph::gen::structured::path;
/// use std::sync::Arc;
///
/// let g = path(6);
/// let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1))?);
/// let pool = SessionPool::new(Arc::clone(&plan));
/// {
///     let mut session = pool.acquire();
///     assert_eq!(session.run(0)?.dist()[5], 5);
/// } // drop returns the session to the pool
/// assert_eq!(pool.idle(), 1);
/// let _reused = pool.acquire(); // same buffers; the next query resets them
/// assert_eq!(pool.idle(), 0);
/// # Ok::<(), Box<dyn std::error::Error>>(())
/// ```
pub struct SessionPool {
    plan: Arc<TraversalPlan>,
    idle: Mutex<Vec<QuerySession>>,
}

impl SessionPool {
    /// Lock the idle stack, *recovering* from poisoning: the guarded
    /// state is a plain `Vec<QuerySession>` whose push/pop cannot leave
    /// it torn, so a panic on some other thread while it held this lock
    /// must not cascade into every later `acquire()`/`idle()` (and — the
    /// fatal case — into `PooledSession::drop` during an unwind, which
    /// would abort the process). The panicking thread's *session* is the
    /// only state that may be mid-query inconsistent, and that session is
    /// discarded, not returned (see [`PooledSession`]'s `Drop`).
    fn idle_lock(&self) -> MutexGuard<'_, Vec<QuerySession>> {
        self.idle.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
    /// An empty pool over `plan`; sessions are built lazily on
    /// [`acquire`](Self::acquire) misses (with the plan's native
    /// backends) and accumulate up to the peak concurrency actually
    /// reached.
    pub fn new(plan: Arc<TraversalPlan>) -> Self {
        Self { plan, idle: Mutex::new(Vec::new()) }
    }

    /// The shared plan this pool's sessions run over.
    pub fn plan(&self) -> &Arc<TraversalPlan> {
        &self.plan
    }

    /// Number of sessions currently idle in the pool.
    pub fn idle(&self) -> usize {
        self.idle_lock().len()
    }

    /// Check out a session — an idle one, or a fresh one when the pool
    /// is empty. The guard returns the session on drop. No reset happens
    /// here: `run`/`run_batch` clear exactly the state they use on
    /// entry, so checkout stays O(1) even after a wide batch left large
    /// lane buffers behind.
    pub fn acquire(&self) -> PooledSession<'_> {
        let session = self.idle_lock().pop().unwrap_or_else(|| self.plan.session());
        PooledSession { pool: self, session: Some(session) }
    }
}

/// RAII guard of one checked-out [`QuerySession`]; derefs to the session
/// and returns it to its [`SessionPool`] on drop.
pub struct PooledSession<'a> {
    pool: &'a SessionPool,
    /// `Some` until drop (taken exactly once there).
    session: Option<QuerySession>,
}

impl Deref for PooledSession<'_> {
    type Target = QuerySession;

    fn deref(&self) -> &QuerySession {
        self.session.as_ref().expect("session present until drop")
    }
}

impl DerefMut for PooledSession<'_> {
    fn deref_mut(&mut self) -> &mut QuerySession {
        self.session.as_mut().expect("session present until drop")
    }
}

impl Drop for PooledSession<'_> {
    fn drop(&mut self) {
        // A drop that runs while this thread is unwinding means the
        // session may have been abandoned mid-query: lane state, queues,
        // and distance arrays can be torn in ways the per-query entry
        // resets were never designed to repair (they clear exactly the
        // state a *completed* query used). Discard the session instead of
        // returning it — the pool rebuilds on the next acquire miss — so
        // the "pooled == fresh, bit-identical" invariant survives a
        // panicking query thread.
        if std::thread::panicking() {
            self.session.take();
            return;
        }
        if let Some(s) = self.session.take() {
            self.pool.idle_lock().push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::coordinator::EngineConfig;
    use crate::graph::csr::VertexId;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn acquire_reuses_and_grows_on_demand() {
        let (g, _) = uniform_random(200, 5, 3);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        assert_eq!(pool.idle(), 0);
        {
            let _a = pool.acquire();
            let _b = pool.acquire(); // concurrent checkout forces a second session
            assert_eq!(pool.idle(), 0);
        }
        assert_eq!(pool.idle(), 2);
        {
            let _c = pool.acquire(); // reuses, does not grow
            assert_eq!(pool.idle(), 1);
        }
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn pooled_queries_bit_identical_to_fresh_sessions() {
        // The satellite smoke: 4 threads × 8 queries each (single-root
        // and batched, interleaved) through one pool, every result
        // bit-identical to a fresh session on the same plan.
        let (g, _) = uniform_random(400, 6, 17);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let pool = &pool;
                let plan = &plan;
                let g = &g;
                scope.spawn(move || {
                    for q in 0..8u32 {
                        let mut session = pool.acquire();
                        if q % 2 == 0 {
                            let root = (t * 97 + q * 13) % 400;
                            let r = session.run(root).unwrap();
                            assert_eq!(r.dist(), &serial_bfs(g, root)[..]);
                            let fresh = plan.session().run(root).unwrap();
                            assert_eq!(r.dist(), fresh.dist());
                            assert_eq!(r.metrics().bytes(), fresh.metrics().bytes());
                        } else {
                            // Vary the batch width across the word sizes.
                            let width = [3usize, 65, 130][(q as usize / 2) % 3];
                            let roots: Vec<VertexId> = (0..width)
                                .map(|i| ((t as usize * 31 + i * 7) % 400) as VertexId)
                                .collect();
                            let b = session.run_batch(&roots).unwrap();
                            session.assert_batch_agreement().unwrap();
                            let fresh = plan.session().run_batch(&roots).unwrap();
                            for lane in 0..width {
                                assert_eq!(
                                    b.dist(lane),
                                    fresh.dist(lane),
                                    "t={t} q={q} lane={lane}"
                                );
                            }
                            assert_eq!(b.metrics().bytes(), fresh.metrics().bytes());
                        }
                    }
                });
            }
        });
        // Everything came back.
        assert!(pool.idle() >= 1 && pool.idle() <= 4);
    }

    #[test]
    fn panicking_query_thread_does_not_poison_the_pool() {
        // The PR-6 bugfix regression: one thread panics mid-query while
        // holding a pooled session. Before the fix this poisoned the
        // idle mutex (every later acquire()/idle() panicked, and a
        // PooledSession dropped during another unwind aborted the
        // process); the session it held could also have been returned
        // with torn lane state. After the fix: the session is discarded,
        // the pool stays usable, and results stay bit-identical.
        let (g, _) = uniform_random(300, 5, 9);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        // Warm the pool so the panicking thread reuses a pooled session.
        drop(pool.acquire());
        assert_eq!(pool.idle(), 1);
        let panicked = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let mut session = pool.acquire();
                    // A query has run: the session holds live state...
                    session.run(5).unwrap();
                    // ...and the thread dies before the query cycle
                    // completes cleanly.
                    panic!("injected mid-query panic");
                })
                .join()
        });
        assert!(panicked.is_err(), "the injected panic must propagate to join()");
        // The dirty session was discarded, not returned.
        assert_eq!(pool.idle(), 0, "a mid-panic session must not re-enter the pool");
        // Other threads keep acquiring, and pooled results stay
        // bit-identical to fresh sessions.
        std::thread::scope(|scope| {
            for t in 0..3u32 {
                let pool = &pool;
                let plan = &plan;
                let g = &g;
                scope.spawn(move || {
                    let root = t * 37 % 300;
                    let mut session = pool.acquire();
                    let r = session.run(root).unwrap();
                    assert_eq!(r.dist(), &serial_bfs(g, root)[..]);
                    let fresh = plan.session().run(root).unwrap();
                    assert_eq!(r.dist(), fresh.dist());
                    assert_eq!(r.metrics().bytes(), fresh.metrics().bytes());
                });
            }
        });
        assert!(pool.idle() >= 1);
    }

    #[test]
    fn poisoned_idle_lock_is_recovered() {
        // Poison the idle mutex directly (a panic while the lock itself
        // is held — the narrowest window of the old cascade) and check
        // every public path still works instead of propagating the
        // poison: acquire, checkout count, and the return-on-drop.
        let (g, _) = uniform_random(120, 4, 2);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        drop(pool.acquire()); // one idle session
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = pool.idle.lock().unwrap();
            panic!("poison the pool lock");
        }));
        assert!(result.is_err());
        assert!(pool.idle.is_poisoned(), "test precondition: lock is poisoned");
        assert_eq!(pool.idle(), 1);
        {
            let mut s = pool.acquire();
            assert_eq!(pool.idle(), 0);
            let r = s.run(3).unwrap();
            assert_eq!(r.dist(), &serial_bfs(&g, 3)[..]);
        } // drop returns the session through the poisoned lock
        assert_eq!(pool.idle(), 1);
    }
}
