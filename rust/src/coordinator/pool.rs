//! A reusable pool of [`QuerySession`]s over one shared
//! [`TraversalPlan`] — the service-facing follow-up to the plan/session
//! split: a request queue draws sessions from the pool instead of
//! constructing one per thread (or worse, per request), so the per-query
//! cost is a buffer reset, never an allocation of the per-vertex arrays.
//!
//! [`SessionPool::acquire`] pops an idle session (or builds one when the
//! pool is empty) behind a mutex; the returned [`PooledSession`] derefs
//! to [`QuerySession`] and hands the session back on drop. Sessions
//! circulate *dirty*: both checkout and return are a lock-push-pop, with
//! no O(V) buffer sweep on either path, because every query entry point
//! ([`run`](QuerySession::run) via `init_root`,
//! [`run_batch`](QuerySession::run_batch) via the lane-state
//! reset/rebuild) already clears exactly the state it uses. A dirty
//! session still exposes its previous query's results through the
//! live-view accessors (`assert_batch_agreement`, the legacy shims) —
//! call [`reset`](QuerySession::reset) explicitly if results must be
//! dropped before the next query runs.
//!
//! Pooled sessions are bit-identical to fresh ones (the pooled-reuse
//! invariant `tests` below pin across 4 threads × 8 queries): a session
//! holds no query state a reset does not clear.

use super::plan::TraversalPlan;
use super::session::QuerySession;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

/// A mutex-guarded stack of idle [`QuerySession`]s over one plan.
///
/// ```
/// use butterfly_bfs::coordinator::{EngineConfig, SessionPool, TraversalPlan};
/// use butterfly_bfs::graph::gen::structured::path;
/// use std::sync::Arc;
///
/// let g = path(6);
/// let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1))?);
/// let pool = SessionPool::new(Arc::clone(&plan));
/// {
///     let mut session = pool.acquire();
///     assert_eq!(session.run(0)?.dist()[5], 5);
/// } // drop returns the session to the pool
/// assert_eq!(pool.idle(), 1);
/// let _reused = pool.acquire(); // same buffers; the next query resets them
/// assert_eq!(pool.idle(), 0);
/// # Ok::<(), Box<dyn std::error::Error>>(())
/// ```
pub struct SessionPool {
    plan: Arc<TraversalPlan>,
    idle: Mutex<Vec<QuerySession>>,
}

impl SessionPool {
    /// An empty pool over `plan`; sessions are built lazily on
    /// [`acquire`](Self::acquire) misses (with the plan's native
    /// backends) and accumulate up to the peak concurrency actually
    /// reached.
    pub fn new(plan: Arc<TraversalPlan>) -> Self {
        Self { plan, idle: Mutex::new(Vec::new()) }
    }

    /// The shared plan this pool's sessions run over.
    pub fn plan(&self) -> &Arc<TraversalPlan> {
        &self.plan
    }

    /// Number of sessions currently idle in the pool.
    pub fn idle(&self) -> usize {
        self.idle.lock().expect("pool lock").len()
    }

    /// Check out a session — an idle one, or a fresh one when the pool
    /// is empty. The guard returns the session on drop. No reset happens
    /// here: `run`/`run_batch` clear exactly the state they use on
    /// entry, so checkout stays O(1) even after a wide batch left large
    /// lane buffers behind.
    pub fn acquire(&self) -> PooledSession<'_> {
        let session = self
            .idle
            .lock()
            .expect("pool lock")
            .pop()
            .unwrap_or_else(|| self.plan.session());
        PooledSession { pool: self, session: Some(session) }
    }
}

/// RAII guard of one checked-out [`QuerySession`]; derefs to the session
/// and returns it to its [`SessionPool`] on drop.
pub struct PooledSession<'a> {
    pool: &'a SessionPool,
    /// `Some` until drop (taken exactly once there).
    session: Option<QuerySession>,
}

impl Deref for PooledSession<'_> {
    type Target = QuerySession;

    fn deref(&self) -> &QuerySession {
        self.session.as_ref().expect("session present until drop")
    }
}

impl DerefMut for PooledSession<'_> {
    fn deref_mut(&mut self) -> &mut QuerySession {
        self.session.as_mut().expect("session present until drop")
    }
}

impl Drop for PooledSession<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.session.take() {
            self.pool.idle.lock().expect("pool lock").push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::coordinator::EngineConfig;
    use crate::graph::csr::VertexId;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn acquire_reuses_and_grows_on_demand() {
        let (g, _) = uniform_random(200, 5, 3);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        assert_eq!(pool.idle(), 0);
        {
            let _a = pool.acquire();
            let _b = pool.acquire(); // concurrent checkout forces a second session
            assert_eq!(pool.idle(), 0);
        }
        assert_eq!(pool.idle(), 2);
        {
            let _c = pool.acquire(); // reuses, does not grow
            assert_eq!(pool.idle(), 1);
        }
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn pooled_queries_bit_identical_to_fresh_sessions() {
        // The satellite smoke: 4 threads × 8 queries each (single-root
        // and batched, interleaved) through one pool, every result
        // bit-identical to a fresh session on the same plan.
        let (g, _) = uniform_random(400, 6, 17);
        let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
        let pool = SessionPool::new(Arc::clone(&plan));
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let pool = &pool;
                let plan = &plan;
                let g = &g;
                scope.spawn(move || {
                    for q in 0..8u32 {
                        let mut session = pool.acquire();
                        if q % 2 == 0 {
                            let root = (t * 97 + q * 13) % 400;
                            let r = session.run(root).unwrap();
                            assert_eq!(r.dist(), &serial_bfs(g, root)[..]);
                            let fresh = plan.session().run(root).unwrap();
                            assert_eq!(r.dist(), fresh.dist());
                            assert_eq!(r.metrics().bytes(), fresh.metrics().bytes());
                        } else {
                            // Vary the batch width across the word sizes.
                            let width = [3usize, 65, 130][(q as usize / 2) % 3];
                            let roots: Vec<VertexId> = (0..width)
                                .map(|i| ((t as usize * 31 + i * 7) % 400) as VertexId)
                                .collect();
                            let b = session.run_batch(&roots).unwrap();
                            session.assert_batch_agreement().unwrap();
                            let fresh = plan.session().run_batch(&roots).unwrap();
                            for lane in 0..width {
                                assert_eq!(
                                    b.dist(lane),
                                    fresh.dist(lane),
                                    "t={t} q={q} lane={lane}"
                                );
                            }
                            assert_eq!(b.metrics().bytes(), fresh.metrics().bytes());
                        }
                    }
                });
            }
        });
        // Everything came back.
        assert!(pool.idle() >= 1 && pool.idle() <= 4);
    }
}
