//! Per-level and per-run metrics for the distributed engine: everything
//! the paper's evaluation reports (times, GTEPS, message/byte counts,
//! per-phase split) plus the simulated-device timeline (DESIGN.md §2).

use crate::net::sim::CommTiming;
use crate::util::json::Json;
use crate::util::stats::gteps;

/// Metrics of one BFS level.
#[derive(Clone, Debug, Default)]
pub struct LevelMetrics {
    /// Level index.
    pub level: u32,
    /// Total active (owned) frontier vertices entering the level.
    pub frontier: u64,
    /// Edges examined across all nodes in Phase 1.
    pub edges_examined: u64,
    /// Max edges examined by any single node (load-balance signal).
    pub max_node_edges: u64,
    /// New vertices discovered (deduped, global).
    pub discovered: u64,
    /// Butterfly/all-to-all/fold+expand messages this level.
    pub messages: u64,
    /// Bytes shipped this level.
    pub bytes: u64,
    /// 2D mode: messages in the fold (row-exchange) rounds; 0 in 1D mode.
    pub fold_messages: u64,
    /// 2D mode: bytes in the fold rounds; 0 in 1D mode.
    pub fold_bytes: u64,
    /// 2D mode: messages in the expand (column-exchange) rounds; 0 in 1D.
    pub expand_messages: u64,
    /// 2D mode: bytes in the expand rounds; 0 in 1D mode.
    pub expand_bytes: u64,
    /// Messages priced on intra-island links (all of them under a flat
    /// [`TopologyModel::uniform`](crate::net::TopologyModel::uniform)).
    pub intra_messages: u64,
    /// Bytes shipped over intra-island links.
    pub intra_bytes: u64,
    /// Messages crossing an island boundary (island-uplink class); 0
    /// under a uniform topology.
    pub inter_messages: u64,
    /// Bytes crossing an island boundary.
    pub inter_bytes: u64,
    /// Simulated Phase-1 compute time (slowest node).
    pub sim_compute: f64,
    /// Simulated Phase-2 communication time.
    pub sim_comm: f64,
    /// Direction tag: true when Phase 1 ran bottom-up this level (the
    /// direction-optimizing trace; always false under pure top-down).
    pub bottom_up: bool,
    /// Retransmissions performed this level recovering from injected
    /// faults (0 on a fault-free run).
    pub retries: u64,
    /// Bytes re-shipped by those retransmissions — extra wire traffic on
    /// top of `bytes`, priced per link class.
    pub retry_bytes: u64,
    /// Simulated time spent in fault recovery this level: exponential
    /// backoff plus per-retransmission wire time
    /// ([`retransmit_time`](crate::net::sim::retransmit_time)); additive
    /// on top of `sim_comm`.
    pub recovery_time: f64,
    /// Mask words actually read or written by the level's kernels
    /// (Phase-1 sweeps plus Phase-2 merges; see
    /// [`KernelWork`](crate::bfs::kernels::KernelWork)). Deterministic —
    /// a function of graph, roots and kernel variant, not of wallclock.
    pub words_touched: u64,
    /// Mask words provably skipped by the chunked kernels' summary
    /// words (fully-settled chunk runs, untouched dense-merge rows);
    /// always 0 under the scalar variant.
    pub words_skipped: u64,
    /// Phase-1 kernel dispatches issued this level (per degree-bin under
    /// LRB, per chunk block / nonempty node otherwise). Phase-2 merges
    /// contribute word traffic but no dispatches.
    pub dispatches: u64,
    /// Largest single-dispatch work item this level — the tail-latency
    /// signal LRB binning is meant to shrink.
    pub dispatch_max_work: u64,
}

impl LevelMetrics {
    /// Direction tag as the CLI/JSON spelling.
    pub fn direction_name(&self) -> &'static str {
        if self.bottom_up {
            "bottomup"
        } else {
            "topdown"
        }
    }
}

/// Metrics of a full traversal.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Per-level breakdown.
    pub levels: Vec<LevelMetrics>,
    /// Measured wallclock of the whole traversal (this process).
    pub wall_seconds: f64,
    /// Number of vertices reached.
    pub reached: u64,
    /// |E| of the input graph (for the Graph500 TEPS convention).
    pub graph_edges: u64,
}

impl RunMetrics {
    /// Simulated end-to-end device time: Σ levels (compute + comm).
    pub fn sim_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.sim_compute + l.sim_comm).sum()
    }

    /// Simulated communication share of total time — the paper contrasts
    /// its small share against Gluon's ~70 % (§2 Multi-GPU BFS).
    pub fn sim_comm_fraction(&self) -> f64 {
        let total = self.sim_seconds();
        if total == 0.0 {
            return 0.0;
        }
        self.levels.iter().map(|l| l.sim_comm).sum::<f64>() / total
    }

    /// Total edges examined.
    pub fn edges_examined(&self) -> u64 {
        self.levels.iter().map(|l| l.edges_examined).sum()
    }

    /// Total messages.
    pub fn messages(&self) -> u64 {
        self.levels.iter().map(|l| l.messages).sum()
    }

    /// Total bytes shipped.
    pub fn bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.bytes).sum()
    }

    /// Graph500-convention GTEPS on the simulated clock (|E| / time — the
    /// convention the paper reports and critiques in §2).
    pub fn sim_gteps(&self) -> f64 {
        gteps(self.graph_edges, self.sim_seconds())
    }

    /// Honest GTEPS: actually-examined edges / simulated time.
    pub fn sim_honest_gteps(&self) -> f64 {
        gteps(self.edges_examined(), self.sim_seconds())
    }

    /// Number of BFS levels.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Levels that ran bottom-up (the direction-optimizing trace).
    pub fn bottom_up_levels(&self) -> u64 {
        self.levels.iter().filter(|l| l.bottom_up).count() as u64
    }

    /// Edges inspected by bottom-up levels only.
    pub fn bottom_up_edges(&self) -> u64 {
        self.levels
            .iter()
            .filter(|l| l.bottom_up)
            .map(|l| l.edges_examined)
            .sum()
    }

    /// Total fold-phase (row-exchange) messages — nonzero only in 2D mode.
    pub fn fold_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.fold_messages).sum()
    }

    /// Total fold-phase bytes — nonzero only in 2D mode.
    pub fn fold_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.fold_bytes).sum()
    }

    /// Total expand-phase (column-exchange) messages — nonzero only in 2D.
    pub fn expand_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.expand_messages).sum()
    }

    /// Total expand-phase bytes — nonzero only in 2D mode.
    pub fn expand_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.expand_bytes).sum()
    }

    /// Total intra-island messages (everything under a uniform topology).
    pub fn intra_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.intra_messages).sum()
    }

    /// Total intra-island bytes.
    pub fn intra_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.intra_bytes).sum()
    }

    /// Total island-crossing messages — 0 under a uniform topology.
    pub fn inter_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.inter_messages).sum()
    }

    /// Total island-crossing bytes.
    pub fn inter_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.inter_bytes).sum()
    }

    /// Total fault-recovery retransmissions (0 on a fault-free run).
    pub fn retries(&self) -> u64 {
        self.levels.iter().map(|l| l.retries).sum()
    }

    /// Total bytes re-shipped by fault-recovery retransmissions.
    pub fn retry_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.retry_bytes).sum()
    }

    /// Total simulated time spent recovering from faults.
    pub fn recovery_time(&self) -> f64 {
        self.levels.iter().map(|l| l.recovery_time).sum()
    }

    /// Simulated end-to-end time including fault recovery:
    /// [`sim_seconds`](Self::sim_seconds) + [`recovery_time`](Self::recovery_time).
    pub fn sim_seconds_with_recovery(&self) -> f64 {
        self.sim_seconds() + self.recovery_time()
    }

    /// Total mask words the kernels actually read or wrote.
    pub fn words_touched(&self) -> u64 {
        self.levels.iter().map(|l| l.words_touched).sum()
    }

    /// Total mask words provably skipped by chunked summary words.
    pub fn words_skipped(&self) -> u64 {
        self.levels.iter().map(|l| l.words_skipped).sum()
    }

    /// Total kernel dispatches issued.
    pub fn dispatches(&self) -> u64 {
        self.levels.iter().map(|l| l.dispatches).sum()
    }

    /// Largest single-dispatch work item over the whole run.
    pub fn dispatch_max_work(&self) -> u64 {
        self.levels.iter().map(|l| l.dispatch_max_work).max().unwrap_or(0)
    }

    /// Record one level from raw phase outputs.
    pub fn push_level(
        &mut self,
        level: u32,
        frontier: u64,
        edges_examined: u64,
        max_node_edges: u64,
        discovered: u64,
        comm: &CommTiming,
        sim_compute: f64,
        bottom_up: bool,
    ) {
        self.levels.push(LevelMetrics {
            level,
            frontier,
            edges_examined,
            max_node_edges,
            discovered,
            messages: comm.total_messages,
            bytes: comm.total_bytes,
            intra_messages: comm.intra_messages,
            intra_bytes: comm.intra_bytes,
            inter_messages: comm.inter_messages,
            inter_bytes: comm.inter_bytes,
            sim_compute,
            sim_comm: comm.total(),
            bottom_up,
            ..Default::default()
        });
    }

    /// JSON dump for the machine-readable bench logs.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wall_seconds", Json::n(self.wall_seconds)),
            ("sim_seconds", Json::n(self.sim_seconds())),
            ("sim_gteps", Json::n(self.sim_gteps())),
            ("sim_comm_fraction", Json::n(self.sim_comm_fraction())),
            ("reached", Json::u(self.reached)),
            ("depth", Json::u(self.depth() as u64)),
            ("edges_examined", Json::u(self.edges_examined())),
            ("bottom_up_levels", Json::u(self.bottom_up_levels())),
            ("bottom_up_edges", Json::u(self.bottom_up_edges())),
            ("messages", Json::u(self.messages())),
            ("bytes", Json::u(self.bytes())),
            ("fold_messages", Json::u(self.fold_messages())),
            ("fold_bytes", Json::u(self.fold_bytes())),
            ("expand_messages", Json::u(self.expand_messages())),
            ("expand_bytes", Json::u(self.expand_bytes())),
            ("intra_messages", Json::u(self.intra_messages())),
            ("intra_bytes", Json::u(self.intra_bytes())),
            ("inter_messages", Json::u(self.inter_messages())),
            ("inter_bytes", Json::u(self.inter_bytes())),
            ("retries", Json::u(self.retries())),
            ("retry_bytes", Json::u(self.retry_bytes())),
            ("recovery_time", Json::n(self.recovery_time())),
            ("words_touched", Json::u(self.words_touched())),
            ("words_skipped", Json::u(self.words_skipped())),
            ("dispatches", Json::u(self.dispatches())),
            ("dispatch_max_work", Json::u(self.dispatch_max_work())),
            (
                "levels",
                Json::Arr(
                    self.levels
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("level", Json::u(l.level as u64)),
                                ("frontier", Json::u(l.frontier)),
                                ("edges", Json::u(l.edges_examined)),
                                ("discovered", Json::u(l.discovered)),
                                ("messages", Json::u(l.messages)),
                                ("bytes", Json::u(l.bytes)),
                                ("direction", Json::s(l.direction_name())),
                                ("sim_compute", Json::n(l.sim_compute)),
                                ("sim_comm", Json::n(l.sim_comm)),
                                ("words_touched", Json::u(l.words_touched)),
                                ("words_skipped", Json::u(l.words_skipped)),
                                ("dispatches", Json::u(l.dispatches)),
                                ("dispatch_max_work", Json::u(l.dispatch_max_work)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Accumulated synchronization totals of running a set of roots one at a
/// time through the single-root engine — the baseline
/// [`run_batch`](crate::coordinator::session::QuerySession::run_batch) is
/// compared against (see
/// [`sequential_baseline`](crate::coordinator::session::QuerySession::sequential_baseline)).
#[derive(Clone, Copy, Debug, Default)]
pub struct SequentialBaseline {
    /// Total bytes shipped across all runs.
    pub bytes: u64,
    /// Total messages across all runs.
    pub messages: u64,
    /// Total synchronization rounds: Σ runs (levels × schedule depth).
    pub sync_rounds: u64,
    /// Total simulated device time across all runs.
    pub sim_seconds: f64,
}

/// Metrics of one batched multi-source traversal
/// ([`run_batch`](crate::coordinator::session::QuerySession::run_batch)):
/// the same per-level breakdown as [`RunMetrics`], but one level now
/// advances up to 64 traversals, so `levels`/`sync_rounds`/`bytes` are
/// *shared* across the whole batch. `LevelMetrics::frontier` counts active
/// owned vertices (not `(vertex, lane)` pairs); `LevelMetrics::discovered`
/// counts newly-set `(vertex, lane)` pairs.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    /// Batch width (lanes).
    pub num_roots: usize,
    /// Lane-mask words (`W`) the batch was monomorphized over — the
    /// per-width byte accounting key: delta entries cost `4 + 8·W` bytes
    /// on the wire, and one exchange serves up to `64·W` roots.
    pub lane_words: usize,
    /// Per-level breakdown (shared by all lanes).
    pub levels: Vec<LevelMetrics>,
    /// Total synchronization rounds executed: schedule depth × levels —
    /// the quantity the butterfly amortizes across the batch.
    pub sync_rounds: u64,
    /// Measured wallclock of the whole batch (this process).
    pub wall_seconds: f64,
    /// |E| of the input graph.
    pub graph_edges: u64,
    /// Total `(root, vertex)` pairs reached.
    pub reached_pairs: u64,
}

impl BatchMetrics {
    /// Simulated end-to-end device time: Σ levels (compute + comm).
    pub fn sim_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.sim_compute + l.sim_comm).sum()
    }

    /// Lane capacity one exchange served: `64 · lane_words`. The
    /// amortization headline — sync rounds per level are width-invariant,
    /// so widening the mask divides rounds-per-root by this.
    pub fn lanes_per_exchange(&self) -> usize {
        64 * self.lane_words
    }

    /// Wire cost of one sparse delta entry at this batch's width
    /// (`4 + 8·lane_words` bytes).
    pub fn entry_bytes(&self) -> u64 {
        4 + 8 * self.lane_words as u64
    }

    /// Total edges examined (each edge expansion serves every active lane
    /// of its frontier vertex at once).
    pub fn edges_examined(&self) -> u64 {
        self.levels.iter().map(|l| l.edges_examined).sum()
    }

    /// Total messages across the batch.
    pub fn messages(&self) -> u64 {
        self.levels.iter().map(|l| l.messages).sum()
    }

    /// Total bytes shipped across the batch.
    pub fn bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.bytes).sum()
    }

    /// Total fold-phase messages — nonzero only in 2D mode.
    pub fn fold_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.fold_messages).sum()
    }

    /// Total fold-phase bytes — nonzero only in 2D mode.
    pub fn fold_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.fold_bytes).sum()
    }

    /// Total expand-phase messages — nonzero only in 2D mode.
    pub fn expand_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.expand_messages).sum()
    }

    /// Total expand-phase bytes — nonzero only in 2D mode.
    pub fn expand_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.expand_bytes).sum()
    }

    /// Total intra-island messages (everything under a uniform topology).
    pub fn intra_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.intra_messages).sum()
    }

    /// Total intra-island bytes.
    pub fn intra_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.intra_bytes).sum()
    }

    /// Total island-crossing messages — 0 under a uniform topology.
    pub fn inter_messages(&self) -> u64 {
        self.levels.iter().map(|l| l.inter_messages).sum()
    }

    /// Total island-crossing bytes.
    pub fn inter_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.inter_bytes).sum()
    }

    /// Number of levels (the max depth over the batch's lanes).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Levels that ran bottom-up — the batched direction-optimizing
    /// trace (0 under pure top-down or when a backend lacks the batched
    /// bottom-up kernel and the batch degraded).
    pub fn bottom_up_levels(&self) -> u64 {
        self.levels.iter().filter(|l| l.bottom_up).count() as u64
    }

    /// Edges inspected by bottom-up levels only.
    pub fn bottom_up_edges(&self) -> u64 {
        self.levels
            .iter()
            .filter(|l| l.bottom_up)
            .map(|l| l.edges_examined)
            .sum()
    }

    /// Total fault-recovery retransmissions (0 on a fault-free run).
    pub fn retries(&self) -> u64 {
        self.levels.iter().map(|l| l.retries).sum()
    }

    /// Total bytes re-shipped by fault-recovery retransmissions.
    pub fn retry_bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.retry_bytes).sum()
    }

    /// Total simulated time spent recovering from faults.
    pub fn recovery_time(&self) -> f64 {
        self.levels.iter().map(|l| l.recovery_time).sum()
    }

    /// Simulated end-to-end time including fault recovery:
    /// [`sim_seconds`](Self::sim_seconds) + [`recovery_time`](Self::recovery_time).
    pub fn sim_seconds_with_recovery(&self) -> f64 {
        self.sim_seconds() + self.recovery_time()
    }

    /// Total mask words the kernels actually read or wrote.
    pub fn words_touched(&self) -> u64 {
        self.levels.iter().map(|l| l.words_touched).sum()
    }

    /// Total mask words provably skipped by chunked summary words.
    pub fn words_skipped(&self) -> u64 {
        self.levels.iter().map(|l| l.words_skipped).sum()
    }

    /// Total kernel dispatches issued.
    pub fn dispatches(&self) -> u64 {
        self.levels.iter().map(|l| l.dispatches).sum()
    }

    /// Largest single-dispatch work item over the whole batch.
    pub fn dispatch_max_work(&self) -> u64 {
        self.levels.iter().map(|l| l.dispatch_max_work).max().unwrap_or(0)
    }

    /// Synchronization bytes amortized per root — the headline
    /// `msbfs_amortization` comparison against a single run's
    /// [`RunMetrics::bytes`].
    pub fn bytes_per_root(&self) -> f64 {
        self.bytes() as f64 / self.num_roots.max(1) as f64
    }

    /// Simulated time amortized per root.
    pub fn sim_seconds_per_root(&self) -> f64 {
        self.sim_seconds() / self.num_roots.max(1) as f64
    }

    /// JSON dump for the machine-readable bench logs.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_roots", Json::u(self.num_roots as u64)),
            ("lane_words", Json::u(self.lane_words as u64)),
            ("lanes_per_exchange", Json::u(self.lanes_per_exchange() as u64)),
            ("wall_seconds", Json::n(self.wall_seconds)),
            ("sim_seconds", Json::n(self.sim_seconds())),
            ("depth", Json::u(self.depth() as u64)),
            ("sync_rounds", Json::u(self.sync_rounds)),
            ("edges_examined", Json::u(self.edges_examined())),
            ("bottom_up_levels", Json::u(self.bottom_up_levels())),
            ("bottom_up_edges", Json::u(self.bottom_up_edges())),
            ("messages", Json::u(self.messages())),
            ("bytes", Json::u(self.bytes())),
            ("fold_messages", Json::u(self.fold_messages())),
            ("fold_bytes", Json::u(self.fold_bytes())),
            ("expand_messages", Json::u(self.expand_messages())),
            ("expand_bytes", Json::u(self.expand_bytes())),
            ("intra_messages", Json::u(self.intra_messages())),
            ("intra_bytes", Json::u(self.intra_bytes())),
            ("inter_messages", Json::u(self.inter_messages())),
            ("inter_bytes", Json::u(self.inter_bytes())),
            ("retries", Json::u(self.retries())),
            ("retry_bytes", Json::u(self.retry_bytes())),
            ("recovery_time", Json::n(self.recovery_time())),
            ("words_touched", Json::u(self.words_touched())),
            ("words_skipped", Json::u(self.words_skipped())),
            ("dispatches", Json::u(self.dispatches())),
            ("dispatch_max_work", Json::u(self.dispatch_max_work())),
            ("bytes_per_root", Json::n(self.bytes_per_root())),
            ("reached_pairs", Json::u(self.reached_pairs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(msgs: u64, bytes: u64, secs: f64) -> CommTiming {
        CommTiming {
            round_times: vec![secs],
            total_bytes: bytes,
            total_messages: msgs,
            intra_bytes: bytes,
            intra_messages: msgs,
            ..Default::default()
        }
    }

    #[test]
    fn aggregation() {
        let mut m = RunMetrics { graph_edges: 1000, ..Default::default() };
        m.push_level(0, 1, 100, 60, 5, &timing(4, 400, 0.001), 0.002, false);
        m.push_level(1, 5, 900, 500, 20, &timing(4, 800, 0.003), 0.004, true);
        assert_eq!(m.depth(), 2);
        assert_eq!(m.edges_examined(), 1000);
        assert_eq!(m.messages(), 8);
        assert_eq!(m.bytes(), 1200);
        assert!((m.sim_seconds() - 0.010).abs() < 1e-12);
        assert!((m.sim_comm_fraction() - 0.4).abs() < 1e-9);
        // 1D levels carry no per-phase split.
        assert_eq!(m.fold_messages(), 0);
        assert_eq!(m.expand_bytes(), 0);
        // Direction trace: level 1 ran bottom-up.
        assert_eq!(m.bottom_up_levels(), 1);
        assert_eq!(m.bottom_up_edges(), 900);
        let s = m.to_json().render();
        assert!(s.contains("\"bottom_up_levels\":1"));
        assert!(s.contains("\"bottom_up_edges\":900"));
        assert!(s.contains("\"direction\":\"topdown\""));
        assert!(s.contains("\"direction\":\"bottomup\""));
    }

    #[test]
    fn phase_split_aggregates() {
        let mut m = RunMetrics { graph_edges: 10, ..Default::default() };
        m.push_level(0, 1, 2, 2, 1, &timing(10, 700, 0.5), 0.5, false);
        let l = m.levels.last_mut().unwrap();
        l.fold_messages = 6;
        l.fold_bytes = 300;
        l.expand_messages = 4;
        l.expand_bytes = 400;
        assert_eq!(m.fold_messages() + m.expand_messages(), m.messages());
        assert_eq!(m.fold_bytes() + m.expand_bytes(), m.bytes());
        let s = m.to_json().render();
        assert!(s.contains("\"fold_bytes\":300"));
        assert!(s.contains("\"expand_messages\":4"));
    }

    #[test]
    fn gteps_conventions_differ() {
        let mut m = RunMetrics { graph_edges: 2000, ..Default::default() };
        m.push_level(0, 1, 500, 500, 5, &timing(0, 0, 0.0), 1.0, false);
        // Graph500 convention uses |E| = 2000, honest uses 500.
        assert!(m.sim_gteps() > m.sim_honest_gteps());
    }

    #[test]
    fn batch_metrics_aggregation_and_json() {
        let mut b = BatchMetrics {
            num_roots: 64,
            lane_words: 1,
            graph_edges: 1000,
            ..Default::default()
        };
        b.levels.push(LevelMetrics {
            level: 0,
            frontier: 1,
            edges_examined: 100,
            max_node_edges: 60,
            discovered: 320,
            messages: 4,
            bytes: 640,
            fold_messages: 3,
            fold_bytes: 400,
            expand_messages: 1,
            expand_bytes: 240,
            sim_compute: 0.002,
            sim_comm: 0.001,
            bottom_up: true,
            intra_messages: 3,
            intra_bytes: 440,
            inter_messages: 1,
            inter_bytes: 200,
            ..Default::default()
        });
        b.sync_rounds = 4;
        b.reached_pairs = 321;
        assert_eq!(b.bottom_up_levels(), 1);
        assert_eq!(b.bottom_up_edges(), 100);
        assert_eq!(b.depth(), 1);
        assert_eq!(b.bytes(), 640);
        assert!((b.bytes_per_root() - 10.0).abs() < 1e-12);
        assert!((b.sim_seconds() - 0.003).abs() < 1e-12);
        assert!((b.sim_seconds_per_root() - 0.003 / 64.0).abs() < 1e-15);
        assert_eq!(b.fold_messages() + b.expand_messages(), b.messages());
        assert_eq!(b.fold_bytes() + b.expand_bytes(), b.bytes());
        assert_eq!(b.intra_messages() + b.inter_messages(), b.messages());
        assert_eq!(b.intra_bytes() + b.inter_bytes(), b.bytes());
        assert_eq!(b.lanes_per_exchange(), 64);
        assert_eq!(b.entry_bytes(), 12);
        let wide = BatchMetrics { num_roots: 256, lane_words: 4, ..Default::default() };
        assert_eq!(wide.lanes_per_exchange(), 256);
        assert_eq!(wide.entry_bytes(), 36);
        let s = b.to_json().render();
        assert!(s.contains("\"num_roots\":64"));
        assert!(s.contains("\"lane_words\":1"));
        assert!(s.contains("\"lanes_per_exchange\":64"));
        assert!(s.contains("\"sync_rounds\":4"));
        assert!(s.contains("\"bottom_up_levels\":1"));
        assert!(s.contains("\"bottom_up_edges\":100"));
        assert!(s.contains("\"fold_bytes\":400"));
        assert!(s.contains("\"expand_messages\":1"));
        assert!(s.contains("\"inter_bytes\":200"));
        assert!(s.contains("\"intra_messages\":3"));
    }

    #[test]
    fn per_class_split_flows_from_comm_timing() {
        let mut m = RunMetrics { graph_edges: 10, ..Default::default() };
        let comm = CommTiming {
            round_times: vec![0.25, 0.25],
            total_bytes: 900,
            total_messages: 9,
            intra_bytes: 600,
            intra_messages: 6,
            inter_bytes: 300,
            inter_messages: 3,
        };
        m.push_level(0, 1, 2, 2, 1, &comm, 0.5, false);
        assert_eq!(m.intra_messages(), 6);
        assert_eq!(m.inter_messages(), 3);
        assert_eq!(m.intra_bytes() + m.inter_bytes(), m.bytes());
        let s = m.to_json().render();
        assert!(s.contains("\"inter_messages\":3"));
        assert!(s.contains("\"intra_bytes\":600"));
    }

    #[test]
    fn recovery_counters_aggregate_and_render() {
        let mut m = RunMetrics { graph_edges: 10, ..Default::default() };
        m.push_level(0, 1, 2, 2, 1, &timing(1, 8, 0.5), 0.5, false);
        m.push_level(1, 1, 2, 2, 1, &timing(1, 8, 0.5), 0.5, false);
        // Fault-free: counters default to zero and recovery adds nothing.
        assert_eq!(m.retries(), 0);
        assert_eq!(m.retry_bytes(), 0);
        assert_eq!(m.recovery_time(), 0.0);
        assert_eq!(m.sim_seconds_with_recovery(), m.sim_seconds());
        let l = m.levels.last_mut().unwrap();
        l.retries = 3;
        l.retry_bytes = 96;
        l.recovery_time = 0.25;
        assert_eq!(m.retries(), 3);
        assert_eq!(m.retry_bytes(), 96);
        assert!((m.sim_seconds_with_recovery() - (m.sim_seconds() + 0.25)).abs() < 1e-12);
        let s = m.to_json().render();
        assert!(s.contains("\"retries\":3"));
        assert!(s.contains("\"retry_bytes\":96"));
        assert!(s.contains("\"recovery_time\":0.25"));
        let mut b = BatchMetrics { num_roots: 2, lane_words: 1, ..Default::default() };
        b.levels.push(LevelMetrics { retries: 2, retry_bytes: 40, ..Default::default() });
        assert_eq!(b.retries(), 2);
        assert!(b.to_json().render().contains("\"retry_bytes\":40"));
    }

    #[test]
    fn kernel_work_counters_aggregate_and_render() {
        let mut m = RunMetrics { graph_edges: 10, ..Default::default() };
        m.push_level(0, 1, 2, 2, 1, &timing(1, 8, 0.5), 0.5, false);
        m.push_level(1, 1, 2, 2, 1, &timing(1, 8, 0.5), 0.5, true);
        // Default-zero until the session threads kernel work through.
        assert_eq!(m.words_touched(), 0);
        assert_eq!(m.dispatch_max_work(), 0);
        m.levels[0].words_touched = 40;
        m.levels[0].dispatches = 2;
        m.levels[0].dispatch_max_work = 30;
        m.levels[1].words_touched = 10;
        m.levels[1].words_skipped = 22;
        m.levels[1].dispatches = 3;
        m.levels[1].dispatch_max_work = 8;
        assert_eq!(m.words_touched(), 50);
        assert_eq!(m.words_skipped(), 22);
        assert_eq!(m.dispatches(), 5);
        // Max over levels, not a sum.
        assert_eq!(m.dispatch_max_work(), 30);
        let s = m.to_json().render();
        assert!(s.contains("\"words_touched\":50"));
        assert!(s.contains("\"words_skipped\":22"));
        assert!(s.contains("\"dispatches\":5"));
        assert!(s.contains("\"dispatch_max_work\":30"));
        // Per-level breakdown carries the counters too.
        assert!(s.contains("\"words_touched\":40"));
        assert!(s.contains("\"dispatch_max_work\":8"));
        let mut b = BatchMetrics { num_roots: 2, lane_words: 1, ..Default::default() };
        b.levels.push(LevelMetrics {
            words_touched: 7,
            words_skipped: 5,
            dispatches: 4,
            dispatch_max_work: 6,
            ..Default::default()
        });
        assert_eq!(b.words_touched(), 7);
        assert_eq!(b.words_skipped(), 5);
        assert_eq!(b.dispatches(), 4);
        assert_eq!(b.dispatch_max_work(), 6);
        let s = b.to_json().render();
        assert!(s.contains("\"words_touched\":7"));
        assert!(s.contains("\"dispatch_max_work\":6"));
    }

    #[test]
    fn json_renders() {
        let mut m = RunMetrics { graph_edges: 10, ..Default::default() };
        m.push_level(0, 1, 2, 2, 1, &timing(1, 8, 0.5), 0.5, false);
        let s = m.to_json().render();
        assert!(s.contains("\"sim_seconds\":1"));
        assert!(s.contains("\"levels\":[{"));
    }
}
