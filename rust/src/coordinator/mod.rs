//! The L3 coordinator: simulated compute nodes, the distributed
//! multi-pattern BFS engine (Alg. 2 over 1D + butterfly/all-to-all or the
//! 2D fold/expand checkerboard), pluggable Phase-1 backends,
//! configuration, and metrics.
//!
//! The engine is split into an immutable, `Arc`-shareable
//! [`TraversalPlan`] (graph slabs + partition + schedule + config, built
//! once per graph via [`TraversalPlan::build`]) and cheap, concurrent
//! [`QuerySession`]s (`plan.session()`) owning all per-query mutable
//! state. Queries return typed results ([`TraversalResult`],
//! [`BatchResult`]) and typed errors ([`PlanError`], [`QueryError`]).
//! The pre-split [`ButterflyBfs`] remains as a deprecated shim.

pub mod backend;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod node;
pub mod plan;
pub mod pool;
pub mod session;

pub use backend::{BatchExpandOutput, ComputeBackend, ExpandOutput, NativeCsr};
pub use config::{
    BatchWidth, DirectionMode, EngineConfig, PartitionMode, PatternKind, PayloadEncoding,
};
pub use crate::bfs::kernels::{KernelVariant, KernelWork};
#[allow(deprecated)]
pub use engine::ButterflyBfs;
pub use metrics::{BatchMetrics, LevelMetrics, RunMetrics, SequentialBaseline};
pub use node::ComputeNode;
pub use plan::{PlanError, TraversalPlan};
pub use pool::{PooledSession, SessionPool};
pub use session::{BatchResult, QueryError, QuerySession, TraversalResult};
