//! The L3 coordinator: simulated compute nodes, the distributed
//! multi-pattern BFS engine (Alg. 2 over 1D + butterfly/all-to-all or the
//! 2D fold/expand checkerboard), pluggable Phase-1 backends,
//! configuration, and metrics.

pub mod backend;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod node;

pub use backend::{ComputeBackend, ExpandOutput, NativeCsr};
pub use config::{
    DirectionMode, EngineConfig, PartitionMode, PatternKind, PayloadEncoding,
};
pub use engine::ButterflyBfs;
pub use metrics::{BatchMetrics, LevelMetrics, RunMetrics, SequentialBaseline};
pub use node::ComputeNode;
