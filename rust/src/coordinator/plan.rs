//! The immutable half of the engine: [`TraversalPlan`].
//!
//! Building a plan is the expensive, once-per-graph step — partitioning
//! the CSR into per-node slabs, generating and validating the
//! synchronization [`Schedule`], and freezing the [`EngineConfig`] with
//! its device/interconnect models. Everything a plan owns is immutable
//! and internally reference-counted, so a plan can be wrapped in an
//! [`Arc`](std::sync::Arc) and shared by any number of concurrently
//! running [`QuerySession`]s: `plan.session()` hands out cheap per-query
//! state (distance arrays, queues, metrics) that references — never
//! copies — the slabs and schedule.
//!
//! All input validation lives here as the typed [`PlanError`] (and, on
//! the query side, [`QueryError`](super::session::QueryError)): a bad
//! grid, an oversized node count, or an empty graph is a value the caller
//! can match on, not a panic.
//!
//! # Build once, query many
//!
//! ```
//! use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
//! use butterfly_bfs::graph::gen::structured::path;
//! use std::sync::Arc;
//!
//! let g = path(8);
//! let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1))?);
//! let mut session = plan.session();
//! let first = session.run(0)?;
//! assert_eq!(first.dist()[7], 7);
//! let second = session.run(7)?; // same session, buffers reused
//! assert_eq!(second.dist()[0], 7);
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! # Typed errors instead of panics
//!
//! ```
//! use butterfly_bfs::coordinator::{EngineConfig, PlanError, TraversalPlan};
//! use butterfly_bfs::graph::gen::structured::path;
//!
//! let g = path(3); // 3 vertices cannot host a 4-column grid
//! let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 4)).unwrap_err();
//! assert!(matches!(err, PlanError::GridTooLarge { .. }));
//! ```

use super::backend::ComputeBackend;
use super::config::{EngineConfig, PartitionMode};
use super::session::QuerySession;
use crate::comm::fold_expand::FoldExpand;
use crate::comm::pattern::{CommPattern, Schedule};
use crate::graph::csr::{Csr, CsrSlab};
use crate::partition::one_d::partition_1d;
use crate::partition::{Partition2D, PartitionSpec};
use std::sync::Arc;

/// Why a [`TraversalPlan`] could not be built. Every invalid engine
/// layout surfaces as one of these values — never a panic or a
/// `process::exit` — so services can report configuration mistakes to
/// their callers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// `num_nodes` was zero.
    NoNodes,
    /// The graph has no vertices.
    EmptyGraph,
    /// 1D mode: more compute nodes than vertices — some slabs would own
    /// nothing.
    TooManyNodes {
        /// Requested node count.
        num_nodes: usize,
        /// Vertices available to partition.
        num_vertices: usize,
    },
    /// 2D mode: `rows * cols` does not equal `num_nodes`.
    GridMismatch {
        /// Requested grid rows.
        rows: u32,
        /// Requested grid columns.
        cols: u32,
        /// Configured node count the grid must cover.
        num_nodes: usize,
    },
    /// 2D mode: a grid axis exceeds the vertex count, which would leave
    /// empty (degenerate) row or column cuts.
    GridTooLarge {
        /// Requested grid rows.
        rows: u32,
        /// Requested grid columns.
        cols: u32,
        /// Vertices available along each axis.
        num_vertices: usize,
    },
    /// Session construction: the caller supplied a backend vector whose
    /// length differs from the node count.
    BackendMismatch {
        /// Supplied backend count.
        backends: usize,
        /// Configured node count.
        num_nodes: usize,
    },
    /// The generated synchronization schedule failed validation — an
    /// internal invariant violation in a
    /// [`CommPattern`](crate::comm::CommPattern) implementation.
    InvalidSchedule(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::NoNodes => write!(f, "engine needs at least one compute node"),
            PlanError::EmptyGraph => {
                write!(f, "cannot plan a traversal over a graph with no vertices")
            }
            PlanError::TooManyNodes { num_nodes, num_vertices } => write!(
                f,
                "{num_nodes} compute nodes exceed the graph's {num_vertices} vertices \
                 (1D slabs would be empty)"
            ),
            PlanError::GridMismatch { rows, cols, num_nodes } => write!(
                f,
                "grid {rows}x{cols} does not cover num_nodes={num_nodes} \
                 (need rows*cols == num_nodes)"
            ),
            PlanError::GridTooLarge { rows, cols, num_vertices } => write!(
                f,
                "grid {rows}x{cols} has an axis larger than the graph's \
                 {num_vertices} vertices"
            ),
            PlanError::BackendMismatch { backends, num_nodes } => write!(
                f,
                "{backends} backends supplied for {num_nodes} compute nodes \
                 (need exactly one per node)"
            ),
            PlanError::InvalidSchedule(msg) => {
                write!(f, "generated synchronization schedule invalid: {msg}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// The immutable, shareable artifacts of a traversal engine: partition,
/// per-node adjacency slabs, synchronization schedule, and configuration
/// (device + interconnect models included).
///
/// A plan holds no per-query state whatsoever — two threads holding the
/// same `Arc<TraversalPlan>` can each [`session()`](Self::session) and
/// run queries fully independently; results are bit-identical to running
/// the same roots sequentially (asserted in `tests/concurrent_queries.rs`).
#[derive(Clone, Debug)]
pub struct TraversalPlan {
    config: EngineConfig,
    partition: PartitionSpec,
    schedule: Arc<Schedule>,
    /// Leading schedule rounds that are the 2D fold phase (0 in 1D mode;
    /// the remaining rounds are the expand phase).
    fold_rounds: usize,
    slabs: Vec<Arc<CsrSlab>>,
    num_vertices: usize,
    graph_edges: u64,
}

impl TraversalPlan {
    /// Partition `g` across `config.num_nodes` simulated devices and
    /// generate the matching synchronization schedule.
    ///
    /// This is the only expensive step of the plan/session API: it walks
    /// the CSR once per partition axis and materializes the per-node
    /// slabs. Every layout mistake is a typed [`PlanError`].
    pub fn build(g: &Csr, config: EngineConfig) -> Result<Self, PlanError> {
        let n = g.num_vertices();
        if config.num_nodes == 0 {
            return Err(PlanError::NoNodes);
        }
        if n == 0 {
            return Err(PlanError::EmptyGraph);
        }
        // The multi-pattern seam: each mode yields its (layout, schedule)
        // pair; everything downstream is mode-agnostic.
        let (partition, slabs, schedule, fold_rounds) = match config.partition {
            PartitionMode::OneD => {
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let p = partition_1d(g, config.num_nodes);
                let slabs = p.slabs(g);
                let schedule = config.pattern.build().schedule(config.num_nodes as u32);
                (PartitionSpec::OneD(p), slabs, schedule, 0)
            }
            PartitionMode::TwoD { rows, cols } => {
                if rows as usize * cols as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows,
                        cols,
                        num_nodes: config.num_nodes,
                    });
                }
                if rows as usize > n || cols as usize > n {
                    return Err(PlanError::GridTooLarge { rows, cols, num_vertices: n });
                }
                let p = Partition2D::new(g, rows, cols);
                let slabs = p.block_slabs(g);
                let fe = FoldExpand::new(rows, cols);
                let schedule = fe.schedule(config.num_nodes as u32);
                (PartitionSpec::TwoD(p), slabs, schedule, fe.fold_rounds())
            }
        };
        schedule.validate().map_err(PlanError::InvalidSchedule)?;
        Ok(Self {
            config,
            partition,
            schedule: Arc::new(schedule),
            fold_rounds,
            slabs: slabs.into_iter().map(Arc::new).collect(),
            num_vertices: n,
            graph_edges: g.num_edges(),
        })
    }

    /// Open a query session with the native CSR backend on every node.
    ///
    /// Sessions are cheap relative to the plan (per-query distance arrays
    /// and queues; the slabs and schedule are shared by reference) and
    /// reusable: run any number of queries back to back, or call
    /// [`QuerySession::reset`] to drop result state while keeping the
    /// buffers.
    pub fn session(&self) -> QuerySession {
        QuerySession::with_native_backends(self)
    }

    /// Open a session with caller-supplied per-node backends (e.g. the
    /// XLA/PJRT backend from `runtime::`). Fails with
    /// [`PlanError::BackendMismatch`] unless there is exactly one backend
    /// per node.
    pub fn session_with_backends(
        &self,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Result<QuerySession, PlanError> {
        if backends.len() != self.config.num_nodes {
            return Err(PlanError::BackendMismatch {
                backends: backends.len(),
                num_nodes: self.config.num_nodes,
            });
        }
        Ok(QuerySession::from_parts(self, backends))
    }

    /// Engine configuration the plan was built with.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The partition in use (1D row slabs or the 2D grid).
    pub fn partition(&self) -> &PartitionSpec {
        &self.partition
    }

    /// The synchronization schedule every session executes per level.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Vertex count of the planned graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Arc count of the planned graph.
    pub fn graph_edges(&self) -> u64 {
        self.graph_edges
    }

    /// Number of simulated compute nodes.
    pub fn num_nodes(&self) -> usize {
        self.config.num_nodes
    }

    /// Shared handle to the schedule (session construction).
    pub(crate) fn schedule_arc(&self) -> Arc<Schedule> {
        Arc::clone(&self.schedule)
    }

    /// Leading fold rounds of the schedule (0 in 1D mode).
    pub(crate) fn fold_rounds(&self) -> usize {
        self.fold_rounds
    }

    /// Shared per-node slabs (session construction).
    pub(crate) fn slabs(&self) -> &[Arc<CsrSlab>] {
        &self.slabs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::PatternKind;
    use crate::graph::gen::structured::path;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn build_validates_layouts() {
        let (g, _) = uniform_random(50, 4, 1);
        assert!(TraversalPlan::build(&g, EngineConfig::dgx2(8, 2)).is_ok());
        assert!(TraversalPlan::build(&g, EngineConfig::dgx2_2d(5, 10)).is_ok());
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(0, 1)).unwrap_err();
        assert_eq!(err, PlanError::NoNodes);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(51, 1)).unwrap_err();
        assert_eq!(err, PlanError::TooManyNodes { num_nodes: 51, num_vertices: 50 });
    }

    #[test]
    fn build_rejects_degenerate_grids() {
        let g = path(3);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 4)).unwrap_err();
        assert_eq!(err, PlanError::GridTooLarge { rows: 2, cols: 4, num_vertices: 3 });
        let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(4, 2)).unwrap_err();
        assert_eq!(err, PlanError::GridTooLarge { rows: 4, cols: 2, num_vertices: 3 });
        // A mismatched grid is a distinct error from an oversized one.
        let cfg = EngineConfig {
            partition: PartitionMode::TwoD { rows: 2, cols: 2 },
            ..EngineConfig::dgx2(6, 1)
        };
        let (big, _) = uniform_random(40, 4, 2);
        let err = TraversalPlan::build(&big, cfg).unwrap_err();
        assert_eq!(err, PlanError::GridMismatch { rows: 2, cols: 2, num_nodes: 6 });
    }

    #[test]
    fn build_rejects_empty_graph() {
        let g = Csr::from_edges(0, &[]);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(1, 1)).unwrap_err();
        assert_eq!(err, PlanError::EmptyGraph);
    }

    #[test]
    fn plan_accessors() {
        let (g, _) = uniform_random(120, 4, 9);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap();
        assert_eq!(plan.num_vertices(), 120);
        assert_eq!(plan.num_nodes(), 4);
        assert_eq!(plan.graph_edges(), g.num_edges());
        assert!(plan.partition().as_one_d().is_some());
        assert!(matches!(plan.config().pattern, PatternKind::Butterfly { fanout: 2 }));
        assert!(plan.schedule().depth() >= 1);
        assert_eq!(plan.fold_rounds(), 0);
        let plan2 = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 3)).unwrap();
        assert!(plan2.partition().as_two_d().is_some());
        assert!(plan2.fold_rounds() >= 1);
    }

    #[test]
    fn errors_display_and_box() {
        let e: Box<dyn std::error::Error> =
            Box::new(PlanError::GridMismatch { rows: 3, cols: 3, num_nodes: 8 });
        let s = e.to_string();
        assert!(s.contains("3x3") && s.contains("num_nodes=8"), "{s}");
        assert!(PlanError::NoNodes.to_string().contains("at least one"));
        assert!(PlanError::InvalidSchedule("boom".into()).to_string().contains("boom"));
    }

    #[test]
    fn backend_mismatch_is_typed() {
        let (g, _) = uniform_random(30, 4, 3);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 1)).unwrap();
        let err = plan.session_with_backends(Vec::new()).unwrap_err();
        assert_eq!(err, PlanError::BackendMismatch { backends: 0, num_nodes: 4 });
    }
}
