//! The immutable half of the engine: [`TraversalPlan`].
//!
//! Building a plan is the expensive, once-per-graph step — partitioning
//! the CSR into per-node slabs, generating and validating the
//! synchronization [`Schedule`], and freezing the [`EngineConfig`] with
//! its device/interconnect models. Everything a plan owns is immutable
//! and internally reference-counted, so a plan can be wrapped in an
//! [`Arc`](std::sync::Arc) and shared by any number of concurrently
//! running [`QuerySession`]s: `plan.session()` hands out cheap per-query
//! state (distance arrays, queues, metrics) that references — never
//! copies — the slabs and schedule.
//!
//! All input validation lives here as the typed [`PlanError`] (and, on
//! the query side, [`QueryError`](super::session::QueryError)): a bad
//! grid, an oversized node count, or an empty graph is a value the caller
//! can match on, not a panic.
//!
//! # Build once, query many
//!
//! ```
//! use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
//! use butterfly_bfs::graph::gen::structured::path;
//! use std::sync::Arc;
//!
//! let g = path(8);
//! let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1))?);
//! let mut session = plan.session();
//! let first = session.run(0)?;
//! assert_eq!(first.dist()[7], 7);
//! let second = session.run(7)?; // same session, buffers reused
//! assert_eq!(second.dist()[0], 7);
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! # Typed errors instead of panics
//!
//! ```
//! use butterfly_bfs::coordinator::{EngineConfig, PlanError, TraversalPlan};
//! use butterfly_bfs::graph::gen::structured::path;
//!
//! let g = path(3); // 3 vertices cannot host a 4-column grid
//! let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 4)).unwrap_err();
//! assert!(matches!(err, PlanError::GridTooLarge { .. }));
//! ```

use super::backend::ComputeBackend;
use super::config::{EngineConfig, PartitionMode, PatternKind};
use super::session::QuerySession;
use crate::comm::fold_expand::FoldExpand;
use crate::comm::hierarchical::GridOfIslands;
use crate::comm::pattern::{CommPattern, Schedule};
use crate::graph::csr::{Csr, CsrSlab, VertexId};
use crate::graph::store::GraphStore;
use crate::partition::one_d::{balanced_cuts_from_prefix, partition_1d, Partition1D};
use crate::partition::relabel::Relabeling;
use crate::partition::{Partition2D, PartitionSpec};
use crate::util::json::Json;
use std::sync::{Arc, OnceLock};

/// Plan-cache format identifier (the first thing version-checked on load).
const PLAN_CACHE_FORMAT: &str = "bbfs-plan-v1";

/// The interconnect component of the plan-cache fingerprint: the resolved
/// topology's preset name, qualified by island width when the fabric is
/// tiered. A hierarchical plan cached under `--net dgx2` must *miss* (with
/// a typed [`PlanError::CacheFingerprintMismatch`] naming `net`) when
/// reopened under `--net dgx2-cluster`, and vice versa — partition cuts
/// are interconnect-independent, but warm-starting silently across
/// topologies would let stale pricing masquerade as a valid plan.
fn net_fingerprint(config: &EngineConfig) -> String {
    let t = config.resolved_topology();
    if t.per_island == u32::MAX {
        t.name.to_string()
    } else {
        format!("{}/{}", t.name, t.per_island)
    }
}

/// Why a [`TraversalPlan`] could not be built. Every invalid engine
/// layout surfaces as one of these values — never a panic or a
/// `process::exit` — so services can report configuration mistakes to
/// their callers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// `num_nodes` was zero.
    NoNodes,
    /// The graph has no vertices.
    EmptyGraph,
    /// 1D mode: more compute nodes than vertices — some slabs would own
    /// nothing.
    TooManyNodes {
        /// Requested node count.
        num_nodes: usize,
        /// Vertices available to partition.
        num_vertices: usize,
    },
    /// 2D mode: `rows * cols` does not equal `num_nodes`; hierarchical
    /// mode: `islands * per_island` does not equal `num_nodes` (reported
    /// with `rows = islands`, `cols = per_island`).
    GridMismatch {
        /// Requested grid rows.
        rows: u32,
        /// Requested grid columns.
        cols: u32,
        /// Configured node count the grid must cover.
        num_nodes: usize,
    },
    /// 2D mode: a grid axis exceeds the vertex count, which would leave
    /// empty (degenerate) row or column cuts.
    GridTooLarge {
        /// Requested grid rows.
        rows: u32,
        /// Requested grid columns.
        cols: u32,
        /// Vertices available along each axis.
        num_vertices: usize,
    },
    /// Session construction: the caller supplied a backend vector whose
    /// length differs from the node count.
    BackendMismatch {
        /// Supplied backend count.
        backends: usize,
        /// Configured node count.
        num_nodes: usize,
    },
    /// The generated synchronization schedule failed validation — an
    /// internal invariant violation in a
    /// [`CommPattern`](crate::comm::CommPattern) implementation.
    InvalidSchedule(String),
    /// Decoding the backing `.bbfs` v2 store failed (corrupt payload,
    /// truncated block, out-of-range id, I/O error).
    StoreDecode(String),
    /// A plan cache declared a format this build does not speak.
    CacheVersionMismatch {
        /// The format string found in the cache file.
        found: String,
    },
    /// A plan cache was built against a different store or engine
    /// configuration than the one being loaded — warm-start must fall
    /// back to a cold build.
    CacheFingerprintMismatch {
        /// Which fingerprint field disagreed.
        field: String,
        /// Value the current store/config requires.
        expected: String,
        /// Value recorded in the cache.
        found: String,
    },
    /// A plan cache file was unreadable or structurally malformed.
    CacheCorrupt(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::NoNodes => write!(f, "engine needs at least one compute node"),
            PlanError::EmptyGraph => {
                write!(f, "cannot plan a traversal over a graph with no vertices")
            }
            PlanError::TooManyNodes { num_nodes, num_vertices } => write!(
                f,
                "{num_nodes} compute nodes exceed the graph's {num_vertices} vertices \
                 (1D slabs would be empty)"
            ),
            PlanError::GridMismatch { rows, cols, num_nodes } => write!(
                f,
                "grid {rows}x{cols} does not cover num_nodes={num_nodes} \
                 (need rows*cols == num_nodes)"
            ),
            PlanError::GridTooLarge { rows, cols, num_vertices } => write!(
                f,
                "grid {rows}x{cols} has an axis larger than the graph's \
                 {num_vertices} vertices"
            ),
            PlanError::BackendMismatch { backends, num_nodes } => write!(
                f,
                "{backends} backends supplied for {num_nodes} compute nodes \
                 (need exactly one per node)"
            ),
            PlanError::InvalidSchedule(msg) => {
                write!(f, "generated synchronization schedule invalid: {msg}")
            }
            PlanError::StoreDecode(msg) => {
                write!(f, "failed to decode the backing graph store: {msg}")
            }
            PlanError::CacheVersionMismatch { found } => write!(
                f,
                "plan cache format {found:?} is not {PLAN_CACHE_FORMAT:?} — rebuild the cache"
            ),
            PlanError::CacheFingerprintMismatch { field, expected, found } => write!(
                f,
                "plan cache was built for a different {field} \
                 (cache has {found}, store/config needs {expected})"
            ),
            PlanError::CacheCorrupt(msg) => write!(f, "plan cache unreadable: {msg}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// The immutable, shareable artifacts of a traversal engine: partition,
/// per-node adjacency slabs, synchronization schedule, and configuration
/// (device + interconnect models included).
///
/// A plan holds no per-query state whatsoever — two threads holding the
/// same `Arc<TraversalPlan>` can each [`session()`](Self::session) and
/// run queries fully independently; results are bit-identical to running
/// the same roots sequentially (asserted in `tests/concurrent_queries.rs`).
#[derive(Clone, Debug)]
pub struct TraversalPlan {
    config: EngineConfig,
    partition: PartitionSpec,
    schedule: Arc<Schedule>,
    /// Leading schedule rounds that are the 2D fold phase (0 in 1D mode;
    /// the remaining rounds are the expand phase).
    fold_rounds: usize,
    slabs: SlabSet,
    num_vertices: usize,
    graph_edges: u64,
    /// Degree-sort permutation of the backing store, if the graph was
    /// relabeled on conversion — callers map roots in and distances out.
    relabeling: Option<Arc<Relabeling>>,
    /// Fingerprint of the backing v2 store (hex), when built from one.
    /// This is what [`cache_json`](Self::cache_json) pins the cache to.
    store_fingerprint: Option<String>,
}

/// The vertex (and, for 2D blocks, neighbor-column) range one lazy slab
/// covers.
#[derive(Clone, Copy, Debug)]
struct SlabRange {
    rows: (VertexId, VertexId),
    cols: Option<(VertexId, VertexId)>,
}

/// Per-node slabs: either materialized up front (in-memory build, 2D
/// cold build) or decoded on demand from a [`GraphStore`] (warm start —
/// the load path performs **zero** adjacency decoding until a slab is
/// first touched or [`materialize`](TraversalPlan::materialize) runs).
#[derive(Clone, Debug)]
enum SlabSet {
    Eager(Vec<Arc<CsrSlab>>),
    Lazy(LazySlabs),
}

#[derive(Clone, Debug)]
struct LazySlabs {
    store: Arc<GraphStore>,
    ranges: Vec<SlabRange>,
    cells: Vec<OnceLock<Arc<CsrSlab>>>,
}

impl LazySlabs {
    fn new(store: Arc<GraphStore>, ranges: Vec<SlabRange>) -> Self {
        let cells = ranges.iter().map(|_| OnceLock::new()).collect();
        Self { store, ranges, cells }
    }

    fn decode(&self, i: usize) -> Result<CsrSlab, PlanError> {
        let r = self.ranges[i];
        self.store
            .decode_rows_filtered(r.rows.0, r.rows.1, r.cols)
            .map_err(|e| PlanError::StoreDecode(e.to_string()))
    }

    fn force(&self, i: usize) -> Result<Arc<CsrSlab>, PlanError> {
        if let Some(slab) = self.cells[i].get() {
            return Ok(Arc::clone(slab));
        }
        let slab = Arc::new(self.decode(i)?);
        // A concurrent materialization may have won the race; either
        // value is identical (decoding is deterministic).
        Ok(Arc::clone(self.cells[i].get_or_init(|| slab)))
    }
}

/// Butterfly fanout of a hierarchical plan: honor the configured
/// butterfly pattern, fall back to fanout 1 (radix 2) for the all-to-all
/// patterns (which have no per-axis fanout to compose).
fn hier_fanout(config: &EngineConfig) -> u32 {
    match config.pattern {
        PatternKind::Butterfly { fanout } => fanout,
        _ => 1,
    }
}

/// Schedule of the 1D-slab family of modes: the configured pattern in
/// [`PartitionMode::OneD`], the grid-of-islands composition in
/// [`PartitionMode::Hierarchical`].
fn one_d_family_schedule(config: &EngineConfig) -> Schedule {
    match config.partition {
        PartitionMode::Hierarchical { islands, per_island } => {
            GridOfIslands::new(islands, per_island, hier_fanout(config))
                .schedule(config.num_nodes as u32)
        }
        _ => config.pattern.build().schedule(config.num_nodes as u32),
    }
}

impl TraversalPlan {
    /// Partition `g` across `config.num_nodes` simulated devices and
    /// generate the matching synchronization schedule.
    ///
    /// This is the only expensive step of the plan/session API: it walks
    /// the CSR once per partition axis and materializes the per-node
    /// slabs. Every layout mistake is a typed [`PlanError`].
    pub fn build(g: &Csr, config: EngineConfig) -> Result<Self, PlanError> {
        let n = g.num_vertices();
        if config.num_nodes == 0 {
            return Err(PlanError::NoNodes);
        }
        if n == 0 {
            return Err(PlanError::EmptyGraph);
        }
        // The multi-pattern seam: each mode yields its (layout, schedule)
        // pair; everything downstream is mode-agnostic.
        let (partition, slabs, schedule, fold_rounds) = match config.partition {
            PartitionMode::OneD => {
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let p = partition_1d(g, config.num_nodes);
                let slabs = p.slabs(g);
                let schedule = config.pattern.build().schedule(config.num_nodes as u32);
                (PartitionSpec::OneD(p), slabs, schedule, 0)
            }
            PartitionMode::TwoD { rows, cols } => {
                if rows as usize * cols as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows,
                        cols,
                        num_nodes: config.num_nodes,
                    });
                }
                if rows as usize > n || cols as usize > n {
                    return Err(PlanError::GridTooLarge { rows, cols, num_vertices: n });
                }
                let p = Partition2D::new(g, rows, cols);
                let slabs = p.block_slabs(g);
                let fe = FoldExpand::new(rows, cols);
                let schedule = fe.schedule(config.num_nodes as u32);
                (PartitionSpec::TwoD(p), slabs, schedule, fe.fold_rounds())
            }
            PartitionMode::Hierarchical { islands, per_island } => {
                if islands as usize * per_island as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows: islands,
                        cols: per_island,
                        num_nodes: config.num_nodes,
                    });
                }
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                // Island-major rank order over the same contiguous 1D
                // slabs: rank = island·per_island + local, so slab
                // ownership composes with the 1D machinery unchanged.
                let p = partition_1d(g, config.num_nodes);
                let slabs = p.slabs(g);
                let schedule = one_d_family_schedule(&config);
                (PartitionSpec::OneD(p), slabs, schedule, 0)
            }
        };
        schedule.validate().map_err(PlanError::InvalidSchedule)?;
        Ok(Self {
            config,
            partition,
            schedule: Arc::new(schedule),
            fold_rounds,
            slabs: SlabSet::Eager(slabs.into_iter().map(Arc::new).collect()),
            num_vertices: n,
            graph_edges: g.num_edges(),
            relabeling: None,
            store_fingerprint: None,
        })
    }

    /// Build a plan directly from an open `.bbfs` v2 store — the **cold**
    /// store-backed path.
    ///
    /// In 1D and hierarchical modes this decodes only the degree stream
    /// (O(n) varints, no adjacency bytes) to compute edge-balanced cuts,
    /// then installs lazy row slabs: adjacency decodes on first touch or
    /// at [`materialize`](Self::materialize). In 2D mode the
    /// checkerboard's column cuts need in-degrees, so each block is
    /// streamed **exactly once** through
    /// [`GraphStore::stream_degree_prefixes`] — never materializing a
    /// full CSR — and the slabs themselves stay lazy. The cuts are
    /// bit-identical to [`Partition2D::new`]'s because both axes route
    /// through the same [`balanced_cuts_from_prefix`] greedy.
    ///
    /// If the store was converted with `--relabel`, the plan carries the
    /// permutation: map roots through [`relabeling`](Self::relabeling)
    /// before running, and distances back through
    /// [`Relabeling::unmap_dist`] after.
    pub fn build_from_store(store: Arc<GraphStore>, config: EngineConfig) -> Result<Self, PlanError> {
        let n = store.num_vertices();
        if config.num_nodes == 0 {
            return Err(PlanError::NoNodes);
        }
        if n == 0 {
            return Err(PlanError::EmptyGraph);
        }
        let relabeling = store.relabeling().map(Arc::new);
        let fingerprint = Some(store.fingerprint_hex());
        match config.partition {
            PartitionMode::OneD => {
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let prefix =
                    store.degree_prefix().map_err(|e| PlanError::StoreDecode(e.to_string()))?;
                let cuts = balanced_cuts_from_prefix(&prefix, config.num_nodes);
                Self::assemble_lazy_1d(store, config, Partition1D { cuts }, relabeling, fingerprint)
            }
            PartitionMode::TwoD { rows, cols } => {
                if rows as usize * cols as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows,
                        cols,
                        num_nodes: config.num_nodes,
                    });
                }
                if rows as usize > n || cols as usize > n {
                    return Err(PlanError::GridTooLarge { rows, cols, num_vertices: n });
                }
                let (out_prefix, in_prefix) = store
                    .stream_degree_prefixes()
                    .map_err(|e| PlanError::StoreDecode(e.to_string()))?;
                let p = Partition2D {
                    grid_rows: rows,
                    grid_cols: cols,
                    row_cuts: balanced_cuts_from_prefix(&out_prefix, rows as usize),
                    col_cuts: balanced_cuts_from_prefix(&in_prefix, cols as usize),
                };
                Self::assemble_lazy_2d(store, config, p, relabeling, fingerprint)
            }
            PartitionMode::Hierarchical { islands, per_island } => {
                if islands as usize * per_island as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows: islands,
                        cols: per_island,
                        num_nodes: config.num_nodes,
                    });
                }
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let prefix =
                    store.degree_prefix().map_err(|e| PlanError::StoreDecode(e.to_string()))?;
                let cuts = balanced_cuts_from_prefix(&prefix, config.num_nodes);
                Self::assemble_lazy_1d(store, config, Partition1D { cuts }, relabeling, fingerprint)
            }
        }
    }

    fn assemble_lazy_1d(
        store: Arc<GraphStore>,
        config: EngineConfig,
        p: Partition1D,
        relabeling: Option<Arc<Relabeling>>,
        store_fingerprint: Option<String>,
    ) -> Result<Self, PlanError> {
        let n = store.num_vertices();
        let m = store.num_edges();
        let ranges: Vec<SlabRange> =
            (0..p.parts()).map(|i| SlabRange { rows: p.range(i), cols: None }).collect();
        let schedule = one_d_family_schedule(&config);
        schedule.validate().map_err(PlanError::InvalidSchedule)?;
        Ok(Self {
            config,
            partition: PartitionSpec::OneD(p),
            schedule: Arc::new(schedule),
            fold_rounds: 0,
            slabs: SlabSet::Lazy(LazySlabs::new(store, ranges)),
            num_vertices: n,
            graph_edges: m,
            relabeling,
            store_fingerprint,
        })
    }

    fn assemble_lazy_2d(
        store: Arc<GraphStore>,
        config: EngineConfig,
        p: Partition2D,
        relabeling: Option<Arc<Relabeling>>,
        store_fingerprint: Option<String>,
    ) -> Result<Self, PlanError> {
        let n = store.num_vertices();
        let m = store.num_edges();
        let mut ranges = Vec::with_capacity(config.num_nodes);
        for rank in 0..p.processors() {
            let (i, j) = p.coords(rank);
            ranges.push(SlabRange { rows: p.row_range(i), cols: Some(p.col_range(j)) });
        }
        let (rows, cols) = (p.grid_rows, p.grid_cols);
        let fe = FoldExpand::new(rows, cols);
        let schedule = fe.schedule(config.num_nodes as u32);
        schedule.validate().map_err(PlanError::InvalidSchedule)?;
        Ok(Self {
            config,
            partition: PartitionSpec::TwoD(p),
            schedule: Arc::new(schedule),
            fold_rounds: fe.fold_rounds(),
            slabs: SlabSet::Lazy(LazySlabs::new(store, ranges)),
            num_vertices: n,
            graph_edges: m,
            relabeling,
            store_fingerprint,
        })
    }

    /// Open a query session with the native CSR backend on every node.
    ///
    /// Sessions are cheap relative to the plan (per-query distance arrays
    /// and queues; the slabs and schedule are shared by reference) and
    /// reusable: run any number of queries back to back, or call
    /// [`QuerySession::reset`] to drop result state while keeping the
    /// buffers.
    pub fn session(&self) -> QuerySession {
        QuerySession::with_native_backends(self)
    }

    /// Open a session with caller-supplied per-node backends (e.g. the
    /// XLA/PJRT backend from `runtime::`). Fails with
    /// [`PlanError::BackendMismatch`] unless there is exactly one backend
    /// per node.
    pub fn session_with_backends(
        &self,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Result<QuerySession, PlanError> {
        if backends.len() != self.config.num_nodes {
            return Err(PlanError::BackendMismatch {
                backends: backends.len(),
                num_nodes: self.config.num_nodes,
            });
        }
        Ok(QuerySession::from_parts(self, backends))
    }

    /// Engine configuration the plan was built with.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The partition in use (1D row slabs or the 2D grid).
    pub fn partition(&self) -> &PartitionSpec {
        &self.partition
    }

    /// The synchronization schedule every session executes per level.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Vertex count of the planned graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Arc count of the planned graph.
    pub fn graph_edges(&self) -> u64 {
        self.graph_edges
    }

    /// Number of simulated compute nodes.
    pub fn num_nodes(&self) -> usize {
        self.config.num_nodes
    }

    /// Shared handle to the schedule (session construction).
    pub(crate) fn schedule_arc(&self) -> Arc<Schedule> {
        Arc::clone(&self.schedule)
    }

    /// Leading fold rounds of the schedule (0 in 1D mode).
    pub(crate) fn fold_rounds(&self) -> usize {
        self.fold_rounds
    }

    /// Shared slab for compute node `i` (session construction).
    ///
    /// On a warm-started plan this forces the lazy decode of node `i`'s
    /// block. Public flows call [`materialize`](Self::materialize) first,
    /// which surfaces corrupt-store failures as typed errors; if that was
    /// skipped and the store is corrupt, this panics with the decode
    /// error (the documented trade-off for keeping `session()` infallible).
    pub(crate) fn slab(&self, i: usize) -> Arc<CsrSlab> {
        match &self.slabs {
            SlabSet::Eager(slabs) => Arc::clone(&slabs[i]),
            SlabSet::Lazy(lazy) => lazy
                .force(i)
                .expect("corrupt graph store: call TraversalPlan::materialize() before session()"),
        }
    }

    /// Force-decode every lazy slab, surfacing any store corruption as a
    /// typed [`PlanError::StoreDecode`]. No-op on eager plans. Call this
    /// once after a warm start (every CLI/server path does) so later
    /// [`session`](Self::session) construction cannot fail.
    pub fn materialize(&self) -> Result<(), PlanError> {
        if let SlabSet::Lazy(lazy) = &self.slabs {
            for i in 0..lazy.cells.len() {
                lazy.force(i)?;
            }
        }
        Ok(())
    }

    /// The stored degree-sort permutation, when the plan's backing store
    /// was converted with relabeling. Map roots via `new_id`, distances
    /// back via [`Relabeling::unmap_dist`].
    pub fn relabeling(&self) -> Option<&Arc<Relabeling>> {
        self.relabeling.as_ref()
    }

    /// Serialize the partition layout + fingerprint as a plan-cache JSON
    /// value, or `None` if the plan was not built from a v2 store (an
    /// in-memory plan has no stable fingerprint to pin against).
    ///
    /// The cache stores only what is expensive or non-derivable: the
    /// partition cuts and the identity of the store/config pair. The
    /// schedule is regenerated on load (pure function of the config) and
    /// the slab index lives in the store itself.
    pub fn cache_json(&self) -> Option<Json> {
        let store = self.store_fingerprint.clone()?;
        let (mode, grid) = match self.config.partition {
            PartitionMode::OneD => ("1d".to_string(), String::new()),
            PartitionMode::TwoD { rows, cols } => ("2d".to_string(), format!("{rows}x{cols}")),
            PartitionMode::Hierarchical { islands, per_island } => {
                ("hier".to_string(), format!("{islands}x{per_island}"))
            }
        };
        let fingerprint = Json::obj(vec![
            ("store", Json::s(store)),
            ("n", Json::u(self.num_vertices as u64)),
            ("m", Json::u(self.graph_edges)),
            ("nodes", Json::u(self.config.num_nodes as u64)),
            ("mode", Json::s(mode)),
            ("grid", Json::s(grid)),
            ("pattern", Json::s(self.config.pattern.name())),
            ("net", Json::s(net_fingerprint(&self.config))),
            ("relabeled", Json::Bool(self.relabeling.is_some())),
        ]);
        let cuts_arr = |cuts: &[VertexId]| {
            Json::Arr(cuts.iter().map(|&c| Json::u(u64::from(c))).collect())
        };
        let mut pairs = vec![
            ("format", Json::s(PLAN_CACHE_FORMAT)),
            ("fingerprint", fingerprint),
        ];
        match &self.partition {
            PartitionSpec::OneD(p) => pairs.push(("cuts", cuts_arr(&p.cuts))),
            PartitionSpec::TwoD(p) => {
                pairs.push(("row_cuts", cuts_arr(&p.row_cuts)));
                pairs.push(("col_cuts", cuts_arr(&p.col_cuts)));
            }
        }
        Some(Json::obj(pairs))
    }

    /// Reconstruct a plan from a cache value produced by
    /// [`cache_json`](Self::cache_json) — the **warm** path.
    ///
    /// Validates the cache format and every fingerprint field against the
    /// open store and requested config (typed mismatch errors tell the
    /// caller to fall back to a cold build), then installs **lazy** slabs
    /// in both modes: the load itself decodes zero degree entries and
    /// zero adjacency bytes.
    pub fn from_cache_json(
        store: Arc<GraphStore>,
        config: EngineConfig,
        cache: &Json,
    ) -> Result<Self, PlanError> {
        let format = cache.get("format").and_then(Json::as_str).unwrap_or("<missing>");
        if format != PLAN_CACHE_FORMAT {
            return Err(PlanError::CacheVersionMismatch { found: format.to_string() });
        }
        let fp = cache
            .get("fingerprint")
            .ok_or_else(|| PlanError::CacheCorrupt("missing fingerprint".into()))?;
        let (mode, grid) = match config.partition {
            PartitionMode::OneD => ("1d".to_string(), String::new()),
            PartitionMode::TwoD { rows, cols } => ("2d".to_string(), format!("{rows}x{cols}")),
            PartitionMode::Hierarchical { islands, per_island } => {
                ("hier".to_string(), format!("{islands}x{per_island}"))
            }
        };
        let expect_str = |field: &str, expected: &str| -> Result<(), PlanError> {
            let found = fp.get(field).and_then(Json::as_str).unwrap_or("<missing>");
            if found != expected {
                return Err(PlanError::CacheFingerprintMismatch {
                    field: field.to_string(),
                    expected: expected.to_string(),
                    found: found.to_string(),
                });
            }
            Ok(())
        };
        let expect_u64 = |field: &str, expected: u64| -> Result<(), PlanError> {
            let found = fp.get(field).and_then(Json::as_u64);
            if found != Some(expected) {
                return Err(PlanError::CacheFingerprintMismatch {
                    field: field.to_string(),
                    expected: expected.to_string(),
                    found: found.map_or("<missing>".to_string(), |v| v.to_string()),
                });
            }
            Ok(())
        };
        expect_str("store", &store.fingerprint_hex())?;
        expect_u64("n", store.num_vertices() as u64)?;
        expect_u64("m", store.num_edges())?;
        expect_u64("nodes", config.num_nodes as u64)?;
        expect_str("mode", &mode)?;
        expect_str("grid", &grid)?;
        expect_str("pattern", &config.pattern.name())?;
        expect_str("net", &net_fingerprint(&config))?;
        let relabeled = matches!(fp.get("relabeled"), Some(Json::Bool(true)));
        if relabeled != store.is_relabeled() {
            return Err(PlanError::CacheFingerprintMismatch {
                field: "relabeled".to_string(),
                expected: store.is_relabeled().to_string(),
                found: relabeled.to_string(),
            });
        }

        let n = store.num_vertices();
        if config.num_nodes == 0 {
            return Err(PlanError::NoNodes);
        }
        if n == 0 {
            return Err(PlanError::EmptyGraph);
        }
        let read_cuts = |key: &str, parts: usize| -> Result<Vec<VertexId>, PlanError> {
            let arr = cache
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| PlanError::CacheCorrupt(format!("missing {key} array")))?;
            if arr.len() != parts + 1 {
                return Err(PlanError::CacheCorrupt(format!(
                    "{key} has {} entries, expected {}",
                    arr.len(),
                    parts + 1
                )));
            }
            let mut cuts = Vec::with_capacity(arr.len());
            let mut prev = 0u64;
            for (i, v) in arr.iter().enumerate() {
                let c = v
                    .as_u64()
                    .filter(|&c| c <= n as u64)
                    .ok_or_else(|| PlanError::CacheCorrupt(format!("bad {key}[{i}]")))?;
                if (i == 0 && c != 0) || c < prev {
                    return Err(PlanError::CacheCorrupt(format!("{key} not monotone from 0")));
                }
                prev = c;
                cuts.push(c as VertexId);
            }
            if prev != n as u64 {
                return Err(PlanError::CacheCorrupt(format!("{key} does not end at n={n}")));
            }
            Ok(cuts)
        };
        let relabeling = store.relabeling().map(Arc::new);
        let fingerprint = Some(store.fingerprint_hex());
        match config.partition {
            PartitionMode::OneD => {
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let cuts = read_cuts("cuts", config.num_nodes)?;
                Self::assemble_lazy_1d(store, config, Partition1D { cuts }, relabeling, fingerprint)
            }
            PartitionMode::TwoD { rows, cols } => {
                if rows as usize * cols as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows,
                        cols,
                        num_nodes: config.num_nodes,
                    });
                }
                if rows as usize > n || cols as usize > n {
                    return Err(PlanError::GridTooLarge { rows, cols, num_vertices: n });
                }
                let row_cuts = read_cuts("row_cuts", rows as usize)?;
                let col_cuts = read_cuts("col_cuts", cols as usize)?;
                let p = Partition2D { grid_rows: rows, grid_cols: cols, row_cuts, col_cuts };
                Self::assemble_lazy_2d(store, config, p, relabeling, fingerprint)
            }
            PartitionMode::Hierarchical { islands, per_island } => {
                if islands as usize * per_island as usize != config.num_nodes {
                    return Err(PlanError::GridMismatch {
                        rows: islands,
                        cols: per_island,
                        num_nodes: config.num_nodes,
                    });
                }
                if config.num_nodes > n {
                    return Err(PlanError::TooManyNodes {
                        num_nodes: config.num_nodes,
                        num_vertices: n,
                    });
                }
                let cuts = read_cuts("cuts", config.num_nodes)?;
                Self::assemble_lazy_1d(store, config, Partition1D { cuts }, relabeling, fingerprint)
            }
        }
    }

    /// Write the plan cache next to the store (see
    /// [`cache_json`](Self::cache_json)). Errors if this plan was not
    /// built from a store.
    ///
    /// Crash-consistent: published via
    /// [`crate::util::fsio::atomic_write`], so a crashed writer leaves
    /// either the previous complete cache or none — never a torn JSON
    /// prefix that `load_cache` would choke on.
    pub fn save_cache(&self, path: &std::path::Path) -> Result<(), PlanError> {
        let json = self.cache_json().ok_or_else(|| {
            PlanError::CacheCorrupt("plan was not built from a v2 store".into())
        })?;
        crate::util::fsio::atomic_write(path, (json.render() + "\n").as_bytes())
            .map_err(|e| PlanError::CacheCorrupt(format!("write {}: {e}", path.display())))
    }

    /// Load a plan cache file and warm-start against `store` (see
    /// [`from_cache_json`](Self::from_cache_json)).
    pub fn load_cache(
        store: Arc<GraphStore>,
        config: EngineConfig,
        path: &std::path::Path,
    ) -> Result<Self, PlanError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| PlanError::CacheCorrupt(format!("read {}: {e}", path.display())))?;
        let json = Json::parse(&text).map_err(PlanError::CacheCorrupt)?;
        Self::from_cache_json(store, config, &json)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::PatternKind;
    use crate::graph::gen::structured::path;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn build_validates_layouts() {
        let (g, _) = uniform_random(50, 4, 1);
        assert!(TraversalPlan::build(&g, EngineConfig::dgx2(8, 2)).is_ok());
        assert!(TraversalPlan::build(&g, EngineConfig::dgx2_2d(5, 10)).is_ok());
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(0, 1)).unwrap_err();
        assert_eq!(err, PlanError::NoNodes);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(51, 1)).unwrap_err();
        assert_eq!(err, PlanError::TooManyNodes { num_nodes: 51, num_vertices: 50 });
    }

    #[test]
    fn build_rejects_degenerate_grids() {
        let g = path(3);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 4)).unwrap_err();
        assert_eq!(err, PlanError::GridTooLarge { rows: 2, cols: 4, num_vertices: 3 });
        let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(4, 2)).unwrap_err();
        assert_eq!(err, PlanError::GridTooLarge { rows: 4, cols: 2, num_vertices: 3 });
        // A mismatched grid is a distinct error from an oversized one.
        let cfg = EngineConfig {
            partition: PartitionMode::TwoD { rows: 2, cols: 2 },
            ..EngineConfig::dgx2(6, 1)
        };
        let (big, _) = uniform_random(40, 4, 2);
        let err = TraversalPlan::build(&big, cfg).unwrap_err();
        assert_eq!(err, PlanError::GridMismatch { rows: 2, cols: 2, num_nodes: 6 });
    }

    #[test]
    fn build_rejects_empty_graph() {
        let g = Csr::from_edges(0, &[]);
        let err = TraversalPlan::build(&g, EngineConfig::dgx2(1, 1)).unwrap_err();
        assert_eq!(err, PlanError::EmptyGraph);
    }

    #[test]
    fn plan_accessors() {
        let (g, _) = uniform_random(120, 4, 9);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap();
        assert_eq!(plan.num_vertices(), 120);
        assert_eq!(plan.num_nodes(), 4);
        assert_eq!(plan.graph_edges(), g.num_edges());
        assert!(plan.partition().as_one_d().is_some());
        assert!(matches!(plan.config().pattern, PatternKind::Butterfly { fanout: 2 }));
        assert!(plan.schedule().depth() >= 1);
        assert_eq!(plan.fold_rounds(), 0);
        let plan2 = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 3)).unwrap();
        assert!(plan2.partition().as_two_d().is_some());
        assert!(plan2.fold_rounds() >= 1);
    }

    #[test]
    fn errors_display_and_box() {
        let e: Box<dyn std::error::Error> =
            Box::new(PlanError::GridMismatch { rows: 3, cols: 3, num_nodes: 8 });
        let s = e.to_string();
        assert!(s.contains("3x3") && s.contains("num_nodes=8"), "{s}");
        assert!(PlanError::NoNodes.to_string().contains("at least one"));
        assert!(PlanError::InvalidSchedule("boom".into()).to_string().contains("boom"));
    }

    #[test]
    fn hierarchical_plan_matches_grid_schedule() {
        let (g, _) = uniform_random(200, 4, 5);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2_cluster_hier(2, 4, 2)).unwrap();
        assert_eq!(plan.num_nodes(), 8);
        assert_eq!(plan.fold_rounds(), 0);
        // Slab layout is plain 1D — identical cuts to the flat config.
        let flat = TraversalPlan::build(&g, EngineConfig::dgx2(8, 2)).unwrap();
        assert_eq!(
            plan.partition().as_one_d().unwrap().cuts,
            flat.partition().as_one_d().unwrap().cuts
        );
        // The schedule is the grid-of-islands composition.
        let want = GridOfIslands::new(2, 4, 2).schedule(8);
        assert_eq!(plan.schedule().rounds, want.rounds);
    }

    #[test]
    fn hierarchical_grid_must_cover_nodes() {
        let (g, _) = uniform_random(100, 4, 5);
        let cfg = EngineConfig {
            partition: PartitionMode::Hierarchical { islands: 3, per_island: 3 },
            ..EngineConfig::dgx2(8, 2)
        };
        let err = TraversalPlan::build(&g, cfg).unwrap_err();
        assert_eq!(err, PlanError::GridMismatch { rows: 3, cols: 3, num_nodes: 8 });
    }

    #[test]
    fn two_d_store_cold_streams_each_block_once() {
        use crate::graph::store::{encode_store, GraphStore, StoreWriteOptions};
        let (g, _) = uniform_random(300, 6, 11);
        let enc = encode_store(&g, StoreWriteOptions { relabel: false, block_size: 64 }).unwrap();
        let store = Arc::new(GraphStore::open_bytes(enc.bytes).unwrap());
        let plan =
            TraversalPlan::build_from_store(Arc::clone(&store), EngineConfig::dgx2_2d(2, 3))
                .unwrap();
        let c = store.counters();
        let n = store.num_vertices() as u64;
        let blocks = n.div_ceil(u64::from(store.block_size()));
        assert_eq!(c.degree_entries_decoded, n);
        assert_eq!(c.edges_decoded, store.num_edges());
        assert_eq!(c.blocks_decoded, blocks, "each block decoded exactly once");
        // Streamed cuts are bit-identical to the in-memory constructor's.
        let reference = Partition2D::new(&g, 2, 3);
        let p = plan.partition().as_two_d().unwrap();
        assert_eq!(p.row_cuts, reference.row_cuts);
        assert_eq!(p.col_cuts, reference.col_cuts);
    }

    #[test]
    fn hierarchical_store_cold_and_cache_roundtrip() {
        use crate::graph::store::{encode_store, GraphStore, StoreWriteOptions};
        let (g, _) = uniform_random(150, 4, 3);
        let enc = encode_store(&g, StoreWriteOptions::default()).unwrap();
        let store = Arc::new(GraphStore::open_bytes(enc.bytes).unwrap());
        let cfg = EngineConfig::dgx2_cluster_hier(2, 3, 2);
        let cold = TraversalPlan::build_from_store(Arc::clone(&store), cfg.clone()).unwrap();
        let cache = cold.cache_json().unwrap();
        let fp = cache.get("fingerprint").unwrap();
        assert_eq!(fp.get("mode").and_then(Json::as_str), Some("hier"));
        assert_eq!(fp.get("grid").and_then(Json::as_str), Some("2x3"));
        let warm = TraversalPlan::from_cache_json(Arc::clone(&store), cfg, &cache).unwrap();
        assert_eq!(
            warm.partition().as_one_d().unwrap().cuts,
            cold.partition().as_one_d().unwrap().cuts
        );
        assert_eq!(warm.schedule().rounds, cold.schedule().rounds);
        // A different grid in the config is a typed mismatch vs the cache.
        let other = EngineConfig::dgx2_cluster_hier(3, 2, 2);
        let err = TraversalPlan::from_cache_json(Arc::clone(&store), other, &cache).unwrap_err();
        assert!(matches!(err, PlanError::CacheFingerprintMismatch { .. }));
    }

    #[test]
    fn cache_fingerprint_covers_net_topology() {
        use crate::graph::store::{encode_store, GraphStore, StoreWriteOptions};
        use crate::net::TopologyModel;
        let (g, _) = uniform_random(150, 4, 3);
        let enc = encode_store(&g, StoreWriteOptions::default()).unwrap();
        let store = Arc::new(GraphStore::open_bytes(enc.bytes).unwrap());

        // Cached under the clustered topology…
        let clustered = EngineConfig::dgx2_cluster_hier(2, 3, 2);
        let cache = TraversalPlan::build_from_store(Arc::clone(&store), clustered.clone())
            .unwrap()
            .cache_json()
            .unwrap();
        assert_eq!(
            cache.get("fingerprint").and_then(|f| f.get("net")).and_then(Json::as_str),
            Some("dgx2-cluster/3")
        );
        // …must miss under the flat dgx2 fabric, naming the field.
        let mut flat = clustered.clone();
        flat.topology = None; // hier + no topology resolves to classified dgx2
        let err =
            TraversalPlan::from_cache_json(Arc::clone(&store), flat.clone(), &cache).unwrap_err();
        match err {
            PlanError::CacheFingerprintMismatch { field, expected, found } => {
                assert_eq!(field, "net");
                assert_eq!(expected, "dgx2/3");
                assert_eq!(found, "dgx2-cluster/3");
            }
            other => panic!("expected net mismatch, got {other:?}"),
        }

        // And the other direction: cached flat, reopened clustered.
        let cache_flat = TraversalPlan::build_from_store(Arc::clone(&store), flat.clone())
            .unwrap()
            .cache_json()
            .unwrap();
        let err = TraversalPlan::from_cache_json(Arc::clone(&store), clustered, &cache_flat)
            .unwrap_err();
        assert!(
            matches!(err, PlanError::CacheFingerprintMismatch { ref field, .. } if field == "net"),
            "expected net mismatch, got {err:?}"
        );

        // Same topology on both sides still warm-starts.
        let again = TraversalPlan::from_cache_json(Arc::clone(&store), flat, &cache_flat);
        assert!(again.is_ok());

        // Uniform (non-tiered) fingerprints omit the island qualifier.
        let one_d = EngineConfig::dgx2(4, 2);
        assert_eq!(net_fingerprint(&one_d), "dgx2");
        let mut tiered_1d = one_d;
        tiered_1d.topology = Some(TopologyModel::dgx2_cluster(2));
        assert_eq!(net_fingerprint(&tiered_1d), "dgx2-cluster/2");
    }

    #[test]
    fn backend_mismatch_is_typed() {
        let (g, _) = uniform_random(30, 4, 3);
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 1)).unwrap();
        let err = plan.session_with_backends(Vec::new()).unwrap_err();
        assert_eq!(err, PlanError::BackendMismatch { backends: 0, num_nodes: 4 });
    }
}
