//! Phase-1 compute backends.
//!
//! The distributed engine is backend-agnostic (the paper: traversal and
//! communication are "two separate and independent phases"). Backends:
//!
//! * [`NativeCsr`] — the Rust CSR engine with LRB binning; handles any
//!   graph size. This is the performance hot path.
//! * `runtime::XlaFrontierBackend` — executes the AOT-compiled JAX/Pallas
//!   BLAS-formulation level step via PJRT (the L1/L2 layers); fixed-shape
//!   artifacts, used on demo-scale graphs and in the e2e example.

use crate::bfs::frontier::Bitmap;
use crate::bfs::lrb::bin_frontier;
use crate::bfs::msbfs::MAX_LANE_WORDS;
use crate::graph::csr::{CsrSlab, VertexId};

/// Output of one node's Phase-1 expansion.
#[derive(Clone, Debug, Default)]
pub struct ExpandOutput {
    /// Newly discovered vertices (deduped against the node's visited set;
    /// global ids, any owner).
    pub discovered: Vec<VertexId>,
    /// Edges examined.
    pub edges_examined: u64,
}

/// Output of one node's *batched* (MS-BFS) Phase-1 bottom-up expansion:
/// every owned vertex that gained lanes, with exactly the newly-gained
/// lane mask (already filtered against the node's `seen` masks). The
/// masks are width-agnostic: `masks` holds `words` 64-bit words per
/// discovered vertex, parallel to `discovered` (`masks[i·words..]` is
/// entry `i`'s mask), so one trait signature serves every monomorphized
/// lane width.
#[derive(Clone, Debug, Default)]
pub struct BatchExpandOutput {
    /// Discovered vertices, ascending (the owned-range scan order).
    pub discovered: Vec<VertexId>,
    /// `words` mask words per discovered vertex, parallel to
    /// `discovered`; each entry's mask is nonzero.
    pub masks: Vec<u64>,
    /// Edges (neighbor probes) examined, counting the bottom-up early
    /// exit — the quantity the direction heuristic is trying to shrink.
    pub edges_examined: u64,
}

/// A per-node Phase-1 implementation.
pub trait ComputeBackend: Send {
    /// Backend name for metrics.
    fn name(&self) -> &'static str;

    /// Top-down step: expand `frontier` (owned vertices of `slab`) against
    /// `visited` (the node's global visited bitmap, already containing
    /// every vertex the node knows). Must mark discoveries in `visited`
    /// and return them. Must not touch any other node's state.
    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// Bottom-up step (Beamer-style child-finds-parent; the paper's
    /// contribution 3 notes the butterfly sync composes with it
    /// unchanged): scan this node's *owned, unvisited* vertices for a
    /// neighbor in `frontier_full` — the complete global frontier, which
    /// every node holds after the previous level's butterfly exchange.
    /// Discoveries are therefore always owned vertices. Must mark them in
    /// `visited`.
    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// True when [`ComputeBackend::expand_bottom_up`] is implemented.
    fn supports_bottom_up(&self) -> bool {
        true
    }

    /// Batched (MS-BFS) bottom-up step over `full_mask.len()`-word lane
    /// masks: scan this node's owned vertices whose `seen` mask is not
    /// yet `full_mask` and accumulate
    /// `new = !seen[v] & (visit_full[u₀] | visit_full[u₁] | …)` word-wise
    /// over the slab's neighbors, early-exiting once every missing lane
    /// (across all words) found a parent. `visit_full` and `seen` are
    /// flat vertex-major word arrays (`W` words per vertex, `W =
    /// full_mask.len() <= `[`MAX_LANE_WORDS`]) — the complete
    /// previous-level frontier as per-vertex lane masks, which every node
    /// holds after the exchange (the batched analog of `frontier_full`).
    /// Discoveries go into `out` only; the session routes them through
    /// `MsBfsNodeState::discover`.
    ///
    /// Only called when [`ComputeBackend::supports_bottom_up_batch`]
    /// returns true — the default body panics so an unprobed call is loud.
    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: &[u64],
        out: &mut BatchExpandOutput,
    ) {
        let _ = (slab, visit_full, seen, full_mask, out);
        unimplemented!(
            "backend {} has no batched bottom-up kernel; probe \
             supports_bottom_up_batch() before dispatching",
            self.name()
        );
    }

    /// Capability probe for [`ComputeBackend::expand_bottom_up_batch`].
    /// Defaults to `false`: the engine degrades the whole batch to
    /// top-down when any node's backend lacks the kernel (the XLA
    /// backend's fixed-shape artifacts have no lane-mask step).
    fn supports_bottom_up_batch(&self) -> bool {
        false
    }
}

/// The native Rust CSR backend (optionally LRB-ordered).
///
/// §Perf note: a sorted-frontier variant (ascending row order for
/// sequential CSR reads) was measured at no gain at suite scale (the
/// working set is cache-resident) and reverted — see EXPERIMENTS.md §Perf.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCsr {
    /// Order edge processing by LRB bins (deterministic + the GPU
    /// load-balancing analog).
    pub use_lrb: bool,
}

impl NativeCsr {
    /// Create a backend (LRB on/off).
    pub fn new(use_lrb: bool) -> Self {
        Self { use_lrb }
    }
}

impl ComputeBackend for NativeCsr {
    fn name(&self) -> &'static str {
        "native-csr"
    }

    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        let expand_one = |v: VertexId, visited: &mut Bitmap, out: &mut ExpandOutput| {
            // Counter hoisted out of the edge loop (§Perf optimization 3).
            out.edges_examined += slab.degree_global(v) as u64;
            for &u in slab.neighbors_global(v) {
                if visited.test_and_set(u) {
                    out.discovered.push(u);
                }
            }
        };
        if self.use_lrb {
            let binned = bin_frontier(frontier, |v| slab.degree_global(v));
            for b in binned.dispatch_order() {
                for &v in binned.bin(b) {
                    expand_one(v, visited, out);
                }
            }
        } else {
            for &v in frontier {
                expand_one(v, visited, out);
            }
        }
    }

    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        for v in slab.first_vertex..slab.end_vertex() {
            if visited.get(v) {
                continue;
            }
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                if frontier_full.get(u) {
                    // First parent wins (early exit — the entire point of
                    // the bottom-up formulation).
                    visited.set(v);
                    out.discovered.push(v);
                    break;
                }
            }
        }
    }

    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: &[u64],
        out: &mut BatchExpandOutput,
    ) {
        let w = full_mask.len();
        debug_assert!(w >= 1 && w <= MAX_LANE_WORDS);
        out.discovered.clear();
        out.masks.clear();
        out.edges_examined = 0;
        let mut missing = [0u64; MAX_LANE_WORDS];
        let mut acc = [0u64; MAX_LANE_WORDS];
        for v in slab.first_vertex..slab.end_vertex() {
            let base = v as usize * w;
            let mut miss_any = 0u64;
            for k in 0..w {
                missing[k] = full_mask[k] & !seen[base + k];
                miss_any |= missing[k];
            }
            if miss_any == 0 {
                continue;
            }
            acc[..w].iter_mut().for_each(|x| *x = 0);
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                let ubase = u as usize * w;
                let mut covered = true;
                for k in 0..w {
                    acc[k] |= visit_full[ubase + k];
                    covered &= acc[k] & missing[k] == missing[k];
                }
                if covered {
                    // Every still-missing lane (in every word) found a
                    // parent — the lane-mask generalization of
                    // first-parent-wins.
                    break;
                }
            }
            let mut d_any = 0u64;
            for k in 0..w {
                missing[k] &= acc[k];
                d_any |= missing[k];
            }
            if d_any != 0 {
                out.discovered.push(v);
                out.masks.extend_from_slice(&missing[..w]);
            }
        }
    }

    fn supports_bottom_up_batch(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn native_expand_matches_manual() {
        let (g, _) = uniform_random(300, 8, 21);
        let slab = g.row_slice(0, 300);
        for use_lrb in [false, true] {
            let mut visited = Bitmap::new(300);
            visited.set(7);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &[7], &mut visited, &mut out);
            assert_eq!(out.edges_examined, g.degree(7) as u64);
            let mut want: Vec<VertexId> =
                g.neighbors(7).iter().copied().filter(|&u| u != 7).collect();
            want.dedup();
            let mut got = out.discovered.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "lrb={use_lrb}");
        }
    }

    #[test]
    fn lrb_and_plain_discover_same_set() {
        let (g, _) = uniform_random(500, 12, 5);
        let slab = g.row_slice(0, 500);
        let frontier: Vec<VertexId> = (0..50).collect();
        let run = |use_lrb: bool| {
            let mut visited = Bitmap::from_queue(500, &frontier);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &frontier, &mut visited, &mut out);
            let mut d = out.discovered;
            d.sort_unstable();
            (d, out.edges_examined)
        };
        let (d1, e1) = run(false);
        let (d2, e2) = run(true);
        assert_eq!(d1, d2);
        assert_eq!(e1, e2);
    }

    /// Generic checker for the batched bottom-up kernel at `words` lane
    /// words: every discovery is an owned vertex gaining exactly its
    /// neighbors' frontier lanes minus what it had seen, early exit can
    /// only truncate once all missing lanes are covered, and no owned
    /// unseen vertex with a frontier neighbor is skipped.
    fn check_batch_bottom_up(words: usize) {
        let (g, _) = uniform_random(200, 6, 33);
        let slab = g.row_slice(50, 150);
        let lanes = words * 64;
        let mut full = vec![u64::MAX; words];
        if words == 1 {
            full[0] = 0b1111; // the original 4-lane case
        }
        // A synthetic frontier: every third vertex carries one lane
        // (striped across all words so every word is exercised).
        let mut visit_full = vec![0u64; 200 * words];
        for v in (0..200usize).step_by(3) {
            let lane = (v * 7) % lanes;
            visit_full[v * words + lane / 64] |= 1 << (lane % 64);
        }
        // Partially-seen owned range: vertex 60 already has lane 0.
        let mut seen = vec![0u64; 200 * words];
        seen[60 * words] = 0b1;
        let mut out = BatchExpandOutput::default();
        NativeCsr::new(false).expand_bottom_up_batch(
            &slab,
            &visit_full,
            &seen,
            &full,
            &mut out,
        );
        assert!(NativeCsr::new(false).supports_bottom_up_batch());
        assert_eq!(out.masks.len(), out.discovered.len() * words);
        for (i, &v) in out.discovered.iter().enumerate() {
            assert!(slab.owns(v));
            let d = &out.masks[i * words..(i + 1) * words];
            assert!(d.iter().any(|&x| x != 0), "v={v} zero mask recorded");
            // Accumulate the full neighbor union for comparison.
            let mut acc = vec![0u64; words];
            for &u in g.neighbors(v) {
                for k in 0..words {
                    acc[k] |= visit_full[u as usize * words + k];
                }
            }
            let vb = v as usize * words;
            for k in 0..words {
                let missing = full[k] & !seen[vb + k];
                assert_eq!(d[k] & !missing, 0, "v={v} word {k} leaked lanes");
                assert_eq!(d[k] & !acc[k], 0, "v={v} word {k} invented lanes");
                // Early exit can only truncate acc when missing is fully
                // covered, in which case d == missing in every word.
                if (0..words).all(|j| {
                    let mj = full[j] & !seen[vb + j];
                    acc[j] & mj == mj
                }) {
                    assert_eq!(d[k], missing, "v={v} word {k} early exit must cover all");
                }
            }
        }
        // Completeness: any owned unseen vertex with a frontier neighbor
        // must appear.
        for v in 50..150u32 {
            let vb = v as usize * words;
            let mut want_any = 0u64;
            for &u in g.neighbors(v) {
                for k in 0..words {
                    want_any |=
                        visit_full[u as usize * words + k] & full[k] & !seen[vb + k];
                }
            }
            let got = out.discovered.iter().any(|&x| x == v);
            assert_eq!(got, want_any != 0, "v={v} words={words}");
        }
        assert!(out.edges_examined > 0);
    }

    #[test]
    fn batch_bottom_up_matches_manual_accumulation() {
        check_batch_bottom_up(1);
    }

    #[test]
    fn batch_bottom_up_wide_words() {
        for words in [2usize, 4, 8] {
            check_batch_bottom_up(words);
        }
    }

    #[test]
    fn expand_respects_visited() {
        let (g, _) = uniform_random(100, 8, 9);
        let slab = g.row_slice(0, 100);
        let mut visited = Bitmap::new(100);
        for v in 0..100u32 {
            visited.set(v);
        }
        let mut out = ExpandOutput::default();
        NativeCsr { use_lrb: false }.expand(&slab, &[0], &mut visited, &mut out);
        assert!(out.discovered.is_empty());
        assert_eq!(out.edges_examined, g.degree(0) as u64);
    }
}
