//! Phase-1 compute backends.
//!
//! The distributed engine is backend-agnostic (the paper: traversal and
//! communication are "two separate and independent phases"). Backends:
//!
//! * [`NativeCsr`] — the Rust CSR engine with LRB binning; handles any
//!   graph size. This is the performance hot path.
//! * `runtime::XlaFrontierBackend` — executes the AOT-compiled JAX/Pallas
//!   BLAS-formulation level step via PJRT (the L1/L2 layers); fixed-shape
//!   artifacts, used on demo-scale graphs and in the e2e example.

use crate::bfs::frontier::Bitmap;
use crate::bfs::lrb::bin_frontier;
use crate::graph::csr::{CsrSlab, VertexId};

/// Output of one node's Phase-1 expansion.
#[derive(Clone, Debug, Default)]
pub struct ExpandOutput {
    /// Newly discovered vertices (deduped against the node's visited set;
    /// global ids, any owner).
    pub discovered: Vec<VertexId>,
    /// Edges examined.
    pub edges_examined: u64,
}

/// A per-node Phase-1 implementation.
pub trait ComputeBackend: Send {
    /// Backend name for metrics.
    fn name(&self) -> &'static str;

    /// Top-down step: expand `frontier` (owned vertices of `slab`) against
    /// `visited` (the node's global visited bitmap, already containing
    /// every vertex the node knows). Must mark discoveries in `visited`
    /// and return them. Must not touch any other node's state.
    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// Bottom-up step (Beamer-style child-finds-parent; the paper's
    /// contribution 3 notes the butterfly sync composes with it
    /// unchanged): scan this node's *owned, unvisited* vertices for a
    /// neighbor in `frontier_full` — the complete global frontier, which
    /// every node holds after the previous level's butterfly exchange.
    /// Discoveries are therefore always owned vertices. Must mark them in
    /// `visited`.
    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// True when [`ComputeBackend::expand_bottom_up`] is implemented.
    fn supports_bottom_up(&self) -> bool {
        true
    }
}

/// The native Rust CSR backend (optionally LRB-ordered).
///
/// §Perf note: a sorted-frontier variant (ascending row order for
/// sequential CSR reads) was measured at no gain at suite scale (the
/// working set is cache-resident) and reverted — see EXPERIMENTS.md §Perf.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCsr {
    /// Order edge processing by LRB bins (deterministic + the GPU
    /// load-balancing analog).
    pub use_lrb: bool,
}

impl NativeCsr {
    /// Create a backend (LRB on/off).
    pub fn new(use_lrb: bool) -> Self {
        Self { use_lrb }
    }
}

impl ComputeBackend for NativeCsr {
    fn name(&self) -> &'static str {
        "native-csr"
    }

    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        let expand_one = |v: VertexId, visited: &mut Bitmap, out: &mut ExpandOutput| {
            // Counter hoisted out of the edge loop (§Perf optimization 3).
            out.edges_examined += slab.degree_global(v) as u64;
            for &u in slab.neighbors_global(v) {
                if visited.test_and_set(u) {
                    out.discovered.push(u);
                }
            }
        };
        if self.use_lrb {
            let binned = bin_frontier(frontier, |v| slab.degree_global(v));
            for b in binned.dispatch_order() {
                for &v in binned.bin(b) {
                    expand_one(v, visited, out);
                }
            }
        } else {
            for &v in frontier {
                expand_one(v, visited, out);
            }
        }
    }

    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        for v in slab.first_vertex..slab.end_vertex() {
            if visited.get(v) {
                continue;
            }
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                if frontier_full.get(u) {
                    // First parent wins (early exit — the entire point of
                    // the bottom-up formulation).
                    visited.set(v);
                    out.discovered.push(v);
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn native_expand_matches_manual() {
        let (g, _) = uniform_random(300, 8, 21);
        let slab = g.row_slice(0, 300);
        for use_lrb in [false, true] {
            let mut visited = Bitmap::new(300);
            visited.set(7);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &[7], &mut visited, &mut out);
            assert_eq!(out.edges_examined, g.degree(7) as u64);
            let mut want: Vec<VertexId> =
                g.neighbors(7).iter().copied().filter(|&u| u != 7).collect();
            want.dedup();
            let mut got = out.discovered.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "lrb={use_lrb}");
        }
    }

    #[test]
    fn lrb_and_plain_discover_same_set() {
        let (g, _) = uniform_random(500, 12, 5);
        let slab = g.row_slice(0, 500);
        let frontier: Vec<VertexId> = (0..50).collect();
        let run = |use_lrb: bool| {
            let mut visited = Bitmap::from_queue(500, &frontier);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &frontier, &mut visited, &mut out);
            let mut d = out.discovered;
            d.sort_unstable();
            (d, out.edges_examined)
        };
        let (d1, e1) = run(false);
        let (d2, e2) = run(true);
        assert_eq!(d1, d2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn expand_respects_visited() {
        let (g, _) = uniform_random(100, 8, 9);
        let slab = g.row_slice(0, 100);
        let mut visited = Bitmap::new(100);
        for v in 0..100u32 {
            visited.set(v);
        }
        let mut out = ExpandOutput::default();
        NativeCsr { use_lrb: false }.expand(&slab, &[0], &mut visited, &mut out);
        assert!(out.discovered.is_empty());
        assert_eq!(out.edges_examined, g.degree(0) as u64);
    }
}
