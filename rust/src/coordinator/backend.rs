//! Phase-1 compute backends.
//!
//! The distributed engine is backend-agnostic (the paper: traversal and
//! communication are "two separate and independent phases"). Backends:
//!
//! * [`NativeCsr`] — the Rust CSR engine with LRB binning; handles any
//!   graph size. This is the performance hot path.
//! * `runtime::XlaFrontierBackend` — executes the AOT-compiled JAX/Pallas
//!   BLAS-formulation level step via PJRT (the L1/L2 layers); fixed-shape
//!   artifacts, used on demo-scale graphs and in the e2e example.

use crate::bfs::frontier::Bitmap;
use crate::bfs::lrb::bin_frontier;
use crate::graph::csr::{CsrSlab, VertexId};

/// Output of one node's Phase-1 expansion.
#[derive(Clone, Debug, Default)]
pub struct ExpandOutput {
    /// Newly discovered vertices (deduped against the node's visited set;
    /// global ids, any owner).
    pub discovered: Vec<VertexId>,
    /// Edges examined.
    pub edges_examined: u64,
}

/// Output of one node's *batched* (MS-BFS) Phase-1 bottom-up expansion:
/// every owned vertex that gained lanes, with exactly the newly-gained
/// lane mask (already filtered against the node's `seen` masks).
#[derive(Clone, Debug, Default)]
pub struct BatchExpandOutput {
    /// `(vertex, new-lane-mask)` discoveries, ascending by vertex (the
    /// owned-range scan order). Masks are nonzero.
    pub discovered: Vec<(VertexId, u64)>,
    /// Edges (neighbor probes) examined, counting the bottom-up early
    /// exit — the quantity the direction heuristic is trying to shrink.
    pub edges_examined: u64,
}

/// A per-node Phase-1 implementation.
pub trait ComputeBackend: Send {
    /// Backend name for metrics.
    fn name(&self) -> &'static str;

    /// Top-down step: expand `frontier` (owned vertices of `slab`) against
    /// `visited` (the node's global visited bitmap, already containing
    /// every vertex the node knows). Must mark discoveries in `visited`
    /// and return them. Must not touch any other node's state.
    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// Bottom-up step (Beamer-style child-finds-parent; the paper's
    /// contribution 3 notes the butterfly sync composes with it
    /// unchanged): scan this node's *owned, unvisited* vertices for a
    /// neighbor in `frontier_full` — the complete global frontier, which
    /// every node holds after the previous level's butterfly exchange.
    /// Discoveries are therefore always owned vertices. Must mark them in
    /// `visited`.
    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// True when [`ComputeBackend::expand_bottom_up`] is implemented.
    fn supports_bottom_up(&self) -> bool {
        true
    }

    /// Batched (MS-BFS) bottom-up step: scan this node's owned vertices
    /// whose `seen` mask is not yet `full_mask` and accumulate
    /// `new = !seen[v] & (visit_full[u₀] | visit_full[u₁] | …)` over the
    /// slab's neighbors, early-exiting once every missing lane found a
    /// parent. `visit_full` is the complete previous-level frontier as
    /// per-vertex lane masks — every node holds it after the exchange
    /// (the batched analog of `frontier_full`). Discoveries go into `out`
    /// only; the session routes them through `MsBfsNodeState::discover`.
    ///
    /// Only called when [`ComputeBackend::supports_bottom_up_batch`]
    /// returns true — the default body panics so an unprobed call is loud.
    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: u64,
        out: &mut BatchExpandOutput,
    ) {
        let _ = (slab, visit_full, seen, full_mask, out);
        unimplemented!(
            "backend {} has no batched bottom-up kernel; probe \
             supports_bottom_up_batch() before dispatching",
            self.name()
        );
    }

    /// Capability probe for [`ComputeBackend::expand_bottom_up_batch`].
    /// Defaults to `false`: the engine degrades the whole batch to
    /// top-down when any node's backend lacks the kernel (the XLA
    /// backend's fixed-shape artifacts have no lane-mask step).
    fn supports_bottom_up_batch(&self) -> bool {
        false
    }
}

/// The native Rust CSR backend (optionally LRB-ordered).
///
/// §Perf note: a sorted-frontier variant (ascending row order for
/// sequential CSR reads) was measured at no gain at suite scale (the
/// working set is cache-resident) and reverted — see EXPERIMENTS.md §Perf.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCsr {
    /// Order edge processing by LRB bins (deterministic + the GPU
    /// load-balancing analog).
    pub use_lrb: bool,
}

impl NativeCsr {
    /// Create a backend (LRB on/off).
    pub fn new(use_lrb: bool) -> Self {
        Self { use_lrb }
    }
}

impl ComputeBackend for NativeCsr {
    fn name(&self) -> &'static str {
        "native-csr"
    }

    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        let expand_one = |v: VertexId, visited: &mut Bitmap, out: &mut ExpandOutput| {
            // Counter hoisted out of the edge loop (§Perf optimization 3).
            out.edges_examined += slab.degree_global(v) as u64;
            for &u in slab.neighbors_global(v) {
                if visited.test_and_set(u) {
                    out.discovered.push(u);
                }
            }
        };
        if self.use_lrb {
            let binned = bin_frontier(frontier, |v| slab.degree_global(v));
            for b in binned.dispatch_order() {
                for &v in binned.bin(b) {
                    expand_one(v, visited, out);
                }
            }
        } else {
            for &v in frontier {
                expand_one(v, visited, out);
            }
        }
    }

    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        for v in slab.first_vertex..slab.end_vertex() {
            if visited.get(v) {
                continue;
            }
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                if frontier_full.get(u) {
                    // First parent wins (early exit — the entire point of
                    // the bottom-up formulation).
                    visited.set(v);
                    out.discovered.push(v);
                    break;
                }
            }
        }
    }

    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: u64,
        out: &mut BatchExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        for v in slab.first_vertex..slab.end_vertex() {
            let missing = full_mask & !seen[v as usize];
            if missing == 0 {
                continue;
            }
            let mut acc = 0u64;
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                acc |= visit_full[u as usize];
                if acc & missing == missing {
                    // Every still-missing lane found a parent — the
                    // lane-mask generalization of first-parent-wins.
                    break;
                }
            }
            let d = acc & missing;
            if d != 0 {
                out.discovered.push((v, d));
            }
        }
    }

    fn supports_bottom_up_batch(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn native_expand_matches_manual() {
        let (g, _) = uniform_random(300, 8, 21);
        let slab = g.row_slice(0, 300);
        for use_lrb in [false, true] {
            let mut visited = Bitmap::new(300);
            visited.set(7);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &[7], &mut visited, &mut out);
            assert_eq!(out.edges_examined, g.degree(7) as u64);
            let mut want: Vec<VertexId> =
                g.neighbors(7).iter().copied().filter(|&u| u != 7).collect();
            want.dedup();
            let mut got = out.discovered.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "lrb={use_lrb}");
        }
    }

    #[test]
    fn lrb_and_plain_discover_same_set() {
        let (g, _) = uniform_random(500, 12, 5);
        let slab = g.row_slice(0, 500);
        let frontier: Vec<VertexId> = (0..50).collect();
        let run = |use_lrb: bool| {
            let mut visited = Bitmap::from_queue(500, &frontier);
            let mut out = ExpandOutput::default();
            NativeCsr { use_lrb }.expand(&slab, &frontier, &mut visited, &mut out);
            let mut d = out.discovered;
            d.sort_unstable();
            (d, out.edges_examined)
        };
        let (d1, e1) = run(false);
        let (d2, e2) = run(true);
        assert_eq!(d1, d2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn batch_bottom_up_matches_manual_accumulation() {
        let (g, _) = uniform_random(200, 6, 33);
        let slab = g.row_slice(50, 150);
        let full = 0b1111u64;
        // A synthetic frontier: every third vertex carries some lanes.
        let mut visit_full = vec![0u64; 200];
        for v in (0..200).step_by(3) {
            visit_full[v] = 1 << (v % 4);
        }
        // Partially-seen owned range: vertex 60 already has lane 0.
        let mut seen = vec![0u64; 200];
        seen[60] = 0b1;
        let mut out = BatchExpandOutput::default();
        NativeCsr::new(false).expand_bottom_up_batch(
            &slab,
            &visit_full,
            &seen,
            full,
            &mut out,
        );
        assert!(NativeCsr::new(false).supports_bottom_up_batch());
        // Every discovery must be an owned vertex gaining exactly the
        // union of its neighbors' frontier lanes, minus what it had seen.
        for &(v, d) in &out.discovered {
            assert!(slab.owns(v));
            let acc: u64 = g
                .neighbors(v)
                .iter()
                .map(|&u| visit_full[u as usize])
                .fold(0, |a, m| a | m);
            // The early exit may stop before the full union, but never
            // before all missing lanes are covered or the list ends —
            // so d is the full filtered union whenever it is nonzero.
            assert_eq!(d & !(full & !seen[v as usize]), 0, "v={v} leaked lanes");
            assert!(d <= acc, "v={v}");
            let missing = full & !seen[v as usize];
            if acc & missing == missing {
                assert_eq!(d, missing, "v={v} early exit must cover all");
            }
        }
        // Completeness: any owned unseen vertex with a frontier neighbor
        // must appear.
        for v in 50..150u32 {
            let missing = full & !seen[v as usize];
            let acc: u64 = g
                .neighbors(v)
                .iter()
                .map(|&u| visit_full[u as usize])
                .fold(0, |a, m| a | m);
            let want = acc & missing;
            let got = out
                .discovered
                .iter()
                .find(|&&(x, _)| x == v)
                .map(|&(_, d)| d)
                .unwrap_or(0);
            // Early exit can only *truncate* acc when missing is already
            // covered, in which case got == missing == want.
            if want != 0 {
                assert!(got != 0, "v={v} missing discovery");
            } else {
                assert_eq!(got, 0, "v={v} spurious discovery");
            }
        }
        assert!(out.edges_examined > 0);
    }

    #[test]
    fn expand_respects_visited() {
        let (g, _) = uniform_random(100, 8, 9);
        let slab = g.row_slice(0, 100);
        let mut visited = Bitmap::new(100);
        for v in 0..100u32 {
            visited.set(v);
        }
        let mut out = ExpandOutput::default();
        NativeCsr { use_lrb: false }.expand(&slab, &[0], &mut visited, &mut out);
        assert!(out.discovered.is_empty());
        assert_eq!(out.edges_examined, g.degree(0) as u64);
    }
}
