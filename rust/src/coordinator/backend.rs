//! Phase-1 compute backends.
//!
//! The distributed engine is backend-agnostic (the paper: traversal and
//! communication are "two separate and independent phases"). Backends:
//!
//! * [`NativeCsr`] — the Rust CSR engine with LRB binning and selectable
//!   mask-kernel shapes ([`KernelVariant`]); handles any graph size.
//!   This is the performance hot path.
//! * `runtime::XlaFrontierBackend` — executes the AOT-compiled JAX/Pallas
//!   BLAS-formulation level step via PJRT (the L1/L2 layers); fixed-shape
//!   artifacts, used on demo-scale graphs and in the e2e example. It has
//!   no native lane-mask kernel, so batched bottom-up reaches it through
//!   the *semiring* formulation
//!   ([`ComputeBackend::expand_bottom_up_batch_semiring`]) instead of
//!   degrading the whole batch to top-down.

use crate::bfs::frontier::Bitmap;
use crate::bfs::kernels::{KernelVariant, KernelWork, CHUNK_VERTICES};
use crate::bfs::lrb::bin_frontier;
use crate::bfs::msbfs::MAX_LANE_WORDS;
use crate::graph::csr::{CsrSlab, VertexId};

/// Output of one node's Phase-1 expansion.
#[derive(Clone, Debug, Default)]
pub struct ExpandOutput {
    /// Newly discovered vertices (deduped against the node's visited set;
    /// global ids, any owner).
    pub discovered: Vec<VertexId>,
    /// Edges examined.
    pub edges_examined: u64,
    /// Deterministic kernel work counters for this expansion (words of
    /// visited/summary traffic plus the dispatch structure).
    pub work: KernelWork,
}

/// Output of one node's *batched* (MS-BFS) Phase-1 bottom-up expansion:
/// every owned vertex that gained lanes, with exactly the newly-gained
/// lane mask (already filtered against the node's `seen` masks). The
/// masks are width-agnostic: `masks` holds `words` 64-bit words per
/// discovered vertex, parallel to `discovered` (`masks[i·words..]` is
/// entry `i`'s mask), so one trait signature serves every monomorphized
/// lane width.
///
/// The struct doubles as the kernel's reusable state: the private
/// candidate/probe scratch buffers and the chunked kernel's cross-level
/// fully-settled summary live here, so a session that keeps one
/// `BatchExpandOutput` per node (cleared in place each level, reset via
/// [`Self::reset_for_batch`] per batch) runs every level allocation-free.
#[derive(Clone, Debug, Default)]
pub struct BatchExpandOutput {
    /// Discovered vertices, ascending (the owned-range scan order).
    pub discovered: Vec<VertexId>,
    /// `words` mask words per discovered vertex, parallel to
    /// `discovered`; each entry's mask is nonzero.
    pub masks: Vec<u64>,
    /// Edges (neighbor probes) examined, counting the bottom-up early
    /// exit — the quantity the direction heuristic is trying to shrink.
    pub edges_examined: u64,
    /// Deterministic kernel work counters for this expansion.
    pub work: KernelWork,
    /// Chunked-kernel summary bitmap over the slab's global vertex range:
    /// bit `v` set once vertex `v`'s missing mask was observed all-zero
    /// (monotone — `seen` only grows within a batch), letting later
    /// levels skip it (and whole 64-vertex chunks of it) without reading
    /// `words` mask words. Persistent across levels, zeroed per batch.
    bu_done: Vec<u64>,
    /// Sweep-stage candidates (owned vertices with a nonzero missing
    /// mask), ascending.
    cand: Vec<VertexId>,
    /// `words` missing-mask words per candidate, parallel to `cand`.
    cand_miss: Vec<u64>,
    /// Probe-stage results: `words` newly-gained words per candidate
    /// (possibly zero), parallel to `cand`. Filled in dispatch order,
    /// emitted in ascending candidate order — how the LRB-binned probe
    /// stays bit-identical to the flat scan.
    probe_new: Vec<u64>,
}

impl BatchExpandOutput {
    /// Reset the cross-level chunked-kernel state (the fully-settled
    /// summary and the work counters) for a fresh batch. Keeps every
    /// allocation.
    pub fn reset_for_batch(&mut self) {
        self.bu_done.iter_mut().for_each(|x| *x = 0);
        self.work.clear();
    }
}

/// A 64-bit mask selecting the bits of chunk word `wi` that fall inside
/// the vertex range `lo..hi`.
#[inline]
fn chunk_range_mask(wi: usize, lo: usize, hi: usize) -> u64 {
    let start = (wi * CHUNK_VERTICES).max(lo);
    let end = ((wi + 1) * CHUNK_VERTICES).min(hi);
    if start >= end {
        return 0;
    }
    let n = end - start;
    let shift = start - wi * CHUNK_VERTICES;
    if n == 64 {
        u64::MAX
    } else {
        ((1u64 << n) - 1) << shift
    }
}

/// A per-node Phase-1 implementation.
pub trait ComputeBackend: Send {
    /// Backend name for metrics.
    fn name(&self) -> &'static str;

    /// Top-down step: expand `frontier` (owned vertices of `slab`) against
    /// `visited` (the node's global visited bitmap, already containing
    /// every vertex the node knows). Must mark discoveries in `visited`
    /// and return them. Must not touch any other node's state.
    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// Bottom-up step (Beamer-style child-finds-parent; the paper's
    /// contribution 3 notes the butterfly sync composes with it
    /// unchanged): scan this node's *owned, unvisited* vertices for a
    /// neighbor in `frontier_full` — the complete global frontier, which
    /// every node holds after the previous level's butterfly exchange.
    /// Discoveries are therefore always owned vertices. Must mark them in
    /// `visited`.
    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    );

    /// True when [`ComputeBackend::expand_bottom_up`] is implemented.
    fn supports_bottom_up(&self) -> bool {
        true
    }

    /// Batched (MS-BFS) bottom-up step over `full_mask.len()`-word lane
    /// masks: scan this node's owned vertices whose `seen` mask is not
    /// yet `full_mask` and accumulate
    /// `new = !seen[v] & (visit_full[u₀] | visit_full[u₁] | …)` word-wise
    /// over the slab's neighbors, early-exiting once every missing lane
    /// (across all words) found a parent. `visit_full` and `seen` are
    /// flat vertex-major word arrays (`W` words per vertex, `W =
    /// full_mask.len() <= `[`MAX_LANE_WORDS`]) — the complete
    /// previous-level frontier as per-vertex lane masks, which every node
    /// holds after the exchange (the batched analog of `frontier_full`).
    /// Discoveries go into `out` only; the session routes them through
    /// `MsBfsNodeState::discover`.
    ///
    /// Only called when [`ComputeBackend::supports_bottom_up_batch`]
    /// returns true — the default body panics so an unprobed call is loud.
    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: &[u64],
        out: &mut BatchExpandOutput,
    ) {
        let _ = (slab, visit_full, seen, full_mask, out);
        unimplemented!(
            "backend {} has no batched bottom-up kernel; probe \
             supports_bottom_up_batch() before dispatching",
            self.name()
        );
    }

    /// Capability probe for [`ComputeBackend::expand_bottom_up_batch`].
    /// Defaults to `false`: backends without a native lane-mask kernel
    /// are reached through
    /// [`ComputeBackend::expand_bottom_up_batch_semiring`] instead.
    fn supports_bottom_up_batch(&self) -> bool {
        false
    }

    /// Batched bottom-up expansion as a **blocked lane-mask semiring
    /// step**: `masks_next = Aᵀ ⊗ masks_frontier` over the
    /// `(OR, AND-NOT-seen)` semiring — for every owned vertex `v`,
    /// OR-reduce the frontier masks of *all* of `v`'s in-neighbors (one
    /// dense "row × vector" product per vertex, no early exit), then
    /// AND the reduction with `full_mask & !seen[v]`. Processed in
    /// 64-vertex row blocks (one dispatch per block), which is exactly
    /// the tiled matmul shape a systolic/vector device compiles — the
    /// formulation the gated XLA path consumes so a backend without a
    /// native lane-mask kernel still runs batched bottom-up instead of
    /// degrading the whole batch to top-down.
    ///
    /// Bit-identical discoveries to
    /// [`ComputeBackend::expand_bottom_up_batch`]: the early exit there
    /// only truncates the OR-reduction once it already covers every
    /// missing lane, so `missing & acc` agrees whether or not the
    /// reduction ran to completion. Only `edges_examined` differs — the
    /// semiring inspects every edge (the GPU bottom-up trade-off the
    /// direction heuristic weighs).
    fn expand_bottom_up_batch_semiring(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: &[u64],
        out: &mut BatchExpandOutput,
    ) {
        let w = full_mask.len();
        debug_assert!(w >= 1 && w <= MAX_LANE_WORDS);
        out.discovered.clear();
        out.masks.clear();
        out.edges_examined = 0;
        out.work.clear();
        let (lo, hi) = (slab.first_vertex as usize, slab.end_vertex() as usize);
        let mut acc = [0u64; MAX_LANE_WORDS];
        let mut block = lo;
        while block < hi {
            let block_end = (block + CHUNK_VERTICES).min(hi);
            let mut block_work = 0u64;
            for v in block as VertexId..block_end as VertexId {
                let base = v as usize * w;
                acc[..w].iter_mut().for_each(|x| *x = 0);
                let neighbors = slab.neighbors_global(v);
                for &u in neighbors {
                    let ubase = u as usize * w;
                    for k in 0..w {
                        acc[k] |= visit_full[ubase + k];
                    }
                }
                out.edges_examined += neighbors.len() as u64;
                let row_words = w as u64 * (1 + neighbors.len() as u64);
                out.work.words_touched += row_words;
                block_work += row_words;
                let mut d_any = 0u64;
                for k in 0..w {
                    acc[k] &= full_mask[k] & !seen[base + k];
                    d_any |= acc[k];
                }
                if d_any != 0 {
                    out.discovered.push(v);
                    out.masks.extend_from_slice(&acc[..w]);
                }
            }
            out.work.record_dispatch(block_work);
            block = block_end;
        }
    }

    /// Capability probe for
    /// [`ComputeBackend::expand_bottom_up_batch_semiring`]. Defaults to
    /// `true` — the blocked default body is pure CSR math every backend
    /// can run. Override to `false` only for a backend that must never
    /// see batched bottom-up work at all (the engine then degrades the
    /// batch to top-down).
    fn supports_bottom_up_batch_semiring(&self) -> bool {
        true
    }
}

/// The native Rust CSR backend (optionally LRB-ordered, with a
/// selectable mask-kernel shape).
///
/// §Perf note: a sorted-frontier variant (ascending row order for
/// sequential CSR reads) was measured at no gain at suite scale (the
/// working set is cache-resident) and reverted — see EXPERIMENTS.md §Perf.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCsr {
    /// Order edge processing by LRB bins (deterministic + the GPU
    /// load-balancing analog). Composes with the wide bottom-up probe
    /// stage: candidates are binned by degree so each dispatch does
    /// uniform work and one hub stops serializing the lane scan.
    pub use_lrb: bool,
    /// Mask-kernel shape for the bottom-up sweeps ([`KernelVariant`]).
    pub kernel: KernelVariant,
}

impl NativeCsr {
    /// Create a backend (LRB on/off) with the default ([`KernelVariant::Auto`])
    /// kernel shape.
    pub fn new(use_lrb: bool) -> Self {
        Self { use_lrb, kernel: KernelVariant::Auto }
    }

    /// Builder: select the mask-kernel shape.
    pub fn with_kernel(mut self, kernel: KernelVariant) -> Self {
        self.kernel = kernel;
        self
    }
}

impl ComputeBackend for NativeCsr {
    fn name(&self) -> &'static str {
        "native-csr"
    }

    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        out.work.clear();
        let expand_one = |v: VertexId, visited: &mut Bitmap, out: &mut ExpandOutput| {
            // Counter hoisted out of the edge loop (§Perf optimization 3).
            out.edges_examined += slab.degree_global(v) as u64;
            for &u in slab.neighbors_global(v) {
                if visited.test_and_set(u) {
                    out.discovered.push(u);
                }
            }
        };
        if self.use_lrb {
            let binned = bin_frontier(frontier, |v| slab.degree_global(v));
            for b in binned.dispatch_order() {
                let before = out.edges_examined;
                for &v in binned.bin(b) {
                    expand_one(v, visited, out);
                }
                out.work.record_dispatch(out.edges_examined - before);
            }
        } else {
            for &v in frontier {
                expand_one(v, visited, out);
            }
            if !frontier.is_empty() {
                out.work.record_dispatch(out.edges_examined);
            }
        }
    }

    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        out.work.clear();
        let (lo, hi) = (slab.first_vertex as usize, slab.end_vertex() as usize);
        let probe_one = |v: VertexId, visited: &mut Bitmap, out: &mut ExpandOutput| {
            for &u in slab.neighbors_global(v) {
                out.edges_examined += 1;
                if frontier_full.get(u) {
                    // First parent wins (early exit — the entire point of
                    // the bottom-up formulation).
                    visited.set(v);
                    out.discovered.push(v);
                    break;
                }
            }
        };
        if self.kernel.is_chunked() {
            // One visited word per 64-vertex chunk: a fully-visited
            // chunk is skipped without per-vertex tests. Discoveries
            // only set bits of vertices already scanned, so the word
            // snapshot taken at chunk entry is exact.
            for wi in lo / CHUNK_VERTICES..hi.div_ceil(CHUNK_VERTICES) {
                let range = chunk_range_mask(wi, lo, hi);
                out.work.words_touched += 1;
                let snapshot = visited.words()[wi];
                let pending = !snapshot & range;
                out.work.words_skipped += (snapshot & range).count_ones() as u64;
                out.work.words_touched += pending.count_ones() as u64;
                let mut bits = pending;
                while bits != 0 {
                    let v = (wi * CHUNK_VERTICES) as u32 + bits.trailing_zeros();
                    bits &= bits - 1;
                    probe_one(v, visited, out);
                }
            }
        } else {
            for v in lo as VertexId..hi as VertexId {
                out.work.words_touched += 1;
                if visited.get(v) {
                    continue;
                }
                probe_one(v, visited, out);
            }
        }
        if hi > lo {
            out.work.record_dispatch(out.edges_examined);
        }
    }

    /// The native wide-lane kernel, restructured as two stages so each
    /// is a clean SIMD shape:
    ///
    /// 1. **Sweep** — walk the owned range computing each vertex's
    ///    missing mask (`full & !seen[v]`), collecting the nonzero ones
    ///    as candidates in ascending order. The scalar shape reads `W`
    ///    words per vertex; the chunked shape consults the persistent
    ///    fully-settled summary ([`BatchExpandOutput`]'s `bu_done`) and
    ///    skips settled vertices — and whole settled 64-vertex chunks —
    ///    without touching their mask words.
    /// 2. **Probe** — for each candidate, OR-accumulate neighbor
    ///    frontier masks with the covered early exit. The probe is pure
    ///    (reads only `visit_full`/`seen` fixed at level start), so with
    ///    LRB composed in the candidates are binned by degree and
    ///    dispatched largest-bin-first — uniform work per dispatch, one
    ///    hub no longer serializing the scan — while results are
    ///    buffered per candidate and emitted in ascending order,
    ///    bit-identical to the flat scan.
    fn expand_bottom_up_batch(
        &mut self,
        slab: &CsrSlab,
        visit_full: &[u64],
        seen: &[u64],
        full_mask: &[u64],
        out: &mut BatchExpandOutput,
    ) {
        let w = full_mask.len();
        debug_assert!(w >= 1 && w <= MAX_LANE_WORDS);
        out.discovered.clear();
        out.masks.clear();
        out.edges_examined = 0;
        out.work.clear();
        out.cand.clear();
        out.cand_miss.clear();
        let (lo, hi) = (slab.first_vertex as usize, slab.end_vertex() as usize);
        let done_words = hi.div_ceil(CHUNK_VERTICES);
        if out.bu_done.len() < done_words {
            out.bu_done.resize(done_words, 0);
        }
        let mut missing = [0u64; MAX_LANE_WORDS];

        // Stage 1: the sweep.
        let mut sweep_one = |v: VertexId, out: &mut BatchExpandOutput| -> bool {
            let base = v as usize * w;
            let mut miss_any = 0u64;
            for k in 0..w {
                missing[k] = full_mask[k] & !seen[base + k];
                miss_any |= missing[k];
            }
            if miss_any == 0 {
                return false;
            }
            out.cand.push(v);
            out.cand_miss.extend_from_slice(&missing[..w]);
            true
        };
        if self.kernel.is_chunked() {
            for wi in lo / CHUNK_VERTICES..done_words {
                let range = chunk_range_mask(wi, lo, hi);
                out.work.words_touched += 1;
                let settled = out.bu_done[wi] & range;
                out.work.words_skipped += w as u64 * settled.count_ones() as u64;
                let mut bits = !out.bu_done[wi] & range;
                while bits != 0 {
                    let v = (wi * CHUNK_VERTICES) as u32 + bits.trailing_zeros();
                    bits &= bits - 1;
                    out.work.words_touched += w as u64;
                    if !sweep_one(v, out) {
                        // Missing went to zero: settled for the rest of
                        // the batch (seen is monotone).
                        out.bu_done[wi] |= 1u64 << (v as usize % CHUNK_VERTICES);
                    }
                }
            }
        } else {
            for v in lo as VertexId..hi as VertexId {
                out.work.words_touched += w as u64;
                sweep_one(v, out);
            }
        }

        // Stage 2: the probe (pure per candidate; any dispatch order).
        let ncand = out.cand.len();
        out.probe_new.clear();
        out.probe_new.resize(ncand * w, 0);
        let mut acc = [0u64; MAX_LANE_WORDS];
        let probe_candidate = |idx: usize,
                               cand: &[VertexId],
                               cand_miss: &[u64],
                               probe_new: &mut [u64],
                               acc: &mut [u64; MAX_LANE_WORDS]|
         -> u64 {
            let v = cand[idx];
            let miss = &cand_miss[idx * w..(idx + 1) * w];
            acc[..w].iter_mut().for_each(|x| *x = 0);
            let mut probes = 0u64;
            for &u in slab.neighbors_global(v) {
                probes += 1;
                let ubase = u as usize * w;
                let mut covered = true;
                for k in 0..w {
                    acc[k] |= visit_full[ubase + k];
                    covered &= acc[k] & miss[k] == miss[k];
                }
                if covered {
                    // Every still-missing lane (in every word) found a
                    // parent — the lane-mask generalization of
                    // first-parent-wins.
                    break;
                }
            }
            for k in 0..w {
                probe_new[idx * w + k] = miss[k] & acc[k];
            }
            probes
        };
        if self.use_lrb && ncand > 0 {
            // Bin candidate *indices* by degree: each dispatch covers one
            // degree class (within 2×), so per-dispatch work is uniform.
            let idxs: Vec<VertexId> = (0..ncand as u32).collect();
            let binned =
                bin_frontier(&idxs, |i| slab.degree_global(out.cand[i as usize]));
            for b in binned.dispatch_order() {
                let mut dispatch_work = 0u64;
                for &i in binned.bin(b) {
                    let probes = probe_candidate(
                        i as usize,
                        &out.cand,
                        &out.cand_miss,
                        &mut out.probe_new,
                        &mut acc,
                    );
                    out.edges_examined += probes;
                    dispatch_work += w as u64 * (1 + probes);
                }
                out.work.record_dispatch(dispatch_work);
            }
        } else if ncand > 0 {
            let mut dispatch_work = 0u64;
            for idx in 0..ncand {
                let probes = probe_candidate(
                    idx,
                    &out.cand,
                    &out.cand_miss,
                    &mut out.probe_new,
                    &mut acc,
                );
                out.edges_examined += probes;
                dispatch_work += w as u64 * (1 + probes);
            }
            out.work.record_dispatch(dispatch_work);
        }

        // Emit in ascending candidate order regardless of dispatch order.
        for idx in 0..ncand {
            let d = &out.probe_new[idx * w..(idx + 1) * w];
            if d.iter().fold(0u64, |a, &b| a | b) != 0 {
                out.discovered.push(out.cand[idx]);
                out.masks.extend_from_slice(d);
            }
        }
    }

    fn supports_bottom_up_batch(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn native_expand_matches_manual() {
        let (g, _) = uniform_random(300, 8, 21);
        let slab = g.row_slice(0, 300);
        for use_lrb in [false, true] {
            let mut visited = Bitmap::new(300);
            visited.set(7);
            let mut out = ExpandOutput::default();
            NativeCsr::new(use_lrb).expand(&slab, &[7], &mut visited, &mut out);
            assert_eq!(out.edges_examined, g.degree(7) as u64);
            let mut want: Vec<VertexId> =
                g.neighbors(7).iter().copied().filter(|&u| u != 7).collect();
            want.dedup();
            let mut got = out.discovered.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "lrb={use_lrb}");
            assert!(out.work.dispatches >= 1);
            assert_eq!(out.work.dispatch_max_work, out.edges_examined);
        }
    }

    #[test]
    fn lrb_and_plain_discover_same_set() {
        let (g, _) = uniform_random(500, 12, 5);
        let slab = g.row_slice(0, 500);
        let frontier: Vec<VertexId> = (0..50).collect();
        let run = |use_lrb: bool| {
            let mut visited = Bitmap::from_queue(500, &frontier);
            let mut out = ExpandOutput::default();
            NativeCsr::new(use_lrb).expand(&slab, &frontier, &mut visited, &mut out);
            let mut d = out.discovered;
            d.sort_unstable();
            (d, out.edges_examined, out.work)
        };
        let (d1, e1, w1) = run(false);
        let (d2, e2, w2) = run(true);
        assert_eq!(d1, d2);
        assert_eq!(e1, e2);
        // LRB splits the flat dispatch into per-bin dispatches: never
        // fewer dispatches, never a larger max.
        assert!(w2.dispatches >= w1.dispatches);
        assert!(w2.dispatch_max_work <= w1.dispatch_max_work);
    }

    /// Generic checker for the batched bottom-up kernel at `words` lane
    /// words: every discovery is an owned vertex gaining exactly its
    /// neighbors' frontier lanes minus what it had seen, early exit can
    /// only truncate once all missing lanes are covered, and no owned
    /// unseen vertex with a frontier neighbor is skipped. Checked for
    /// every kernel shape × LRB composition (all must agree bit-for-bit)
    /// and against the semiring formulation.
    fn check_batch_bottom_up(words: usize) {
        let (g, _) = uniform_random(200, 6, 33);
        let slab = g.row_slice(50, 150);
        let lanes = words * 64;
        let mut full = vec![u64::MAX; words];
        if words == 1 {
            full[0] = 0b1111; // the original 4-lane case
        }
        // A synthetic frontier: every third vertex carries one lane
        // (striped across all words so every word is exercised).
        let mut visit_full = vec![0u64; 200 * words];
        for v in (0..200usize).step_by(3) {
            let lane = (v * 7) % lanes;
            visit_full[v * words + lane / 64] |= 1 << (lane % 64);
        }
        // Partially-seen owned range: vertex 60 already has lane 0.
        let mut seen = vec![0u64; 200 * words];
        seen[60 * words] = 0b1;
        let mut out = BatchExpandOutput::default();
        NativeCsr::new(false).expand_bottom_up_batch(
            &slab,
            &visit_full,
            &seen,
            &full,
            &mut out,
        );
        assert!(NativeCsr::new(false).supports_bottom_up_batch());
        // Every kernel shape / LRB / semiring combination reproduces the
        // baseline exactly (discoveries and masks; the semiring also
        // matches on everything but edges_examined).
        for (use_lrb, kernel) in [
            (false, KernelVariant::Scalar),
            (false, KernelVariant::Chunked),
            (true, KernelVariant::Scalar),
            (true, KernelVariant::Chunked),
            (true, KernelVariant::Auto),
        ] {
            let mut alt = BatchExpandOutput::default();
            NativeCsr::new(use_lrb).with_kernel(kernel).expand_bottom_up_batch(
                &slab,
                &visit_full,
                &seen,
                &full,
                &mut alt,
            );
            assert_eq!(alt.discovered, out.discovered, "lrb={use_lrb} {kernel:?}");
            assert_eq!(alt.masks, out.masks, "lrb={use_lrb} {kernel:?}");
            assert_eq!(alt.edges_examined, out.edges_examined);
        }
        let mut semi = BatchExpandOutput::default();
        NativeCsr::new(false).expand_bottom_up_batch_semiring(
            &slab,
            &visit_full,
            &seen,
            &full,
            &mut semi,
        );
        assert_eq!(semi.discovered, out.discovered, "semiring discoveries");
        assert_eq!(semi.masks, out.masks, "semiring masks");
        assert!(semi.edges_examined >= out.edges_examined);

        assert_eq!(out.masks.len(), out.discovered.len() * words);
        for (i, &v) in out.discovered.iter().enumerate() {
            assert!(slab.owns(v));
            let d = &out.masks[i * words..(i + 1) * words];
            assert!(d.iter().any(|&x| x != 0), "v={v} zero mask recorded");
            // Accumulate the full neighbor union for comparison.
            let mut acc = vec![0u64; words];
            for &u in g.neighbors(v) {
                for k in 0..words {
                    acc[k] |= visit_full[u as usize * words + k];
                }
            }
            let vb = v as usize * words;
            for k in 0..words {
                let missing = full[k] & !seen[vb + k];
                assert_eq!(d[k] & !missing, 0, "v={v} word {k} leaked lanes");
                assert_eq!(d[k] & !acc[k], 0, "v={v} word {k} invented lanes");
                // Early exit can only truncate acc when missing is fully
                // covered, in which case d == missing in every word.
                if (0..words).all(|j| {
                    let mj = full[j] & !seen[vb + j];
                    acc[j] & mj == mj
                }) {
                    assert_eq!(d[k], missing, "v={v} word {k} early exit must cover all");
                }
            }
        }
        // Completeness: any owned unseen vertex with a frontier neighbor
        // must appear.
        for v in 50..150u32 {
            let vb = v as usize * words;
            let mut want_any = 0u64;
            for &u in g.neighbors(v) {
                for k in 0..words {
                    want_any |=
                        visit_full[u as usize * words + k] & full[k] & !seen[vb + k];
                }
            }
            let got = out.discovered.iter().any(|&x| x == v);
            assert_eq!(got, want_any != 0, "v={v} words={words}");
        }
        assert!(out.edges_examined > 0);
    }

    #[test]
    fn batch_bottom_up_matches_manual_accumulation() {
        check_batch_bottom_up(1);
    }

    #[test]
    fn batch_bottom_up_wide_words() {
        for words in [2usize, 4, 8] {
            check_batch_bottom_up(words);
        }
    }

    #[test]
    fn chunked_sweep_skips_settled_vertices_across_levels() {
        // All lanes fully seen on most of the owned range: the second
        // sweep of a chunked kernel must skip the settled chunks
        // wholesale, while the scalar kernel re-reads every vertex.
        let (g, _) = uniform_random(256, 5, 9);
        let slab = g.row_slice(0, 256);
        let words = 2usize;
        let full = vec![u64::MAX; words];
        let visit_full = vec![0u64; 256 * words];
        let mut seen = vec![u64::MAX; 256 * words];
        // Leave vertices 200..205 unseen.
        for v in 200..205 {
            for k in 0..words {
                seen[v * words + k] = 0;
            }
        }
        let mut chunked = BatchExpandOutput::default();
        let mut bk = NativeCsr::new(false).with_kernel(KernelVariant::Chunked);
        bk.expand_bottom_up_batch(&slab, &visit_full, &seen, &full, &mut chunked);
        let first_touched = chunked.work.words_touched;
        // Level 1: settled bits recorded; the sweep now reads only the
        // summary words plus the 5 pending vertices.
        bk.expand_bottom_up_batch(&slab, &visit_full, &seen, &full, &mut chunked);
        assert_eq!(chunked.work.words_touched, 4 + 5 * words as u64);
        assert_eq!(chunked.work.words_skipped, (256 - 5) * words as u64);
        assert!(chunked.work.words_touched < first_touched);
        let mut scalar = BatchExpandOutput::default();
        NativeCsr::new(false)
            .with_kernel(KernelVariant::Scalar)
            .expand_bottom_up_batch(&slab, &visit_full, &seen, &full, &mut scalar);
        assert_eq!(scalar.work.words_touched, 256 * words as u64);
        assert_eq!(scalar.work.words_skipped, 0);
        assert_eq!(scalar.discovered, chunked.discovered);
        assert_eq!(scalar.masks, chunked.masks);
        // reset_for_batch forgets the settled summary.
        chunked.reset_for_batch();
        bk.expand_bottom_up_batch(&slab, &visit_full, &seen, &full, &mut chunked);
        assert_eq!(chunked.work.words_touched, first_touched);
    }

    #[test]
    fn lrb_probe_reduces_max_dispatch_work_on_skewed_candidates() {
        // A hub plus many leaves: flat probing is one dispatch carrying
        // all the work; LRB splits the hub's bin from the leaves' bin.
        let n = 400usize;
        let g = crate::graph::gen::structured::star(n);
        let slab = g.row_slice(0, n as VertexId);
        let full = vec![0b1u64];
        // Frontier: vertex 1 only; nothing seen.
        let mut visit_full = vec![0u64; n];
        visit_full[1] = 0b1;
        let seen = vec![0u64; n];
        let run = |use_lrb: bool| {
            let mut out = BatchExpandOutput::default();
            NativeCsr::new(use_lrb)
                .with_kernel(KernelVariant::Scalar)
                .expand_bottom_up_batch(&slab, &visit_full, &seen, &full, &mut out);
            out
        };
        let flat = run(false);
        let lrb = run(true);
        assert_eq!(flat.discovered, lrb.discovered);
        assert_eq!(flat.masks, lrb.masks);
        assert_eq!(flat.edges_examined, lrb.edges_examined);
        assert_eq!(flat.work.dispatches, 1);
        assert!(lrb.work.dispatches > 1);
        assert!(
            lrb.work.dispatch_max_work < flat.work.dispatch_max_work,
            "lrb {} vs flat {}",
            lrb.work.dispatch_max_work,
            flat.work.dispatch_max_work
        );
    }

    #[test]
    fn single_root_chunked_bottom_up_matches_scalar() {
        let (g, _) = uniform_random(300, 6, 41);
        let slab = g.row_slice(100, 180);
        let mut frontier_full = Bitmap::new(300);
        for v in (0..300u32).step_by(7) {
            frontier_full.set(v);
        }
        let run = |kernel: KernelVariant, visited_fill: &[u32]| {
            let mut visited = Bitmap::from_queue(300, visited_fill);
            let mut out = ExpandOutput::default();
            NativeCsr::new(false).with_kernel(kernel).expand_bottom_up(
                &slab,
                &frontier_full,
                &mut visited,
                &mut out,
            );
            (out, visited)
        };
        let fill: Vec<u32> = (100..220u32).step_by(2).collect();
        let (scalar, vs) = run(KernelVariant::Scalar, &fill);
        let (chunked, vc) = run(KernelVariant::Chunked, &fill);
        assert_eq!(scalar.discovered, chunked.discovered);
        assert_eq!(scalar.edges_examined, chunked.edges_examined);
        assert_eq!(vs, vc);
        // Scalar reads one visited word per owned vertex (|100..180| = 80);
        // chunked reads 2 summary words (chunks 64..128, 128..192) plus
        // one word per pending vertex, skipping the visited ones.
        assert_eq!(scalar.work.words_touched, 80);
        assert_eq!(scalar.work.words_skipped, 0);
        assert!(chunked.work.words_touched < scalar.work.words_touched);
        assert_eq!(
            (chunked.work.words_touched - 2) + chunked.work.words_skipped,
            80,
            "chunked per-vertex accounting covers the owned range"
        );
    }

    #[test]
    fn expand_respects_visited() {
        let (g, _) = uniform_random(100, 8, 9);
        let slab = g.row_slice(0, 100);
        let mut visited = Bitmap::new(100);
        for v in 0..100u32 {
            visited.set(v);
        }
        let mut out = ExpandOutput::default();
        NativeCsr::new(false).expand(&slab, &[0], &mut visited, &mut out);
        assert!(out.discovered.is_empty());
        assert_eq!(out.edges_examined, g.degree(0) as u64);
    }
}
