//! A loaded frontier-step executable.
//!
//! The artifact is the L2 JAX model (`python/compile/model.py`) lowered to
//! HLO text: `frontier_step(adj, frontier, visited) -> (new,)` over
//! `f32[V,V], f32[V], f32[V]` with 0/1 values, where
//! `new = saturate(frontier @ adj) * (1 - visited)` — one BFS level in the
//! Buluç–Madduri BLAS formulation, with the inner product computed by the
//! L1 Pallas kernel.

use super::client::RuntimeClient;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A compiled, ready-to-execute frontier step of a fixed padded size.
pub struct FrontierStep {
    exe: xla::PjRtLoadedExecutable,
    /// Padded vertex count `V` the artifact was lowered for.
    pub num_vertices: usize,
}

// SAFETY: PJRT executables are thread-compatible (see client.rs); the
// wrapper type only stores an opaque handle.
unsafe impl Send for FrontierStep {}
unsafe impl Sync for FrontierStep {}

impl FrontierStep {
    /// Load HLO text from `path` and compile it for the global CPU client.
    pub fn load(path: &Path, num_vertices: usize) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = RuntimeClient::global().compile(&comp)?;
        Ok(Self { exe, num_vertices })
    }

    /// Build the dense 0/1 adjacency literal for one node's slab, padded
    /// to `V×V`: `adj[i][j] = 1` iff the slab owns global vertex `i` and
    /// has arc `i → j`. Build once per node, reuse across levels
    /// (device-resident graph, as on the real GPU).
    pub fn adjacency_literal(&self, slab: &crate::graph::csr::CsrSlab) -> Result<xla::Literal> {
        let v = self.num_vertices;
        assert!(
            (slab.end_vertex() as usize) <= v,
            "slab exceeds artifact size {v}"
        );
        let mut dense = vec![0f32; v * v];
        for r in 0..slab.num_rows() {
            let g = slab.first_vertex + r as u32;
            for &u in slab.neighbors_global(g) {
                dense[g as usize * v + u as usize] = 1.0;
            }
        }
        xla::Literal::vec1(&dense)
            .reshape(&[v as i64, v as i64])
            .context("reshaping adjacency literal")
    }

    /// Execute one level step. `frontier`/`visited` are 0/1 f32 vectors of
    /// length `V`. Returns the 0/1 `new` vector (discoveries).
    pub fn run(
        &self,
        adj: &xla::Literal,
        frontier: &[f32],
        visited: &[f32],
    ) -> Result<Vec<f32>> {
        let v = self.num_vertices;
        assert_eq!(frontier.len(), v);
        assert_eq!(visited.len(), v);
        let f = xla::Literal::vec1(frontier);
        let vis = xla::Literal::vec1(visited);
        // Borrowed args: the big adjacency literal is never copied.
        let args: [&xla::Literal; 3] = [adj, &f, &vis];
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .context("executing frontier step")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{find_artifact, ArtifactKey};

    fn load_smallest() -> Option<FrontierStep> {
        let key = ArtifactKey { num_vertices: 256 };
        let path = find_artifact(key)?;
        Some(FrontierStep::load(&path, 256).expect("artifact must compile"))
    }

    #[test]
    fn step_expands_one_level() {
        // Requires `make artifacts`; skip silently when not built so
        // `cargo test` stays green pre-AOT (CI runs make first).
        let Some(step) = load_smallest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let g = crate::graph::gen::structured::path(256);
        let slab = g.row_slice(0, 256);
        let adj = step.adjacency_literal(&slab).unwrap();
        let mut frontier = vec![0f32; 256];
        frontier[0] = 1.0;
        let mut visited = vec![0f32; 256];
        visited[0] = 1.0;
        let new = step.run(&adj, &frontier, &visited).unwrap();
        // From vertex 0 of a path: only vertex 1 discovered.
        assert_eq!(new[1], 1.0);
        assert_eq!(new.iter().map(|&x| x as u32).sum::<u32>(), 1);
    }

    #[test]
    fn step_masks_visited() {
        let Some(step) = load_smallest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let g = crate::graph::gen::structured::complete(16);
        let slab = g.row_slice(0, 16);
        let adj = step.adjacency_literal(&slab).unwrap();
        let mut frontier = vec![0f32; 256];
        frontier[0] = 1.0;
        let mut visited = vec![0f32; 256];
        visited[0] = 1.0;
        visited[1] = 1.0; // pre-visited: must not reappear
        let new = step.run(&adj, &frontier, &visited).unwrap();
        assert_eq!(new[1], 0.0);
        // Vertices 2..16 all discovered (complete graph).
        for v in 2..16 {
            assert_eq!(new[v], 1.0, "vertex {v}");
        }
        for v in 16..256 {
            assert_eq!(new[v], 0.0, "padding vertex {v}");
        }
    }
}
