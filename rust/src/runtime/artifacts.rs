//! Artifact discovery: locates the HLO text files `make artifacts`
//! produces under `artifacts/`.

use std::path::{Path, PathBuf};

/// Identifies one compiled model variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactKey {
    /// Padded vertex count the step was lowered for.
    pub num_vertices: usize,
}

impl ArtifactKey {
    /// File name of this variant.
    pub fn file_name(&self) -> String {
        format!("frontier_step_v{}.hlo.txt", self.num_vertices)
    }
}

/// The artifact sizes `python/compile/aot.py` emits, ascending.
pub const ARTIFACT_SIZES: &[usize] = &[256, 1024, 2048];

/// Artifact directory: `$BBFS_ARTIFACTS` if set, else `./artifacts`
/// relative to the current directory, else relative to the crate root
/// (for `cargo test` runs from anywhere inside the workspace).
pub fn artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("BBFS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    // CARGO_MANIFEST_DIR is compiled in; works for tests/benches/examples.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Find the artifact for `key`, if built.
pub fn find_artifact(key: ArtifactKey) -> Option<PathBuf> {
    let p = artifact_dir().join(key.file_name());
    p.is_file().then_some(p)
}

/// Smallest compiled variant that fits `num_vertices` (artifacts are
/// padded; a graph with 700 vertices runs on the v1024 variant).
pub fn variant_for(num_vertices: usize) -> Option<ArtifactKey> {
    ARTIFACT_SIZES
        .iter()
        .copied()
        .find(|&v| v >= num_vertices)
        .map(|v| ArtifactKey { num_vertices: v })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_names() {
        assert_eq!(
            ArtifactKey { num_vertices: 1024 }.file_name(),
            "frontier_step_v1024.hlo.txt"
        );
    }

    #[test]
    fn variant_selection() {
        assert_eq!(variant_for(100).unwrap().num_vertices, 256);
        assert_eq!(variant_for(256).unwrap().num_vertices, 256);
        assert_eq!(variant_for(257).unwrap().num_vertices, 1024);
        assert_eq!(variant_for(2048).unwrap().num_vertices, 2048);
        assert!(variant_for(1 << 20).is_none());
    }

    #[test]
    fn artifact_dir_resolves() {
        let d = artifact_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
