//! The PJRT runtime: loads AOT-compiled HLO text artifacts (produced by
//! `python/compile/aot.py` from the L2 JAX model + L1 Pallas kernel) and
//! executes them from the Rust traversal path. Python never runs here.

pub mod artifacts;
pub mod client;
pub mod executable;
pub mod xla_backend;

pub use artifacts::{artifact_dir, find_artifact, variant_for, ArtifactKey};
pub use client::RuntimeClient;
pub use executable::FrontierStep;
pub use xla_backend::XlaFrontierBackend;
