//! Process-wide PJRT client.
//!
//! One `PjRtClient::cpu()` per process (the client owns the thread pool
//! and device state; constructing several wastes memory). `RuntimeClient`
//! is a thin handle; `global()` hands out the lazily created singleton.

use anyhow::{Context, Result};
use std::sync::OnceLock;

/// Shared handle to the PJRT CPU client.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

// SAFETY: PJRT clients are documented thread-compatible for compilation
// and execution (XLA's PJRT C API contract); the Rust wrapper only lacks
// the marker because it stores a raw pointer.
unsafe impl Send for RuntimeClient {}
unsafe impl Sync for RuntimeClient {}

static GLOBAL: OnceLock<RuntimeClient> = OnceLock::new();

impl RuntimeClient {
    /// Create a fresh CPU client (prefer [`RuntimeClient::global`]).
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// The process-wide client (created on first use).
    pub fn global() -> &'static RuntimeClient {
        GLOBAL.get_or_init(|| Self::new().expect("PJRT CPU client must initialize"))
    }

    /// Underlying xla client.
    pub fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Platform name ("cpu" here; "cuda"/"tpu" on real devices).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO computation for this client.
    pub fn compile(&self, comp: &xla::XlaComputation) -> Result<xla::PjRtLoadedExecutable> {
        self.client.compile(comp).context("PJRT compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_client_initializes_cpu() {
        let c = RuntimeClient::global();
        assert_eq!(c.platform(), "cpu");
        assert!(c.raw().device_count() >= 1);
    }
}
