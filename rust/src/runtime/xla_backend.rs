//! The XLA Phase-1 backend: runs the AOT-compiled JAX/Pallas frontier step
//! (L2+L1) as a node's traversal engine.
//!
//! The node's adjacency slab is densified once into a device-resident
//! literal (the analog of the graph living in GPU HBM); each level then
//! executes `frontier_step` and converts the 0/1 output vector back into
//! a discovery queue. Fixed-shape artifacts cap the graph size at the
//! largest compiled variant (2048 padded vertices) — the demo/e2e scale;
//! the native backend covers everything larger.

use super::executable::FrontierStep;
use crate::bfs::frontier::Bitmap;
use crate::coordinator::backend::{ComputeBackend, ExpandOutput};
use crate::graph::csr::{CsrSlab, VertexId};
use std::sync::Arc;

/// Per-node XLA backend state.
pub struct XlaFrontierBackend {
    step: Arc<FrontierStep>,
    adj: xla::Literal,
    /// Transposed adjacency for the bottom-up step (`adjT[i][j] = adj[j][i]`;
    /// `frontier @ adjT` computes "owned vertices with a frontier
    /// neighbor"). Built lazily on first bottom-up call.
    adj_t: Option<xla::Literal>,
    /// Scratch f32 frontier/visited vectors (padded size V).
    frontier_f32: Vec<f32>,
    visited_f32: Vec<f32>,
}

// SAFETY: single raw-pointer-backed literal + executable handle; PJRT is
// thread-compatible and the engine gives each backend exclusive &mut use.
unsafe impl Send for XlaFrontierBackend {}

impl XlaFrontierBackend {
    /// Build the backend for one node. `step` may be shared by all nodes
    /// (same compiled program, different adjacency literals).
    pub fn new(step: Arc<FrontierStep>, slab: &CsrSlab) -> anyhow::Result<Self> {
        let adj = step.adjacency_literal(slab)?;
        let v = step.num_vertices;
        Ok(Self {
            step,
            adj,
            adj_t: None,
            frontier_f32: vec![0.0; v],
            visited_f32: vec![0.0; v],
        })
    }

    /// Dense transposed adjacency literal for the bottom-up direction.
    fn transposed_literal(
        step: &FrontierStep,
        slab: &CsrSlab,
    ) -> anyhow::Result<xla::Literal> {
        let v = step.num_vertices;
        let mut dense = vec![0f32; v * v];
        for r in 0..slab.num_rows() {
            let g = slab.first_vertex + r as u32;
            for &u in slab.neighbors_global(g) {
                dense[u as usize * v + g as usize] = 1.0;
            }
        }
        use anyhow::Context;
        xla::Literal::vec1(&dense)
            .reshape(&[v as i64, v as i64])
            .context("reshaping transposed adjacency literal")
    }

    /// Build one backend per slab, sharing a single compiled step.
    pub fn for_slabs(
        step: Arc<FrontierStep>,
        slabs: &[CsrSlab],
    ) -> anyhow::Result<Vec<Box<dyn ComputeBackend>>> {
        slabs
            .iter()
            .map(|s| {
                Ok(Box::new(Self::new(Arc::clone(&step), s)?) as Box<dyn ComputeBackend>)
            })
            .collect()
    }
}

impl ComputeBackend for XlaFrontierBackend {
    fn name(&self) -> &'static str {
        "xla-frontier"
    }

    fn expand(
        &mut self,
        slab: &CsrSlab,
        frontier: &[VertexId],
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        out.work.clear();
        if frontier.is_empty() {
            return;
        }
        // Encode inputs.
        self.frontier_f32.iter_mut().for_each(|x| *x = 0.0);
        for &v in frontier {
            self.frontier_f32[v as usize] = 1.0;
            out.edges_examined += slab.degree_global(v) as u64;
        }
        for (i, x) in self.visited_f32.iter_mut().enumerate() {
            *x = if i < visited.len() && visited.get(i as VertexId) { 1.0 } else { 0.0 };
        }
        // One BLAS-formulation level step on the device: a single dense
        // dispatch over the padded vertex domain (the device kernel has no
        // sparse path, so the work counters record the full vector scan).
        let v_dom = self.frontier_f32.len() as u64;
        out.work.words_touched += v_dom;
        out.work.record_dispatch(v_dom);
        let new = self
            .step
            .run(&self.adj, &self.frontier_f32, &self.visited_f32)
            .expect("frontier step execution");
        for (v, &x) in new.iter().enumerate() {
            if x > 0.5 && v < visited.len() {
                let v = v as VertexId;
                if visited.test_and_set(v) {
                    out.discovered.push(v);
                }
            }
        }
    }

    fn expand_bottom_up(
        &mut self,
        slab: &CsrSlab,
        frontier_full: &Bitmap,
        visited: &mut Bitmap,
        out: &mut ExpandOutput,
    ) {
        out.discovered.clear();
        out.edges_examined = 0;
        out.work.clear();
        if frontier_full.is_empty() {
            return;
        }
        if self.adj_t.is_none() {
            self.adj_t =
                Some(Self::transposed_literal(&self.step, slab).expect("transposed literal"));
        }
        // Encode the FULL frontier (bottom-up checks against everyone).
        self.frontier_f32.iter_mut().for_each(|x| *x = 0.0);
        for v in frontier_full.iter() {
            self.frontier_f32[v as usize] = 1.0;
        }
        for (i, x) in self.visited_f32.iter_mut().enumerate() {
            *x = if i < visited.len() && visited.get(i as VertexId) { 1.0 } else { 0.0 };
        }
        // frontier @ adjT = owned unvisited vertices with a parent in the
        // frontier. The dense kernel has no early exit, so the examined
        // count is the full slab (this is exactly the GPU bottom-up
        // trade-off the direction heuristic weighs). One dense dispatch
        // over the padded vertex domain, same as the top-down step.
        out.edges_examined = slab.num_edges();
        let v_dom = self.frontier_f32.len() as u64;
        out.work.words_touched += v_dom;
        out.work.record_dispatch(v_dom);
        let new = self
            .step
            .run(self.adj_t.as_ref().unwrap(), &self.frontier_f32, &self.visited_f32)
            .expect("bottom-up frontier step execution");
        for (v, &x) in new.iter().enumerate() {
            if x > 0.5 && v < visited.len() {
                let v = v as VertexId;
                debug_assert!(slab.owns(v));
                if visited.test_and_set(v) {
                    out.discovered.push(v);
                }
            }
        }
    }

    /// The compiled artifacts are 0/1 frontier steps with no *native*
    /// lane-mask variant, so this probe stays `false` — but `run_batch`
    /// with a bottom-up-capable `DirectionMode` no longer degrades the
    /// batch to top-down: the engine's capability probe falls through to
    /// [`ComputeBackend::expand_bottom_up_batch_semiring`] (left at its
    /// default `true` here), whose blocked
    /// `masks_next = Aᵀ ⊗ masks_frontier` formulation over the
    /// `(OR, AND-NOT-seen)` semiring is exactly the tiled matmul shape a
    /// future compiled lane-mask artifact would implement on-device.
    /// Explicit here (the trait default is already `false`) so the
    /// capability split is visible at the impl.
    fn supports_bottom_up_batch(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;
    use crate::coordinator::config::EngineConfig;
    use crate::coordinator::plan::TraversalPlan;
    use crate::graph::gen::urand::uniform_random;
    use crate::partition::one_d::partition_1d;
    use crate::runtime::artifacts::{find_artifact, variant_for};

    fn load_step(v: usize) -> Option<Arc<FrontierStep>> {
        let key = variant_for(v)?;
        let path = find_artifact(key)?;
        Some(Arc::new(
            FrontierStep::load(&path, key.num_vertices).expect("artifact compiles"),
        ))
    }

    #[test]
    fn xla_session_matches_serial_bfs() {
        let Some(step) = load_step(240) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (g, _) = uniform_random(240, 6, 11);
        let cfg = EngineConfig::dgx2(4, 2);
        let part = partition_1d(&g, cfg.num_nodes);
        let backends = XlaFrontierBackend::for_slabs(step, &part.slabs(&g)).unwrap();
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut session = plan.session_with_backends(backends).unwrap();
        let r = session.run(0).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
    }

    #[test]
    fn xla_and_native_backends_agree() {
        let Some(step) = load_step(200) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (g, _) = uniform_random(200, 4, 5);
        let cfg = EngineConfig::dgx2(2, 1);
        let part = partition_1d(&g, cfg.num_nodes);
        let backends = XlaFrontierBackend::for_slabs(step, &part.slabs(&g)).unwrap();
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut xla_session = plan.session_with_backends(backends).unwrap();
        let mut native = plan.session();
        let rx = xla_session.run(7).unwrap();
        let rn = native.run(7).unwrap();
        assert_eq!(rx.dist(), rn.dist());
        assert_eq!(rx.reached(), rn.reached());
        assert_eq!(rx.depth(), rn.depth());
    }
}
