//! A small scoped thread pool + barrier.
//!
//! `rayon`/`tokio` are unavailable in the offline crate set, so the
//! coordinator drives its simulated compute nodes with this pool. The design
//! goal is *deterministic structure*, not maximal throughput: each simulated
//! device is a persistent worker, and the engine issues bulk-synchronous
//! steps (`run_indexed`) with an implicit barrier at the end — exactly the
//! synchronization discipline of Alg. 2 in the paper.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of persistent worker threads.
pub struct ThreadPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    next: AtomicUsize,
}

impl ThreadPool {
    /// Create a pool with `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "ThreadPool::new(0)");
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            let handle = thread::Builder::new()
                .name(format!("bbfs-worker-{i}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // A panicking job must not kill the worker: jobs
                        // still queued behind it would be dropped without
                        // ever signalling their latch, deadlocking
                        // `run_indexed`. The panic payload is re-thrown on
                        // the issuing thread by `run_indexed` instead.
                        let _ = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(job),
                        );
                    }
                })
                .expect("spawn worker");
            senders.push(tx);
            handles.push(handle);
        }
        Self { senders, handles, next: AtomicUsize::new(0) }
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.senders.len()
    }

    /// True when the pool has no workers (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// Fire-and-forget a job on the least-recently-used worker.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.senders[i].send(Box::new(f)).expect("worker alive");
    }

    /// Run `f(i)` for `i in 0..count`, pinning task `i` to worker
    /// `i % workers`, and wait for all of them (bulk-synchronous step).
    ///
    /// `f` only needs to live for the duration of the call: we use a scoped
    /// barrier internally, so borrowed data is fine.
    ///
    /// Panic semantics match `std::thread::scope`: if any `f(i)` panics,
    /// the call still waits for every task, then re-throws the first
    /// panic payload on the issuing thread.
    pub fn run_indexed<'scope, F>(&self, count: usize, f: F)
    where
        F: Fn(usize) + Sync + Send + 'scope,
    {
        if count == 0 {
            return;
        }
        let barrier = Arc::new(CountdownLatch::new(count));
        let first_panic: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        // Scoped-borrow transport: the worker channel demands 'static jobs,
        // so we smuggle `&f` through a thin raw pointer. This is sound
        // because `run_indexed` blocks on the latch below, and every job
        // signals the latch only after its last use of `f` — `f` therefore
        // strictly outlives all dereferences.
        struct SendPtr(*const ());
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let thin = SendPtr(&f as *const _ as *const ());
        let thin = Arc::new(thin);
        for i in 0..count {
            let latch = Arc::clone(&barrier);
            let thin = Arc::clone(&thin);
            let panic_slot = Arc::clone(&first_panic);
            let w = i % self.senders.len();
            let job: Job = Box::new(move || {
                // Count down even if `f` panics, so the issuing thread does
                // not deadlock (the payload is re-thrown there instead).
                struct Guard(Arc<CountdownLatch>);
                impl Drop for Guard {
                    fn drop(&mut self) {
                        self.0.count_down();
                    }
                }
                let _guard = Guard(latch);
                // SAFETY: `run_indexed` blocks on the latch until every job
                // has signalled, so `f` (borrowed for 'scope) is alive for
                // the entire execution of this closure.
                let f = unsafe { &*(thin.0 as *const F) };
                if let Err(payload) =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)))
                {
                    let mut slot = panic_slot.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            });
            self.senders[w].send(job).expect("worker alive");
        }
        barrier.wait();
        if let Some(payload) = first_panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channels terminates the workers.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A simple countdown latch: `count_down()` N times releases all `wait()`ers.
pub struct CountdownLatch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl CountdownLatch {
    /// Latch that opens after `n` count-downs.
    pub fn new(n: usize) -> Self {
        Self { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    /// Signal one completion.
    pub fn count_down(&self) {
        let mut rem = self.remaining.lock().unwrap();
        assert!(*rem > 0, "latch underflow");
        *rem -= 1;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until the latch opens.
    pub fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.cv.wait(rem).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_indexed_visits_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        pool.run_indexed(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn run_indexed_is_a_barrier() {
        let pool = ThreadPool::new(3);
        let counter = AtomicU64::new(0);
        for _round in 0..10 {
            pool.run_indexed(8, |_i| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
            // After return, all 8 increments of this round must be visible.
            assert_eq!(counter.load(Ordering::SeqCst) % 8, 0);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 80);
    }

    #[test]
    fn run_indexed_borrows_local_data() {
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..32).collect();
        let out: Vec<AtomicU64> = (0..32).map(|_| AtomicU64::new(0)).collect();
        pool.run_indexed(32, |i| {
            out[i].store(data[i] * 2, Ordering::SeqCst);
        });
        for i in 0..32 {
            assert_eq!(out[i].load(Ordering::SeqCst), (i as u64) * 2);
        }
    }

    #[test]
    fn zero_count_returns_immediately() {
        let pool = ThreadPool::new(2);
        pool.run_indexed(0, |_| panic!("must not run"));
    }

    #[test]
    fn panicking_task_propagates_without_deadlock() {
        // More tasks than workers: the panicking job must not kill its
        // worker (jobs queued behind it would drop their latch signal and
        // deadlock), and the panic must re-throw on the issuing thread —
        // `std::thread::scope` semantics.
        let pool = ThreadPool::new(2);
        let ran: Vec<AtomicU64> = (0..16).map(|_| AtomicU64::new(0)).collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_indexed(16, |i| {
                ran[i].fetch_add(1, Ordering::SeqCst);
                if i == 3 {
                    panic!("task 3 boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // Every task still ran exactly once (no dropped queue tail).
        for (i, r) in ran.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 1, "task {i}");
        }
        // The pool stays usable afterwards.
        let counter = AtomicU64::new(0);
        pool.run_indexed(8, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn spawn_runs_jobs() {
        let pool = ThreadPool::new(2);
        let latch = Arc::new(CountdownLatch::new(5));
        for _ in 0..5 {
            let l = Arc::clone(&latch);
            pool.spawn(move || l.count_down());
        }
        latch.wait();
    }

    #[test]
    fn latch_opens_exactly_after_n() {
        let latch = Arc::new(CountdownLatch::new(2));
        let l2 = Arc::clone(&latch);
        let t = thread::spawn(move || {
            l2.wait();
            true
        });
        latch.count_down();
        thread::sleep(std::time::Duration::from_millis(10));
        assert!(!t.is_finished(), "latch opened early");
        latch.count_down();
        assert!(t.join().unwrap());
    }
}
