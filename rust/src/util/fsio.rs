//! Crash-consistent file writes.
//!
//! Every on-disk artifact the engine persists (plan caches, `.bbfs`
//! snapshots and stores, bench protocol files) goes through
//! [`atomic_write`]: the bytes land in a same-directory temporary file,
//! are `fsync`ed, and only then renamed over the destination. POSIX
//! `rename(2)` is atomic, so a reader — or a writer that crashed mid-way —
//! can only ever observe the complete old file or the complete new file,
//! never a torn prefix. `tests/crash_consistency.rs` drives the torn/
//! partial-write corpus proving the loaders reject anything less.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

/// Build the sibling temporary path `<file>.tmp.<pid>` used by
/// [`atomic_write`]. Same directory as the destination, so the final
/// rename never crosses a filesystem boundary.
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".tmp.{}", std::process::id()));
    path.with_file_name(name)
}

/// Write `bytes` to `path` crash-consistently: write-tmp → fsync →
/// atomic-rename. On any error the temporary file is cleaned up and the
/// destination is left exactly as it was — either the previous complete
/// contents or absent, never a torn prefix.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = tmp_sibling(path);
    let result = (|| {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        // Durability of the rename itself: fsync the containing directory
        // (best-effort — some filesystems refuse directory handles).
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                if let Ok(d) = File::open(dir) {
                    let _ = d.sync_all();
                }
            }
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bbfs-fsio-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn writes_and_replaces() {
        let path = scratch("replace.txt");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second-longer").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second-longer");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_write_leaves_destination_untouched() {
        let path = scratch("keep.txt");
        atomic_write(&path, b"survivor").unwrap();
        // Writing *through* the file as if it were a directory must fail
        // without touching the existing bytes.
        let bogus = path.join("child.txt");
        assert!(atomic_write(&bogus, b"x").is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"survivor");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn no_tmp_residue_after_success_or_failure() {
        let path = scratch("clean.txt");
        atomic_write(&path, b"ok").unwrap();
        let dir = path.parent().unwrap();
        let residue: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(residue.is_empty(), "leftover tmp files: {residue:?}");
        let _ = std::fs::remove_file(&path);
    }
}
