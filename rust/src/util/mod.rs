//! Infrastructure substrates: PRNG, thread pool, CLI, JSON, stats, logging,
//! and a mini property-testing harness.
//!
//! These exist because the default build is fully dependency-free (the
//! optional `xla` feature is the only thing that pulls external crates);
//! the roles of `rand`, `rayon`, `clap`, `serde`, `proptest`, and `log`
//! are filled here.

pub mod cli;
pub mod fsio;
pub mod json;
pub mod log;
pub mod prng;
pub mod propcheck;
pub mod stats;
pub mod threadpool;
