//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own small PRNG
//! stack: [`SplitMix64`] for seeding and [`Xoshiro256StarStar`] as the
//! general-purpose generator (the same pairing `rand`'s `SmallRng` family
//! uses). Everything in the repository that needs randomness — graph
//! generators, root sampling, property tests — goes through these types so
//! runs are reproducible from a single `u64` seed.

/// SplitMix64: tiny, fast, passes BigCrush; used to expand a single `u64`
/// seed into the 256-bit state of [`Xoshiro256StarStar`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256★★ — the repository's workhorse PRNG.
///
/// Period 2²⁵⁶−1, passes all known statistical batteries, 4×u64 state.
#[derive(Clone, Debug)]
pub struct Xoshiro256StarStar {
    s: [u64; 4],
}

impl Xoshiro256StarStar {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors;
    /// avoids the all-zero state for any input seed).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's multiply-shift rejection
    /// method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            // threshold = 2^64 mod bound
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn next_usize(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_usize(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small `k`, shuffle-prefix otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k > n");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_usize(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Split off an independently seeded child generator (for per-thread
    /// streams): draws two words from `self` to seed the child.
    pub fn split(&mut self) -> Self {
        let seed = self.next_u64() ^ self.next_u64().rotate_left(32);
        Self::seed_from_u64(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain
        // splitmix64.c implementation.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_nondegenerate() {
        let mut r1 = Xoshiro256StarStar::seed_from_u64(42);
        let mut r2 = Xoshiro256StarStar::seed_from_u64(42);
        let xs: Vec<u64> = (0..100).map(|_| r1.next_u64()).collect();
        let ys: Vec<u64> = (0..100).map(|_| r2.next_u64()).collect();
        assert_eq!(xs, ys);
        // Not all equal
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256StarStar::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256StarStar::seed_from_u64(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn next_f64_mean_near_half() {
        let mut r = Xoshiro256StarStar::seed_from_u64(11);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256StarStar::seed_from_u64(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut r = Xoshiro256StarStar::seed_from_u64(5);
        for (n, k) in [(100, 5), (100, 90), (10, 10), (1, 1), (1000, 100)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn split_streams_diverge() {
        let mut r = Xoshiro256StarStar::seed_from_u64(1);
        let mut a = r.split();
        let mut b = r.split();
        let xa: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
